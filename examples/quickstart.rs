//! Quickstart: optimize, place, route, simulate and power-model the
//! paper's flagship design in ~30 lines of API use.
//!
//!     cargo run --release --example quickstart

use maxeva::arch::device::AieDevice;
use maxeva::arch::precision::Precision;
use maxeva::optimizer::array::optimize_array;
use maxeva::optimizer::single_kernel::{optimize_single_kernel, top_ranked};
use maxeva::report::evaluate::evaluate_config;
use maxeva::sim::engine::SimConfig;

fn main() {
    // 1. The device: VC1902 on the VCK190 board (or describe your own).
    let dev = AieDevice::vc1902();
    println!(
        "device: {} — {} AIE cores @ {:.2} GHz, peak {:.0} TOPs int8",
        dev.name,
        dev.total_cores(),
        dev.freq_hz / 1e9,
        dev.peak_ops_per_sec(Precision::Int8) / 1e12
    );

    // 2. Single-kernel DSE (paper eq. 3–6): for int8 exactly one tile
    //    size survives all constraints.
    let kernels = optimize_single_kernel(&dev, Precision::Int8, 0.95);
    let best = top_ranked(&kernels)[0].kernel;
    println!(
        "int8 kernel: {}x{}x{} — {} cycles, {:.2}% efficiency",
        best.m,
        best.k,
        best.n,
        best.latency_cycles(),
        best.efficiency() * 100.0
    );

    // 3. Array-level DSE (eq. 7–9): maximize MatMul kernels.
    let arrays = optimize_array(&dev, None);
    println!(
        "array DSE: best candidate {} with {} kernels (fails PnR!), runner-up 13x4x6",
        arrays[0].label(),
        arrays[0].matmul_kernels()
    );

    // 4. Full pipeline on the flagship 13×4×6 (pattern P1).
    for prec in Precision::all() {
        let r = evaluate_config(
            &dev,
            13,
            4,
            6,
            maxeva::placement::pattern::Pattern::P1,
            prec,
            &SimConfig::default(),
        )
        .expect("flagship must evaluate");
        println!(
            "{prec}: {:.2} {} @ {:.2} W → {:.2} {}/W ({} cores, {} DMA banks)",
            r.throughput_table_units(),
            prec.ops_unit(),
            r.power.total_w(),
            r.energy_eff_table_units(),
            prec.ops_unit(),
            r.total_cores,
            r.dma_banks,
        );
    }
}
