//! DNN inference on the MaxEVA stack, two ways:
//!
//! 1. **Real numerics** — run a 3-layer MLP forward pass through the AOT
//!    `mlp_fp32` artifact (every GEMM inside is the L1 Pallas tile
//!    kernel) and verify against a host reference.
//! 2. **Device-time estimate** — the paper's §V-B4 estimate: the CHARM
//!    MLP throughput on the 13×4×6 design vs the CHARM baseline.
//!
//!     make artifacts && cargo run --release --example dnn_inference

use maxeva::arch::device::AieDevice;
use maxeva::arch::precision::Precision;
use maxeva::config::schema::DesignConfig;
use maxeva::coordinator::tiler::matmul_ref_f32;
use maxeva::report::evaluate::evaluate_config;
use maxeva::report::paper;
use maxeva::runtime::{default_artifacts_dir, Runtime};
use maxeva::sim::engine::SimConfig;
use maxeva::tiling::mlp::{charm_mlp, estimate_mlp};
use maxeva::util::prng::XorShift64;

fn rand_vec(n: usize, rng: &mut XorShift64, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32 * scale).collect()
}

fn relu(v: Vec<f32>) -> Vec<f32> {
    v.into_iter().map(|x| x.max(0.0)).collect()
}

fn main() {
    // ---- Part 1: real numerics through the artifact ----
    println!("[1] MLP forward pass through the AOT artifact (mlp_fp32)");
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable: {e}");
            std::process::exit(1);
        }
    };
    match rt.load_named(&default_artifacts_dir(), "mlp_fp32") {
        Ok(exe) => {
            let mut rng = XorShift64::new(77);
            // MLP_DIMS = 128 → 256 → 256 → 64, batch 64 (python/compile/model.py).
            let x = rand_vec(64 * 128, &mut rng, 0.3);
            let w1 = rand_vec(128 * 256, &mut rng, 0.1);
            let w2 = rand_vec(256 * 256, &mut rng, 0.1);
            let w3 = rand_vec(256 * 64, &mut rng, 0.1);
            let t0 = std::time::Instant::now();
            let out = exe
                .run_f32(&[
                    (x.as_slice(), &[64, 128]),
                    (w1.as_slice(), &[128, 256]),
                    (w2.as_slice(), &[256, 256]),
                    (w3.as_slice(), &[256, 64]),
                ])
                .expect("mlp artifact must run");
            let wall = t0.elapsed();
            // Host reference.
            let h1 = relu(matmul_ref_f32(&x, &w1, 64, 128, 256));
            let h2 = relu(matmul_ref_f32(&h1, &w2, 64, 256, 256));
            let want = matmul_ref_f32(&h2, &w3, 64, 256, 64);
            let max_err = out
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "    output {}x{}, wall {:.2} ms, max abs err vs host ref {max_err:.2e}",
                64,
                64,
                wall.as_secs_f64() * 1e3
            );
        }
        Err(e) => {
            println!("    SKIPPED: {e} (run `make artifacts`)");
        }
    }

    // ---- Part 2: the paper's §V-B4 estimate ----
    println!("\n[2] §V-B4 full-DNN estimate on the 13x4x6 design");
    let dev = AieDevice::vc1902();
    let d = DesignConfig::flagship(Precision::Fp32);
    let r = evaluate_config(&dev, d.x, d.y, d.z, d.pattern, Precision::Fp32, &SimConfig::default())
        .expect("flagship evaluates");
    let est = estimate_mlp(
        &charm_mlp(),
        &d.candidate(),
        &d.kernel(),
        r.sim.period_cycles,
        dev.freq_hz,
    );
    println!(
        "    MaxEVA : {:.2} GFLOPs   (paper: {:.2})",
        est.ops_per_sec / 1e9,
        paper::MLP_MAXEVA_GFLOPS
    );
    println!(
        "    CHARM  : {:.2} GFLOPs   (scaled to 1.25 GHz from [19])",
        paper::MLP_CHARM_GFLOPS
    );
    println!(
        "    gain   : {:.2}x          (paper: 1.29x)",
        est.ops_per_sec / 1e9 / paper::MLP_CHARM_GFLOPS
    );
    println!(
        "    layers : {} GEMMs, {:.1} GFLOP total, {:.2} ms device time",
        charm_mlp().len(),
        est.total_ops / 1e9,
        est.time_s * 1e3
    );
}
