//! Full design-space exploration — the MaxEVA methodology end to end:
//! single-kernel IP (eq. 3–6), array IP (eq. 7–9), pattern selection,
//! PnR feasibility filtering, and final ranking by simulated throughput.
//!
//!     cargo run --release --example optimize_design
//!
//! Also demonstrates generalization to a different (hypothetical) Versal
//! device, as claimed in paper §IV.

use maxeva::arch::device::AieDevice;
use maxeva::arch::precision::Precision;
use maxeva::kernels::matmul::MatMulKernel;
use maxeva::optimizer::array::{optimize_array, top_tiers};
use maxeva::optimizer::single_kernel::{optimize_single_kernel, top_ranked};
use maxeva::placement::pattern::Pattern;
use maxeva::placement::placer::{capacity, place_design};
use maxeva::power::estimate_power;
use maxeva::report::table::Table;
use maxeva::routing::router::route_design;
use maxeva::sim::engine::{simulate_design, SimConfig};

fn explore(dev: &AieDevice, prec: Precision) {
    println!("\n===== {} / {} =====", dev.name, prec);

    // Stage 1: single-kernel tile sizes.
    let kernels = optimize_single_kernel(dev, prec, 0.95);
    let top = top_ranked(&kernels);
    println!(
        "stage 1 — kernel IP: {} feasible, {} top-ranked at {} MACs:",
        kernels.len(),
        top.len(),
        top.first().map(|c| c.macs).unwrap_or(0)
    );
    for c in top.iter().take(6) {
        println!(
            "  {}x{}x{}  ({} cyc, {:.2}%)",
            c.kernel.m,
            c.kernel.k,
            c.kernel.n,
            c.kernel.latency_cycles(),
            c.kernel.efficiency() * 100.0
        );
    }
    let kernel = top[0].kernel;

    // Stage 2: array mapping tiers + PnR filter + simulation ranking.
    let arrays = optimize_array(dev, None);
    let mut t = Table::new(vec![
        "X×Y×Z", "kernels", "pattern", "PnR", "sim throughput", "power(W)", "EE",
    ]);
    let mut ranked: Vec<(f64, String)> = Vec::new();
    for tier in top_tiers(&arrays, 4) {
        for cand in tier.iter().take(3) {
            let Some(pat) = Pattern::for_y(cand.y) else {
                t.row(vec![
                    cand.label(),
                    cand.matmul_kernels().to_string(),
                    "—".into(),
                    "no pattern".into(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                ]);
                continue;
            };
            if cand.groups() as usize > capacity(dev, pat) {
                t.row(vec![
                    cand.label(),
                    cand.matmul_kernels().to_string(),
                    pat.to_string(),
                    "no capacity".into(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                ]);
                continue;
            }
            let placed = match place_design(dev, *cand, pat, kernel) {
                Ok(p) => p,
                Err(e) => {
                    t.row(vec![
                        cand.label(),
                        cand.matmul_kernels().to_string(),
                        pat.to_string(),
                        format!("place: {e}"),
                        "—".into(),
                        "—".into(),
                        "—".into(),
                    ]);
                    continue;
                }
            };
            match route_design(dev, &placed) {
                Ok(_) => {
                    let sim = simulate_design(dev, &placed, &SimConfig::default());
                    let pw = estimate_power(dev, &placed, &sim);
                    let (thr, unit_scale) = match prec {
                        Precision::Fp32 | Precision::Bf16 => (sim.ops_per_sec / 1e9, 1e9),
                        Precision::Int8 | Precision::Int16 => (sim.ops_per_sec / 1e12, 1e12),
                    };
                    let ee = pw.energy_efficiency(sim.ops_per_sec) / unit_scale;
                    ranked.push((sim.ops_per_sec, cand.label()));
                    t.row(vec![
                        cand.label(),
                        cand.matmul_kernels().to_string(),
                        pat.to_string(),
                        "ok".into(),
                        format!("{thr:.2} {}", prec.ops_unit()),
                        format!("{:.2}", pw.total_w()),
                        format!("{ee:.3}"),
                    ]);
                }
                Err(e) => {
                    let reason = match e {
                        maxeva::routing::router::RoutingError::NoSlack { .. } => {
                            "FAIL (no slack)".to_string()
                        }
                        other => format!("FAIL ({other})"),
                    };
                    t.row(vec![
                        cand.label(),
                        cand.matmul_kernels().to_string(),
                        pat.to_string(),
                        reason,
                        "—".into(),
                        "—".into(),
                        "—".into(),
                    ]);
                }
            }
        }
    }
    println!("stage 2 — array IP + PnR + simulation:");
    print!("{}", t.render());
    ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    if let Some((thr, label)) = ranked.first() {
        let scaled = match prec {
            Precision::Fp32 | Precision::Bf16 => format!("{:.2} GFLOPs", thr / 1e9),
            Precision::Int8 | Precision::Int16 => format!("{:.2} TOPs", thr / 1e12),
        };
        println!("winner: {label} @ {scaled}");
    }
}

fn main() {
    let vc1902 = AieDevice::vc1902();
    for prec in Precision::all() {
        explore(&vc1902, prec);
    }

    // Generalization: the same methodology on a hypothetical half-size
    // Versal part — nothing in the flow is VC1902-specific.
    let half = AieDevice::half_vc1902();
    explore(&half, Precision::Int8);

    // Sanity print: the paper's flagship must be the realized winner on
    // the VC1902 (10×4×8 is filtered by PnR).
    let kernel = MatMulKernel::paper_kernel(Precision::Fp32);
    let c = maxeva::optimizer::array::ArrayCandidate::new(10, 4, 8);
    let placed = place_design(&vc1902, c, Pattern::P1, kernel).unwrap();
    match route_design(&vc1902, &placed) {
        Err(e) => println!("\n10x4x8 PnR check: correctly rejected ({e})"),
        Ok(_) => println!("\n10x4x8 PnR check: UNEXPECTEDLY routed"),
    }
}
