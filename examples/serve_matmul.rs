//! **End-to-end driver** (the required full-system example): serve a
//! stream of batched MatMul requests through the complete stack —
//!
//!   request trace → coordinator (router + dynamic tile batcher)
//!     → device thread → PJRT CPU executing the AOT-compiled JAX/Pallas
//!       artifact (the 13×4×6 design's native 416×128×192 MatMul)
//!     → accumulation → verification against a host reference
//!
//! and report latency + throughput, both wall-clock (CPU emulation) and
//! device-time (VCK190-equivalent, from the calibrated simulator).
//!
//!     make artifacts && cargo run --release --example serve_matmul

use maxeva::arch::precision::Precision;
use maxeva::config::schema::{DesignConfig, ServeConfig};
use maxeva::coordinator::server::MatMulServer;
use maxeva::coordinator::tiler::matmul_ref_f32;
use maxeva::runtime::default_artifacts_dir;
use maxeva::util::stats::percentile;
use maxeva::workloads::{materialize_batch, random_trace, transformer_block_gemms};

fn main() {
    let mut cfg = ServeConfig::new(DesignConfig::flagship(Precision::Fp32));
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();

    let mut server = match MatMulServer::start(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot start server: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "server up — design 13x4x6 fp32, native MatMul {:?}, period {:.0} cyc @ {:.2} GHz",
        server.native(),
        server.period_cycles(),
        server.freq_hz() / 1e9,
    );
    println!(
        "backend {} · {} device workers · pipeline window {}",
        server.backend(),
        server.workers(),
        server.pipeline_depth(),
    );

    // Workload 1: a random GEMM trace (DL-typical power-of-two shapes).
    let trace = random_trace(6, 11);
    println!("\n[1] random trace: {} requests", trace.len());
    let batch = materialize_batch(&trace, 4242);
    // Keep references for verification.
    let refs: Vec<Vec<f32>> = batch
        .iter()
        .map(|(r, a, b)| matmul_ref_f32(a, b, r.m as usize, r.k as usize, r.n as usize))
        .collect();
    let outs = server.run_batch(batch).expect("batch must run");
    let mut max_err = 0.0f32;
    for (out, want) in outs.iter().zip(&refs) {
        for (x, y) in out.iter().zip(want) {
            max_err = max_err.max((x - y).abs());
        }
    }
    println!("    verified: max abs error {max_err:.2e} across {} outputs", outs.len());

    // Workload 2: the GEMMs of one transformer block (batch·seq = 512,
    // d_model 768, d_ff 3072) — the kind of DL workload the intro
    // motivates.
    let gemms = transformer_block_gemms(512, 768, 3072);
    println!("\n[2] transformer block GEMMs: {} requests", gemms.len());
    let batch = materialize_batch(&gemms, 4243);
    server.run_batch(batch).expect("transformer batch");

    let stats = server.stats();
    println!("\n==== serving report ====");
    println!("requests        : {}", stats.requests);
    println!("tile invocations: {}", stats.invocations);
    println!("mean latency    : {:.1} ms (wall, CPU emulation)", stats.mean_latency_ms);
    println!("p99 latency     : {:.1} ms", stats.p99_latency_ms);
    println!("wall time       : {:.2} s (CPU emulation of the array)", stats.wall_time_s);
    println!("device time     : {:.3} ms (simulated VCK190 @1.25 GHz)", stats.device_time_s * 1e3);
    println!(
        "device thr      : {:.1} GFLOPs VCK190-equivalent (design peak 5442 GFLOPs; \
         gap = zero-padding of non-native request shapes, cf. Fig. 8)",
        stats.device_ops_per_sec / 1e9
    );
    let lat = vec![stats.mean_latency_ms, stats.p99_latency_ms];
    let _ = percentile(&lat, 50.0);
    server.shutdown();
    println!("server shut down cleanly");
}
