//! **End-to-end driver** (the required full-system example): serve
//! MatMul traffic through the complete stack —
//!
//!   request stream → streaming admission queue (bounded, block/reject
//!     backpressure) → scheduler (tile-major packing + pipelined
//!     in-flight window) → device worker pool → PJRT CPU executing the
//!     AOT-compiled JAX/Pallas artifact (or the pure-Rust reference
//!     backend) → ordered reduction → per-request completion handles
//!     → verification against host references
//!
//! and report latency + throughput, both wall-clock (CPU emulation) and
//! device-time (VCK190-equivalent, from the calibrated simulator).
//! Demonstrates both serving modes:
//!
//!   1. closed fp32 batches replayed through the streaming API
//!      (`submit` with blocking admission, wait in request order), and
//!   2. an **open mixed fp32/int8 request stream** via `submit` /
//!      `RequestHandle` — per-request precision through one window.
//!
//!     make artifacts && cargo run --release --example serve_matmul
//!
//! (Without artifacts the reference backend serves the same stack.)

use maxeva::coordinator::fault::{DeadlineExceeded, RequestShed};
use maxeva::coordinator::tiler::{matmul_ref_f32, matmul_ref_i32};
use maxeva::prelude::*;
use maxeva::runtime::default_artifacts_dir;
use maxeva::util::stats::percentile;
use maxeva::workloads::{
    materialize_batch, materialize_mixed, mixed_trace, random_trace, transformer_block_gemms,
};
use std::time::Duration;

/// Replay a closed fp32 batch through the streaming API: submit
/// everything (blocking admission), wait in request order. This is what
/// the deprecated `run_batch` wrapper does internally.
fn serve_batch(
    server: &MatMulServer,
    batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
) -> Vec<Vec<f32>> {
    let handles: Vec<RequestHandle> = batch
        .into_iter()
        .map(|(req, a, b)| {
            server.submit(req, Operands::F32 { a, b }).expect("admission (blocking) must succeed")
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.wait().expect("request must retire").into_f32().expect("fp32 output"))
        .collect()
}

fn main() {
    let cfg = ServeConfig::builder(DesignConfig::flagship(Precision::Fp32))
        .artifacts_dir(default_artifacts_dir().to_string_lossy().into_owned())
        .build()
        .expect("default serving config is valid");

    let server = match MatMulServer::start(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot start server: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "server up — design 13x4x6, native fp32 {:?} / int8 {:?}, period {:.0} cyc @ {:.2} GHz",
        server.native(),
        server.native_for(Precision::Int8).unwrap(),
        server.period_cycles(),
        server.freq_hz() / 1e9,
    );
    println!(
        "backend {} · {} device workers · pipeline window {} · queue depth {} ({})",
        server.backend(),
        server.workers(),
        server.pipeline_depth(),
        server.queue_depth(),
        cfg.admission,
    );

    // Workload 1: a random fp32 GEMM trace as a closed batch
    // (DL-typical power-of-two shapes).
    let trace = random_trace(6, 11);
    println!("\n[1] closed fp32 batch: {} requests", trace.len());
    let batch = materialize_batch(&trace, 4242);
    // Keep references for verification.
    let refs: Vec<Vec<f32>> = batch
        .iter()
        .map(|(r, a, b)| matmul_ref_f32(a, b, r.m as usize, r.k as usize, r.n as usize))
        .collect();
    let t0 = std::time::Instant::now();
    let outs = serve_batch(&server, batch);
    let mut wall_s = t0.elapsed().as_secs_f64();
    let mut max_err = 0.0f32;
    for (out, want) in outs.iter().zip(&refs) {
        for (x, y) in out.iter().zip(want) {
            max_err = max_err.max((x - y).abs());
        }
    }
    println!("    verified: max abs error {max_err:.2e} across {} outputs", outs.len());

    // Workload 2: an OPEN mixed fp32/int8 stream — requests admitted
    // one by one through the bounded queue (blocking backpressure) and
    // retired out of band via per-request handles. Int8 results are
    // exact i32 accumulations; fp32 checked within tolerance.
    let stream = mixed_trace(8, 23);
    let int8_count = stream.iter().filter(|r| r.precision == Precision::Int8).count();
    println!(
        "\n[2] open mixed stream: {} requests ({} int8, {} fp32)",
        stream.len(),
        int8_count,
        stream.len() - int8_count
    );
    let materialized = materialize_mixed(&stream, 9001);
    let handles: Vec<_> = materialized
        .iter()
        .map(|(req, ops)| {
            server
                .submit(*req, ops.clone())
                .expect("admission (blocking policy) must succeed")
        })
        .collect();
    let mut exact_int8 = 0usize;
    let mut max_err = 0.0f32;
    for ((req, ops), handle) in materialized.iter().zip(handles) {
        let (m, k, n) = (req.m as usize, req.k as usize, req.n as usize);
        match (ops, handle.wait().expect("request must retire")) {
            (Operands::I32 { a, b }, MatOutput::I32(got)) => {
                assert_eq!(got, matmul_ref_i32(a, b, m, k, n), "int8 req {}", req.id);
                exact_int8 += 1;
            }
            (Operands::F32 { a, b }, MatOutput::F32(got)) => {
                for (x, y) in got.iter().zip(&matmul_ref_f32(a, b, m, k, n)) {
                    max_err = max_err.max((x - y).abs());
                }
            }
            _ => unreachable!("output precision follows request precision"),
        }
    }
    println!(
        "    verified: {exact_int8} int8 results bit-exact vs i32 reference, \
         fp32 max abs error {max_err:.2e}"
    );

    // Workload 3: the GEMMs of one transformer block (batch·seq = 512,
    // d_model 768, d_ff 3072) — the kind of DL workload the intro
    // motivates.
    let gemms = transformer_block_gemms(512, 768, 3072);
    println!("\n[3] transformer block GEMMs: {} requests", gemms.len());
    let batch = materialize_batch(&gemms, 4243);
    let t0 = std::time::Instant::now();
    serve_batch(&server, batch);
    wall_s += t0.elapsed().as_secs_f64();

    // Workload 4: weighted-fair scheduling + cancellation. A second
    // server runs the WeightedFair policy: int8 bulk traffic in class 1,
    // latency-sensitive fp32 in class 0 (weight 4), so the heavy stream
    // cannot monopolize the window. One bulk request is cancelled
    // mid-flight — its undispatched tiles are reclaimed, and the handle
    // still resolves (with a typed `Cancelled` error).
    println!("\n[4] weighted-fair policy + cancellation");
    let mut fair_cfg = cfg.clone();
    fair_cfg.policy = PolicyKind::WeightedFair;
    fair_cfg.class_weights = vec![4, 1];
    let fair = MatMulServer::start(&fair_cfg).expect("fair server");
    let bulk: Vec<MatMulRequest> = (0..4)
        .map(|i| MatMulRequest::int8(900 + i, 256, 1024, 256).with_class(1))
        .collect();
    let latency: Vec<MatMulRequest> = (0..4)
        .map(|i| MatMulRequest::f32(950 + i, 128, 128, 128).with_class(0))
        .collect();
    let bulk_batch = materialize_mixed(&bulk, 77);
    let latency_batch = materialize_mixed(&latency, 78);
    let mut bulk_handles: Vec<_> = bulk_batch
        .iter()
        .map(|(req, ops)| fair.submit(*req, ops.clone()).expect("bulk admission"))
        .collect();
    let latency_handles: Vec<_> = latency_batch
        .iter()
        .map(|(req, ops)| fair.submit(*req, ops.clone()).expect("latency admission"))
        .collect();
    // Change of plan: the last bulk request is no longer needed.
    let doomed = bulk_handles.pop().unwrap();
    doomed.cancel();
    match doomed.wait() {
        Err(e) if e.downcast_ref::<Cancelled>().is_some() => {
            println!("    cancelled bulk request resolved with: {e}")
        }
        Err(e) => println!("    cancelled bulk request failed otherwise: {e}"),
        Ok(_) => println!("    bulk request finished before the cancel landed"),
    }
    for h in latency_handles.into_iter().chain(bulk_handles) {
        h.wait().expect("fair-served request");
    }
    let fstats = fair.stats();
    println!(
        "    policy {} · {} served / {} cancelled",
        fair.sched_policy(),
        fstats.requests,
        fstats.cancelled
    );
    for c in &fstats.classes {
        println!(
            "    class {}: queue p50/p99 {:.1}/{:.1} ms · service p50/p99 {:.1}/{:.1} ms",
            c.class, c.queue_p50_ms, c.queue_p99_ms, c.service_p50_ms, c.service_p99_ms
        );
    }
    fair.shutdown();

    // Workload 5: weight-reuse serving through the packed-weight cache.
    // One "model" weight tagged with `with_weight_id` is multiplied by a
    // stream of activations on a cache-enabled server: B is extracted
    // and packed once, every later request reuses the packed pool
    // (`ServerStats::mem` counts the hits), and outputs stay
    // bit-identical to the uncached engine — verified against the main
    // (cache-off) server.
    println!("\n[5] weight-reuse stream through the packed-weight cache");
    let mut cached_cfg = cfg.clone();
    cached_cfg.weight_cache_bytes = 64 << 20;
    let cached = MatMulServer::start(&cached_cfg).expect("cached server");
    let (rm, rk, rn) = (96u64, 512u64, 96u64);
    let reuse_reqs: Vec<MatMulRequest> = (0..6)
        .map(|i| MatMulRequest::f32(1000 + i, rm, rk, rn).with_weight_id(1))
        .collect();
    let shared_weight = match materialize_mixed(&[reuse_reqs[0]], 555).remove(0).1 {
        Operands::F32 { b, .. } => b,
        _ => unreachable!(),
    };
    let reuse_batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)> = reuse_reqs
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let a = match materialize_mixed(&[*r], 600 + i as u64).remove(0).1 {
                Operands::F32 { a, .. } => a,
                _ => unreachable!(),
            };
            (*r, a, shared_weight.clone())
        })
        .collect();
    let warm = serve_batch(&cached, reuse_batch.clone());
    let t0 = std::time::Instant::now();
    let cold = serve_batch(&server, reuse_batch);
    wall_s += t0.elapsed().as_secs_f64();
    assert_eq!(warm, cold, "cache hits must not change outputs");
    let mem = cached.stats().mem;
    println!(
        "    {} requests, one shared {rk}x{rn} weight: {} cache hit(s), {} miss(es), \
         {:.1} KiB resident — outputs bit-identical to the uncached server",
        reuse_reqs.len(),
        mem.weight_cache_hits,
        mem.weight_cache_misses,
        mem.weight_cache_bytes as f64 / 1024.0
    );
    println!(
        "    tile buffers: {} recycled / {} allocated across the stream",
        mem.tile_buffers_recycled, mem.tile_buffers_allocated
    );
    cached.shutdown();

    // Workload 6: parallel operand packing (the PR 5 host compute
    // plane). The same tall-K batch is served by a serial-packing
    // server and a `pack_workers = 4` server: arena extraction fans out
    // across threads, the new `ServerStats::pack` counters attribute
    // the packing time, and outputs stay bit-identical — parallel
    // packing is a pure latency knob.
    println!("\n[6] parallel operand packing: pack_workers 1 vs 4");
    let (qm, qk, qn) = (128u64, 2048u64, 512u64);
    let pack_reqs: Vec<MatMulRequest> = (0..3)
        .map(|i| MatMulRequest::f32(1100 + i, qm, qk, qn))
        .collect();
    let pack_batch = materialize_batch(&pack_reqs, 6001);
    let mut walls = Vec::new();
    let mut outs_by_leg = Vec::new();
    for pack_workers in [1usize, 4] {
        let mut leg_cfg = cfg.clone();
        leg_cfg.pack_workers = pack_workers;
        let leg = MatMulServer::start(&leg_cfg).expect("packing server");
        let t0 = std::time::Instant::now();
        let outs = serve_batch(&leg, pack_batch.clone());
        let wall = t0.elapsed().as_secs_f64();
        let p = leg.stats().pack;
        println!(
            "    pack_workers {}: batch wall {:.3} s · {} matrices packed \
             ({} parallel) · {:.1} ms packing time",
            leg.pack_workers(),
            wall,
            p.matrices_packed,
            p.parallel_packs,
            p.pack_time_s * 1e3
        );
        walls.push(wall);
        outs_by_leg.push(outs);
        leg.shutdown();
    }
    assert_eq!(outs_by_leg[0], outs_by_leg[1], "parallel packing must not change outputs");
    println!(
        "    {qm}x{qk}x{qn} ×{}: wall {:.2}× with parallel packing — outputs bit-identical",
        pack_reqs.len(),
        walls[0] / walls[1].max(1e-12)
    );

    // Workload 7: the request-level robustness plane (PR 9). A 2-shard
    // server with the failover plane armed, a small admission queue and
    // the brownout shedder at a 0.5 occupancy watermark serves a
    // past-saturation burst: bulk class-3 traffic is shed with the
    // typed `RequestShed` while class-0 requests only ever see plain
    // queue backpressure. One request carries an impossible 5 ms
    // deadline and resolves with the typed `DeadlineExceeded` — never
    // partial output. `ServerStats::shed` and the per-shard breaker
    // states report it all.
    println!("\n[7] request deadlines + brownout shedding under overload");
    let mut robust_cfg = cfg.clone();
    robust_cfg.shards = 2;
    robust_cfg.shard_failover = true;
    robust_cfg.queue_depth = 3;
    robust_cfg.shed_watermark = 0.5;
    robust_cfg.admission = AdmissionPolicy::Reject;
    let robust = MatMulServer::start(&robust_cfg).expect("robust server");

    // An impossible deadline: ~26M MACs cannot retire in 5 ms.
    let doomed =
        [MatMulRequest::f32(1200, 128, 1600, 128).with_deadline(Duration::from_millis(5))];
    let (req, ops) = materialize_mixed(&doomed, 700).remove(0);
    let deadline_handle = robust.submit(req, ops).expect("deadline request admits");

    // A burst past saturation: heavy bulk requests in class 3, latency
    // requests in class 0, rejected (not blocked) at the gate.
    let burst: Vec<MatMulRequest> = (0..12)
        .map(|i| {
            if i % 2 == 0 {
                MatMulRequest::int8(1210 + i, 192, 768, 192).with_class(3)
            } else {
                MatMulRequest::f32(1210 + i, 64, 128, 64).with_class(0)
            }
        })
        .collect();
    let (mut served, mut shed, mut backpressured) = (Vec::new(), 0usize, 0usize);
    for (req, ops) in materialize_mixed(&burst, 701) {
        match robust.submit(req, ops) {
            Ok(h) => served.push(h),
            Err(e) if e.downcast_ref::<RequestShed>().is_some() => shed += 1,
            Err(e) if e.downcast_ref::<QueueFull>().is_some() => backpressured += 1,
            Err(e) => panic!("unexpected admission failure: {e:#}"),
        }
    }
    match deadline_handle.wait() {
        Err(e) if e.downcast_ref::<DeadlineExceeded>().is_some() => {
            println!("    deadline request resolved with: {e}")
        }
        Err(e) => println!("    deadline request failed otherwise: {e}"),
        Ok(_) => println!("    deadline request finished inside its budget"),
    }
    for h in served {
        h.wait().expect("admitted burst request must retire");
    }
    let rstats = robust.stats();
    println!(
        "    burst of {}: {} served · {} shed (brownout) · {} backpressured (QueueFull)",
        burst.len(),
        rstats.requests,
        shed,
        backpressured
    );
    println!(
        "    ShedStats: brownout {} · SLO {} · deadline expiries {} · \
         failovers {}+{} bands · breaker trips/probes/recoveries {}/{}/{}",
        rstats.shed.shed_brownout,
        rstats.shed.shed_slo,
        rstats.shed.deadline_expired,
        rstats.shed.failovers,
        rstats.shed.failover_bands,
        rstats.shed.breaker_trips,
        rstats.shed.breaker_probes,
        rstats.shed.breaker_recoveries
    );
    println!("    breaker states: {:?} (healthy fleet — all closed)", rstats.breaker_states);
    robust.shutdown();

    // Workload 8: the self-healing plane (PR 10). A 3-shard fleet with
    // failover, shard respawn and sampled cache verification armed
    // serves a stream; mid-stream one shard's scheduler is chaos-killed.
    // The failover plane masks the crash (every handle still resolves),
    // the respawn supervisor rebuilds the shard from its config, and
    // the victim's breaker walks Open → HalfOpen → Closed on probe
    // traffic. `ServerStats::recovery` and the typed per-shard breaker
    // snapshots report the whole arc.
    println!("\n[8] self-healing: shard crash, respawn, breaker re-close");
    let mut heal_cfg = cfg.clone();
    heal_cfg.shards = 3;
    heal_cfg.shard_failover = true;
    heal_cfg.breaker_threshold = 1;
    heal_cfg.breaker_probe_ms = 50;
    heal_cfg.shard_respawn = true;
    heal_cfg.respawn_max_attempts = 3;
    heal_cfg.respawn_backoff_ms = 20;
    heal_cfg.cache_verify_interval = 1;
    let heal = MatMulServer::start(&heal_cfg).expect("self-healing server");
    let heal_reqs: Vec<MatMulRequest> =
        (0..9).map(|i| MatMulRequest::f32(1300 + i, 96, 256, 96)).collect();
    let heal_handles: Vec<_> = materialize_mixed(&heal_reqs, 800)
        .into_iter()
        .map(|(req, ops)| heal.submit(req, ops).expect("admission"))
        .collect();
    let victim = {
        let s = heal.stats();
        s.shards.iter().enumerate().max_by_key(|(_, sh)| sh.requests).map_or(0, |(i, _)| i)
    };
    heal.inject_scheduler_panic_on(victim);
    for h in heal_handles {
        h.wait().expect("failover must mask the crash");
    }
    println!("    shard {victim} killed mid-stream — all 9 requests still resolved");
    // Drive small concurrent probe batches until the respawned victim's
    // breaker closes (concurrency pushes least-loaded routing onto the
    // idle replacement, which is what lets the half-open probe through).
    let t0 = std::time::Instant::now();
    let mut probe_id = 1400u64;
    loop {
        let s = heal.stats();
        if s.recovery.breaker_recoveries >= 1
            && s.breaker_states.get(victim).copied() == Some("closed")
        {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "breaker did not re-close");
        let probes: Vec<MatMulRequest> =
            (0..3).map(|j| MatMulRequest::f32(probe_id + j, 64, 128, 64)).collect();
        probe_id += 3;
        let probe_handles: Vec<_> = materialize_mixed(&probes, 801)
            .into_iter()
            .map(|(req, ops)| heal.submit(req, ops).expect("probe admission"))
            .collect();
        for h in probe_handles {
            h.wait().expect("probe must succeed under failover");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let hstats = heal.stats();
    println!(
        "    RecoveryStats: respawns {} (failures {}) · rewarmed entries {} · cache \
         verifications {} · poisoned evictions {} · breaker trips/probes/recoveries {}/{}/{}",
        hstats.recovery.respawns,
        hstats.recovery.respawn_failures,
        hstats.recovery.rewarmed_entries,
        hstats.recovery.cache_verifications,
        hstats.recovery.poisoned_evictions,
        hstats.recovery.breaker_trips,
        hstats.recovery.breaker_probes,
        hstats.recovery.breaker_recoveries
    );
    for (i, sh) in hstats.shards.iter().enumerate() {
        if let Some(b) = sh.breaker {
            println!(
                "    shard {i}: breaker {} · consecutive failures {} · last failure {}",
                b.state,
                b.consecutive_failures,
                b.last_failure.unwrap_or("none"),
            );
        }
    }
    heal.shutdown();

    let stats = server.stats();
    println!("\n==== serving report ====");
    println!("requests        : {}", stats.requests);
    println!("tile invocations: {}", stats.invocations);
    println!("mean latency    : {:.1} ms (wall, CPU emulation)", stats.mean_latency_ms);
    println!("p99 latency     : {:.1} ms", stats.p99_latency_ms);
    println!("wall time       : {:.2} s (CPU emulation, closed-batch replays)", wall_s);
    println!("device time     : {:.3} ms (simulated VCK190 @1.25 GHz)", stats.device_time_s * 1e3);
    println!(
        "device thr      : {:.1} GFLOPs VCK190-equivalent (design peak 5442 GFLOPs; \
         gap = zero-padding of non-native request shapes, cf. Fig. 8)",
        stats.device_ops_per_sec / 1e9
    );
    let lat = vec![stats.mean_latency_ms, stats.p99_latency_ms];
    let _ = percentile(&lat, 50.0);
    server.shutdown();
    println!("server shut down cleanly");
}
