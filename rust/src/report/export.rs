//! Plot-ready data exporters: CSV and JSON series for every figure/table,
//! written under `out/` by the benches (so the paper's plots can be
//! regenerated with any plotting tool).

use crate::config::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// A columnar data series (one figure/table worth of data).
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Series {
    pub fn new<S: Into<String>>(columns: Vec<S>) -> Self {
        Series {
            columns: columns.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) -> &mut Self {
        assert_eq!(row.len(), self.columns.len(), "row width mismatch");
        self.rows.push(row);
        self
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            let cells: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }

    /// Render as a JSON object {column: [values...]}.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (i, c) in self.columns.iter().enumerate() {
            let col: Vec<Json> = self.rows.iter().map(|r| Json::Num(r[i])).collect();
            obj.insert(c.clone(), Json::Arr(col));
        }
        Json::Obj(obj)
    }

    /// Write both `<stem>.csv` and `<stem>.json` into `dir`.
    pub fn write(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.json")))?;
        f.write_all(self.to_json().to_string_pretty().as_bytes())?;
        Ok(())
    }
}

/// Default export directory for bench data.
pub fn default_out_dir() -> std::path::PathBuf {
    std::env::var("MAXEVA_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("out"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_format() {
        let mut s = Series::new(vec!["x", "y"]);
        s.push(vec![1.0, 2.5]).push(vec![3.0, 4.0]);
        assert_eq!(s.to_csv(), "x,y\n1,2.5\n3,4\n");
    }

    #[test]
    fn json_columnar() {
        let mut s = Series::new(vec!["a"]);
        s.push(vec![1.0]).push(vec![2.0]);
        let j = s.to_json();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Series::new(vec!["a", "b"]).push(vec![1.0]);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("maxeva_export_test");
        let mut s = Series::new(vec!["size", "gflops"]);
        s.push(vec![256.0, 2232.0]);
        s.write(&dir, "fig8_test").unwrap();
        assert!(dir.join("fig8_test.csv").exists());
        assert!(dir.join("fig8_test.json").exists());
        // Round-trip the JSON through the parser.
        let text = std::fs::read_to_string(dir.join("fig8_test.json")).unwrap();
        assert!(Json::parse(&text).is_ok());
    }
}
