//! Minimal aligned text-table renderer for bench/report output.

/// A text table with a header row and aligned columns.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    /// Render with column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                if i == 0 {
                    line.push_str(c);
                    line.push_str(&" ".repeat(pad));
                } else {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(c);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }
}

/// Format a signed percentage delta, e.g. "+1.3%".
pub fn pct(delta_frac: f64) -> String {
    format!("{:+.1}%", delta_frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "val"]);
        t.row(vec!["a", "1"]).row(vec!["long-name", "123"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert_eq!(lines[0].len(), lines[2].len().max(lines[0].len()));
        assert!(lines[3].starts_with("long-name"));
        assert!(lines[3].ends_with("123"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.208), "+20.8%");
        assert_eq!(pct(-0.013), "-1.3%");
    }
}
