//! Table rendering and paper-vs-measured comparison.
//!
//! [`paper`] embeds the published values of Tables I–III and the §V-B4
//! estimates; [`table`] renders aligned text tables; [`evaluate`] runs the
//! full pipeline (place → route → simulate → power) for one configuration
//! and produces a table row directly comparable against the paper.

pub mod evaluate;
pub mod export;
pub mod paper;
pub mod table;

pub use evaluate::{evaluate_config, ConfigRow};
pub use table::Table;
