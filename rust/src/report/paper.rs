//! Published values from the paper, used by benches and integration tests
//! to report paper-vs-measured deltas.

use crate::arch::precision::Precision;
use crate::placement::pattern::Pattern;

/// One published row of Table II (fp32) or Table III (int8).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub x: u64,
    pub y: u64,
    pub z: u64,
    pub pattern: Pattern,
    pub matmul_kernels: u64,
    pub total_cores: u64,
    pub memory_banks: u64,
    pub dma_banks: u64,
    pub plios: u64,
    /// GFLOPs for fp32, GOPs (=TOPs·1000) for int8, so both fit one field.
    pub throughput_gops: f64,
    /// Total AIE power (W). `None` where the paper could not publish.
    pub power_w: Option<f64>,
    /// Energy efficiency: GFLOPs/W (fp32) or TOPs/W (int8).
    pub energy_eff: Option<f64>,
    /// AIE core power (W).
    pub core_power_w: Option<f64>,
    /// Memory power (W).
    pub memory_power_w: Option<f64>,
}

/// Table II (fp32): the six MaxEVA configurations.
#[rustfmt::skip]
pub fn table2_fp32() -> Vec<PaperRow> {
    vec![
        PaperRow { x: 13, y: 4, z: 6, pattern: Pattern::P1, matmul_kernels: 312, total_cores: 390, memory_banks: 3138, dma_banks: 18, plios: 154, throughput_gops: 5442.11, power_w: Some(43.83), energy_eff: Some(124.16), core_power_w: Some(25.62), memory_power_w: Some(18.21) },
        PaperRow { x: 10, y: 3, z: 10, pattern: Pattern::P2, matmul_kernels: 300, total_cores: 400, memory_banks: 3190, dma_banks: 0, plios: 160, throughput_gops: 5405.33, power_w: Some(44.66), energy_eff: Some(121.03), core_power_w: Some(25.54), memory_power_w: Some(19.12) },
        PaperRow { x: 11, y: 4, z: 7, pattern: Pattern::P1, matmul_kernels: 308, total_cores: 385, memory_banks: 3106, dma_banks: 18, plios: 149, throughput_gops: 5414.39, power_w: Some(44.01), energy_eff: Some(123.03), core_power_w: Some(25.36), memory_power_w: Some(18.65) },
        PaperRow { x: 11, y: 3, z: 9, pattern: Pattern::P2, matmul_kernels: 297, total_cores: 396, memory_banks: 3176, dma_banks: 0, plios: 159, throughput_gops: 5382.27, power_w: Some(44.13), energy_eff: Some(121.96), core_power_w: Some(25.35), memory_power_w: Some(18.78) },
        PaperRow { x: 12, y: 4, z: 6, pattern: Pattern::P1, matmul_kernels: 288, total_cores: 360, memory_banks: 2934, dma_banks: 16, plios: 144, throughput_gops: 5031.19, power_w: Some(40.68), energy_eff: Some(123.68), core_power_w: Some(23.77), memory_power_w: Some(16.91) },
        PaperRow { x: 12, y: 3, z: 8, pattern: Pattern::P2, matmul_kernels: 288, total_cores: 384, memory_banks: 3092, dma_banks: 0, plios: 156, throughput_gops: 5225.05, power_w: Some(42.28), energy_eff: Some(123.58), core_power_w: Some(24.68), memory_power_w: Some(17.60) },
    ]
}

/// Table III (int8): the six MaxEVA configurations (throughput in GOPs).
#[rustfmt::skip]
pub fn table3_int8() -> Vec<PaperRow> {
    vec![
        PaperRow { x: 13, y: 4, z: 6, pattern: Pattern::P1, matmul_kernels: 312, total_cores: 390, memory_banks: 3112, dma_banks: 18, plios: 154, throughput_gops: 77010.0, power_w: Some(66.83), energy_eff: Some(1.152), core_power_w: Some(48.65), memory_power_w: Some(18.18) },
        PaperRow { x: 10, y: 3, z: 10, pattern: Pattern::P2, matmul_kernels: 300, total_cores: 400, memory_banks: 3194, dma_banks: 0, plios: 160, throughput_gops: 76080.0, power_w: Some(65.52), energy_eff: Some(1.161), core_power_w: Some(47.44), memory_power_w: Some(19.08) },
        PaperRow { x: 11, y: 4, z: 7, pattern: Pattern::P1, matmul_kernels: 308, total_cores: 385, memory_banks: 3096, dma_banks: 18, plios: 149, throughput_gops: 75670.0, power_w: Some(66.79), energy_eff: Some(1.133), core_power_w: Some(48.17), memory_power_w: Some(18.62) },
        PaperRow { x: 11, y: 3, z: 9, pattern: Pattern::P2, matmul_kernels: 297, total_cores: 396, memory_banks: 3178, dma_banks: 0, plios: 159, throughput_gops: 74660.0, power_w: Some(65.83), energy_eff: Some(1.134), core_power_w: Some(47.04), memory_power_w: Some(18.79) },
        PaperRow { x: 12, y: 4, z: 6, pattern: Pattern::P1, matmul_kernels: 288, total_cores: 360, memory_banks: 2918, dma_banks: 16, plios: 144, throughput_gops: 71250.0, power_w: Some(62.13), energy_eff: Some(1.147), core_power_w: Some(45.15), memory_power_w: Some(16.98) },
        PaperRow { x: 12, y: 3, z: 8, pattern: Pattern::P2, matmul_kernels: 288, total_cores: 384, memory_banks: 3080, dma_banks: 0, plios: 156, throughput_gops: 72930.0, power_w: Some(63.24), energy_eff: Some(1.153), core_power_w: Some(45.71), memory_power_w: Some(17.53) },
    ]
}

/// CHARM baseline rows (bottom rows of Tables II/III).
pub fn charm_row(prec: Precision) -> PaperRow {
    match prec {
        Precision::Int16 | Precision::Bf16 => {
            panic!("the paper publishes CHARM rows only for fp32/int8")
        }
        Precision::Fp32 => PaperRow {
            x: 8, y: 6, z: 8, pattern: Pattern::P1, // pattern n/a; placeholder
            matmul_kernels: 384, total_cores: 384, memory_banks: 3086,
            dma_banks: 0, plios: 80, throughput_gops: 4504.46,
            power_w: Some(43.69), energy_eff: Some(103.10),
            core_power_w: Some(26.95), memory_power_w: Some(16.74),
        },
        Precision::Int8 => PaperRow {
            x: 8, y: 3, z: 8, pattern: Pattern::P1,
            matmul_kernels: 192, total_cores: 192, memory_banks: 0,
            dma_banks: 0, plios: 0, throughput_gops: 35190.0,
            power_w: None, energy_eff: None,
            core_power_w: None, memory_power_w: None,
        },
    }
}

/// Table I published values.
pub struct PaperKernelRow {
    pub name: &'static str,
    pub latency_cyc: u64,
    pub throughput_macs_per_cyc: f64,
    pub efficiency: f64,
}

#[rustfmt::skip]
pub fn table1() -> Vec<PaperKernelRow> {
    vec![
        PaperKernelRow { name: "MatMul int8 32x128x32", latency_cyc: 1075, throughput_macs_per_cyc: 121.93, efficiency: 0.9526 },
        PaperKernelRow { name: "Add int32 32x32", latency_cyc: 164, throughput_macs_per_cyc: 6.24, efficiency: 0.7805 },
        PaperKernelRow { name: "MatMul fp32 32x32x32", latency_cyc: 4329, throughput_macs_per_cyc: 7.57, efficiency: 0.9470 },
        PaperKernelRow { name: "Add fp32 32x32", latency_cyc: 167, throughput_macs_per_cyc: 6.13, efficiency: 0.7665 },
    ]
}

/// §V-B4 estimates.
pub const MLP_MAXEVA_GFLOPS: f64 = 4735.94;
pub const MLP_CHARM_GFLOPS: f64 = 3670.88;

/// Relative delta (measured vs paper), as a signed fraction.
pub fn rel_delta(measured: f64, paper: f64) -> f64 {
    (measured - paper) / paper
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_six_rows() {
        assert_eq!(table2_fp32().len(), 6);
        assert_eq!(table3_int8().len(), 6);
        assert_eq!(table1().len(), 4);
    }

    #[test]
    fn headline_numbers_present() {
        // 5442.11 GFLOPs and 77.01 TOPs are the abstract's headlines.
        assert_eq!(table2_fp32()[0].throughput_gops, 5442.11);
        assert_eq!(table3_int8()[0].throughput_gops, 77010.0);
        assert_eq!(charm_row(Precision::Fp32).throughput_gops, 4504.46);
    }

    #[test]
    fn headline_gains_match_paper_claims() {
        // +20.8% fp32 and 2.19× int8 over CHARM.
        let fp32_gain =
            table2_fp32()[0].throughput_gops / charm_row(Precision::Fp32).throughput_gops;
        assert!((fp32_gain - 1.208).abs() < 0.001);
        let int8_gain =
            table3_int8()[0].throughput_gops / charm_row(Precision::Int8).throughput_gops;
        assert!((int8_gain - 2.19).abs() < 0.005);
    }

    #[test]
    fn rel_delta_signs() {
        assert!(rel_delta(101.0, 100.0) > 0.0);
        assert!(rel_delta(99.0, 100.0) < 0.0);
    }
}
