//! End-to-end evaluation of one configuration: place → route → simulate →
//! power, producing one row of Table II/III.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::kernels::matmul::MatMulKernel;
use crate::optimizer::array::ArrayCandidate;
use crate::placement::pattern::Pattern;
use crate::placement::placer::{place_design, PlacedDesign};
use crate::power::{estimate_power, PowerEstimate};
use crate::routing::router::{route_design, RouteReport};
use crate::sim::engine::{simulate_design, SimConfig, SimResult};

/// Errors from any stage of the pipeline.
#[derive(Debug, thiserror::Error)]
pub enum EvalError {
    #[error("placement: {0}")]
    Placement(#[from] crate::placement::placer::PlacementError),
    #[error("routing: {0}")]
    Routing(#[from] crate::routing::router::RoutingError),
}

/// One evaluated configuration — the full set of Table II/III columns.
#[derive(Debug, Clone)]
pub struct ConfigRow {
    pub label: String,
    pub pattern: Pattern,
    pub prec: Precision,
    pub matmul_kernels: u64,
    pub total_cores: u64,
    pub core_util: f64,
    pub memory_banks: u64,
    pub bank_util: f64,
    pub dma_banks: u64,
    pub plios: u64,
    pub plio_util: f64,
    /// ops/s (2 ops per MAC).
    pub ops_per_sec: f64,
    pub power: PowerEstimate,
    pub route: RouteReport,
    pub sim: SimResult,
}

impl ConfigRow {
    /// Throughput in the paper's table unit (GFLOPs for fp32, TOPs int8).
    pub fn throughput_table_units(&self) -> f64 {
        match self.prec {
            Precision::Fp32 | Precision::Bf16 => self.ops_per_sec / 1e9,
            Precision::Int8 | Precision::Int16 => self.ops_per_sec / 1e12,
        }
    }

    /// Throughput in GOPs regardless of precision (comparison key against
    /// [`crate::report::paper::PaperRow::throughput_gops`]).
    pub fn throughput_gops(&self) -> f64 {
        self.ops_per_sec / 1e9
    }

    /// Energy efficiency in the paper's unit (GFLOPs/W or TOPs/W).
    pub fn energy_eff_table_units(&self) -> f64 {
        match self.prec {
            Precision::Fp32 | Precision::Bf16 => {
                self.power.energy_efficiency(self.ops_per_sec) / 1e9
            }
            Precision::Int8 | Precision::Int16 => {
                self.power.energy_efficiency(self.ops_per_sec) / 1e12
            }
        }
    }
}

/// Run the whole pipeline for `(x, y, z, pattern)` at `prec`.
pub fn evaluate_config(
    dev: &AieDevice,
    x: u64,
    y: u64,
    z: u64,
    pattern: Pattern,
    prec: Precision,
    sim_cfg: &SimConfig,
) -> Result<ConfigRow, EvalError> {
    let cand = ArrayCandidate::new(x, y, z);
    let kernel = MatMulKernel::paper_kernel(prec);
    let placed: PlacedDesign = place_design(dev, cand, pattern, kernel)?;
    let route = route_design(dev, &placed)?;
    let sim = simulate_design(dev, &placed, sim_cfg);
    let power = estimate_power(dev, &placed, &sim);
    Ok(ConfigRow {
        label: format!("{}x{}x{} ({})", x, y, z, pattern),
        pattern,
        prec,
        matmul_kernels: cand.matmul_kernels(),
        total_cores: cand.total_cores(),
        core_util: placed.core_utilization(dev),
        memory_banks: placed.memory_banks,
        bank_util: placed.bank_utilization(dev),
        dma_banks: placed.dma_banks,
        plios: cand.plios(),
        plio_util: placed.plio_utilization(dev),
        ops_per_sec: sim.ops_per_sec,
        power,
        route,
        sim,
    })
}

/// The six table configurations of the paper, in row order.
pub fn paper_configs() -> [(u64, u64, u64, Pattern); 6] {
    [
        (13, 4, 6, Pattern::P1),
        (10, 3, 10, Pattern::P2),
        (11, 4, 7, Pattern::P1),
        (11, 3, 9, Pattern::P2),
        (12, 4, 6, Pattern::P1),
        (12, 3, 8, Pattern::P2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluates_flagship_fp32() {
        let dev = AieDevice::vc1902();
        let r = evaluate_config(&dev, 13, 4, 6, Pattern::P1, Precision::Fp32, &SimConfig::default())
            .unwrap();
        assert_eq!(r.matmul_kernels, 312);
        assert_eq!(r.dma_banks, 18);
        assert!((r.plio_util - 0.79).abs() < 0.005);
        assert!(r.throughput_table_units() > 5000.0);
    }

    #[test]
    fn infeasible_config_errors() {
        let dev = AieDevice::vc1902();
        let err = evaluate_config(
            &dev, 10, 4, 8, Pattern::P1, Precision::Fp32, &SimConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, EvalError::Routing(_)));
    }

    #[test]
    fn all_paper_configs_evaluate_both_precisions() {
        let dev = AieDevice::vc1902();
        for (x, y, z, pat) in paper_configs() {
            for prec in Precision::all() {
                evaluate_config(&dev, x, y, z, pat, prec, &SimConfig::default())
                    .unwrap_or_else(|e| panic!("{x}x{y}x{z} {prec}: {e}"));
            }
        }
    }

    #[test]
    fn table_units_differ_by_precision() {
        let dev = AieDevice::vc1902();
        let f = evaluate_config(&dev, 12, 3, 8, Pattern::P2, Precision::Fp32, &SimConfig::default())
            .unwrap();
        let i = evaluate_config(&dev, 12, 3, 8, Pattern::P2, Precision::Int8, &SimConfig::default())
            .unwrap();
        // fp32 reported in GFLOPs (thousands), int8 in TOPs (tens).
        assert!(f.throughput_table_units() > 1000.0);
        assert!(i.throughput_table_units() < 100.0);
    }
}
