//! The CHARM baseline [Zhuang et al., FPGA'23 + DAC'23] — the
//! state-of-the-art MaxEVA compares against (Tables II/III bottom rows).
//!
//! CHARM maps MatMul onto the AIE array with an all-MatMul design: no
//! on-array reduction, packet-switched input sharing, and far fewer PLIOs
//! (80, i.e. 41% utilization), which becomes the performance bottleneck
//! MaxEVA removes. For int8, CHARM only routes 192 of 400 cores (48%)
//! because of routing congestion [34].
//!
//! The fp32 design is modelled from the open-source CHARM architecture
//! (8×6×8 = 384 kernels of 32×32×32); the paper simulates it under the
//! same no-PL/no-DRAM assumptions, measuring 4504.46 GFLOPs. The int8
//! numbers are the authors' published 28.15 TOPs @1GHz, frequency-scaled
//! to 1.25 GHz (35.19 TOPs) exactly as the paper does (§V-B2).

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::kernels::matmul::MatMulKernel;
use crate::power::{estimate_power_all_matmul, PowerEstimate};

/// Packet-switch sharing degree of CHARM's input streams: four kernels
/// share one physical PLIO via dynamically-headed packets (the mechanism
/// MaxEVA replaces with circuit-switched broadcast).
pub const PKT_SHARE: u64 = 4;

/// Per-packet header + reconfiguration overhead cycles (packet switching
/// has non-deterministic latency; this is the mean service penalty,
/// calibrated so the fp32 model reproduces the measured 4504.46 GFLOPs).
pub const PKT_OVERHEAD_CYC: f64 = 722.0;

/// The CHARM design point for a precision.
#[derive(Debug, Clone)]
pub struct CharmDesign {
    pub prec: Precision,
    pub kernel: MatMulKernel,
    /// MatMul kernels (= AIE cores; CHARM runs no Add kernels).
    pub kernels: u64,
    /// Total PLIOs used.
    pub plios: u64,
    /// Memory banks used (fp32: measured by the paper's re-simulation).
    pub memory_banks: u64,
}

/// CHARM simulation output (mirror of [`crate::sim::SimResult`] fields
/// used in the tables).
#[derive(Debug, Clone, Copy)]
pub struct CharmResult {
    pub period_cycles: f64,
    pub ops_per_sec: f64,
    pub efficiency: f64,
}

impl CharmDesign {
    pub fn for_precision(prec: Precision) -> Self {
        match prec {
            // 8×6×8 architecture of the open-source fp32 CHARM.
            Precision::Fp32 => CharmDesign {
                prec,
                kernel: MatMulKernel::new(32, 32, 32, prec),
                kernels: 384,
                plios: 80,
                memory_banks: 3086,
            },
            // No CHARM baseline exists for the extension precisions.
            Precision::Int16 | Precision::Bf16 => {
                panic!(
                    "CHARM published only fp32/int8 designs (extension precisions have no \
                     baseline)"
                )
            }
            // int8: 192 cores only (routing congestion, [34]).
            Precision::Int8 => CharmDesign {
                prec,
                kernel: MatMulKernel::new(32, 128, 32, prec),
                kernels: 192,
                plios: 80,
                memory_banks: 1552, // not published; scaled ~8 banks/core
            },
        }
    }

    /// Core utilization vs the device.
    pub fn core_utilization(&self, dev: &AieDevice) -> f64 {
        self.kernels as f64 / dev.total_cores() as f64
    }

    /// PLIO utilization vs the device (paper: 41% for fp32).
    pub fn plio_utilization(&self, dev: &AieDevice) -> f64 {
        self.plios as f64 / dev.total_plios() as f64
    }

    /// Simulate the CHARM design.
    ///
    /// * fp32: input delivery is packet-switched with `PKT_SHARE`-way
    ///   sharing, so each kernel's per-iteration input service serializes
    ///   behind its sharers' A/B packets plus per-packet overhead — the
    ///   PLIO bottleneck MaxEVA removes (the paper measures CHARM's
    ///   open-source fp32 design in its own harness; our packet model is
    ///   calibrated to that measurement, 4504.46 GFLOPs).
    /// * int8: CHARM int8 is closed-source; exactly like the paper
    ///   (§V-B2), the comparison point is the authors' published
    ///   28.15 TOPs @1 GHz frequency-scaled to 1.25 GHz, from which the
    ///   per-kernel period is derived.
    pub fn simulate(&self, dev: &AieDevice) -> CharmResult {
        let kernel_cyc = self.kernel.latency_cycles() as f64;
        let period = match self.prec {
            Precision::Int16 | Precision::Bf16 => unreachable!("no CHARM baseline"),
            Precision::Fp32 => {
                let (a_cyc, _b, _c) = self.kernel.io_cycles(dev);
                let input_service = PKT_SHARE as f64 * (a_cyc as f64 + PKT_OVERHEAD_CYC);
                kernel_cyc.max(input_service)
            }
            Precision::Int8 => {
                // Published 28.15 TOPs @1GHz, 192 kernels: derive cycles.
                let pub_ops_at_1ghz = 28.15e12;
                let ops = 2.0 * self.kernels as f64 * self.kernel.macs() as f64;
                ops / pub_ops_at_1ghz * 1e9
            }
        };
        let ops = 2.0 * self.kernels as f64 * self.kernel.macs() as f64;
        let ops_per_sec = ops / (period / dev.freq_hz);
        CharmResult {
            period_cycles: period,
            ops_per_sec,
            efficiency: ops_per_sec / dev.peak_ops_per_sec(self.prec),
        }
    }

    /// Power estimate (fp32 only in the paper; int8 power was not
    /// publishable because CHARM int8 is closed-source — we still expose
    /// the model's estimate, flagged in the report).
    pub fn power(&self, dev: &AieDevice) -> PowerEstimate {
        let r = self.simulate(dev);
        estimate_power_all_matmul(self.prec, self.kernels, self.memory_banks, r.efficiency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    #[test]
    fn charm_fp32_matches_paper_measurement() {
        // Paper Table II: CHARM fp32 4504.46 GFLOPs (±1%).
        let r = CharmDesign::for_precision(Precision::Fp32).simulate(&dev());
        let gflops = r.ops_per_sec / 1e9;
        assert!(
            (gflops - 4504.46).abs() / 4504.46 < 0.01,
            "measured {gflops:.2}"
        );
    }

    #[test]
    fn charm_int8_matches_scaled_publication() {
        // Paper Table III: CHARM int8 35.19 TOPs (28.15 @1GHz × 1.25).
        let r = CharmDesign::for_precision(Precision::Int8).simulate(&dev());
        let tops = r.ops_per_sec / 1e12;
        assert!((tops - 35.19).abs() / 35.19 < 0.02, "measured {tops:.2}");
    }

    #[test]
    fn charm_plio_utilization_41_percent() {
        let c = CharmDesign::for_precision(Precision::Fp32);
        assert!((c.plio_utilization(&dev()) - 0.41).abs() < 0.005);
    }

    #[test]
    fn charm_int8_uses_48_percent_cores() {
        let c = CharmDesign::for_precision(Precision::Int8);
        assert!((c.core_utilization(&dev()) - 0.48).abs() < 1e-9);
    }

    #[test]
    fn charm_fp32_power_matches_paper() {
        // Paper: CHARM core 26.95 W, memory 16.74 W, total 43.69 W (±3%).
        let p = CharmDesign::for_precision(Precision::Fp32).power(&dev());
        assert!((p.core_w - 26.95).abs() / 26.95 < 0.01, "{}", p.core_w);
        assert!((p.memory_w - 16.74).abs() / 16.74 < 0.03, "{}", p.memory_w);
        assert!((p.total_w() - 43.69).abs() / 43.69 < 0.02, "{}", p.total_w());
    }

    #[test]
    fn charm_energy_efficiency_fp32() {
        // Paper: 103.10 GFLOPs/W (±3%).
        let c = CharmDesign::for_precision(Precision::Fp32);
        let r = c.simulate(&dev());
        let ee = c.power(&dev()).energy_efficiency(r.ops_per_sec) / 1e9;
        assert!((ee - 103.10).abs() / 103.10 < 0.03, "{ee}");
    }
}
