//! Full-array discrete-event simulator.
//!
//! Where [`crate::sim::group_pipeline`] solves one group's steady-state
//! recurrence (fast — used for the tables), this module simulates the
//! *entire placed array* with an event queue: every MatMul core, adder
//! core, PLIO stream and DMA channel is a resource with explicit busy
//! intervals. It exists to (a) cross-validate the group-pipeline model
//! (they must agree on the steady-state period within 1%, see tests),
//! (b) expose transient behaviour — pipeline fill, drain, per-iteration
//! jitter — that the recurrence hides, and (c) serve as the L3
//! profiling target for the §Perf pass.

use crate::arch::device::AieDevice;
use crate::kernels::add::AddKernel;
use crate::placement::group::GroupShape;
use crate::placement::placer::PlacedDesign;
use crate::sim::group_pipeline::OverheadModel;
use crate::util::prng::XorShift64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event kinds, ordered by time through the queue.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// MatMul kernel of (group, k) finished iteration `iter`.
    MatMulDone { group: usize, k: usize, iter: usize },
    /// Adder of `group` finished consuming all C-buffers of `iter`.
    AdderDone { group: usize, iter: usize },
    /// Output stream of `group` drained iteration `iter`.
    OutDone { group: usize, iter: usize },
}

#[derive(Debug, Clone, Copy)]
struct QueuedEvent {
    time_fp: u64, // fixed-point cycles (×16) for a total order
    seq: u64,
    ev: Ev,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        (self.time_fp, self.seq) == (other.time_fp, other.seq)
    }
}
impl Eq for QueuedEvent {}
impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time_fp, self.seq).cmp(&(other.time_fp, other.seq))
    }
}

/// Result of the event simulation.
#[derive(Debug, Clone)]
pub struct EventSimResult {
    /// Steady-state period of the slowest group (cycles/iteration).
    pub period_cycles: f64,
    /// Cycles until the first output of the slowest group (pipeline fill).
    pub fill_cycles: f64,
    /// Total cycles for all groups to complete `iters` iterations.
    pub makespan_cycles: f64,
    /// Throughput over the full makespan (includes fill/drain), ops/s.
    pub ops_per_sec_total: f64,
    /// Steady-state throughput (excludes fill), ops/s.
    pub ops_per_sec_steady: f64,
    /// Events processed (diagnostics / perf).
    pub events: u64,
}

/// Per-group mutable state.
struct GroupState {
    /// Completion time (cycles) of each MatMul's previous iteration.
    mm_done: Vec<f64>,
    /// Which iteration each MatMul runs next.
    mm_iter: Vec<usize>,
    /// c_ready[k]: completion time of the latest C produced by MatMul k.
    c_ready: Vec<Vec<f64>>,
    /// Adder consumption completion per iteration.
    consumed: Vec<f64>,
    adder_free: f64,
    out_free: f64,
    out_times: Vec<f64>,
    /// Per-group stall jitter factor.
    jitter: f64,
    has_dma: bool,
}

/// Simulate the whole placed array for `iters` iterations per group.
pub fn simulate_events(
    dev: &AieDevice,
    design: &PlacedDesign,
    iters: usize,
    seed: u64,
    jitter_amp: f64,
) -> EventSimResult {
    assert!(iters >= 8);
    let kernel = design.kernel;
    let ovh = OverheadModel::calibrated(kernel.prec);
    let add = AddKernel::new(kernel.m, kernel.n, kernel.prec);
    let add_cyc = add.latency_cycles() as f64;
    let (a_cyc, _b_cyc, c_cyc) = kernel.io_cycles(dev);
    let kernel_cyc = kernel.latency_cycles() as f64;
    let y = design.cand.y as usize;
    let mut rng = XorShift64::new(seed ^ 0xE5E5);

    let bank_stall = |jit: f64| ovh.bank_conflict_frac * (y as f64 - 1.0) * add_cyc * (1.0 + jit);

    let mut groups: Vec<GroupState> = design
        .groups
        .iter()
        .map(|g| GroupState {
            mm_done: vec![0.0; y],
            mm_iter: vec![0; y],
            c_ready: vec![vec![0.0; iters]; y],
            consumed: vec![0.0; iters],
            adder_free: 0.0,
            out_free: 0.0,
            out_times: Vec::with_capacity(iters),
            jitter: rng.jitter(jitter_amp),
            has_dma: g.shape == GroupShape::TShape,
        })
        .collect();

    let mut heap: BinaryHeap<Reverse<QueuedEvent>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut events = 0u64;
    let fp = |t: f64| (t * 16.0) as u64;

    let push = |heap: &mut BinaryHeap<Reverse<QueuedEvent>>, seq: &mut u64, t: f64, ev: Ev| {
        *seq += 1;
        heap.push(Reverse(QueuedEvent {
            time_fp: fp(t),
            seq: *seq,
            ev,
        }));
    };

    // Kick off: every MatMul starts its first iteration after its input
    // streams fill (A and B fill concurrently on separate channels).
    for (gi, g) in groups.iter_mut().enumerate() {
        for k in 0..y {
            let start = a_cyc as f64 + ovh.lock_cycles as f64;
            let dma = if g.has_dma && k == y - 1 { ovh.dma_penalty as f64 } else { 0.0 };
            let done = start + kernel_cyc + dma;
            g.mm_done[k] = done;
            g.c_ready[k][0] = done;
            push(&mut heap, &mut seq, done, Ev::MatMulDone { group: gi, k, iter: 0 });
        }
    }

    while let Some(Reverse(qe)) = heap.pop() {
        events += 1;
        let t = qe.time_fp as f64 / 16.0;
        match qe.ev {
            Ev::MatMulDone { group, k, iter } => {
                let g = &mut groups[group];
                g.mm_iter[k] = iter + 1;
                // Schedule next iteration if any: gated by the C
                // ping-pong (iteration i needs consumed[i-2]).
                let next = iter + 1;
                if next < iters {
                    let c_free = if next >= 2 { g.consumed[next - 2] } else { 0.0 };
                    let stall = bank_stall(g.jitter);
                    let dma = if g.has_dma && k == y - 1 { ovh.dma_penalty as f64 } else { 0.0 };
                    let start = g.mm_done[k].max(c_free) + ovh.lock_cycles as f64;
                    let done = start + kernel_cyc + stall + dma;
                    g.mm_done[k] = done;
                    g.c_ready[k][next] = done;
                    push(&mut heap, &mut seq, done, Ev::MatMulDone { group, k, iter: next });
                }
                // If this completes the set for `iter`, the adder can run.
                if k == y - 1 || g.c_ready.iter().all(|c| c[iter] > 0.0) {
                    let all_ready = g.c_ready.iter().all(|c| c[iter] > 0.0);
                    if all_ready && g.consumed[iter] == 0.0 {
                        // Adder consumes sequentially.
                        let mut ta = g.adder_free.max(g.c_ready[0][iter]);
                        for kk in 1..y {
                            ta = ta.max(g.c_ready[kk][iter]) + add_cyc;
                        }
                        g.consumed[iter] = ta;
                        g.adder_free = ta;
                        push(&mut heap, &mut seq, ta, Ev::AdderDone { group, iter });
                    }
                }
            }
            Ev::AdderDone { group, iter } => {
                let g = &mut groups[group];
                // Output stream (double-buffered; serializes on the PLIO).
                let out_done = t.max(g.out_free) + c_cyc as f64;
                g.out_free = out_done;
                push(&mut heap, &mut seq, out_done, Ev::OutDone { group, iter });
            }
            Ev::OutDone { group, iter } => {
                let g = &mut groups[group];
                debug_assert_eq!(g.out_times.len(), iter);
                g.out_times.push(t);
            }
        }
    }

    // Analyze the slowest group.
    let slowest = groups
        .iter()
        .max_by(|a, b| {
            a.out_times
                .last()
                .partial_cmp(&b.out_times.last())
                .unwrap()
        })
        .unwrap();
    let outs = &slowest.out_times;
    let fill = outs[0];
    let half = outs.len() / 2;
    let period = (outs[outs.len() - 1] - outs[half]) / (outs.len() - 1 - half) as f64;
    let makespan = groups
        .iter()
        .map(|g| *g.out_times.last().unwrap())
        .fold(0.0, f64::max);

    let total_macs = design.cand.matmul_kernels() as f64 * kernel.macs() as f64 * iters as f64;
    let steady_ops = 2.0
        * design.cand.matmul_kernels() as f64
        * kernel.macs() as f64
        / (period / dev.freq_hz);
    EventSimResult {
        period_cycles: period,
        fill_cycles: fill,
        makespan_cycles: makespan,
        ops_per_sec_total: 2.0 * total_macs / (makespan / dev.freq_hz),
        ops_per_sec_steady: steady_ops,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;
    use crate::kernels::matmul::MatMulKernel;
    use crate::optimizer::array::ArrayCandidate;
    use crate::placement::pattern::Pattern;
    use crate::placement::placer::place_design;
    use crate::sim::engine::{simulate_design, SimConfig};

    fn placed(x: u64, y: u64, z: u64, pat: Pattern, prec: Precision) -> PlacedDesign {
        place_design(
            &AieDevice::vc1902(),
            ArrayCandidate::new(x, y, z),
            pat,
            MatMulKernel::paper_kernel(prec),
        )
        .unwrap()
    }

    #[test]
    fn agrees_with_group_pipeline_model() {
        // The event sim and the recurrence model must agree on the
        // steady-state period within 1% for all paper configs.
        let dev = AieDevice::vc1902();
        for (x, y, z, pat) in maxeva_paper_configs() {
            for prec in Precision::all() {
                let pd = placed(x, y, z, pat, prec);
                let fast = simulate_design(&dev, &pd, &SimConfig::default());
                let ev = simulate_events(&dev, &pd, 48, 7, 0.005);
                let delta = (ev.period_cycles - fast.period_cycles).abs() / fast.period_cycles;
                assert!(
                    delta < 0.01,
                    "{x}x{y}x{z} {prec}: event {} vs model {}",
                    ev.period_cycles,
                    fast.period_cycles
                );
            }
        }
    }

    fn maxeva_paper_configs() -> [(u64, u64, u64, Pattern); 3] {
        // A subset for test speed; the full set is covered by the bench.
        [
            (13, 4, 6, Pattern::P1),
            (10, 3, 10, Pattern::P2),
            (12, 4, 6, Pattern::P1),
        ]
    }

    #[test]
    fn fill_is_positive_and_less_than_two_periods() {
        let dev = AieDevice::vc1902();
        let pd = placed(13, 4, 6, Pattern::P1, Precision::Fp32);
        let ev = simulate_events(&dev, &pd, 32, 7, 0.0);
        assert!(ev.fill_cycles > 0.0);
        assert!(ev.fill_cycles < 2.0 * ev.period_cycles, "fill {}", ev.fill_cycles);
    }

    #[test]
    fn total_throughput_below_steady() {
        // Makespan includes fill → total ≤ steady-state throughput.
        let dev = AieDevice::vc1902();
        let pd = placed(10, 3, 10, Pattern::P2, Precision::Int8);
        let ev = simulate_events(&dev, &pd, 32, 3, 0.005);
        assert!(ev.ops_per_sec_total <= ev.ops_per_sec_steady);
        // And converges: with more iterations the gap shrinks.
        let ev2 = simulate_events(&dev, &pd, 96, 3, 0.005);
        let gap1 = 1.0 - ev.ops_per_sec_total / ev.ops_per_sec_steady;
        let gap2 = 1.0 - ev2.ops_per_sec_total / ev2.ops_per_sec_steady;
        assert!(gap2 < gap1);
    }

    #[test]
    fn event_count_scales_linearly() {
        let dev = AieDevice::vc1902();
        let pd = placed(12, 3, 8, Pattern::P2, Precision::Int8);
        let e1 = simulate_events(&dev, &pd, 16, 1, 0.0);
        let e2 = simulate_events(&dev, &pd, 32, 1, 0.0);
        let ratio = e2.events as f64 / e1.events as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn deterministic() {
        let dev = AieDevice::vc1902();
        let pd = placed(11, 4, 7, Pattern::P1, Precision::Fp32);
        let a = simulate_events(&dev, &pd, 32, 5, 0.005);
        let b = simulate_events(&dev, &pd, 32, 5, 0.005);
        assert_eq!(a.period_cycles, b.period_cycles);
        assert_eq!(a.events, b.events);
    }
}
