//! Discrete-event pipeline simulation of one group (Y MatMul cores + one
//! adder-tree core) over many iterations.
//!
//! Dependency structure per iteration `i` (Fig. 5):
//!
//! ```text
//!   PLIO A_k ──fill──▶ A-buf(k) ─┐
//!   PLIO B_k ──fill──▶ B-buf(k) ─┼─▶ MatMul_k ──▶ C-buf(k) ─▶ adder ─▶ out
//!                                 ┘   (kernel_cyc)  (ping-pong)  (Y−1 adds)
//! ```
//!
//! All buffers between distinct cores are double-buffered (ping-pong), so
//! fills/consumes of iteration `i+1` overlap compute of iteration `i`.
//! The adder consumes the Y C-buffers sequentially; each consume interferes
//! with the producer's concurrent write into the other ping-pong half
//! (shared memory banks), stalling the MatMul by `bank_conflict_frac ·
//! add_cyc`. DMA-connected buffers (P1 T-shapes) add a round-trip penalty
//! to their producer.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::kernels::add::AddKernel;
use crate::kernels::matmul::MatMulKernel;

/// Calibrated per-precision overhead constants (DESIGN.md §5).
#[derive(Debug, Clone, Copy)]
pub struct OverheadModel {
    /// Per-iteration lock acquire/release + stream arbitration cost on a
    /// MatMul core (6 lock ops: A, B, C × acquire/release).
    pub lock_cycles: u64,
    /// Fraction of one Add-kernel latency lost by the producing MatMul to
    /// memory-bank conflicts while the adder consumes its buffer.
    pub bank_conflict_frac: f64,
    /// Extra cycles per iteration for a DMA-connected output buffer
    /// (switch round trip + DMA descriptor service, P1 T-shapes).
    pub dma_penalty: u64,
}

impl OverheadModel {
    /// Constants fit on rows 1–2 of Tables II and III (fp32 / int8);
    /// all other table rows are predictions (EXPERIMENTS.md).
    pub fn calibrated(prec: Precision) -> Self {
        match prec {
            Precision::Fp32 => OverheadModel {
                lock_cycles: 64,
                bank_conflict_frac: 0.40,
                dma_penalty: 104,
            },
            Precision::Int8 => OverheadModel {
                lock_cycles: 185,
                bank_conflict_frac: 0.10,
                dma_penalty: 19,
            },
            // Extensions (int16/bf16): interpolated between the two
            // calibrated points by kernel length — estimates, not
            // paper-calibrated (DESIGN.md §7).
            Precision::Int16 => OverheadModel {
                lock_cycles: 130,
                bank_conflict_frac: 0.22,
                dma_penalty: 55,
            },
            Precision::Bf16 => OverheadModel {
                lock_cycles: 95,
                bank_conflict_frac: 0.32,
                dma_penalty: 80,
            },
        }
    }
}

/// Result of simulating one group.
#[derive(Debug, Clone, Copy)]
pub struct GroupSim {
    /// Steady-state iteration period in cycles.
    pub period_cycles: f64,
    /// Fraction of the period the adder core is busy (for the power model).
    pub adder_duty: f64,
    /// Fraction of the period each MatMul core is computing.
    pub matmul_duty: f64,
}

/// Simulate one group for `iters` iterations and measure the steady-state
/// period. `has_dma` marks T-shape groups; `stall_jitter` is a seeded
/// relative perturbation modelling PnR buffer-placement dissimilarities
/// (the paper's "<1% memory conflicts", §V-B3).
pub fn simulate_group(
    dev: &AieDevice,
    kernel: MatMulKernel,
    y: u64,
    has_dma: bool,
    ovh: &OverheadModel,
    iters: usize,
    stall_jitter: f64,
) -> GroupSim {
    assert!(iters >= 16, "need warmup + measurement window");
    let add = AddKernel::new(kernel.m, kernel.n, kernel.prec);
    let add_cyc = add.latency_cycles();
    let (a_cyc, b_cyc, c_cyc) = kernel.io_cycles(dev);
    let kernel_cyc = kernel.latency_cycles();
    let y = y as usize;

    // Per-MatMul state: completion time of each iteration.
    let mut mm_done = vec![0.0f64; y]; // done time of previous iteration
    let mut c_ready = vec![vec![0.0f64; iters]; y];
    // PLIO fills: the k-th MatMul's A/B stream can prefill one iteration
    // ahead (double buffer). fill_done[k] = time its stream finished the
    // current fill.
    let mut a_fill_done = vec![0.0f64; y];
    let mut b_fill_done = vec![0.0f64; y];
    // Adder: time it finished consuming C(k) of each iteration.
    let mut consumed = vec![vec![0.0f64; iters]; y];
    let mut adder_free = 0.0f64;
    let mut out_stream_free = 0.0f64;

    let mut period_samples = Vec::new();
    let mut last_out = 0.0f64;
    let mut adder_busy_acc = 0.0f64;

    // The adder performs (Y−1) sequential adds per iteration over buffers
    // co-located (shared modules) with the MatMul write targets; the
    // producer-side stall scales with total adder memory activity.
    let bank_stall =
        ovh.bank_conflict_frac * ((y - 1) as f64) * add_cyc as f64 * (1.0 + stall_jitter);
    let dma_extra = if has_dma { ovh.dma_penalty as f64 } else { 0.0 };

    for i in 0..iters {
        // --- MatMul cores ---
        for k in 0..y {
            // Input fills (streams run ahead, gated by ping-pong reuse:
            // the buffer of iteration i-2 must have been consumed by the
            // kernel, i.e. the kernel started iteration i-1).
            let gate = if i >= 2 { mm_done[k] - kernel_cyc as f64 } else { 0.0 };
            a_fill_done[k] = (a_fill_done[k]).max(gate) + a_cyc as f64;
            b_fill_done[k] = (b_fill_done[k]).max(gate) + b_cyc as f64;
            // C ping-pong: slot of iteration i is free once the adder
            // consumed iteration i-2.
            let c_free = if i >= 2 { consumed[k][i - 2] } else { 0.0 };
            let start = mm_done[k]
                .max(a_fill_done[k])
                .max(b_fill_done[k])
                .max(c_free)
                + ovh.lock_cycles as f64;
            // Bank-conflict interference: while the adder consumed the
            // other ping-pong half (previous iteration), the concurrent
            // write stalls the kernel; DMA buffers pay the round trip.
            let stall = if i >= 1 { bank_stall } else { 0.0 };
            let done = start + kernel_cyc as f64 + stall
                + if k == y - 1 { dma_extra } else { 0.0 };
            mm_done[k] = done;
            c_ready[k][i] = done;
        }

        // --- Adder core: consumes C(0..Y) sequentially, Y−1 adds ---
        let mut t = adder_free.max(c_ready[0][i]);
        consumed[0][i] = t;
        for k in 1..y {
            t = t.max(c_ready[k][i]) + add_cyc as f64;
            consumed[k][i] = t;
        }
        let adds_done = t;
        adder_busy_acc = (y as f64 - 1.0) * add_cyc as f64;
        // Output write to PLIO (double-buffered: overlaps next iteration,
        // but the out stream itself serializes).
        let out_done = adds_done.max(out_stream_free) + 0.0;
        out_stream_free = out_done + c_cyc as f64;
        adder_free = adds_done;

        if i >= iters / 2 {
            period_samples.push(adds_done - last_out);
        }
        last_out = adds_done;
    }

    let period = crate::util::stats::mean(&period_samples);
    GroupSim {
        period_cycles: period,
        adder_duty: (adder_busy_acc / period).min(1.0),
        matmul_duty: (kernel_cyc as f64 / period).min(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::device::AieDevice;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    fn run(prec: Precision, y: u64, dma: bool) -> GroupSim {
        let k = MatMulKernel::paper_kernel(prec);
        simulate_group(&dev(), k, y, dma, &OverheadModel::calibrated(prec), 64, 0.0)
    }

    #[test]
    fn fp32_period_near_table2_row1() {
        // Table II row 1 implies a per-kernel period of ~4697 cycles
        // (312 kernels, 5442.11 GFLOPs @1.25GHz). Calibration targets ±1%.
        let g = run(Precision::Fp32, 4, true);
        assert!(
            (g.period_cycles - 4697.0).abs() / 4697.0 < 0.01,
            "period {}",
            g.period_cycles
        );
    }

    #[test]
    fn int8_period_near_table3_row1() {
        // Table III row 1 implies ~1327.6 cycles.
        let g = run(Precision::Int8, 4, true);
        assert!(
            (g.period_cycles - 1327.6).abs() / 1327.6 < 0.01,
            "period {}",
            g.period_cycles
        );
    }

    #[test]
    fn y3_faster_than_y4() {
        // Less adder interference with a shallower tree (drives the P2
        // per-kernel advantage of Tables II/III).
        for p in Precision::all() {
            let g3 = run(p, 3, false);
            let g4 = run(p, 4, false);
            assert!(g3.period_cycles < g4.period_cycles, "{p}");
        }
    }

    #[test]
    fn dma_slows_group() {
        for p in Precision::all() {
            let clean = run(p, 4, false);
            let t = run(p, 4, true);
            assert!(t.period_cycles > clean.period_cycles, "{p}");
        }
    }

    #[test]
    fn adder_duty_matches_table1_ratio_ordering() {
        // fp32 adder idles much more than int8 (Table I: 0.04× vs 0.15×
        // relative latency) — duty must reflect that.
        let g8 = run(Precision::Int8, 4, false);
        let g32 = run(Precision::Fp32, 4, false);
        assert!(g8.adder_duty > 2.0 * g32.adder_duty);
    }

    #[test]
    fn period_at_least_kernel_latency() {
        for p in Precision::all() {
            let k = MatMulKernel::paper_kernel(p);
            let g = run(p, 4, true);
            assert!(g.period_cycles >= k.latency_cycles() as f64);
        }
    }

    #[test]
    fn jitter_changes_period_slightly() {
        let k = MatMulKernel::paper_kernel(Precision::Int8);
        let m = OverheadModel::calibrated(Precision::Int8);
        let base = simulate_group(&dev(), k, 4, false, &m, 64, 0.0).period_cycles;
        let j = simulate_group(&dev(), k, 4, false, &m, 64, 0.005).period_cycles;
        assert!((base - j).abs() / base < 0.01);
        assert_ne!(base, j);
    }
}
