//! Design-level simulation: runs the group pipeline for every group of a
//! placed design (clean and T-shape variants), applies seeded per-group
//! PnR jitter, and aggregates array throughput the way the paper measures
//! it (total work over the completion time of the slowest group).

use crate::arch::device::AieDevice;
use crate::placement::group::GroupShape;
use crate::placement::placer::PlacedDesign;
use crate::sim::group_pipeline::{simulate_group, GroupSim, OverheadModel};
use crate::util::prng::XorShift64;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Iterations simulated per group (warmup is the first half).
    pub iters: usize,
    /// Seed for the PnR buffer-placement jitter.
    pub seed: u64,
    /// Amplitude of the per-group jitter (paper §V-B3 reports <1% effects;
    /// default 0.5%).
    pub jitter_amp: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            iters: 64,
            seed: 0x4D41_5845_5641, // "MAXEVA"
            jitter_amp: 0.005,
        }
    }
}

/// Aggregated simulation result for one design (one row of Table II/III).
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Steady-state iteration period of the slowest group, in cycles.
    pub period_cycles: f64,
    /// Throughput in ops/s (2 ops per MAC).
    pub ops_per_sec: f64,
    /// Array-level efficiency vs device peak [0, 1].
    pub efficiency: f64,
    /// Adder-core busy fraction (input to the power model).
    pub adder_duty: f64,
    /// MatMul-core busy fraction.
    pub matmul_duty: f64,
    /// Per-group periods (diagnostics; length = number of groups).
    pub group_periods: Vec<f64>,
}

/// Simulate a placed design.
///
/// §Perf: groups only differ in (a) T-shape vs clean and (b) the seeded
/// jitter, and jitter enters the steady-state period *additively*
/// (`Δperiod = frac·(Y−1)·add_cyc·jit` — verified by
/// `fast_path_matches_full_sim`). So only the two archetype pipelines are
/// simulated and per-group periods are reconstructed analytically —
/// ~40× fewer pipeline simulations than the naive per-group loop.
pub fn simulate_design(dev: &AieDevice, design: &PlacedDesign, cfg: &SimConfig) -> SimResult {
    let ovh = OverheadModel::calibrated(design.kernel.prec);
    let mut rng = XorShift64::new(cfg.seed ^ design.cand.matmul_kernels());
    let y = design.cand.y;

    // Archetype pipelines at zero jitter.
    let base_clean = simulate_group(dev, design.kernel, y, false, &ovh, cfg.iters, 0.0);
    let has_t = design.groups.iter().any(|g| g.shape == GroupShape::TShape);
    let base_t = if has_t {
        simulate_group(dev, design.kernel, y, true, &ovh, cfg.iters, 0.0)
    } else {
        base_clean
    };
    // Jitter sensitivity: d(period)/d(jit) of the bank-conflict stall.
    let add_cyc =
        crate::kernels::add::AddKernel::new(design.kernel.m, design.kernel.n, design.kernel.prec)
            .latency_cycles() as f64;
    let stall_slope = ovh.bank_conflict_frac * (y as f64 - 1.0) * add_cyc;

    let mut periods = Vec::with_capacity(design.groups.len());
    let mut slowest: Option<GroupSim> = None;
    let mut duty_acc = (0.0, 0.0);
    for g in &design.groups {
        let jitter = rng.jitter(cfg.jitter_amp);
        let base = if g.shape == GroupShape::TShape { base_t } else { base_clean };
        let period = base.period_cycles + stall_slope * jitter;
        let gs = GroupSim {
            period_cycles: period,
            adder_duty: (y as f64 - 1.0) * add_cyc / period,
            matmul_duty: design.kernel.latency_cycles() as f64 / period,
        };
        periods.push(gs.period_cycles);
        duty_acc.0 += gs.adder_duty;
        duty_acc.1 += gs.matmul_duty;
        if slowest.map_or(true, |s| gs.period_cycles > s.period_cycles) {
            slowest = Some(gs);
        }
    }
    let slowest = slowest.expect("design has no groups");

    // The paper measures aggregate throughput over a fixed workload: all
    // groups iterate the same number of times, so completion is gated by
    // the slowest group (T-shapes in P1).
    let period = slowest.period_cycles;
    let macs_per_iter = design.cand.matmul_kernels() as f64 * design.kernel.macs() as f64;
    let ops_per_sec = 2.0 * macs_per_iter / (period / dev.freq_hz);
    let n = design.groups.len() as f64;
    SimResult {
        period_cycles: period,
        ops_per_sec,
        efficiency: ops_per_sec / dev.peak_ops_per_sec(design.kernel.prec),
        adder_duty: duty_acc.0 / n,
        matmul_duty: duty_acc.1 / n,
        group_periods: periods,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;
    use crate::kernels::matmul::MatMulKernel;
    use crate::optimizer::array::ArrayCandidate;
    use crate::placement::pattern::Pattern;
    use crate::placement::placer::place_design;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    fn sim(x: u64, y: u64, z: u64, pat: Pattern, prec: Precision) -> SimResult {
        let d = dev();
        let pd = place_design(
            &d,
            ArrayCandidate::new(x, y, z),
            pat,
            MatMulKernel::paper_kernel(prec),
        )
        .unwrap();
        simulate_design(&d, &pd, &SimConfig::default())
    }

    #[test]
    fn table2_row1_fp32_throughput() {
        // Paper: 13×4×6 (P1) fp32 → 5442.11 GFLOPs. Model target ±1.5%.
        let r = sim(13, 4, 6, Pattern::P1, Precision::Fp32);
        let gflops = r.ops_per_sec / 1e9;
        assert!(
            (gflops - 5442.11).abs() / 5442.11 < 0.015,
            "measured {gflops:.2} GFLOPs"
        );
    }

    #[test]
    fn table3_row1_int8_throughput() {
        // Paper: 13×4×6 (P1) int8 → 77.01 TOPs. Model target ±1.5%.
        let r = sim(13, 4, 6, Pattern::P1, Precision::Int8);
        let tops = r.ops_per_sec / 1e12;
        assert!(
            (tops - 77.01).abs() / 77.01 < 0.015,
            "measured {tops:.2} TOPs"
        );
    }

    #[test]
    fn predicted_rows_within_1_5_percent() {
        // Rows 2–6 of both tables are *predictions* of the calibrated
        // model (only rows 1–2 were used for fitting).
        let cases: &[(u64, u64, u64, Pattern, Precision, f64)] = &[
            (10, 3, 10, Pattern::P2, Precision::Fp32, 5405.33),
            (11, 4, 7, Pattern::P1, Precision::Fp32, 5414.39),
            (11, 3, 9, Pattern::P2, Precision::Fp32, 5382.27),
            (12, 4, 6, Pattern::P1, Precision::Fp32, 5031.19),
            (12, 3, 8, Pattern::P2, Precision::Fp32, 5225.05),
            (10, 3, 10, Pattern::P2, Precision::Int8, 76080.0),
            (11, 4, 7, Pattern::P1, Precision::Int8, 75670.0),
            (11, 3, 9, Pattern::P2, Precision::Int8, 74660.0),
            (12, 4, 6, Pattern::P1, Precision::Int8, 71250.0),
            (12, 3, 8, Pattern::P2, Precision::Int8, 72930.0),
        ];
        for &(x, y, z, pat, prec, paper_gops) in cases {
            let r = sim(x, y, z, pat, prec);
            let gops = r.ops_per_sec / 1e9;
            let err = (gops - paper_gops).abs() / paper_gops;
            assert!(
                err < 0.015,
                "{x}x{y}x{z} {prec}: measured {gops:.1} vs paper {paper_gops:.1} ({:.2}%)",
                err * 100.0
            );
        }
    }

    #[test]
    fn p2_beats_p1_at_equal_kernels() {
        // Paper §V-B3 ablation: at 288 kernels, P2 (no DMA) outperforms P1.
        for prec in Precision::all() {
            let p1 = sim(12, 4, 6, Pattern::P1, prec);
            let p2 = sim(12, 3, 8, Pattern::P2, prec);
            assert!(p2.ops_per_sec > p1.ops_per_sec, "{prec}");
        }
    }

    #[test]
    fn throughput_increases_with_kernels_within_pattern() {
        let a = sim(12, 4, 6, Pattern::P1, Precision::Int8); // 288
        let b = sim(13, 4, 6, Pattern::P1, Precision::Int8); // 312
        assert!(b.ops_per_sec > a.ops_per_sec);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sim(13, 4, 6, Pattern::P1, Precision::Fp32);
        let b = sim(13, 4, 6, Pattern::P1, Precision::Fp32);
        assert_eq!(a.ops_per_sec, b.ops_per_sec);
    }

    #[test]
    fn fast_path_matches_full_sim() {
        // §Perf validity: the analytic jitter reconstruction must equal a
        // full per-group pipeline simulation.
        let d = dev();
        for prec in Precision::all() {
            for (x, y, z, pat) in [(13u64, 4u64, 6u64, Pattern::P1), (10, 3, 10, Pattern::P2)] {
                let pd = place_design(&d, ArrayCandidate::new(x, y, z), pat,
                    MatMulKernel::paper_kernel(prec)).unwrap();
                let cfg = SimConfig::default();
                let fast = simulate_design(&d, &pd, &cfg);
                // Reference: explicit per-group sims with the same seeds.
                let ovh = crate::sim::group_pipeline::OverheadModel::calibrated(prec);
                let mut rng = crate::util::prng::XorShift64::new(
                    cfg.seed ^ pd.cand.matmul_kernels(),
                );
                let mut worst: f64 = 0.0;
                for g in &pd.groups {
                    let jit = rng.jitter(cfg.jitter_amp);
                    let gs = crate::sim::group_pipeline::simulate_group(
                        &d, pd.kernel, y,
                        g.shape == crate::placement::group::GroupShape::TShape,
                        &ovh, cfg.iters, jit,
                    );
                    worst = worst.max(gs.period_cycles);
                }
                let delta = (fast.period_cycles - worst).abs() / worst;
                assert!(delta < 1e-3, "{x}x{y}x{z} {prec}: {delta}");
            }
        }
    }

    #[test]
    fn efficiency_below_single_kernel_bound() {
        // Array efficiency can't exceed the single-kernel efficiency.
        let r = sim(13, 4, 6, Pattern::P1, Precision::Int8);
        let k = MatMulKernel::paper_kernel(Precision::Int8);
        // Efficiency is vs whole-device peak: scale by utilization.
        let used_frac = 312.0 / 400.0;
        assert!(r.efficiency <= k.efficiency() * used_frac);
        assert!(r.efficiency > 0.5 * used_frac);
    }
}
