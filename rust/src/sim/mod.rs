//! Event-driven, cycle-approximate simulation of a placed MaxEVA design —
//! the stand-in for the AMD aiesimulator used in the paper's evaluation.
//!
//! The simulator models, per group and per iteration: the ping-pong
//! double buffers between PLIO streams and MatMul kernels and between
//! MatMul kernels and the adder core; PLIO stream transfer times
//! (4 B/cycle); lock acquire/release and stream-arbitration overheads;
//! write-back interference between the adder's sequential buffer
//! consumption and the producing MatMuls (shared memory banks); and the
//! extra round-trip latency of DMA-connected buffers in P1 T-shapes.
//!
//! The three overhead constants (per precision) are calibrated on ONE row
//! of each of Tables II and III and then *predict* the remaining ten rows
//! within ~1% (see DESIGN.md §5 and EXPERIMENTS.md).

pub mod engine;
pub mod event;
pub mod group_pipeline;

pub use engine::{simulate_design, SimConfig, SimResult};
