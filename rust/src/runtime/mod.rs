//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! HLO **text** is the interchange format (not serialized protos —
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids). See /opt/xla-example and
//! DESIGN.md §6.
//!
//! PJRT handles are not `Send` (raw pointers), so the coordinator owns
//! dedicated *device threads* that construct the [`Runtime`], load
//! executables and serve tile jobs over channels
//! (see [`crate::coordinator`]).
//!
//! # Feature gating
//!
//! The `xla` crate needs the `xla_extension` C++ bundle, which is not
//! available in every build environment. The PJRT path is therefore
//! gated behind the **`pjrt`** cargo feature; without it this module
//! keeps the same public API but every constructor returns an error, and
//! the serving stack falls back to the pure-Rust reference backend in
//! [`crate::coordinator::device`] (numerically equivalent, slower).

use anyhow::Result;
use std::path::{Path, PathBuf};

#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;
#[cfg(feature = "pjrt")]
use anyhow::Context;

/// Artifact naming scheme shared with `python/compile/aot.py`.
pub fn artifact_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.hlo.txt"))
}

/// The PJRT CPU runtime: client + loaded executables.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

/// One compiled artifact.
pub struct Executable {
    pub name: String,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Construct a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    /// Load a named artifact from a directory.
    pub fn load_named(&self, dir: &Path, name: &str) -> Result<Executable> {
        self.load(&artifact_path(dir, name))
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with f32 inputs, returning the f32 elements of the single
    /// (1-tuple) output. `inputs` are (data, dims) pairs.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .context("reshaping f32 input")
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with i32 inputs (the int8 artifacts accept int32 operands
    /// and cast internally — the `xla` crate has no i8 literal
    /// constructor), returning i32 output elements.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .context("reshaping i32 input")
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<i32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Without the `pjrt` feature there is no PJRT client to construct.
    pub fn cpu() -> Result<Self> {
        Err(anyhow!(
            "maxeva was built without the `pjrt` feature — to enable it, \
             uncomment the `xla` git dependency in rust/Cargo.toml, change \
             the feature to `pjrt = [\"dep:xla\"]`, and rebuild with \
             `--features pjrt` (needs the xla_extension C++ bundle); or use \
             the reference backend (BackendKind::Reference / Auto)"
        ))
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".into()
    }

    /// Loading artifacts requires the PJRT compiler.
    pub fn load(&self, _path: &Path) -> Result<Executable> {
        Err(anyhow!("built without the `pjrt` feature"))
    }

    /// Load a named artifact from a directory.
    pub fn load_named(&self, dir: &Path, name: &str) -> Result<Executable> {
        self.load(&artifact_path(dir, name))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Unreachable without `pjrt` ([`Runtime::load`] never constructs one).
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        Err(anyhow!("built without the `pjrt` feature"))
    }

    /// Unreachable without `pjrt` ([`Runtime::load`] never constructs one).
    pub fn run_i32(&self, _inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        Err(anyhow!("built without the `pjrt` feature"))
    }
}

/// True when the PJRT path was compiled in.
pub const fn pjrt_compiled() -> bool {
    cfg!(feature = "pjrt")
}

/// True if the standard artifact set exists in `dir` (used by tests and
/// examples to skip gracefully before `make artifacts` has run).
pub fn artifacts_available(dir: &Path) -> bool {
    artifact_path(dir, "array_fp32_13x4x6").exists()
}

/// True if a specific named artifact — or its panel-scheduled `_fast`
/// variant — exists in `dir`. The device pool uses this to decide
/// whether the optional int8 executable can be loaded.
pub fn named_artifact_available(dir: &Path, name: &str) -> bool {
    artifact_path(dir, name).exists() || artifact_path(dir, &format!("{name}_fast")).exists()
}

/// The default artifacts directory: `$MAXEVA_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("MAXEVA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_path_naming() {
        let p = artifact_path(Path::new("artifacts"), "array_fp32_13x4x6");
        assert_eq!(p, PathBuf::from("artifacts/array_fp32_13x4x6.hlo.txt"));
    }

    #[test]
    fn default_dir_env_override() {
        // NOTE: relies on MAXEVA_ARTIFACTS being unset in the test env.
        let d = default_artifacts_dir();
        assert!(
            d == PathBuf::from("artifacts")
                || d.is_absolute()
                || d.exists()
                || !d.as_os_str().is_empty()
        );
    }

    #[test]
    fn named_artifact_availability_checks_fast_variant() {
        let dir = std::env::temp_dir().join("maxeva_named_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!named_artifact_available(&dir, "array_int8_13x4x6"));
        let p = artifact_path(&dir, "array_int8_13x4x6_fast");
        std::fs::write(&p, "HloModule stub").unwrap();
        assert!(named_artifact_available(&dir, "array_int8_13x4x6"));
        std::fs::remove_file(&p).unwrap();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::cpu().err().expect("stub must refuse to construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
        assert!(!pjrt_compiled());
    }

    // Execution-path tests live in rust/tests/runtime_artifacts.rs (they
    // need the artifacts built by `make artifacts`).
}
