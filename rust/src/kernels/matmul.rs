//! The single-AIE MatMul kernel model.
//!
//! One MatMul kernel computes `C (M×N) += A (M×K) · B (K×N)` on one AIE
//! core using the SIMD vector datapath. The paper's kernels are written in
//! C/C++ with AIE APIs + pragmas (software pipelining, loop
//! unrolling/flattening); the resulting latency is very close to the
//! roofline `M·K·N / peak_MACs` plus a small pipeline overhead.
//!
//! Calibration (DESIGN.md §5): `latency = ideal · (1 + ovh_ratio)` with
//! `ovh_ratio` fit on Table I — int8 32×128×32 measures 1075 cycles
//! (ideal 1024 → 4.98%), fp32 32×32×32 measures 4329 (ideal 4096 → 5.69%).
//! The fp32 kernel is CHARM's intrinsics kernel (the paper reuses it for a
//! fair comparison), which explains the slightly different pipeline
//! overhead versus the paper's own int8 kernel.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;

/// Pipeline overhead ratio fit on Table I (see module docs).
pub fn overhead_ratio(prec: Precision) -> f64 {
    match prec {
        Precision::Int8 => 1075.0 / 1024.0 - 1.0, // 4.98%
        Precision::Fp32 => 4329.0 / 4096.0 - 1.0, // 5.69%
        // Extensions: no Table-I measurement exists; use the midpoint of
        // the two measured overheads (engineering estimate).
        Precision::Int16 | Precision::Bf16 => 0.0533,
    }
}

/// A single-AIE MatMul kernel of tile size `M×K×N`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatMulKernel {
    pub m: u64,
    pub k: u64,
    pub n: u64,
    pub prec: Precision,
}

impl MatMulKernel {
    pub fn new(m: u64, k: u64, n: u64, prec: Precision) -> Self {
        MatMulKernel { m, k, n, prec }
    }

    /// The paper's two demonstrated kernels (Table I).
    pub fn paper_kernel(prec: Precision) -> Self {
        match prec {
            Precision::Int8 => MatMulKernel::new(32, 128, 32, prec),
            Precision::Fp32 => MatMulKernel::new(32, 32, 32, prec),
            // Extension winners of the same IP (eq. 3-6): 65536 MACs.
            Precision::Int16 | Precision::Bf16 => MatMulKernel::new(32, 64, 32, prec),
        }
    }

    /// Number of multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }

    /// Ideal (roofline) latency in cycles: `MACs / peak_MACs`.
    pub fn ideal_cycles(&self) -> u64 {
        self.macs().div_ceil(self.prec.peak_macs_per_cycle())
    }

    /// Modelled kernel latency in cycles (calibrated, see module docs).
    pub fn latency_cycles(&self) -> u64 {
        let ideal = self.ideal_cycles() as f64;
        (ideal * (1.0 + overhead_ratio(self.prec))).round() as u64
    }

    /// Achieved throughput in MACs/cycle.
    pub fn throughput_macs_per_cycle(&self) -> f64 {
        self.macs() as f64 / self.latency_cycles() as f64
    }

    /// Efficiency: achieved / peak throughput of the vector processor
    /// (paper eq. (1) definition).
    pub fn efficiency(&self) -> f64 {
        self.throughput_macs_per_cycle() / self.prec.peak_macs_per_cycle() as f64
    }

    /// Bytes of the `A` input tile.
    pub fn a_bytes(&self) -> u64 {
        self.m * self.k * self.prec.sizeof_input()
    }

    /// Bytes of the `B` input tile.
    pub fn b_bytes(&self) -> u64 {
        self.k * self.n * self.prec.sizeof_input()
    }

    /// Bytes of the `C` output tile (int8 accumulates to int32).
    pub fn c_bytes(&self) -> u64 {
        self.m * self.n * self.prec.sizeof_output()
    }

    /// Single-buffered memory footprint (eq. 6 left-hand side).
    pub fn buffer_bytes(&self) -> u64 {
        self.a_bytes() + self.b_bytes() + self.c_bytes()
    }

    /// PLIO/stream transmission cycles for A / B / C at `bw` bytes/cycle
    /// (eq. 2). Returns `(a_cyc, b_cyc, c_cyc)`.
    pub fn io_cycles(&self, dev: &AieDevice) -> (u64, u64, u64) {
        let bw = dev.bw_io_bytes_per_cycle;
        (
            self.a_bytes().div_ceil(bw),
            self.b_bytes().div_ceil(bw),
            self.c_bytes().div_ceil(bw),
        )
    }

    /// True if no single I/O transfer is longer than the compute latency
    /// (eq. 2) — the kernel is not I/O-bound under double buffering.
    pub fn io_feasible(&self, dev: &AieDevice) -> bool {
        let (a, b, c) = self.io_cycles(dev);
        let lat = self.latency_cycles();
        a <= lat && b <= lat && c <= lat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_int8_kernel() {
        // Paper Table I: int8 32×128×32 → 1075 cyc, 121.93 MACs/cyc, 95.26%.
        let k = MatMulKernel::paper_kernel(Precision::Int8);
        assert_eq!(k.macs(), 131072);
        assert_eq!(k.latency_cycles(), 1075);
        assert!((k.throughput_macs_per_cycle() - 121.93).abs() < 0.05);
        assert!((k.efficiency() - 0.9526).abs() < 0.001);
    }

    #[test]
    fn table1_fp32_kernel() {
        // Paper Table I: fp32 32×32×32 → 4329 cyc, 7.57 MACs/cyc, 94.70%.
        let k = MatMulKernel::paper_kernel(Precision::Fp32);
        assert_eq!(k.macs(), 32768);
        assert_eq!(k.latency_cycles(), 4329);
        assert!((k.throughput_macs_per_cycle() - 7.57).abs() < 0.01);
        assert!((k.efficiency() - 0.9470).abs() < 0.001);
    }

    #[test]
    fn io_cycles_eq2() {
        let d = AieDevice::vc1902();
        let k = MatMulKernel::paper_kernel(Precision::Int8);
        // a: 32·128·1/4 = 1024; b: 128·32·1/4 = 1024; c: 32·32·4/4 = 1024.
        assert_eq!(k.io_cycles(&d), (1024, 1024, 1024));
        assert!(k.io_feasible(&d));

        let f = MatMulKernel::paper_kernel(Precision::Fp32);
        // a: 32·32·4/4 = 1024 etc.
        assert_eq!(f.io_cycles(&d), (1024, 1024, 1024));
        assert!(f.io_feasible(&d));
    }

    #[test]
    fn buffer_bytes_fit_eq6() {
        let d = AieDevice::vc1902();
        // Both paper kernels fit the 14KB single-buffer budget.
        for p in Precision::all() {
            let k = MatMulKernel::paper_kernel(p);
            assert!(k.buffer_bytes() <= d.single_buffer_budget_bytes());
        }
        // int8 32×128×32 uses exactly 12 KB.
        assert_eq!(
            MatMulKernel::paper_kernel(Precision::Int8).buffer_bytes(),
            12 * 1024
        );
        // fp32 32×32×32 uses exactly 12 KB.
        assert_eq!(
            MatMulKernel::paper_kernel(Precision::Fp32).buffer_bytes(),
            12 * 1024
        );
    }

    #[test]
    fn io_infeasible_when_k_too_small() {
        // A skinny kernel (tiny M·K·N but large transfers relative to
        // compute) becomes I/O-bound: e.g. int8 4×4×4 has latency ~1 cyc
        // but c transfer 16 cyc.
        let d = AieDevice::vc1902();
        let k = MatMulKernel::new(4, 4, 4, Precision::Int8);
        assert!(!k.io_feasible(&d));
    }

    #[test]
    fn efficiency_monotone_in_reuse() {
        // Larger tiles (more reuse) never lower modelled efficiency.
        let small = MatMulKernel::new(8, 8, 8, Precision::Fp32);
        let big = MatMulKernel::new(32, 32, 32, Precision::Fp32);
        assert!(big.efficiency() >= small.efficiency() - 1e-9);
    }
}
