//! Single-AIE kernel performance models (paper §V-A, Table I).
//!
//! The paper measures two kernel families with the AMD aiesimulator:
//! the `M×K×N` MatMul kernel (one per AIE core) and the `M×N` Add kernel
//! (a whole `Y−1`-adder tree runs sequentially on one core). We model
//! their latency with a calibrated VLIW pipeline model — the calibration
//! constants (one overhead ratio per kernel family and precision) are fit
//! on Table I and documented in DESIGN.md §5.

pub mod add;
pub mod matmul;

pub use add::AddKernel;
pub use matmul::MatMulKernel;
