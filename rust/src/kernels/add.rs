//! The Add (reduction) kernel model.
//!
//! One Add kernel computes `S (M×N) = P (M×N) + Q (M×N)` elementwise. A
//! whole adder tree of `Y−1` Add kernels runs *sequentially* on a single
//! AIE core (paper §IV-B, Fig. 5): only single buffers are needed between
//! the adds, halving memory versus spreading the tree over cores, and the
//! tree latency stays far below the MatMul latency so it never becomes the
//! bottleneck.
//!
//! Calibration: the paper measures (Table I) 164 cycles for a 32×32 int32
//! add and 167 for fp32 — efficiencies 78.05% / 76.65% against the 8-lane
//! fp32-equivalent peak. We model `latency = elems / (8 · eff_add)` with
//! `eff_add` fit per precision.

use crate::arch::precision::Precision;

/// Vector lanes used by the paper's efficiency accounting for Add kernels
/// (both precisions evaluated against an 8-lane peak in Table I).
const ADD_PEAK_LANES: f64 = 8.0;

/// Calibrated Add-kernel efficiency (Table I).
pub fn add_efficiency(prec: Precision) -> f64 {
    match prec {
        Precision::Int8 => 0.7805, // int32 accumulator adds
        Precision::Fp32 => 0.7665,
        // Extensions: midpoint estimate (accumulators are 32-bit either way).
        Precision::Int16 | Precision::Bf16 => 0.7735,
    }
}

/// A single Add kernel over an `M×N` tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AddKernel {
    pub m: u64,
    pub n: u64,
    /// Precision of the *design*; int8 designs reduce int32 partials.
    pub prec: Precision,
}

impl AddKernel {
    pub fn new(m: u64, n: u64, prec: Precision) -> Self {
        AddKernel { m, n, prec }
    }

    /// Elements reduced per invocation.
    pub fn elems(&self) -> u64 {
        self.m * self.n
    }

    /// Modelled latency in cycles of one Add kernel invocation.
    pub fn latency_cycles(&self) -> u64 {
        (self.elems() as f64 / (ADD_PEAK_LANES * add_efficiency(self.prec))).round() as u64
    }

    /// Achieved ops (adds) per cycle.
    pub fn throughput_ops_per_cycle(&self) -> f64 {
        self.elems() as f64 / self.latency_cycles() as f64
    }

    /// Efficiency against the 8-lane peak (paper Table I definition).
    pub fn efficiency(&self) -> f64 {
        self.throughput_ops_per_cycle() / ADD_PEAK_LANES
    }

    /// Latency of the whole sequential adder tree reducing `y` partial
    /// tiles (`y − 1` adds on one core).
    pub fn tree_latency_cycles(&self, y: u64) -> u64 {
        assert!(y >= 1);
        (y - 1) * self.latency_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_add_int32() {
        // Paper Table I: Add int32 32×32 → 164 cyc, 6.24 ops/cyc, 78.05%.
        let a = AddKernel::new(32, 32, Precision::Int8);
        assert_eq!(a.latency_cycles(), 164);
        assert!((a.throughput_ops_per_cycle() - 6.24).abs() < 0.01);
        assert!((a.efficiency() - 0.7805).abs() < 0.001);
    }

    #[test]
    fn table1_add_fp32() {
        // Paper Table I: Add fp32 32×32 → 167 cyc, 6.13 ops/cyc, 76.65%.
        let a = AddKernel::new(32, 32, Precision::Fp32);
        assert_eq!(a.latency_cycles(), 167);
        assert!((a.throughput_ops_per_cycle() - 6.13).abs() < 0.01);
        assert!((a.efficiency() - 0.7665).abs() < 0.002);
    }

    #[test]
    fn tree_is_much_faster_than_matmul() {
        // Paper §IV-B claim: whole adder tree latency < MatMul latency,
        // for both precisions and Y ∈ {3, 4}.
        use crate::kernels::matmul::MatMulKernel;
        for p in Precision::all() {
            let mm = MatMulKernel::paper_kernel(p);
            let add = AddKernel::new(mm.m, mm.n, p);
            for y in [3, 4] {
                assert!(
                    add.tree_latency_cycles(y) < mm.latency_cycles(),
                    "adder tree must not bottleneck ({p}, Y={y})"
                );
            }
        }
    }

    #[test]
    fn relative_latency_ratios_match_table1() {
        // Paper: Add/MatMul latency ratio 0.15× (int8), 0.04× (fp32) —
        // the fp32 adder core idles much longer (power implications §V-B).
        use crate::kernels::matmul::MatMulKernel;
        let r8 = AddKernel::new(32, 32, Precision::Int8).latency_cycles() as f64
            / MatMulKernel::paper_kernel(Precision::Int8).latency_cycles() as f64;
        let r32 = AddKernel::new(32, 32, Precision::Fp32).latency_cycles() as f64
            / MatMulKernel::paper_kernel(Precision::Fp32).latency_cycles() as f64;
        assert!((r8 - 0.15).abs() < 0.01);
        assert!((r32 - 0.04).abs() < 0.005);
    }

    #[test]
    fn tree_latency_scales_linearly() {
        let a = AddKernel::new(32, 32, Precision::Fp32);
        assert_eq!(a.tree_latency_cycles(1), 0);
        assert_eq!(a.tree_latency_cycles(4), 3 * a.latency_cycles());
    }
}
