//! Minimal, dependency-free JSON parser and writer.
//!
//! The offline crate set has no `serde` facade, so the config system uses
//! this hand-rolled implementation. Supports the full JSON grammar except
//! `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{1}' at byte {0}")]
    Unexpected(usize, char),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid escape '\\{1}' at byte {0}")]
    BadEscape(usize, char),
    #[error("trailing garbage at byte {0}")]
    Trailing(usize),
}

impl Json {
    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---------- parsing ----------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(v)
    }

    // ---------- writing ----------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_string_compact())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(JsonError::Eof(*pos));
    }
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
                    None => return Err(JsonError::Eof(*pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut obj = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(obj));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b':') => *pos += 1,
                    Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
                    None => return Err(JsonError::Eof(*pos)),
                }
                obj.insert(key, parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(obj));
                    }
                    Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
                    None => return Err(JsonError::Eof(*pos)),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => Err(JsonError::Unexpected(*pos, c as char)),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, JsonError> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(*pos, b[*pos] as char))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    match b.get(*pos) {
        Some(&b'"') => {}
        Some(&c) => return Err(JsonError::Unexpected(*pos, c as char)),
        None => return Err(JsonError::Eof(*pos)),
    }
    *pos += 1;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(JsonError::Eof(*pos)),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if *pos + 4 >= b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| JsonError::BadEscape(*pos, 'u'))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape(*pos, 'u'))?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    Some(&c) => return Err(JsonError::BadEscape(*pos, c as char)),
                    None => return Err(JsonError::Eof(*pos)),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar.
                let start = *pos;
                let width = utf8_width(b[start]);
                let end = (start + width).min(b.len());
                let chunk = std::str::from_utf8(&b[start..end])
                    .map_err(|_| JsonError::Unexpected(start, b[start] as char))?;
                s.push_str(chunk);
                *pos = end;
            }
        }
    }
}

fn utf8_width(byte: u8) -> usize {
    match byte {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ∀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀"));
    }

    #[test]
    fn errors_reported() {
        assert!(matches!(Json::parse(""), Err(JsonError::Eof(_))));
        assert!(matches!(Json::parse("{"), Err(JsonError::Eof(_))));
        assert!(matches!(Json::parse("nope"), Err(JsonError::Unexpected(..))));
        assert!(matches!(Json::parse("1 2"), Err(JsonError::Trailing(_))));
        assert!(matches!(Json::parse("[1,]"), Err(JsonError::Unexpected(..))));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null],"name":"x\"y","obj":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 3.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
    }

    #[test]
    fn property_roundtrip_random_structures() {
        // Hand-rolled property test: generate random JSON trees, check
        // parse(write(v)) == v.
        use crate::util::prng::XorShift64;
        let mut rng = XorShift64::new(77);
        fn gen(rng: &mut XorShift64, depth: usize) -> Json {
            // gen_range is inclusive: scalars only at depth 0.
            match if depth == 0 { rng.gen_range(0, 2) } else { rng.gen_range(0, 4) } {
                0 => Json::Null,
                1 => Json::Num((rng.gen_range(0, 10_000) as f64) / 4.0),
                2 => Json::Str(format!("s{}", rng.gen_range(0, 999))),
                3 => Json::Arr((0..rng.gen_range(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => {
                    let mut o = BTreeMap::new();
                    for i in 0..rng.gen_range(0, 4) {
                        o.insert(format!("k{i}"), gen(rng, depth - 1));
                    }
                    Json::Obj(o)
                }
            }
        }
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
            assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
        }
    }
}
