//! Configuration system: typed schemas serialized to/from JSON files
//! (dependency-free; see [`json`]).

pub mod json;
pub mod schema;

pub use json::{Json, JsonError};
pub use schema::{DesignConfig, RunConfig, ServeConfig};
