//! Typed configuration schemas for the launcher and benches.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::config::json::Json;
use crate::coordinator::fault::FaultPlan;
use crate::kernels::matmul::MatMulKernel;
use crate::optimizer::array::ArrayCandidate;
use crate::placement::pattern::Pattern;
use crate::sim::engine::SimConfig;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(#[from] crate::config::json::JsonError),
    #[error("missing field '{0}'")]
    Missing(&'static str),
    #[error("invalid value for '{0}': {1}")]
    Invalid(&'static str, String),
}

/// A complete design configuration: device + precision + mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignConfig {
    pub device: String,
    pub precision: Precision,
    pub x: u64,
    pub y: u64,
    pub z: u64,
    pub pattern: Pattern,
    /// Single-kernel tile size (defaults to the paper kernel for the
    /// precision when omitted).
    pub m: u64,
    pub k: u64,
    pub n: u64,
}

impl DesignConfig {
    /// The paper's flagship configuration for a precision
    /// (13×4×6, pattern P1 — Tables II/III row 1).
    pub fn flagship(precision: Precision) -> Self {
        let k = MatMulKernel::paper_kernel(precision);
        DesignConfig {
            device: "VC1902".into(),
            precision,
            x: 13,
            y: 4,
            z: 6,
            pattern: Pattern::P1,
            m: k.m,
            k: k.k,
            n: k.n,
        }
    }

    pub fn device(&self) -> Result<AieDevice, ConfigError> {
        AieDevice::by_name(&self.device)
            .ok_or_else(|| ConfigError::Invalid("device", self.device.clone()))
    }

    /// The same array geometry (device, X/Y/Z, pattern) in another
    /// precision. When the current kernel is the paper kernel for the
    /// current precision, the sibling uses the paper kernel of the new
    /// precision (the kernels differ — int8 is 32×128×32, fp32 is
    /// 32×32×32); an explicitly customized kernel is kept as-is. This is
    /// how the serving engine derives its int8 tile geometry from an
    /// fp32 design (and vice versa).
    pub fn with_precision(&self, precision: Precision) -> DesignConfig {
        let mut d = self.clone();
        let cur = MatMulKernel::paper_kernel(d.precision);
        if (d.m, d.k, d.n) == (cur.m, cur.k, cur.n) {
            let kp = MatMulKernel::paper_kernel(precision);
            (d.m, d.k, d.n) = (kp.m, kp.k, kp.n);
        }
        d.precision = precision;
        d
    }

    pub fn candidate(&self) -> ArrayCandidate {
        ArrayCandidate::new(self.x, self.y, self.z)
    }

    pub fn kernel(&self) -> MatMulKernel {
        MatMulKernel::new(self.m, self.k, self.n, self.precision)
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("device".into(), Json::Str(self.device.clone()));
        o.insert("precision".into(), Json::Str(self.precision.to_string()));
        o.insert("x".into(), Json::Num(self.x as f64));
        o.insert("y".into(), Json::Num(self.y as f64));
        o.insert("z".into(), Json::Num(self.z as f64));
        o.insert("pattern".into(), Json::Str(self.pattern.to_string()));
        o.insert("m".into(), Json::Num(self.m as f64));
        o.insert("k".into(), Json::Num(self.k as f64));
        o.insert("n".into(), Json::Num(self.n as f64));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let s = |f: &'static str| -> Result<&str, ConfigError> {
            v.get(f).and_then(Json::as_str).ok_or(ConfigError::Missing(f))
        };
        let u = |f: &'static str| -> Result<u64, ConfigError> {
            v.get(f).and_then(Json::as_u64).ok_or(ConfigError::Missing(f))
        };
        let precision = Precision::parse(s("precision")?)
            .ok_or_else(|| ConfigError::Invalid("precision", s("precision").unwrap().into()))?;
        let pattern = Pattern::parse(s("pattern")?)
            .ok_or_else(|| ConfigError::Invalid("pattern", s("pattern").unwrap().into()))?;
        let paper = MatMulKernel::paper_kernel(precision);
        Ok(DesignConfig {
            device: s("device").unwrap_or("VC1902").to_string(),
            precision,
            x: u("x")?,
            y: u("y")?,
            z: u("z")?,
            pattern,
            m: u("m").unwrap_or(paper.m),
            k: u("k").unwrap_or(paper.k),
            n: u("n").unwrap_or(paper.n),
        })
    }

    pub fn save(&self, path: &Path) -> Result<(), ConfigError> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Simulation / run parameters attached to a design.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub design: DesignConfig,
    pub sim_iters: usize,
    pub seed: u64,
    pub jitter_amp: f64,
}

impl RunConfig {
    pub fn new(design: DesignConfig) -> Self {
        let d = SimConfig::default();
        RunConfig {
            design,
            sim_iters: d.iters,
            seed: d.seed,
            jitter_amp: d.jitter_amp,
        }
    }

    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            iters: self.sim_iters,
            seed: self.seed,
            jitter_amp: self.jitter_amp,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("design".into(), self.design.to_json());
        o.insert("sim_iters".into(), Json::Num(self.sim_iters as f64));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("jitter_amp".into(), Json::Num(self.jitter_amp));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let design =
            DesignConfig::from_json(v.get("design").ok_or(ConfigError::Missing("design"))?)?;
        let d = SimConfig::default();
        Ok(RunConfig {
            design,
            sim_iters: v.get("sim_iters").and_then(Json::as_u64).unwrap_or(d.iters as u64)
                as usize,
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
            jitter_amp: v
                .get("jitter_amp")
                .and_then(Json::as_f64)
                .unwrap_or(d.jitter_amp),
        })
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ConfigError> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Which tile-execution backend the device threads use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in (`pjrt` feature) and artifacts exist,
    /// otherwise the pure-Rust reference backend.
    #[default]
    Auto,
    /// PJRT only; fail fast if artifacts or the feature are missing.
    Pjrt,
    /// Pure-Rust reference matmul (no artifacts needed; slower, exact
    /// same tile semantics).
    Reference,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(BackendKind::Auto),
            "pjrt" => Some(BackendKind::Pjrt),
            "reference" => Some(BackendKind::Reference),
            _ => None,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
        })
    }
}

/// What `MatMulServer::submit` does when the admission queue is full
/// (`queue_depth` open requests already admitted and not yet retired).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Block the submitting thread until a slot frees (classic
    /// backpressure — slows producers down to the engine's pace).
    #[default]
    Block,
    /// Fail fast with [`crate::coordinator::QueueFull`] so the
    /// caller can shed load or retry.
    Reject,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "block" => Some(AdmissionPolicy::Block),
            "reject" => Some(AdmissionPolicy::Reject),
            _ => None,
        }
    }
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Reject => "reject",
        })
    }
}

/// Which scheduling policy arbitrates the in-flight window between
/// open requests (see `crate::coordinator::policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// Window-level round-robin across flights — the original engine's
    /// scheduling, bit-identical outputs and ordering.
    #[default]
    Fifo,
    /// Deficit round-robin over priority classes with per-precision
    /// tile costs: a heavy int8 stream cannot starve fp32 traffic.
    WeightedFair,
    /// Strict priority classes (lower class index wins) with aging.
    Priority,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" => Some(PolicyKind::Fifo),
            "weighted_fair" => Some(PolicyKind::WeightedFair),
            "priority" => Some(PolicyKind::Priority),
            _ => None,
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::WeightedFair => "weighted_fair",
            PolicyKind::Priority => "priority",
        })
    }
}

/// Serving-layer configuration (the end-to-end coordinator).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    pub design: DesignConfig,
    /// Path to the AOT artifact directory.
    pub artifacts_dir: String,
    /// Device worker threads executing tile jobs.
    pub workers: usize,
    /// Maximum open (admitted, not yet retired) requests before
    /// admission backpressure kicks in; `0` = unbounded.
    pub queue_depth: usize,
    /// Default backpressure policy when the queue is full.
    pub admission: AdmissionPolicy,
    /// Tiles kept in flight by the serving pipeline (software ping-pong
    /// window). `1` reproduces the synchronous one-tile-at-a-time engine.
    pub pipeline_depth: usize,
    /// Byte budget of the packed-weight (B operand) LRU cache. `0`
    /// disables the cache — per-request packing, the pre-PR 4 behavior
    /// bit-for-bit. Size it to hold the working set of distinct
    /// weights: ≈ `Σ ⌈k/nk⌉·⌈n/nn⌉ · nk·nn · 4` bytes over the weights
    /// you want resident (packed pools store 4-byte elements in both
    /// precisions — int8 operands are carried as i32).
    pub weight_cache_bytes: usize,
    /// Tile-execution backend selection.
    pub backend: BackendKind,
    /// Scheduling policy for the in-flight window.
    pub policy: PolicyKind,
    /// Per-class weights for [`PolicyKind::WeightedFair`] (index =
    /// request class; also fixes the number of classes for
    /// [`PolicyKind::Priority`]). Out-of-range request classes clamp to
    /// the last entry; zero weights are bumped to 1.
    pub class_weights: Vec<u64>,
    /// Scheduling decisions a flight may wait before
    /// [`PolicyKind::Priority`] promotes it one class (`0` = no aging).
    pub aging_threshold: u64,
    /// Fan-out width for operand arena extraction: packing a request's
    /// A/B matrices splits the tile grid across up to this many
    /// threads (`1` = serial packing, today's behavior bit-for-bit —
    /// parallel packs are bit-identical too, this is a pure latency
    /// knob for large requests). See
    /// `crate::coordinator::pool::TilePool::pack_timed`.
    pub pack_workers: usize,
    /// Run the pack fan-out on a persistent per-shard worker pool
    /// (`crate::coordinator::workpool::WorkPool`, the default) instead
    /// of spawning scoped threads per packed matrix. Pure overhead
    /// knob: outputs are bit-identical either way (and to serial
    /// packing); `false` keeps the legacy per-call spawn as the A/B
    /// baseline, and the `pack_spawn_s` stat shows the difference.
    /// Irrelevant while `pack_workers = 1`.
    pub pack_persistent: bool,
    /// Admission slots reserved per request class, carved out of
    /// `queue_depth` (empty = unreserved = one shared semaphore, the
    /// historical behavior). With reserves, a class always finds its
    /// reserved slots and competes for the shared remainder
    /// (`queue_depth − Σ reserves`) only beyond them — so a bulk class
    /// cannot consume the whole admission queue ahead of latency-class
    /// traffic. Out-of-range classes clamp to the last entry; ignored
    /// while `queue_depth = 0`.
    pub class_queue_reserve: Vec<u64>,
    /// Deterministic chaos schedule for the device pool (`None` =
    /// disabled, the default: no checksumming, no injection, no change
    /// to the steady-state hot path). See
    /// [`crate::coordinator::fault::FaultPlan`].
    pub fault_plan: Option<FaultPlan>,
    /// Execution attempts a tile gets beyond its first: a tile that
    /// errors, times out, or fails checksum verification is re-packed
    /// from the arenas and re-dispatched (preferring a different
    /// worker) up to this many times before its flight fails with
    /// [`crate::coordinator::fault::TileRetriesExhausted`]. `0`
    /// restores the historical fail-on-first-error behavior.
    pub max_tile_retries: u32,
    /// Per-tile deadline, as a multiple of the tile's simulated device
    /// period (the precision's `period_cycles / freq_hz`). `0.0`
    /// (default) disables deadlines — a lost completion blocks its
    /// flight forever, the historical behavior. Because the simulated
    /// period (µs) undershoots host execution time (ms), the armed
    /// deadline is never shorter than `tile_timeout_floor_ms`.
    pub tile_timeout_mult: f64,
    /// Lower bound on any armed tile deadline, milliseconds — keeps
    /// `tile_timeout_mult` calibrated against simulated device time
    /// from flagging host-speed reference tiles as lost.
    pub tile_timeout_floor_ms: u64,
    /// Consecutive faults (errors, timeouts, checksum failures) after
    /// which a worker is quarantined: it stops receiving new tiles
    /// while any healthy worker remains. `0` = never quarantine.
    pub quarantine_after: u32,
    /// Graceful-shutdown drain budget, milliseconds: shutdown waits
    /// this long for in-flight tiles, then fails stragglers with
    /// [`crate::coordinator::fault::DrainDeadlineExpired`] instead of
    /// hanging. `0` = unbounded drain, the historical behavior.
    pub drain_deadline_ms: u64,
    /// Independent serving engines ("cards") behind the facade: each
    /// shard owns a full scheduler + device pool + memory plane. `1`
    /// (the default) is the single-engine server, bit-for-bit. With
    /// more shards the front-end router steers small requests whole
    /// (weight-affinity or least-loaded) and splits large GEMMs along M
    /// — see [`crate::coordinator::shard`]. Every per-engine knob above
    /// (`workers`, `queue_depth`, `pipeline_depth`, caches, fault plan)
    /// applies *per shard*.
    pub shards: usize,
    /// Minimum M-tile count (`⌈m / nm⌉` in the request's precision
    /// geometry) at which a request is split along M across shards
    /// instead of routed whole. `0` disables splitting entirely.
    /// Irrelevant while `shards = 1`.
    pub shard_split_tiles: usize,
    /// Steer repeat-`weight_id` requests to a consistent shard
    /// (rendezvous hashing on the weight identity) so that shard's
    /// packed-weight cache stays warm. `false` routes every unsplit
    /// request least-loaded. Irrelevant while `shards = 1`.
    pub shard_affinity: bool,
    /// SLO-aware admission: reject a deadline-carrying request
    /// immediately (typed `SloUnattainable`) when the per-class
    /// service-time p99 times the open-flight load says its deadline
    /// cannot be met. `false` (the default) admits everything and lets
    /// deadlines expire in flight. Requests without a deadline are
    /// never SLO-rejected.
    pub slo_admission: bool,
    /// Brownout shedder watermark as a fraction of `queue_depth` in
    /// `[0, 1]`: when a shard's open-request occupancy crosses it,
    /// admission starts rejecting the lowest-priority classes (typed
    /// `RequestShed`), shedding progressively more classes as occupancy
    /// approaches 1.0 — class 0 is never shed. `0.0` (the default)
    /// disables shedding; ignored while `queue_depth = 0`.
    pub shed_watermark: f64,
    /// Router-level shard failover: wrap every dispatched request so a
    /// `SchedulerPanicked` resolution re-dispatches it (whole, or the
    /// failed row-band of an M-split) to a healthy shard, and track a
    /// per-shard circuit breaker (closed → open after
    /// `breaker_threshold` consecutive failures, half-open probe after
    /// `breaker_probe_ms`). `false` (the default) delivers shard
    /// failures to the client directly, the historical behavior.
    /// Irrelevant while `shards = 1` (there is nowhere to fail over).
    pub shard_failover: bool,
    /// Consecutive scheduler-level failures that trip a shard's circuit
    /// breaker from closed to open (failover mode only).
    pub breaker_threshold: u32,
    /// How long an open breaker waits before letting one probe request
    /// through (half-open), milliseconds. A successful probe closes the
    /// breaker — a respawned shard rejoins the rotation.
    pub breaker_probe_ms: u64,
    /// Supervised shard respawn (the recovery plane): when a shard's
    /// scheduler dies and its breaker records the failure, a supervisor
    /// rebuilds the shard from this config under the same index. No
    /// in-flight work carries over (failover already re-dispatched it);
    /// the rebuilt shard rejoins once a half-open probe succeeds.
    /// `false` (the default) keeps dead shards permanently removed —
    /// the PR 9 behavior. Requires `shard_failover`.
    pub shard_respawn: bool,
    /// Respawn attempts a shard gets over the server's lifetime before
    /// it degrades to permanent removal (a crash-looping shard must not
    /// flap forever). Only meaningful with `shard_respawn`.
    pub respawn_max_attempts: u32,
    /// Backoff before respawn attempt `n`, as `n × this` milliseconds
    /// (linear), so repeated crashes space their rebuilds out.
    pub respawn_backoff_ms: u64,
    /// Rewarm budget: up to this many of the hottest cached packed
    /// weights (by per-entry hit count) are rescued from a dead shard's
    /// cache into its respawned successor, each CRC-verified on its
    /// first hit. `0` (the default) starts every respawned shard cold.
    pub respawn_rewarm_top_k: usize,
    /// Release-mode memory-plane integrity: verify a cache hit's packed
    /// pool against the FNV-1a checksum stamped at insert every this
    /// many hits (plus the first hit on every rewarmed entry). A
    /// mismatch quarantines the entry and the request re-packs from its
    /// source operands — no client-visible error. `0` (the default)
    /// disables sampled verification, the PR 9 behavior (debug builds
    /// still byte-verify every hit).
    pub cache_verify_interval: u64,
    /// How long a poisoned cache key stays blacklisted after a
    /// verification failure, milliseconds — re-inserts are refused for
    /// the cooldown so a corrupting entry cannot immediately repoison
    /// the cache.
    pub cache_quarantine_ms: u64,
}

impl ServeConfig {
    pub fn new(design: DesignConfig) -> Self {
        ServeConfig {
            design,
            artifacts_dir: "artifacts".into(),
            workers: 2,
            queue_depth: 64,
            admission: AdmissionPolicy::Block,
            pipeline_depth: 4,
            weight_cache_bytes: 0,
            backend: BackendKind::Auto,
            policy: PolicyKind::Fifo,
            class_weights: vec![1, 1, 1, 1],
            aging_threshold: 64,
            pack_workers: 1,
            pack_persistent: true,
            class_queue_reserve: Vec::new(),
            fault_plan: None,
            max_tile_retries: 2,
            tile_timeout_mult: 0.0,
            tile_timeout_floor_ms: 50,
            quarantine_after: 3,
            drain_deadline_ms: 0,
            shards: 1,
            shard_split_tiles: 8,
            shard_affinity: true,
            slo_admission: false,
            shed_watermark: 0.0,
            shard_failover: false,
            breaker_threshold: 3,
            breaker_probe_ms: 500,
            shard_respawn: false,
            respawn_max_attempts: 3,
            respawn_backoff_ms: 100,
            respawn_rewarm_top_k: 0,
            cache_verify_interval: 0,
            cache_quarantine_ms: 5000,
        }
    }

    /// A validating builder over the same fields (see
    /// [`ServeConfigBuilder`]): misconfigurations are rejected at
    /// `build()` time instead of surfacing inside
    /// `MatMulServer::start` or, worse, as silent clamping.
    pub fn builder(design: DesignConfig) -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::new(design) }
    }

    /// Reject configurations the server would otherwise have to clamp
    /// or misinterpret. Called by [`ServeConfigBuilder::build`]; plain
    /// struct construction stays unvalidated for backward
    /// compatibility (the engine clamps defensively).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.shards == 0 {
            return Err(ConfigError::Invalid("shards", "0 (need at least one shard)".into()));
        }
        if self.pipeline_depth == 0 {
            return Err(ConfigError::Invalid(
                "pipeline_depth",
                "0 (need at least one tile in flight)".into(),
            ));
        }
        if self.workers == 0 {
            return Err(ConfigError::Invalid("workers", "0 (need at least one worker)".into()));
        }
        if self.pack_workers == 0 {
            return Err(ConfigError::Invalid(
                "pack_workers",
                "0 (need at least serial packing)".into(),
            ));
        }
        let reserved: u64 = self.class_queue_reserve.iter().sum();
        if self.queue_depth > 0 && reserved > self.queue_depth as u64 {
            return Err(ConfigError::Invalid(
                "class_queue_reserve",
                format!("reserves {reserved} exceed queue_depth {}", self.queue_depth),
            ));
        }
        if !self.tile_timeout_mult.is_finite() || self.tile_timeout_mult < 0.0 {
            return Err(ConfigError::Invalid(
                "tile_timeout_mult",
                self.tile_timeout_mult.to_string(),
            ));
        }
        if !self.shed_watermark.is_finite() || !(0.0..=1.0).contains(&self.shed_watermark) {
            return Err(ConfigError::Invalid(
                "shed_watermark",
                self.shed_watermark.to_string(),
            ));
        }
        if self.shard_failover && self.breaker_threshold == 0 {
            return Err(ConfigError::Invalid(
                "breaker_threshold",
                "0 (failover needs at least one failure to trip)".into(),
            ));
        }
        if self.shard_respawn && !self.shard_failover {
            return Err(ConfigError::Invalid(
                "shard_respawn",
                "true without shard_failover (the supervisor is driven by the failover plane)"
                    .into(),
            ));
        }
        if self.shard_respawn && self.respawn_max_attempts == 0 {
            return Err(ConfigError::Invalid(
                "respawn_max_attempts",
                "0 (respawn needs at least one attempt)".into(),
            ));
        }
        if let Some(plan) = &self.fault_plan {
            if !(0.0..=1.0).contains(&plan.rate) {
                return Err(ConfigError::Invalid("fault_plan.rate", plan.rate.to_string()));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("design".into(), self.design.to_json());
        o.insert("artifacts_dir".into(), Json::Str(self.artifacts_dir.clone()));
        o.insert("workers".into(), Json::Num(self.workers as f64));
        o.insert("queue_depth".into(), Json::Num(self.queue_depth as f64));
        o.insert("admission".into(), Json::Str(self.admission.to_string()));
        o.insert("pipeline_depth".into(), Json::Num(self.pipeline_depth as f64));
        o.insert(
            "weight_cache_bytes".into(),
            Json::Num(self.weight_cache_bytes as f64),
        );
        o.insert("backend".into(), Json::Str(self.backend.to_string()));
        o.insert("policy".into(), Json::Str(self.policy.to_string()));
        o.insert(
            "class_weights".into(),
            Json::Arr(self.class_weights.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        o.insert("aging_threshold".into(), Json::Num(self.aging_threshold as f64));
        o.insert("pack_workers".into(), Json::Num(self.pack_workers as f64));
        o.insert("pack_persistent".into(), Json::Bool(self.pack_persistent));
        let reserve = self.class_queue_reserve.iter().map(|&r| Json::Num(r as f64)).collect();
        o.insert("class_queue_reserve".into(), Json::Arr(reserve));
        if let Some(plan) = &self.fault_plan {
            o.insert("fault_plan".into(), plan.to_json());
        }
        o.insert("max_tile_retries".into(), Json::Num(self.max_tile_retries as f64));
        o.insert("tile_timeout_mult".into(), Json::Num(self.tile_timeout_mult));
        o.insert(
            "tile_timeout_floor_ms".into(),
            Json::Num(self.tile_timeout_floor_ms as f64),
        );
        o.insert("quarantine_after".into(), Json::Num(self.quarantine_after as f64));
        o.insert("drain_deadline_ms".into(), Json::Num(self.drain_deadline_ms as f64));
        o.insert("shards".into(), Json::Num(self.shards as f64));
        o.insert("shard_split_tiles".into(), Json::Num(self.shard_split_tiles as f64));
        o.insert("shard_affinity".into(), Json::Bool(self.shard_affinity));
        o.insert("slo_admission".into(), Json::Bool(self.slo_admission));
        o.insert("shed_watermark".into(), Json::Num(self.shed_watermark));
        o.insert("shard_failover".into(), Json::Bool(self.shard_failover));
        o.insert("breaker_threshold".into(), Json::Num(self.breaker_threshold as f64));
        o.insert("breaker_probe_ms".into(), Json::Num(self.breaker_probe_ms as f64));
        o.insert("shard_respawn".into(), Json::Bool(self.shard_respawn));
        o.insert(
            "respawn_max_attempts".into(),
            Json::Num(self.respawn_max_attempts as f64),
        );
        o.insert("respawn_backoff_ms".into(), Json::Num(self.respawn_backoff_ms as f64));
        o.insert(
            "respawn_rewarm_top_k".into(),
            Json::Num(self.respawn_rewarm_top_k as f64),
        );
        o.insert(
            "cache_verify_interval".into(),
            Json::Num(self.cache_verify_interval as f64),
        );
        o.insert("cache_quarantine_ms".into(), Json::Num(self.cache_quarantine_ms as f64));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let design =
            DesignConfig::from_json(v.get("design").ok_or(ConfigError::Missing("design"))?)?;
        let backend = match v.get("backend").and_then(Json::as_str) {
            None => BackendKind::Auto,
            Some(s) => BackendKind::parse(s)
                .ok_or_else(|| ConfigError::Invalid("backend", s.to_string()))?,
        };
        let admission = match v.get("admission").and_then(Json::as_str) {
            None => AdmissionPolicy::Block,
            Some(s) => AdmissionPolicy::parse(s)
                .ok_or_else(|| ConfigError::Invalid("admission", s.to_string()))?,
        };
        let policy = match v.get("policy").and_then(Json::as_str) {
            None => PolicyKind::Fifo,
            Some(s) => PolicyKind::parse(s)
                .ok_or_else(|| ConfigError::Invalid("policy", s.to_string()))?,
        };
        let u64_list = |field: &'static str, default: Vec<u64>| -> Result<Vec<u64>, ConfigError> {
            match v.get(field) {
                None => Ok(default),
                Some(Json::Arr(a)) => a
                    .iter()
                    .map(|w| w.as_u64().ok_or_else(|| ConfigError::Invalid(field, w.to_string())))
                    .collect(),
                Some(other) => Err(ConfigError::Invalid(field, other.to_string())),
            }
        };
        let class_weights = u64_list("class_weights", vec![1, 1, 1, 1])?;
        let class_queue_reserve = u64_list("class_queue_reserve", Vec::new())?;
        let fault_plan = match v.get("fault_plan") {
            None => None,
            Some(p) => Some(FaultPlan::from_json(p)?),
        };
        let tile_timeout_mult =
            v.get("tile_timeout_mult").and_then(Json::as_f64).unwrap_or(0.0);
        if !tile_timeout_mult.is_finite() || tile_timeout_mult < 0.0 {
            return Err(ConfigError::Invalid(
                "tile_timeout_mult",
                tile_timeout_mult.to_string(),
            ));
        }
        let shed_watermark = v.get("shed_watermark").and_then(Json::as_f64).unwrap_or(0.0);
        if !shed_watermark.is_finite() || !(0.0..=1.0).contains(&shed_watermark) {
            return Err(ConfigError::Invalid("shed_watermark", shed_watermark.to_string()));
        }
        Ok(ServeConfig {
            design,
            artifacts_dir: v
                .get("artifacts_dir")
                .and_then(Json::as_str)
                .unwrap_or("artifacts")
                .to_string(),
            workers: v.get("workers").and_then(Json::as_u64).unwrap_or(2) as usize,
            queue_depth: v.get("queue_depth").and_then(Json::as_u64).unwrap_or(64) as usize,
            admission,
            pipeline_depth: v
                .get("pipeline_depth")
                .and_then(Json::as_u64)
                .unwrap_or(4) as usize,
            weight_cache_bytes: v
                .get("weight_cache_bytes")
                .and_then(Json::as_u64)
                .unwrap_or(0) as usize,
            backend,
            policy,
            class_weights,
            aging_threshold: v
                .get("aging_threshold")
                .and_then(Json::as_u64)
                .unwrap_or(64),
            pack_workers: v.get("pack_workers").and_then(Json::as_u64).unwrap_or(1) as usize,
            pack_persistent: v
                .get("pack_persistent")
                .and_then(Json::as_bool)
                .unwrap_or(true),
            class_queue_reserve,
            fault_plan,
            max_tile_retries: v
                .get("max_tile_retries")
                .and_then(Json::as_u64)
                .unwrap_or(2) as u32,
            tile_timeout_mult,
            tile_timeout_floor_ms: v
                .get("tile_timeout_floor_ms")
                .and_then(Json::as_u64)
                .unwrap_or(50),
            quarantine_after: v
                .get("quarantine_after")
                .and_then(Json::as_u64)
                .unwrap_or(3) as u32,
            drain_deadline_ms: v
                .get("drain_deadline_ms")
                .and_then(Json::as_u64)
                .unwrap_or(0),
            shards: v.get("shards").and_then(Json::as_u64).unwrap_or(1) as usize,
            shard_split_tiles: v
                .get("shard_split_tiles")
                .and_then(Json::as_u64)
                .unwrap_or(8) as usize,
            shard_affinity: v
                .get("shard_affinity")
                .and_then(Json::as_bool)
                .unwrap_or(true),
            slo_admission: v
                .get("slo_admission")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            shed_watermark,
            shard_failover: v
                .get("shard_failover")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            breaker_threshold: v
                .get("breaker_threshold")
                .and_then(Json::as_u64)
                .unwrap_or(3) as u32,
            breaker_probe_ms: v
                .get("breaker_probe_ms")
                .and_then(Json::as_u64)
                .unwrap_or(500),
            shard_respawn: v
                .get("shard_respawn")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            respawn_max_attempts: v
                .get("respawn_max_attempts")
                .and_then(Json::as_u64)
                .unwrap_or(3) as u32,
            respawn_backoff_ms: v
                .get("respawn_backoff_ms")
                .and_then(Json::as_u64)
                .unwrap_or(100),
            respawn_rewarm_top_k: v
                .get("respawn_rewarm_top_k")
                .and_then(Json::as_u64)
                .unwrap_or(0) as usize,
            cache_verify_interval: v
                .get("cache_verify_interval")
                .and_then(Json::as_u64)
                .unwrap_or(0),
            cache_quarantine_ms: v
                .get("cache_quarantine_ms")
                .and_then(Json::as_u64)
                .unwrap_or(5000),
        })
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Validating builder for [`ServeConfig`] — chainable setters over the
/// defaults of [`ServeConfig::new`], with misconfigurations (zero
/// shards, zero pipeline depth, oversubscribed class reserves, …)
/// rejected by [`ServeConfigBuilder::build`] instead of surfacing at
/// server start. The plain struct (and its JSON round-trip) keeps
/// working unvalidated for existing call sites.
///
/// ```no_run
/// use maxeva::config::schema::{DesignConfig, ServeConfig};
/// use maxeva::Precision;
///
/// let cfg = ServeConfig::builder(DesignConfig::flagship(Precision::Fp32))
///     .workers(4)
///     .shards(2)
///     .weight_cache_bytes(64 << 20)
///     .build()
///     .expect("valid serving config");
/// ```
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.cfg.queue_depth = depth;
        self
    }

    pub fn admission(mut self, policy: AdmissionPolicy) -> Self {
        self.cfg.admission = policy;
        self
    }

    pub fn pipeline_depth(mut self, depth: usize) -> Self {
        self.cfg.pipeline_depth = depth;
        self
    }

    pub fn weight_cache_bytes(mut self, bytes: usize) -> Self {
        self.cfg.weight_cache_bytes = bytes;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn class_weights(mut self, weights: Vec<u64>) -> Self {
        self.cfg.class_weights = weights;
        self
    }

    pub fn aging_threshold(mut self, threshold: u64) -> Self {
        self.cfg.aging_threshold = threshold;
        self
    }

    pub fn pack_workers(mut self, workers: usize) -> Self {
        self.cfg.pack_workers = workers;
        self
    }

    pub fn pack_persistent(mut self, persistent: bool) -> Self {
        self.cfg.pack_persistent = persistent;
        self
    }

    pub fn class_queue_reserve(mut self, reserve: Vec<u64>) -> Self {
        self.cfg.class_queue_reserve = reserve;
        self
    }

    pub fn fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.cfg.fault_plan = plan;
        self
    }

    pub fn max_tile_retries(mut self, retries: u32) -> Self {
        self.cfg.max_tile_retries = retries;
        self
    }

    pub fn tile_timeout_mult(mut self, mult: f64) -> Self {
        self.cfg.tile_timeout_mult = mult;
        self
    }

    pub fn tile_timeout_floor_ms(mut self, floor_ms: u64) -> Self {
        self.cfg.tile_timeout_floor_ms = floor_ms;
        self
    }

    pub fn quarantine_after(mut self, faults: u32) -> Self {
        self.cfg.quarantine_after = faults;
        self
    }

    pub fn drain_deadline_ms(mut self, ms: u64) -> Self {
        self.cfg.drain_deadline_ms = ms;
        self
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = shards;
        self
    }

    pub fn shard_split_tiles(mut self, tiles: usize) -> Self {
        self.cfg.shard_split_tiles = tiles;
        self
    }

    pub fn shard_affinity(mut self, affinity: bool) -> Self {
        self.cfg.shard_affinity = affinity;
        self
    }

    pub fn slo_admission(mut self, on: bool) -> Self {
        self.cfg.slo_admission = on;
        self
    }

    pub fn shed_watermark(mut self, watermark: f64) -> Self {
        self.cfg.shed_watermark = watermark;
        self
    }

    pub fn shard_failover(mut self, on: bool) -> Self {
        self.cfg.shard_failover = on;
        self
    }

    pub fn breaker_threshold(mut self, failures: u32) -> Self {
        self.cfg.breaker_threshold = failures;
        self
    }

    pub fn breaker_probe_ms(mut self, ms: u64) -> Self {
        self.cfg.breaker_probe_ms = ms;
        self
    }

    pub fn shard_respawn(mut self, on: bool) -> Self {
        self.cfg.shard_respawn = on;
        self
    }

    pub fn respawn_max_attempts(mut self, attempts: u32) -> Self {
        self.cfg.respawn_max_attempts = attempts;
        self
    }

    pub fn respawn_backoff_ms(mut self, ms: u64) -> Self {
        self.cfg.respawn_backoff_ms = ms;
        self
    }

    pub fn respawn_rewarm_top_k(mut self, k: usize) -> Self {
        self.cfg.respawn_rewarm_top_k = k;
        self
    }

    pub fn cache_verify_interval(mut self, hits: u64) -> Self {
        self.cfg.cache_verify_interval = hits;
        self
    }

    pub fn cache_quarantine_ms(mut self, ms: u64) -> Self {
        self.cfg.cache_quarantine_ms = ms;
        self
    }

    /// Validate and produce the config ([`ServeConfig::validate`]).
    pub fn build(self) -> Result<ServeConfig, ConfigError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flagship_roundtrip() {
        for p in Precision::all() {
            let c = DesignConfig::flagship(p);
            let j = c.to_json();
            assert_eq!(DesignConfig::from_json(&j).unwrap(), c);
        }
    }

    #[test]
    fn flagship_matches_paper_row1() {
        let c = DesignConfig::flagship(Precision::Int8);
        assert_eq!((c.x, c.y, c.z), (13, 4, 6));
        assert_eq!(c.pattern, Pattern::P1);
        assert_eq!((c.m, c.k, c.n), (32, 128, 32));
    }

    #[test]
    fn run_config_roundtrip_via_file() {
        let dir = std::env::temp_dir().join("maxeva_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        let rc = RunConfig::new(DesignConfig::flagship(Precision::Fp32));
        rc.save(&path).unwrap();
        let loaded = RunConfig::load(&path).unwrap();
        assert_eq!(loaded, rc);
    }

    #[test]
    fn missing_fields_error() {
        let v = Json::parse(r#"{"precision": "fp32"}"#).unwrap();
        assert!(DesignConfig::from_json(&v).is_err());
    }

    #[test]
    fn invalid_precision_error() {
        let v = Json::parse(
            r#"{"device":"VC1902","precision":"fp64","x":1,"y":3,"z":1,"pattern":"P2"}"#,
        )
        .unwrap();
        assert!(matches!(
            DesignConfig::from_json(&v),
            Err(ConfigError::Invalid("precision", _))
        ));
    }

    #[test]
    fn kernel_defaults_to_paper_kernel() {
        let v = Json::parse(
            r#"{"device":"VC1902","precision":"int8","x":13,"y":4,"z":6,"pattern":"P1"}"#,
        )
        .unwrap();
        let c = DesignConfig::from_json(&v).unwrap();
        assert_eq!((c.m, c.k, c.n), (32, 128, 32));
    }

    #[test]
    fn serve_config_defaults() {
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&v).unwrap();
        assert_eq!(c.workers, 2);
        assert_eq!(c.artifacts_dir, "artifacts");
        assert_eq!(c.pipeline_depth, 4);
        assert_eq!(c.weight_cache_bytes, 0, "weight cache defaults off");
        assert_eq!(c.backend, BackendKind::Auto);
        assert_eq!(c.admission, AdmissionPolicy::Block);
        assert_eq!(c.policy, PolicyKind::Fifo);
        assert_eq!(c.class_weights, vec![1, 1, 1, 1]);
        assert_eq!(c.aging_threshold, 64);
        assert_eq!(c.pack_workers, 1, "packing defaults to serial");
        assert!(c.pack_persistent, "pack fan-out defaults to the persistent pool");
        assert!(c.class_queue_reserve.is_empty(), "admission defaults to unreserved");
        assert_eq!(c.fault_plan, None, "fault injection defaults off");
        assert_eq!(c.max_tile_retries, 2);
        assert_eq!(c.tile_timeout_mult, 0.0, "tile deadlines default off");
        assert_eq!(c.tile_timeout_floor_ms, 50);
        assert_eq!(c.quarantine_after, 3);
        assert_eq!(c.drain_deadline_ms, 0, "drain defaults unbounded");
        assert_eq!(c.shards, 1, "sharding defaults to the single engine");
        assert_eq!(c.shard_split_tiles, 8);
        assert!(c.shard_affinity, "weight-affinity routing defaults on");
        assert!(!c.slo_admission, "SLO admission defaults off");
        assert_eq!(c.shed_watermark, 0.0, "brownout shedding defaults off");
        assert!(!c.shard_failover, "shard failover defaults off");
        assert_eq!(c.breaker_threshold, 3);
        assert_eq!(c.breaker_probe_ms, 500);
        assert!(!c.shard_respawn, "shard respawn defaults off");
        assert_eq!(c.respawn_max_attempts, 3);
        assert_eq!(c.respawn_backoff_ms, 100);
        assert_eq!(c.respawn_rewarm_top_k, 0, "rewarm defaults off");
        assert_eq!(c.cache_verify_interval, 0, "sampled cache verification defaults off");
        assert_eq!(c.cache_quarantine_ms, 5000);
    }

    #[test]
    fn serve_config_roundtrip_with_pipeline_knobs() {
        let mut c = ServeConfig::new(DesignConfig::flagship(Precision::Fp32));
        c.pipeline_depth = 8;
        c.backend = BackendKind::Reference;
        c.admission = AdmissionPolicy::Reject;
        c.queue_depth = 3;
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn serve_config_roundtrip_covers_every_field() {
        // Every field set to a non-default value: a field missing from
        // to_json/from_json fails this equality (the PR 2 gap — knobs
        // added to the struct but silently dropped by the JSON layer).
        let mut c = ServeConfig::new(DesignConfig::flagship(Precision::Int8));
        c.artifacts_dir = "/tmp/maxeva_artifacts".into();
        c.workers = 7;
        c.queue_depth = 9;
        c.admission = AdmissionPolicy::Reject;
        c.pipeline_depth = 16;
        c.weight_cache_bytes = 64 << 20;
        c.backend = BackendKind::Reference;
        c.policy = PolicyKind::WeightedFair;
        c.class_weights = vec![8, 2, 1];
        c.aging_threshold = 512;
        c.pack_workers = 6;
        c.pack_persistent = false;
        c.class_queue_reserve = vec![3, 0, 1];
        c.fault_plan = Some({
            use crate::coordinator::fault::FaultKind;
            let mut p = FaultPlan::new(99, 0.125, vec![FaultKind::Hang, FaultKind::Corrupt]);
            p.worker = Some(1);
            p.delay_ms = 9;
            p.max_faults = 17;
            p
        });
        c.max_tile_retries = 5;
        c.tile_timeout_mult = 2048.0;
        c.tile_timeout_floor_ms = 120;
        c.quarantine_after = 7;
        c.drain_deadline_ms = 1500;
        c.shards = 5;
        c.shard_split_tiles = 3;
        c.shard_affinity = false;
        c.slo_admission = true;
        c.shed_watermark = 0.75;
        c.shard_failover = true;
        c.breaker_threshold = 9;
        c.breaker_probe_ms = 250;
        c.shard_respawn = true;
        c.respawn_max_attempts = 5;
        c.respawn_backoff_ms = 40;
        c.respawn_rewarm_top_k = 12;
        c.cache_verify_interval = 32;
        c.cache_quarantine_ms = 900;
        let back = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // And through a file, like the launcher loads it.
        let dir = std::env::temp_dir().join("maxeva_cfg_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(&path, c.to_json().to_string_pretty()).unwrap();
        assert_eq!(ServeConfig::load(&path).unwrap(), c);
    }

    #[test]
    fn policy_kind_parse_display_roundtrip() {
        for p in [PolicyKind::Fifo, PolicyKind::WeightedFair, PolicyKind::Priority] {
            assert_eq!(PolicyKind::parse(&p.to_string()), Some(p));
        }
        assert_eq!(PolicyKind::parse("edf"), None);
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"policy":"lifo"}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("policy", _))
        ));
    }

    #[test]
    fn bad_class_weights_rejected() {
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"class_weights":[1,-2]}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("class_weights", _))
        ));
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"class_weights":3}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("class_weights", _))
        ));
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"class_queue_reserve":[1,"two"]}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("class_queue_reserve", _))
        ));
    }

    #[test]
    fn bad_fault_knobs_rejected() {
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"tile_timeout_mult":-1.0}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("tile_timeout_mult", _))
        ));
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"fault_plan":{"rate":0.5,"kinds":["sparkle"]}}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("fault_plan.kinds", _))
        ));
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"shed_watermark":2.0}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("shed_watermark", _))
        ));
    }

    #[test]
    fn admission_policy_parse_display_roundtrip() {
        for p in [AdmissionPolicy::Block, AdmissionPolicy::Reject] {
            assert_eq!(AdmissionPolicy::parse(&p.to_string()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("drop"), None);
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"admission":"shed"}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("admission", _))
        ));
    }

    #[test]
    fn with_precision_tracks_paper_kernels() {
        // Paper-kernel designs swap to the sibling precision's paper
        // kernel; explicit custom kernels are preserved.
        let fp = DesignConfig::flagship(Precision::Fp32);
        assert_eq!(fp.with_precision(Precision::Int8), DesignConfig::flagship(Precision::Int8));
        assert_eq!(fp.with_precision(Precision::Fp32), fp);

        let mut small = DesignConfig::flagship(Precision::Fp32);
        (small.m, small.k, small.n) = (4, 4, 4);
        let sib = small.with_precision(Precision::Int8);
        assert_eq!(sib.precision, Precision::Int8);
        assert_eq!((sib.m, sib.k, sib.n), (4, 4, 4));
        assert_eq!((sib.x, sib.y, sib.z), (13, 4, 6));
    }

    #[test]
    fn backend_kind_parse_display_roundtrip() {
        for b in [BackendKind::Auto, BackendKind::Pjrt, BackendKind::Reference] {
            assert_eq!(BackendKind::parse(&b.to_string()), Some(b));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        let v = Json::parse(
            r#"{"design":{"device":"VC1902","precision":"fp32","x":13,"y":4,"z":6,"pattern":"P1"},"backend":"gpu"}"#,
        )
        .unwrap();
        assert!(matches!(
            ServeConfig::from_json(&v),
            Err(ConfigError::Invalid("backend", _))
        ));
    }

    #[test]
    fn builder_builds_and_validates() {
        let design = DesignConfig::flagship(Precision::Fp32);
        let cfg = ServeConfig::builder(design.clone())
            .workers(4)
            .queue_depth(32)
            .admission(AdmissionPolicy::Reject)
            .pipeline_depth(8)
            .weight_cache_bytes(16 << 20)
            .backend(BackendKind::Reference)
            .policy(PolicyKind::WeightedFair)
            .class_weights(vec![4, 1])
            .pack_workers(2)
            .pack_persistent(false)
            .class_queue_reserve(vec![8, 0])
            .max_tile_retries(3)
            .shards(4)
            .shard_split_tiles(2)
            .shard_affinity(false)
            .slo_admission(true)
            .shed_watermark(0.8)
            .shard_failover(true)
            .breaker_threshold(2)
            .breaker_probe_ms(100)
            .shard_respawn(true)
            .respawn_max_attempts(2)
            .respawn_backoff_ms(25)
            .respawn_rewarm_top_k(4)
            .cache_verify_interval(16)
            .cache_quarantine_ms(750)
            .build()
            .unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.shard_split_tiles, 2);
        assert!(!cfg.shard_affinity);
        assert!(!cfg.pack_persistent);
        assert!(cfg.slo_admission);
        assert_eq!(cfg.shed_watermark, 0.8);
        assert!(cfg.shard_failover);
        assert_eq!(cfg.breaker_threshold, 2);
        assert_eq!(cfg.breaker_probe_ms, 100);
        assert!(cfg.shard_respawn);
        assert_eq!(cfg.respawn_max_attempts, 2);
        assert_eq!(cfg.respawn_backoff_ms, 25);
        assert_eq!(cfg.respawn_rewarm_top_k, 4);
        assert_eq!(cfg.cache_verify_interval, 16);
        assert_eq!(cfg.cache_quarantine_ms, 750);
        // Untouched knobs keep their ServeConfig::new defaults.
        assert_eq!(cfg.aging_threshold, 64);
        assert_eq!(cfg.drain_deadline_ms, 0);
        // The built config round-trips like the plain struct.
        assert_eq!(ServeConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // And defaults alone build fine.
        ServeConfig::builder(design).build().unwrap();
    }

    #[test]
    fn builder_rejects_misconfigurations() {
        let design = DesignConfig::flagship(Precision::Fp32);
        let b = || ServeConfig::builder(design.clone());
        assert!(matches!(
            b().shards(0).build(),
            Err(ConfigError::Invalid("shards", _))
        ));
        assert!(matches!(
            b().pipeline_depth(0).build(),
            Err(ConfigError::Invalid("pipeline_depth", _))
        ));
        assert!(matches!(
            b().workers(0).build(),
            Err(ConfigError::Invalid("workers", _))
        ));
        assert!(matches!(
            b().pack_workers(0).build(),
            Err(ConfigError::Invalid("pack_workers", _))
        ));
        // Reserves exceeding the queue depth are almost certainly a
        // typo (the gate would run with an empty shared pool).
        assert!(matches!(
            b().queue_depth(4).class_queue_reserve(vec![3, 2]).build(),
            Err(ConfigError::Invalid("class_queue_reserve", _))
        ));
        // Unbounded queues ignore reserves, so any reserve is fine.
        b().queue_depth(0).class_queue_reserve(vec![3, 2]).build().unwrap();
        assert!(matches!(
            b().tile_timeout_mult(f64::NAN).build(),
            Err(ConfigError::Invalid("tile_timeout_mult", _))
        ));
        // The shed watermark is a queue-occupancy fraction.
        assert!(matches!(
            b().shed_watermark(1.5).build(),
            Err(ConfigError::Invalid("shed_watermark", _))
        ));
        assert!(matches!(
            b().shed_watermark(f64::NAN).build(),
            Err(ConfigError::Invalid("shed_watermark", _))
        ));
        // A zero breaker threshold can never trip; reject it when
        // failover is actually on (it is inert otherwise).
        assert!(matches!(
            b().shard_failover(true).breaker_threshold(0).build(),
            Err(ConfigError::Invalid("breaker_threshold", _))
        ));
        b().breaker_threshold(0).build().unwrap();
        // Respawn is driven by the failover plane — without it the
        // supervisor would never hear about a death.
        assert!(matches!(
            b().shard_respawn(true).build(),
            Err(ConfigError::Invalid("shard_respawn", _))
        ));
        assert!(matches!(
            b().shard_failover(true).shard_respawn(true).respawn_max_attempts(0).build(),
            Err(ConfigError::Invalid("respawn_max_attempts", _))
        ));
        b().shard_failover(true).shard_respawn(true).build().unwrap();
        // Inert while respawn is off, whatever the attempt budget says.
        b().respawn_max_attempts(0).build().unwrap();
        let mut bad_plan = FaultPlan::new(1, 0.5, vec![]);
        bad_plan.rate = 2.0;
        assert!(matches!(
            b().fault_plan(Some(bad_plan)).build(),
            Err(ConfigError::Invalid("fault_plan.rate", _))
        ));
    }

    #[test]
    fn unknown_device_rejected_at_instantiation() {
        let mut c = DesignConfig::flagship(Precision::Fp32);
        c.device = "VP9999".into();
        assert!(c.device().is_err());
    }
}
