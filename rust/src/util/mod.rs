//! Small self-contained utilities: deterministic PRNG (for seeded
//! PnR-noise models and property tests) and statistics helpers.

pub mod prng;
pub mod stats;

pub use prng::XorShift64;
pub use stats::{geomean, mean, percentile, stddev};
