//! Statistics helpers used by the benchmark harnesses and reports.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation. Returns 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Geometric mean of strictly-positive samples. Returns 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Percentile by linear interpolation (p in [0, 100]).
/// Returns 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0]), 2.0);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn stddev_known_value() {
        // Sample stddev of [2,4,4,4,5,5,7,9] is ~2.138.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn geomean_known_value() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }
}
