//! Deterministic xorshift64* PRNG.
//!
//! Used for (a) the seeded "PnR noise" terms in the simulator/power model
//! (the paper attributes <1% run-to-run wiggles to buffer-placement
//! dissimilarities of the AMD PnR tool; we model them deterministically so
//! results are reproducible), and (b) the hand-rolled property tests
//! (`proptest` is not available offline).

/// xorshift64* generator. Deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator. A zero seed is remapped to a fixed constant
    /// (xorshift has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range: lo > hi");
        let span = hi - lo + 1;
        lo + self.next_u64() % span
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Symmetric relative jitter: uniform in [-amp, +amp].
    pub fn jitter(&mut self, amp: f64) -> f64 {
        self.gen_range_f64(-amp, amp)
    }

    /// Pick a random element of a slice. Panics on empty slices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose: empty slice");
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut a = XorShift64::new(0);
        assert_ne!(a.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v), "out of range: {v}");
        }
    }

    #[test]
    fn gen_range_inclusive_bounds_hit() {
        let mut r = XorShift64::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.gen_range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn jitter_is_symmetric_range() {
        let mut r = XorShift64::new(11);
        for _ in 0..1000 {
            let j = r.jitter(0.02);
            assert!(j.abs() <= 0.02);
        }
    }

    #[test]
    fn rough_uniformity() {
        // Chi-square-ish sanity: 16 buckets over 64k draws, each within 20%.
        let mut r = XorShift64::new(1234);
        let mut buckets = [0u32; 16];
        let n = 65_536;
        for _ in 0..n {
            buckets[(r.next_f64() * 16.0) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        for b in buckets {
            assert!((b as f64 - expect).abs() < expect * 0.2, "bucket {b}");
        }
    }
}
