//! XPE-like power model (paper §V: "power consumption is estimated
//! through the AIE XPE tool", total AIE power = core power + memory
//! power).
//!
//! Decomposition mirrors XPE:
//!
//! * **Core power** — every MatMul core draws a constant active power
//!   (it computes nearly back-to-back); every adder core draws an idle
//!   floor plus a dynamic term proportional to its duty cycle (fp32
//!   adder cores idle ~96% of the time, int8 ~63% — Table I ratios —
//!   which is exactly why MaxEVA's fp32 core power undercuts CHARM's
//!   all-MatMul array).
//! * **Memory power** — per-bank clock/static power plus a dynamic term
//!   proportional to array activity.
//!
//! Constants are fit on the CHARM row + rows 1–2 of Table II (fp32) and
//! rows 1–2 of Table III (int8); the remaining rows are predictions
//! (EXPERIMENTS.md records the deltas, all ≲1%).

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::placement::placer::PlacedDesign;
use crate::sim::engine::SimResult;

/// Per-precision core power constants (Watts per core).
#[derive(Debug, Clone, Copy)]
pub struct CorePowerModel {
    /// Active MatMul core power.
    pub matmul_w: f64,
    /// Adder core idle floor.
    pub adder_idle_w: f64,
    /// Adder core dynamic power at 100% duty.
    pub adder_dyn_w: f64,
}

impl CorePowerModel {
    pub fn calibrated(prec: Precision) -> Self {
        match prec {
            // Fit: CHARM row (384 cores, all MatMul, 26.95 W) plus rows
            // 1–2 of Table II.
            Precision::Fp32 => CorePowerModel {
                matmul_w: 0.07018,
                adder_idle_w: 0.0384,
                adder_dyn_w: 0.0873,
            },
            // Fit: rows 1–2 of Table III (no CHARM int8 power published).
            Precision::Int8 => CorePowerModel {
                matmul_w: 0.13534,
                adder_idle_w: 0.03786,
                adder_dyn_w: 0.12,
            },
            // Extensions: scale the active-core power between the two
            // calibrated points by datapath width (estimates).
            Precision::Int16 => CorePowerModel {
                matmul_w: 0.105,
                adder_idle_w: 0.038,
                adder_dyn_w: 0.10,
            },
            Precision::Bf16 => CorePowerModel {
                matmul_w: 0.088,
                adder_idle_w: 0.038,
                adder_dyn_w: 0.09,
            },
        }
    }
}

/// Memory power constants (Watts per bank), precision-independent: bank
/// power tracks access rate, which the `activity` term captures.
pub const MEM_BANK_STATIC_W: f64 = 0.00359;
pub const MEM_BANK_DYN_W: f64 = 0.00325;

/// Power estimate for one design (one row of Tables II/III).
#[derive(Debug, Clone, Copy)]
pub struct PowerEstimate {
    /// AIE core power (Tables II/III "AIE core P." column), Watts.
    pub core_w: f64,
    /// Data memory power ("Memory P." column), Watts.
    pub memory_w: f64,
}

impl PowerEstimate {
    /// Total AIE power = core + memory (paper's summation, [48]).
    pub fn total_w(&self) -> f64 {
        self.core_w + self.memory_w
    }

    /// Energy efficiency in ops/J (= throughput / power).
    pub fn energy_efficiency(&self, ops_per_sec: f64) -> f64 {
        ops_per_sec / self.total_w()
    }
}

/// Estimate power for a placed + simulated design.
pub fn estimate_power(dev: &AieDevice, design: &PlacedDesign, sim: &SimResult) -> PowerEstimate {
    let m = CorePowerModel::calibrated(design.kernel.prec);
    let n_mm = design.cand.matmul_kernels() as f64;
    let n_add = design.cand.adder_cores() as f64;
    let core_w = n_mm * m.matmul_w + n_add * (m.adder_idle_w + m.adder_dyn_w * sim.adder_duty);
    let activity = sim.efficiency; // array activity vs device peak
    let memory_w = design.memory_banks as f64 * (MEM_BANK_STATIC_W + MEM_BANK_DYN_W * activity);
    let _ = dev;
    PowerEstimate { core_w, memory_w }
}

/// Estimate power for an all-MatMul design (the CHARM baseline has no
/// adder cores).
pub fn estimate_power_all_matmul(
    prec: Precision,
    n_cores: u64,
    memory_banks: u64,
    efficiency: f64,
) -> PowerEstimate {
    let m = CorePowerModel::calibrated(prec);
    PowerEstimate {
        core_w: n_cores as f64 * m.matmul_w,
        memory_w: memory_banks as f64 * (MEM_BANK_STATIC_W + MEM_BANK_DYN_W * efficiency),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matmul::MatMulKernel;
    use crate::optimizer::array::ArrayCandidate;
    use crate::placement::pattern::Pattern;
    use crate::placement::placer::place_design;
    use crate::sim::engine::{simulate_design, SimConfig};

    fn run(x: u64, y: u64, z: u64, pat: Pattern, prec: Precision) -> (PowerEstimate, SimResult) {
        let d = AieDevice::vc1902();
        let pd =
            place_design(&d, ArrayCandidate::new(x, y, z), pat, MatMulKernel::paper_kernel(prec))
                .unwrap();
        let sim = simulate_design(&d, &pd, &SimConfig::default());
        (estimate_power(&d, &pd, &sim), sim)
    }

    #[test]
    fn table2_row1_core_power() {
        // Paper: 13×4×6 fp32 core power 25.62 W (±2%).
        let (p, _) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        assert!((p.core_w - 25.62).abs() / 25.62 < 0.02, "{}", p.core_w);
    }

    #[test]
    fn table3_row1_core_power() {
        // Paper: 13×4×6 int8 core power 48.65 W (±2%).
        let (p, _) = run(13, 4, 6, Pattern::P1, Precision::Int8);
        assert!((p.core_w - 48.65).abs() / 48.65 < 0.02, "{}", p.core_w);
    }

    #[test]
    fn charm_fp32_core_power() {
        // Paper: CHARM 384 MatMul cores → 26.95 W core power.
        let p = estimate_power_all_matmul(Precision::Fp32, 384, 3086, 4504.46 / 8000.0);
        assert!((p.core_w - 26.95).abs() / 26.95 < 0.01, "{}", p.core_w);
    }

    #[test]
    fn maxeva_fp32_core_power_below_charm() {
        // §V-B1: MaxEVA uses MORE total cores than CHARM but LESS core
        // power (fp32 adder cores mostly idle).
        let (p, _) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        let charm = estimate_power_all_matmul(Precision::Fp32, 384, 3086, 4504.46 / 8000.0);
        assert!(p.core_w < charm.core_w);
    }

    #[test]
    fn total_power_near_paper_row1() {
        // Paper: 13×4×6 fp32 total 43.83 W; int8 66.83 W (±3%).
        let (p32, _) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        assert!((p32.total_w() - 43.83).abs() / 43.83 < 0.03, "{}", p32.total_w());
        let (p8, _) = run(13, 4, 6, Pattern::P1, Precision::Int8);
        assert!((p8.total_w() - 66.83).abs() / 66.83 < 0.03, "{}", p8.total_w());
    }

    #[test]
    fn energy_efficiency_near_paper_row1() {
        // Paper: 124.16 GFLOPs/W fp32; 1.152 TOPs/W int8 (±4%).
        let (p32, s32) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        let ee32 = p32.energy_efficiency(s32.ops_per_sec) / 1e9;
        assert!((ee32 - 124.16).abs() / 124.16 < 0.04, "{ee32}");
        let (p8, s8) = run(13, 4, 6, Pattern::P1, Precision::Int8);
        let ee8 = p8.energy_efficiency(s8.ops_per_sec) / 1e12;
        assert!((ee8 - 1.152).abs() / 1.152 < 0.04, "{ee8}");
    }

    #[test]
    fn int8_draws_more_than_fp32() {
        let (p8, _) = run(13, 4, 6, Pattern::P1, Precision::Int8);
        let (p32, _) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        assert!(p8.core_w > 1.5 * p32.core_w);
    }

    #[test]
    fn p2_more_add_cores_not_more_core_power_fp32() {
        // §V-B3: 10×3×10 (400 cores) has LOWER core power than 13×4×6
        // (390 cores) — fewer MatMul kernels, more idle adder cores.
        let (p1, _) = run(13, 4, 6, Pattern::P1, Precision::Fp32);
        let (p2, _) = run(10, 3, 10, Pattern::P2, Precision::Fp32);
        assert!(p2.core_w < p1.core_w);
    }
}
