//! Single AIE kernel optimization: the `M, K, N` integer program
//! (paper §IV-C1, eq. 1–6).
//!
//! Maximize `M·K·N` (more MACs ⇒ more vector-register reuse ⇒ higher
//! kernel efficiency) subject to:
//!
//! * eq. 3: `N ≥ eff_lb · peak_MACs · sizeof(a) / BW_IO`
//! * eq. 4: `M ≥ eff_lb · peak_MACs · sizeof(b) / BW_IO`
//! * eq. 5: `K ≥ eff_lb · peak_MACs · sizeof(c) / BW_IO`
//! * eq. 6: `M·K·sa + K·N·sb + M·N·sc ≤ 14 KB` (double-buffered budget)
//!
//! `M, K, N` are restricted to powers of two (paper §V-A: power-of-two
//! kernels measure higher efficiency), which makes exhaustive search
//! trivially cheap.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;
use crate::kernels::matmul::MatMulKernel;

/// One feasible tile-size candidate, ranked by MACs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCandidate {
    pub kernel: MatMulKernel,
    /// Objective value `M·K·N`.
    pub macs: u64,
}

/// Search bounds: powers of two from 2^2 to 2^9 cover everything that can
/// fit the 14 KB budget on both precisions.
fn pow2_range() -> Vec<u64> {
    (2..=9).map(|e| 1u64 << e).collect()
}

/// Lower bounds from eq. 3–5, rounded up to the next power of two the
/// search will actually test.
pub fn dim_lower_bounds(dev: &AieDevice, prec: Precision, eff_lb: f64) -> (f64, f64, f64) {
    let peak = prec.peak_macs_per_cycle() as f64;
    let bw = dev.bw_io_bytes_per_cycle as f64;
    let n_lb = eff_lb * peak * prec.sizeof_input() as f64 / bw; // eq. 3
    let m_lb = eff_lb * peak * prec.sizeof_input() as f64 / bw; // eq. 4
    let k_lb = eff_lb * peak * prec.sizeof_output() as f64 / bw; // eq. 5
    (m_lb, k_lb, n_lb)
}

/// Exhaustively solve the single-kernel IP. Returns all feasible
/// candidates sorted by (macs desc, latency asc, M, K, N) — the paper
/// reports the top-ranked points.
pub fn optimize_single_kernel(
    dev: &AieDevice,
    prec: Precision,
    eff_lb: f64,
) -> Vec<KernelCandidate> {
    let (m_lb, k_lb, n_lb) = dim_lower_bounds(dev, prec, eff_lb);
    let budget = dev.single_buffer_budget_bytes();
    let mut out = Vec::new();
    for &m in &pow2_range() {
        if (m as f64) < m_lb {
            continue;
        }
        for &k in &pow2_range() {
            if (k as f64) < k_lb {
                continue;
            }
            for &n in &pow2_range() {
                if (n as f64) < n_lb {
                    continue;
                }
                let kern = MatMulKernel::new(m, k, n, prec);
                if kern.buffer_bytes() > budget {
                    continue; // eq. 6
                }
                out.push(KernelCandidate {
                    kernel: kern,
                    macs: kern.macs(),
                });
            }
        }
    }
    out.sort_by(|a, b| {
        b.macs
            .cmp(&a.macs)
            .then(a.kernel.latency_cycles().cmp(&b.kernel.latency_cycles()))
            .then(a.kernel.m.cmp(&b.kernel.m))
            .then(a.kernel.k.cmp(&b.kernel.k))
            .then(a.kernel.n.cmp(&b.kernel.n))
    });
    out
}

/// The candidates achieving the maximum objective (the paper's
/// "top-ranked solutions").
pub fn top_ranked(cands: &[KernelCandidate]) -> Vec<KernelCandidate> {
    match cands.first() {
        None => vec![],
        Some(best) => cands.iter().copied().take_while(|c| c.macs == best.macs).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EFF_LB: f64 = 0.95; // paper §IV-C1: 95% lower bound

    #[test]
    fn int8_unique_solution_is_32x128x32() {
        // Paper §V-A: for int8, 32×128×32 is the ONLY feasible solution.
        let dev = AieDevice::vc1902();
        let cands = optimize_single_kernel(&dev, Precision::Int8, EFF_LB);
        let top = top_ranked(&cands);
        assert_eq!(top.len(), 1, "expected a unique int8 solution: {top:?}");
        let k = top[0].kernel;
        assert_eq!((k.m, k.k, k.n), (32, 128, 32));
        // And it is not merely top-ranked — it is the only feasible point.
        assert_eq!(cands.len(), 1, "all other int8 points violate eq. 2–6");
    }

    #[test]
    fn fp32_ties_at_32768_macs_including_paper_points() {
        // Paper §V-A: many fp32 top solutions, all with 32768 MACs,
        // e.g. 16×64×32, 64×16×32, 32×32×32.
        let dev = AieDevice::vc1902();
        let cands = optimize_single_kernel(&dev, Precision::Fp32, EFF_LB);
        let top = top_ranked(&cands);
        assert!(!top.is_empty());
        assert!(top.iter().all(|c| c.macs == 32768));
        let has = |m, k, n| top.iter().any(|c| (c.kernel.m, c.kernel.k, c.kernel.n) == (m, k, n));
        assert!(has(32, 32, 32), "paper/CHARM kernel must be top-ranked");
        assert!(has(16, 64, 32));
        assert!(has(64, 16, 32));
    }

    #[test]
    fn lower_bounds_match_hand_computation() {
        let dev = AieDevice::vc1902();
        // int8: N,M ≥ .95·128·1/4 = 30.4 ; K ≥ .95·128·4/4 = 121.6.
        let (m, k, n) = dim_lower_bounds(&dev, Precision::Int8, EFF_LB);
        assert!((m - 30.4).abs() < 1e-9);
        assert!((k - 121.6).abs() < 1e-9);
        assert!((n - 30.4).abs() < 1e-9);
        // fp32: all ≥ 7.6.
        let (m, k, n) = dim_lower_bounds(&dev, Precision::Fp32, EFF_LB);
        assert!((m - 7.6).abs() < 1e-9 && (k - 7.6).abs() < 1e-9 && (n - 7.6).abs() < 1e-9);
    }

    #[test]
    fn all_candidates_satisfy_constraints() {
        // With the paper's 95% efficiency bound, every candidate also
        // satisfies eq. 2 (I/O never exceeds compute) under the calibrated
        // latency model — eq. 3–5 are exactly that condition.
        let dev = AieDevice::vc1902();
        for prec in Precision::all() {
            for c in optimize_single_kernel(&dev, prec, EFF_LB) {
                assert!(c.kernel.buffer_bytes() <= dev.single_buffer_budget_bytes());
                assert!(c.kernel.io_feasible(&dev));
                assert!(c.kernel.efficiency() >= 0.90, "candidates stay near roofline");
            }
        }
    }

    #[test]
    fn relaxing_eff_lb_grows_search_space() {
        let dev = AieDevice::vc1902();
        let strict = optimize_single_kernel(&dev, Precision::Fp32, 0.95).len();
        let loose = optimize_single_kernel(&dev, Precision::Fp32, 0.5).len();
        assert!(loose > strict);
    }

    #[test]
    fn sorted_by_macs_descending() {
        let dev = AieDevice::vc1902();
        let cands = optimize_single_kernel(&dev, Precision::Fp32, 0.5);
        assert!(cands.windows(2).all(|w| w[0].macs >= w[1].macs));
    }
}
