//! Array-level mapping optimization: the `X, Y, Z` integer program
//! (paper §IV-C2, eq. 7–9).
//!
//! Maximize the number of MatMul kernels `X·Y·Z` subject to:
//!
//! * eq. 7: `X·Y·Z + X·Z ≤ AIE_cores`  (MatMul kernels + adder-tree cores)
//! * eq. 8: `X·Y + Y·Z ≤ PLIO_in`      (broadcast inputs)
//! * eq. 9: `X·Z ≤ PLIO_out`           (reduced outputs)
//!
//! Solved exhaustively; the paper reports multiple top-ranked points and
//! then filters them through PnR feasibility (our [`crate::routing`]
//! module reproduces that filter — e.g. 10×4×8 fails routing).

use crate::arch::device::AieDevice;

/// One feasible array mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayCandidate {
    pub x: u64,
    pub y: u64,
    pub z: u64,
}

impl ArrayCandidate {
    pub fn new(x: u64, y: u64, z: u64) -> Self {
        ArrayCandidate { x, y, z }
    }

    /// Number of MatMul kernels (the objective).
    pub fn matmul_kernels(&self) -> u64 {
        self.x * self.y * self.z
    }

    /// Number of adder-tree cores (one per group).
    pub fn adder_cores(&self) -> u64 {
        self.x * self.z
    }

    /// Total AIE cores used (eq. 7 LHS).
    pub fn total_cores(&self) -> u64 {
        self.matmul_kernels() + self.adder_cores()
    }

    /// Input PLIOs used (eq. 8 LHS): `X·Y` A-streams + `Y·Z` B-streams.
    pub fn plio_in(&self) -> u64 {
        self.x * self.y + self.y * self.z
    }

    /// Output PLIOs used (eq. 9 LHS): one per group.
    pub fn plio_out(&self) -> u64 {
        self.x * self.z
    }

    /// Total PLIOs used (Tables II/III "PLIOs" column).
    pub fn plios(&self) -> u64 {
        self.plio_in() + self.plio_out()
    }

    /// Number of groups (each: Y MatMul kernels + 1 adder-tree core).
    pub fn groups(&self) -> u64 {
        self.x * self.z
    }

    /// Feasibility under eq. 7–9 for `dev`.
    pub fn feasible(&self, dev: &AieDevice) -> bool {
        self.total_cores() <= dev.total_cores() as u64
            && self.plio_in() <= dev.plio_in as u64
            && self.plio_out() <= dev.plio_out as u64
    }

    /// Paper-style label, e.g. "13x4x6".
    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.x, self.y, self.z)
    }
}

/// Exhaustively solve the array IP. Returns all feasible candidates sorted
/// by (MatMul kernels desc, total cores asc, X desc) so ties prefer using
/// fewer cores. `y_range` restricts Y (the paper places patterns only for
/// Y ∈ {3,4} — pass `None` to search all Y).
pub fn optimize_array(dev: &AieDevice, y_range: Option<(u64, u64)>) -> Vec<ArrayCandidate> {
    // eq. 8 bounds Y directly: X·Y + Y·Z = Y·(X+Z) ≤ PLIO_in with
    // X, Z ≥ 1, so Y ≤ PLIO_in/2. Scanning Y to total_cores (400 on the
    // VC1902) only walked 360+ provably-infeasible outer iterations.
    let y_cap = (dev.plio_in as u64 / 2).max(1);
    let (y_lo, y_hi) = y_range.unwrap_or((1, y_cap));
    let mut out = Vec::new();
    for y in y_lo..=y_hi.min(y_cap) {
        // x·y ≤ plio_in gives a cheap bound on x; same for z.
        for x in 1..=(dev.plio_in as u64 / y.max(1)).max(1) {
            for z in 1..=(dev.plio_out as u64 / x.max(1)).max(1) {
                let c = ArrayCandidate::new(x, y, z);
                if c.feasible(dev) {
                    out.push(c);
                }
            }
        }
    }
    out.sort_by(|a, b| {
        b.matmul_kernels()
            .cmp(&a.matmul_kernels())
            .then(a.total_cores().cmp(&b.total_cores()))
            .then(b.x.cmp(&a.x))
    });
    out
}

/// Return the best `n` *distinct kernel-count* tiers (the paper examines
/// the top-ranked design points tier by tier).
pub fn top_tiers(cands: &[ArrayCandidate], n: usize) -> Vec<Vec<ArrayCandidate>> {
    let mut tiers: Vec<Vec<ArrayCandidate>> = Vec::new();
    for &c in cands {
        let same_tier = tiers
            .last()
            .is_some_and(|t| t[0].matmul_kernels() == c.matmul_kernels());
        if same_tier {
            tiers.last_mut().unwrap().push(c);
        } else if tiers.len() < n {
            tiers.push(vec![c]);
        } else {
            break;
        }
    }
    tiers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    #[test]
    fn paper_configs_are_feasible_with_paper_counts() {
        // All six Table II/III configurations, with their reported
        // kernel counts, core counts and PLIO usage.
        let rows: &[(u64, u64, u64, u64, u64, u64)] = &[
            // (X, Y, Z, kernels, cores, plios)
            (13, 4, 6, 312, 390, 154),
            (10, 3, 10, 300, 400, 160),
            (11, 4, 7, 308, 385, 149),
            (11, 3, 9, 297, 396, 159),
            (12, 4, 6, 288, 360, 144),
            (12, 3, 8, 288, 384, 156),
        ];
        for &(x, y, z, kernels, cores, plios) in rows {
            let c = ArrayCandidate::new(x, y, z);
            assert!(c.feasible(&dev()), "{} must be feasible", c.label());
            assert_eq!(c.matmul_kernels(), kernels, "{}", c.label());
            assert_eq!(c.total_cores(), cores, "{}", c.label());
            assert_eq!(c.plios(), plios, "{}", c.label());
        }
    }

    #[test]
    fn global_optimum_is_10x4x8() {
        // Paper §V-B1: 10×4×8 maximizes kernels (320, all 400 cores) but
        // later fails PnR; the optimizer itself must rank it first.
        let cands = optimize_array(&dev(), None);
        let best = cands[0];
        assert_eq!(best.matmul_kernels(), 320);
        assert!(cands
            .iter()
            .take_while(|c| c.matmul_kernels() == 320)
            .any(|c| (c.x, c.y, c.z) == (10, 4, 8)));
    }

    #[test]
    fn second_tier_is_312_with_13x4x6() {
        // Paper: the second top-ranked solution is 13×4×6 (312 kernels).
        let cands = optimize_array(&dev(), None);
        let tiers = top_tiers(&cands, 2);
        assert_eq!(tiers[1][0].matmul_kernels(), 312);
        assert!(tiers[1].iter().any(|c| (c.x, c.y, c.z) == (13, 4, 6)));
    }

    #[test]
    fn top_solutions_have_y_3_or_4() {
        // Paper §IV-D: placement patterns exist only for Y = 3, 4 because
        // those dominate the top tiers.
        let cands = optimize_array(&dev(), None);
        for tier in top_tiers(&cands, 4) {
            assert!(tier.iter().any(|c| c.y == 3 || c.y == 4));
            // No tier in the top 4 is exclusively another Y.
            assert!(tier.iter().all(|c| c.matmul_kernels() >= 297));
        }
    }

    #[test]
    fn all_results_satisfy_constraints() {
        let d = dev();
        for c in optimize_array(&d, None) {
            assert!(c.total_cores() <= 400);
            assert!(c.plio_in() <= 78);
            assert!(c.plio_out() <= 117);
        }
    }

    #[test]
    fn y_range_filter_respected() {
        let cands = optimize_array(&dev(), Some((3, 4)));
        assert!(cands.iter().all(|c| c.y == 3 || c.y == 4));
        assert!(!cands.is_empty());
    }

    #[test]
    fn generalizes_to_smaller_device() {
        // The model is device-generic (paper §IV: "generalizable to any
        // Versal device").
        let d = AieDevice::half_vc1902();
        let cands = optimize_array(&d, None);
        assert!(!cands.is_empty());
        let best = cands[0];
        assert!(best.total_cores() <= 200);
        assert!(best.plio_in() <= 38);
    }

    #[test]
    fn tight_y_bound_loses_no_candidates() {
        // The eq.-8 cap on Y (Y·(X+Z) ≤ PLIO_in, X,Z ≥ 1 → Y ≤ PLIO_in/2)
        // must yield exactly the candidate set of the old unbounded scan
        // (Y up to total_cores), on both device models.
        for d in [AieDevice::vc1902(), AieDevice::half_vc1902()] {
            let bounded = optimize_array(&d, None);
            let mut reference = Vec::new();
            for y in 1..=d.total_cores() as u64 {
                for x in 1..=(d.plio_in as u64 / y.max(1)).max(1) {
                    for z in 1..=(d.plio_out as u64 / x.max(1)).max(1) {
                        let c = ArrayCandidate::new(x, y, z);
                        if c.feasible(&d) {
                            reference.push(c);
                        }
                    }
                }
            }
            assert_eq!(bounded.len(), reference.len());
            let mut b: Vec<_> = bounded.iter().map(|c| (c.x, c.y, c.z)).collect();
            let mut r: Vec<_> = reference.iter().map(|c| (c.x, c.y, c.z)).collect();
            b.sort_unstable();
            r.sort_unstable();
            assert_eq!(b, r);
        }
    }

    #[test]
    fn y_above_cap_is_always_infeasible() {
        // Directly: any Y > PLIO_in/2 violates eq. 8 for every X, Z ≥ 1.
        let d = dev();
        let cap = d.plio_in as u64 / 2;
        assert!(!ArrayCandidate::new(1, cap + 1, 1).feasible(&d));
        assert!(optimize_array(&d, Some((cap + 1, cap + 10))).is_empty());
    }

    #[test]
    fn plio_accounting_formulas() {
        let c = ArrayCandidate::new(13, 4, 6);
        assert_eq!(c.plio_in(), 13 * 4 + 4 * 6); // 76
        assert_eq!(c.plio_out(), 78);
        assert_eq!(c.groups(), 78);
        assert_eq!(c.adder_cores(), 78);
    }
}
