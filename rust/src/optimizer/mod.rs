//! The MaxEVA analytical optimization model (paper §IV-C).
//!
//! Two nested integer programs, both solved by exhaustive search exactly as
//! in the paper (the search spaces are tiny once M,K,N are restricted to
//! powers of two and the X,Y,Z constants are in the hundreds):
//!
//! * [`single_kernel`] — choose the tile size `M×K×N` of the single-AIE
//!   MatMul kernel, maximizing MACs subject to the efficiency bound
//!   (eq. 1), the I/O-bandwidth bounds (eq. 2–5) and the local-memory
//!   bound (eq. 6).
//! * [`array`] — choose the array mapping `X×Y×Z`, maximizing the number
//!   of MatMul kernels `X·Y·Z` subject to the core-count bound (eq. 7)
//!   and the PLIO bounds (eq. 8–9).

pub mod array;
pub mod single_kernel;

pub use array::{optimize_array, ArrayCandidate};
pub use single_kernel::{optimize_single_kernel, KernelCandidate};
