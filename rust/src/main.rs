//! `maxeva` — the MaxEVA launcher.
//!
//! Subcommands:
//!   optimize  [--precision fp32|int8] [--eff-lb 0.95]   kernel + array DSE
//!   evaluate  [--precision P] [--config cfg.json]       one table row
//!   table1                                              paper Table I
//!   table2                                              paper Table II (fp32)
//!   table3                                              paper Table III (int8)
//!   fig8      [--precision P]                           matrix-size sweep
//!   mlp                                                 §V-B4 MLP estimate
//!   serve     [--requests N] [--size S] [--config cfg]  end-to-end serving
//!   info                                                device + artifact info

// Same lint posture as the library crate (see rust/src/lib.rs). The
// `serve` subcommand replays a closed batch through the deprecated
// `run_batch` wrapper (`coordinator::compat`) on purpose.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]
#![allow(deprecated)]

use maxeva::arch::device::AieDevice;
use maxeva::arch::precision::Precision;
use maxeva::charm::CharmDesign;
use maxeva::config::schema::{DesignConfig, ServeConfig};
use maxeva::coordinator::server::MatMulServer;
use maxeva::kernels::add::AddKernel;
use maxeva::kernels::matmul::MatMulKernel;
use maxeva::optimizer::array::{optimize_array, top_tiers};
use maxeva::optimizer::single_kernel::{optimize_single_kernel, top_ranked};
use maxeva::placement::pattern::Pattern;
use maxeva::report::evaluate::{evaluate_config, paper_configs};
use maxeva::report::paper;
use maxeva::report::table::{pct, Table};
use maxeva::runtime::default_artifacts_dir;
use maxeva::sim::engine::SimConfig;
use maxeva::tiling::mlp::{charm_mlp, estimate_mlp};
use maxeva::tiling::padding::TiledWorkload;
use maxeva::workloads::{random_trace, square_sweep};

/// Tiny argv parser: flags of the form `--key value`.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let val = rest.get(i + 1).cloned().unwrap_or_default();
                flags.push((key.to_string(), val));
                i += 2;
            } else {
                i += 1;
            }
        }
        Args { cmd, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn precision(&self) -> Precision {
        self.get("precision")
            .and_then(Precision::parse)
            .unwrap_or(Precision::Fp32)
    }
}

fn main() {
    let args = Args::parse();
    let code = match args.cmd.as_str() {
        "optimize" => cmd_optimize(&args),
        "evaluate" => cmd_evaluate(&args),
        "table1" => cmd_table1(),
        "table2" => cmd_table(Precision::Fp32),
        "table3" => cmd_table(Precision::Int8),
        "fig8" => cmd_fig8(&args),
        "mlp" => cmd_mlp(),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            eprint!("{}", HELP);
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
maxeva — MaxEVA (MatMul on Versal AI Engine) reproduction

USAGE: maxeva <command> [--flag value ...]

COMMANDS:
  optimize   kernel (M,K,N) + array (X,Y,Z) design-space exploration
  evaluate   place/route/simulate/power one configuration
  table1     single-kernel results (paper Table I)
  table2     fp32 full-array results vs CHARM (paper Table II)
  table3     int8 full-array results vs CHARM (paper Table III)
  fig8       throughput vs matrix size (paper Fig. 8)
  mlp        MLP inference estimate (paper §V-B4)
  serve      end-to-end serving through the PJRT runtime (needs artifacts)
  info       device + artifact status

FLAGS:
  --precision fp32|int8     (default fp32)
  --eff-lb <0..1>           kernel-efficiency lower bound (default 0.95)
  --config <file.json>      design config (default: paper flagship 13x4x6)
  --x/--y/--z <int>         explicit mapping for `evaluate`
  --pattern P1|P2           placement pattern for `evaluate`
  --requests <n>            serving requests (default 4)
  --size <n>                serving request square size (default 512)
";

fn load_design(args: &Args) -> DesignConfig {
    if let Some(path) = args.get("config") {
        match DesignConfig::load(std::path::Path::new(path)) {
            Ok(c) => return c,
            Err(e) => {
                eprintln!("failed to load {path}: {e}; using flagship defaults");
            }
        }
    }
    DesignConfig::flagship(args.precision())
}

fn cmd_optimize(args: &Args) -> i32 {
    let dev = AieDevice::vc1902();
    let prec = args.precision();
    let eff_lb: f64 = args.get("eff-lb").and_then(|s| s.parse().ok()).unwrap_or(0.95);

    println!("== Single-kernel optimization (eq. 3–6), {prec}, eff_lb={eff_lb} ==");
    let cands = optimize_single_kernel(&dev, prec, eff_lb);
    let top = top_ranked(&cands);
    let mut t = Table::new(vec!["M×K×N", "MACs", "latency(cyc)", "efficiency", "buffers(B)"]);
    for c in top.iter().take(10) {
        t.row(vec![
            format!("{}x{}x{}", c.kernel.m, c.kernel.k, c.kernel.n),
            format!("{}", c.macs),
            format!("{}", c.kernel.latency_cycles()),
            format!("{:.2}%", c.kernel.efficiency() * 100.0),
            format!("{}", c.kernel.buffer_bytes()),
        ]);
    }
    print!("{}", t.render());
    println!("({} feasible points, {} top-ranked)\n", cands.len(), top.len());

    println!("== Array optimization (eq. 7–9) ==");
    let arr = optimize_array(&dev, None);
    let mut t = Table::new(vec!["X×Y×Z", "kernels", "cores", "PLIO in", "PLIO out", "routes?"]);
    for tier in top_tiers(&arr, 4) {
        for c in tier.iter().take(4) {
            let routable = Pattern::for_y(c.y)
                .and_then(|p| {
                    maxeva::placement::placer::place_design(
                        &dev, *c, p, MatMulKernel::paper_kernel(prec),
                    )
                    .ok()
                })
                .map(|pd| maxeva::routing::router::route_design(&dev, &pd).is_ok());
            t.row(vec![
                c.label(),
                format!("{}", c.matmul_kernels()),
                format!("{}", c.total_cores()),
                format!("{}", c.plio_in()),
                format!("{}", c.plio_out()),
                match routable {
                    Some(true) => "yes".to_string(),
                    Some(false) => "NO (PnR)".to_string(),
                    None => "no pattern".to_string(),
                },
            ]);
        }
    }
    print!("{}", t.render());
    0
}

fn cmd_evaluate(args: &Args) -> i32 {
    let design = load_design(args);
    let dev = match design.device() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let (x, y, z) = (
        args.get("x").and_then(|s| s.parse().ok()).unwrap_or(design.x),
        args.get("y").and_then(|s| s.parse().ok()).unwrap_or(design.y),
        args.get("z").and_then(|s| s.parse().ok()).unwrap_or(design.z),
    );
    let pattern = args
        .get("pattern")
        .and_then(Pattern::parse)
        .unwrap_or(design.pattern);
    match evaluate_config(&dev, x, y, z, pattern, design.precision, &SimConfig::default()) {
        Ok(r) => {
            println!("config      : {} {} on {}", r.label, r.prec, dev.name);
            println!(
                "kernels     : {} MatMul + {} adder cores",
                r.matmul_kernels,
                r.total_cores - r.matmul_kernels
            );
            println!("cores       : {} ({:.1}%)", r.total_cores, r.core_util * 100.0);
            println!(
                "memory banks: {} ({:.1}%)  DMA banks: {}",
                r.memory_banks,
                r.bank_util * 100.0,
                r.dma_banks
            );
            println!("PLIOs       : {} ({:.1}%)", r.plios, r.plio_util * 100.0);
            println!("period      : {:.1} cycles", r.sim.period_cycles);
            println!("throughput  : {:.2} {}", r.throughput_table_units(), r.prec.ops_unit());
            println!(
                "power       : {:.2} W (core {:.2} + mem {:.2})",
                r.power.total_w(),
                r.power.core_w,
                r.power.memory_w
            );
            println!("energy eff. : {:.2} {}/W", r.energy_eff_table_units(), r.prec.ops_unit());
            0
        }
        Err(e) => {
            eprintln!("evaluation failed: {e}");
            1
        }
    }
}

fn cmd_table1() -> i32 {
    let mut t = Table::new(vec![
        "Kernel", "Latency(cyc)", "paper", "Thr(MACs/cyc)", "paper", "Eff", "paper",
    ]);
    let mm8 = MatMulKernel::paper_kernel(Precision::Int8);
    let mm32 = MatMulKernel::paper_kernel(Precision::Fp32);
    let a8 = AddKernel::new(32, 32, Precision::Int8);
    let a32 = AddKernel::new(32, 32, Precision::Fp32);
    #[rustfmt::skip]
    let rows: Vec<(String, u64, f64, f64)> = vec![
        ("MatMul int8 32x128x32".into(), mm8.latency_cycles(), mm8.throughput_macs_per_cycle(), mm8.efficiency()),
        ("Add int32 32x32".into(), a8.latency_cycles(), a8.throughput_ops_per_cycle(), a8.efficiency()),
        ("MatMul fp32 32x32x32".into(), mm32.latency_cycles(), mm32.throughput_macs_per_cycle(), mm32.efficiency()),
        ("Add fp32 32x32".into(), a32.latency_cycles(), a32.throughput_ops_per_cycle(), a32.efficiency()),
    ];
    for (r, p) in rows.iter().zip(paper::table1()) {
        t.row(vec![
            r.0.clone(),
            format!("{}", r.1),
            format!("{}", p.latency_cyc),
            format!("{:.2}", r.2),
            format!("{:.2}", p.throughput_macs_per_cyc),
            format!("{:.2}%", r.3 * 100.0),
            format!("{:.2}%", p.efficiency * 100.0),
        ]);
    }
    println!("Table I — single AIE kernel results (measured vs paper)");
    print!("{}", t.render());
    0
}

fn cmd_table(prec: Precision) -> i32 {
    let dev = AieDevice::vc1902();
    let paper_rows = match prec {
        Precision::Fp32 => paper::table2_fp32(),
        Precision::Int8 => paper::table3_int8(),
        other => {
            eprintln!("no paper table exists for {other} (extension precision)");
            return 1;
        }
    };
    let unit = prec.ops_unit();
    println!(
        "Table {} — MaxEVA configurations, {prec} (measured vs paper)",
        if prec == Precision::Fp32 { "II" } else { "III" }
    );
    let thr_hdr = format!("Thr({unit})");
    let mut t = Table::new(vec![
        "Cfg", "kernels", "cores", "banks", "DMA", "PLIOs",
        thr_hdr.as_str(), "paper", "Δ", "Power(W)", "paper", "EE", "paper",
    ]);
    for ((x, y, z, pat), p) in paper_configs().iter().zip(&paper_rows) {
        let r = match evaluate_config(&dev, *x, *y, *z, *pat, prec, &SimConfig::default()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{x}x{y}x{z}: {e}");
                continue;
            }
        };
        let paper_thr = match prec {
            Precision::Fp32 | Precision::Bf16 => p.throughput_gops,
            Precision::Int8 | Precision::Int16 => p.throughput_gops / 1000.0,
        };
        t.row(vec![
            r.label.clone(),
            format!("{}", r.matmul_kernels),
            format!("{} ({:.1}%)", r.total_cores, r.core_util * 100.0),
            format!("{}", r.memory_banks),
            format!("{}", r.dma_banks),
            format!("{} ({:.1}%)", r.plios, r.plio_util * 100.0),
            format!("{:.2}", r.throughput_table_units()),
            format!("{paper_thr:.2}"),
            pct(paper::rel_delta(r.throughput_table_units(), paper_thr)),
            format!("{:.2}", r.power.total_w()),
            p.power_w.map_or("—".into(), |w| format!("{w:.2}")),
            format!("{:.2}", r.energy_eff_table_units()),
            p.energy_eff.map_or("—".into(), |e| format!("{e:.2}")),
        ]);
    }
    // CHARM baseline row.
    let charm = CharmDesign::for_precision(prec);
    let cr = charm.simulate(&dev);
    let cp = charm.power(&dev);
    let charm_paper = paper::charm_row(prec);
    let thr = match prec {
        Precision::Fp32 | Precision::Bf16 => cr.ops_per_sec / 1e9,
        Precision::Int8 | Precision::Int16 => cr.ops_per_sec / 1e12,
    };
    let paper_thr = match prec {
        Precision::Fp32 | Precision::Bf16 => charm_paper.throughput_gops,
        Precision::Int8 | Precision::Int16 => charm_paper.throughput_gops / 1000.0,
    };
    let ee = match prec {
        Precision::Fp32 | Precision::Bf16 => cp.energy_efficiency(cr.ops_per_sec) / 1e9,
        Precision::Int8 | Precision::Int16 => cp.energy_efficiency(cr.ops_per_sec) / 1e12,
    };
    t.row(vec![
        "CHARM [19,34]".to_string(),
        format!("{}", charm.kernels),
        format!("{} ({:.1}%)", charm.kernels, charm.core_utilization(&dev) * 100.0),
        format!("{}", charm.memory_banks),
        "0".to_string(),
        format!("{} ({:.1}%)", charm.plios, charm.plio_utilization(&dev) * 100.0),
        format!("{thr:.2}"),
        format!("{paper_thr:.2}"),
        pct(paper::rel_delta(thr, paper_thr)),
        format!("{:.2}", cp.total_w()),
        charm_paper.power_w.map_or("—".into(), |w| format!("{w:.2}")),
        format!("{ee:.3}"),
        charm_paper.energy_eff.map_or("—".into(), |e| format!("{e:.2}")),
    ]);
    print!("{}", t.render());
    if prec == Precision::Int8 {
        println!(
            "note: CHARM int8 power is not published (closed source); EE column model-estimated."
        );
    }
    0
}

fn cmd_fig8(args: &Args) -> i32 {
    let dev = AieDevice::vc1902();
    let prec = args.precision();
    let design = DesignConfig::flagship(prec);
    let r = evaluate_config(
        &dev, design.x, design.y, design.z, design.pattern, prec, &SimConfig::default(),
    )
    .unwrap();
    println!("Fig. 8 — throughput vs square matrix size, 13x4x6 {prec}");
    let thr_hdr = format!("throughput ({})", prec.ops_unit());
    let mut t = Table::new(vec!["size", "invocations", "useful ratio", thr_hdr.as_str()]);
    for s in square_sweep(256, 16384) {
        let w = TiledWorkload::new(s, s, s, &design.candidate(), &design.kernel());
        let thr = w.effective_ops_per_sec(r.ops_per_sec);
        t.row(vec![
            format!("{s}"),
            format!("{}", w.invocations()),
            format!("{:.4}", w.useful_ratio()),
            match prec {
                Precision::Fp32 | Precision::Bf16 => format!("{:.1}", thr / 1e9),
                Precision::Int8 | Precision::Int16 => format!("{:.2}", thr / 1e12),
            },
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_mlp() -> i32 {
    let dev = AieDevice::vc1902();
    let design = DesignConfig::flagship(Precision::Fp32);
    let r = evaluate_config(
        &dev, design.x, design.y, design.z, design.pattern, Precision::Fp32, &SimConfig::default(),
    )
    .unwrap();
    let est = estimate_mlp(
        &charm_mlp(),
        &design.candidate(),
        &design.kernel(),
        r.sim.period_cycles,
        dev.freq_hz,
    );
    println!("MLP inference estimate (paper §V-B4)");
    println!(
        "MaxEVA : {:.2} GFLOPs (paper {:.2}, Δ {})",
        est.ops_per_sec / 1e9,
        paper::MLP_MAXEVA_GFLOPS,
        pct(paper::rel_delta(est.ops_per_sec / 1e9, paper::MLP_MAXEVA_GFLOPS))
    );
    println!("CHARM  : {:.2} GFLOPs (scaled from [19])", paper::MLP_CHARM_GFLOPS);
    println!(
        "gain   : {:.2}x (paper: 1.29x)",
        est.ops_per_sec / 1e9 / paper::MLP_CHARM_GFLOPS
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let design = load_design(args);
    let mut cfg = ServeConfig::new(design);
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();
    let n: usize = args.get("requests").and_then(|s| s.parse().ok()).unwrap_or(4);
    let size: u64 = args.get("size").and_then(|s| s.parse().ok()).unwrap_or(512);

    let mut server = match MatMulServer::start(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: {e}");
            return 1;
        }
    };
    println!(
        "device ready: native size {:?}, backend {}, {} workers, pipeline window {}",
        server.native(),
        server.backend(),
        server.workers(),
        server.pipeline_depth()
    );
    let mut rng = maxeva::util::prng::XorShift64::new(99);
    let reqs: Vec<_> = random_trace(n, 5)
        .into_iter()
        .map(|mut r| {
            r.m = size;
            r.k = size;
            r.n = size;
            r
        })
        .collect();
    let batch: Vec<_> = reqs
        .iter()
        .map(|r| {
            let a: Vec<f32> =
                (0..r.m * r.k).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
            let b: Vec<f32> =
                (0..r.k * r.n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
            (*r, a, b)
        })
        .collect();
    match server.run_batch(batch) {
        Ok(outs) => {
            let stats = server.stats();
            println!(
                "served {} requests ({} fp32 / {} int8, {} tile invocations)",
                stats.requests, stats.requests_fp32, stats.requests_int8, stats.invocations
            );
            println!("mean latency : {:.1} ms (wall, CPU emulation)", stats.mean_latency_ms);
            println!("device time  : {:.3} ms total", stats.device_time_s * 1e3);
            println!(
                "device thr   : {:.2} GFLOPs (VCK190-equivalent)",
                stats.device_ops_per_sec / 1e9
            );
            let checksum: f32 = outs.iter().flat_map(|o| o.iter()).sum();
            println!("checksum     : {checksum:.3}");
            server.shutdown();
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    let dev = AieDevice::vc1902();
    println!(
        "device        : {} ({} rows x {} cols = {} AIE cores)",
        dev.name, dev.rows, dev.cols, dev.total_cores()
    );
    println!(
        "memory        : {} KB/tile, {} banks/tile, {} total banks",
        dev.data_mem_bytes / 1024, dev.banks_per_tile, dev.total_banks()
    );
    println!(
        "PLIOs         : {} in / {} out ({} interface tiles)",
        dev.plio_in, dev.plio_out, dev.aie_pl_tiles
    );
    println!(
        "clock         : {:.2} GHz AIE / {:.1} MHz PL (PLIO width {} bits)",
        dev.freq_hz / 1e9, dev.pl_freq_hz / 1e6, dev.plio_width_bits()
    );
    println!(
        "peak          : {:.1} TFLOPs fp32 / {:.1} TOPs int8",
        dev.peak_ops_per_sec(Precision::Fp32) / 1e12,
        dev.peak_ops_per_sec(Precision::Int8) / 1e12
    );
    let dir = default_artifacts_dir();
    println!(
        "artifacts     : {} ({})",
        dir.display(),
        if maxeva::runtime::artifacts_available(&dir) {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
    0
}
