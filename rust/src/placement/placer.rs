//! The whole-array placer: fills the AIE array with groups following
//! pattern P1 or P2 and produces the resource accounting of Tables II/III
//! (AIE cores, memory banks, DMA banks).

use crate::arch::device::AieDevice;
use crate::arch::topology::Coord;
use crate::kernels::matmul::MatMulKernel;
use crate::optimizer::array::ArrayCandidate;
use crate::placement::group::{GroupShape, PlacedGroup};
use crate::placement::pattern::Pattern;

/// P1 places one "T"-like filler shape per this many groups (inferred from
/// the paper's published DMA-bank counts: 18 banks for 78 and 77 groups,
/// 16 for 72, at 2 banks per double-buffered DMA output buffer).
pub const P1_GROUPS_PER_TSHAPE: usize = 9;

/// Memory banks consumed by one DMA-connected (double-buffered) output
/// buffer.
pub const BANKS_PER_DMA_BUFFER: u64 = 2;

/// Fraction of the banks of *unused* tiles that the PnR tool still claims
/// for stream FIFOs / buffer spreading (fit on Table II, see DESIGN.md §5).
pub const PNR_SPILL_FRACTION: f64 = 0.15;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PlacementError {
    #[error("pattern {pattern} requires Y={want}, design has Y={got}")]
    WrongY { pattern: Pattern, want: u64, got: u64 },
    #[error("design needs {need} groups but pattern capacity is {capacity}")]
    DoesNotFit { need: usize, capacity: usize },
    #[error("no placement pattern for Y={0} (paper proposes Y=3,4 only)")]
    UnsupportedY(u64),
    #[error("group validation failed: {0}")]
    Invalid(String),
}

/// A fully placed design with its resource accounting.
#[derive(Debug, Clone)]
pub struct PlacedDesign {
    pub cand: ArrayCandidate,
    pub pattern: Pattern,
    pub kernel: MatMulKernel,
    pub groups: Vec<PlacedGroup>,
    /// Memory banks used by DMA connections (Tables II/III "DMA banks").
    pub dma_banks: u64,
    /// Total memory banks used (Tables II/III "Memory banks").
    pub memory_banks: u64,
}

impl PlacedDesign {
    pub fn total_cores(&self) -> u64 {
        self.cand.total_cores()
    }

    pub fn matmul_kernels(&self) -> u64 {
        self.cand.matmul_kernels()
    }

    pub fn unused_cores(&self, dev: &AieDevice) -> u64 {
        dev.total_cores() as u64 - self.total_cores()
    }

    /// Number of T-shaped groups (P1 fillers).
    pub fn t_shapes(&self) -> usize {
        self.groups.iter().filter(|g| g.shape == GroupShape::TShape).count()
    }

    /// Utilization of AIE cores [0, 1].
    pub fn core_utilization(&self, dev: &AieDevice) -> f64 {
        self.total_cores() as f64 / dev.total_cores() as f64
    }

    /// Utilization of memory banks [0, 1].
    pub fn bank_utilization(&self, dev: &AieDevice) -> f64 {
        self.memory_banks as f64 / dev.total_banks() as f64
    }

    /// Utilization of PLIOs [0, 1].
    pub fn plio_utilization(&self, dev: &AieDevice) -> f64 {
        self.cand.plios() as f64 / dev.total_plios() as f64
    }

    /// Validate every group against the sharing rules and check that no
    /// core is used twice and everything is in bounds.
    pub fn validate(&self, dev: &AieDevice) -> Result<(), PlacementError> {
        // §Perf: FxHashSet (validate is on the DSE hot path).
        let mut seen = rustc_hash::FxHashSet::default();
        for g in &self.groups {
            g.validate(dev).map_err(PlacementError::Invalid)?;
            for c in g.cores() {
                if c.row >= dev.rows || c.col >= dev.cols {
                    return Err(PlacementError::Invalid(format!(
                        "core {c:?} out of bounds"
                    )));
                }
                if !seen.insert(c) {
                    return Err(PlacementError::Invalid(format!(
                        "core {c:?} used by two groups"
                    )));
                }
            }
        }
        if seen.len() != self.total_cores() as usize {
            return Err(PlacementError::Invalid(format!(
                "placed {} cores, expected {}",
                seen.len(),
                self.total_cores()
            )));
        }
        Ok(())
    }
}

/// Pattern capacity in groups for a device.
pub fn capacity(dev: &AieDevice, pattern: Pattern) -> usize {
    let bands = dev.rows / 2;
    match pattern {
        // P1: 2-row bands hold pairs of 5-core groups in 5-column strips.
        Pattern::P1 => bands * (dev.cols / 5) * 2,
        // P2: 2×2 squares.
        Pattern::P2 => bands * (dev.cols / 2),
    }
}

/// Place `cand` on `dev` using `pattern`.
pub fn place_design(
    dev: &AieDevice,
    cand: ArrayCandidate,
    pattern: Pattern,
    kernel: MatMulKernel,
) -> Result<PlacedDesign, PlacementError> {
    if pattern.y() != cand.y {
        return Err(PlacementError::WrongY {
            pattern,
            want: pattern.y(),
            got: cand.y,
        });
    }
    let need = cand.groups() as usize;
    let cap = capacity(dev, pattern);
    if need > cap {
        return Err(PlacementError::DoesNotFit { need, capacity: cap });
    }

    let slots = match pattern {
        Pattern::P1 => p1_slots(dev),
        Pattern::P2 => p2_slots(dev),
    };
    debug_assert!(slots.len() >= need);

    let mut groups = Vec::with_capacity(need);
    for (id, slot) in slots.into_iter().take(need).enumerate() {
        // P1 designates every P1_GROUPS_PER_TSHAPE-th group (starting with
        // the first) as the "T"-like filler of Fig. 7 whose 4th MatMul
        // output buffer travels over DMA — ceil(groups/9) T-shapes total,
        // matching the paper's 18/18/16 DMA-bank counts.
        let is_t = pattern == Pattern::P1 && id % P1_GROUPS_PER_TSHAPE == 0;
        let shape = if is_t { GroupShape::TShape } else { GroupShape::Clean };
        let mut out_buf = Vec::with_capacity(slot.matmuls.len());
        for (k, mm) in slot.matmuls.iter().enumerate() {
            if is_t && k == slot.matmuls.len() - 1 {
                out_buf.push(None); // DMA-connected
            } else {
                let module = PlacedGroup::find_shared_module(*mm, slot.adder, dev)
                    .ok_or_else(|| {
                        PlacementError::Invalid(format!(
                            "no shared module between {:?} and adder {:?}",
                            mm, slot.adder
                        ))
                    })?;
                out_buf.push(Some(module));
            }
        }
        groups.push(PlacedGroup {
            id,
            matmuls: slot.matmuls,
            adder: slot.adder,
            out_buf_module: out_buf,
            shape,
        });
    }

    let dma_banks: u64 = groups
        .iter()
        .map(|g| g.dma_buffers() as u64 * BANKS_PER_DMA_BUFFER)
        .sum();
    let used = cand.total_cores();
    let unused = dev.total_cores() as u64 - used;
    // Bank accounting (DESIGN.md §5): the AMD PnR tool spreads buffers
    // across essentially all banks of a used tile to avoid access
    // conflicts (observed ≈8 banks/core across every Table II/III row),
    // plus the DMA ping-pong banks, plus a spill fraction on unused tiles.
    let memory_banks = used * dev.banks_per_tile
        + dma_banks
        + (unused as f64 * dev.banks_per_tile as f64 * PNR_SPILL_FRACTION).round() as u64;

    let design = PlacedDesign {
        cand,
        pattern,
        kernel,
        groups,
        dma_banks,
        memory_banks: memory_banks.min(dev.total_banks()),
    };
    design.validate(dev)?;
    Ok(design)
}

/// Convenience: place with the pattern implied by Y.
pub fn place_auto(
    dev: &AieDevice,
    cand: ArrayCandidate,
    kernel: MatMulKernel,
) -> Result<PlacedDesign, PlacementError> {
    let pattern = Pattern::for_y(cand.y).ok_or(PlacementError::UnsupportedY(cand.y))?;
    place_design(dev, cand, pattern, kernel)
}

/// A group slot: core coordinates before buffer assignment.
struct Slot {
    matmuls: Vec<Coord>,
    adder: Coord,
}

/// P1 slots: per 2-row band, 5-column strips hold a pair of groups
/// (see module docs of [`crate::placement`] for the legality argument).
fn p1_slots(dev: &AieDevice) -> Vec<Slot> {
    let mut slots = Vec::new();
    for band in 0..dev.rows / 2 {
        let r = 2 * band; // even row
        for strip in 0..dev.cols / 5 {
            let c = 5 * strip;
            // Group A: MatMuls (r,c), (r+1,c), (r+1,c+1), (r,c+2); adder (r,c+1).
            slots.push(Slot {
                matmuls: vec![
                    Coord::new(r, c),
                    Coord::new(r + 1, c),
                    Coord::new(r + 1, c + 1),
                    Coord::new(r, c + 2),
                ],
                adder: Coord::new(r, c + 1),
            });
            // Group B (mirrored): MatMuls (r,c+3), (r,c+4), (r+1,c+4),
            // (r+1,c+2); adder (r+1,c+3).
            slots.push(Slot {
                matmuls: vec![
                    Coord::new(r, c + 3),
                    Coord::new(r, c + 4),
                    Coord::new(r + 1, c + 4),
                    Coord::new(r + 1, c + 2),
                ],
                adder: Coord::new(r + 1, c + 3),
            });
        }
    }
    slots
}

/// P2 slots: 2×2 squares, adder at the even-row east cell (reaches its own
/// module, the north module and the west module — covering all three
/// MatMul outputs).
fn p2_slots(dev: &AieDevice) -> Vec<Slot> {
    let mut slots = Vec::new();
    for band in 0..dev.rows / 2 {
        let r = 2 * band;
        for sq in 0..dev.cols / 2 {
            let c = 2 * sq;
            slots.push(Slot {
                matmuls: vec![
                    Coord::new(r, c),
                    Coord::new(r + 1, c),
                    Coord::new(r + 1, c + 1),
                ],
                adder: Coord::new(r, c + 1),
            });
        }
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;
    use crate::util::prng::XorShift64;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    fn kernel(p: Precision) -> MatMulKernel {
        MatMulKernel::paper_kernel(p)
    }

    #[test]
    fn capacities_match_vc1902() {
        let d = dev();
        assert_eq!(capacity(&d, Pattern::P1), 80); // 4 bands × 20 groups
        assert_eq!(capacity(&d, Pattern::P2), 100); // 4 bands × 25
    }

    #[test]
    fn paper_13x4x6_p1_dma_banks() {
        // Table II row 1: 13×4×6 (P1) uses 18 DMA banks.
        let d = dev();
        let pd = place_design(
            &d,
            ArrayCandidate::new(13, 4, 6),
            Pattern::P1,
            kernel(Precision::Fp32),
        )
        .unwrap();
        assert_eq!(pd.groups.len(), 78);
        assert_eq!(pd.dma_banks, 18);
        assert_eq!(pd.t_shapes(), 9);
    }

    #[test]
    fn paper_11x4x7_and_12x4x6_dma_banks() {
        // Table II rows 3 and 5: 18 and 16 DMA banks.
        let d = dev();
        let a =
            place_design(&d, ArrayCandidate::new(11, 4, 7), Pattern::P1, kernel(Precision::Fp32))
                .unwrap();
        assert_eq!(a.dma_banks, 18); // 77 groups → 9 T-shapes... wait: 77/9
        let b =
            place_design(&d, ArrayCandidate::new(12, 4, 6), Pattern::P1, kernel(Precision::Fp32))
                .unwrap();
        assert_eq!(b.dma_banks, 16); // 72 groups → 8 T-shapes
    }

    #[test]
    fn p2_designs_use_no_dma() {
        // Table II/III: all P2 rows report 0 DMA banks.
        let d = dev();
        for (x, z) in [(10u64, 10u64), (11, 9), (12, 8)] {
            let pd = place_design(
                &d,
                ArrayCandidate::new(x, 3, z),
                Pattern::P2,
                kernel(Precision::Int8),
            )
            .unwrap();
            assert_eq!(pd.dma_banks, 0, "{}", pd.cand.label());
            assert_eq!(pd.t_shapes(), 0);
        }
    }

    #[test]
    fn placements_validate() {
        let d = dev();
        for (x, y, z) in [(13u64, 4u64, 6u64), (10, 3, 10), (11, 4, 7), (11, 3, 9)] {
            let cand = ArrayCandidate::new(x, y, z);
            let pd = place_auto(&d, cand, kernel(Precision::Fp32)).unwrap();
            pd.validate(&d).unwrap();
        }
    }

    #[test]
    fn memory_banks_close_to_paper() {
        // Table II: 13×4×6 → 3138 banks, 10×3×10 → 3190 banks. The model
        // must land within 1% (PnR allocation noise, DESIGN.md §7).
        let d = dev();
        let a = place_auto(&d, ArrayCandidate::new(13, 4, 6), kernel(Precision::Fp32)).unwrap();
        assert!((a.memory_banks as f64 - 3138.0).abs() / 3138.0 < 0.01, "{}", a.memory_banks);
        let b = place_auto(&d, ArrayCandidate::new(10, 3, 10), kernel(Precision::Fp32)).unwrap();
        assert!((b.memory_banks as f64 - 3190.0).abs() / 3190.0 < 0.01, "{}", b.memory_banks);
    }

    #[test]
    fn wrong_y_rejected() {
        let d = dev();
        let err = place_design(
            &d,
            ArrayCandidate::new(10, 3, 10),
            Pattern::P1,
            kernel(Precision::Fp32),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::WrongY { .. }));
    }

    #[test]
    fn capacity_overflow_rejected() {
        let d = dev();
        // 100 P1 groups (Y=4) exceed the 80-group capacity.
        let err = place_design(
            &d,
            ArrayCandidate::new(10, 4, 10),
            Pattern::P1,
            kernel(Precision::Fp32),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::DoesNotFit { .. }));
    }

    #[test]
    fn unsupported_y_rejected() {
        let d = dev();
        let err =
            place_auto(&d, ArrayCandidate::new(10, 5, 6), kernel(Precision::Fp32)).unwrap_err();
        assert_eq!(err, PlacementError::UnsupportedY(5));
    }

    #[test]
    fn property_random_designs_place_and_validate() {
        // Hand-rolled property test: any feasible (X,Y,Z) with Y in {3,4}
        // that fits the pattern capacity places with no overlaps, correct
        // group count and the DMA formula.
        let d = dev();
        let mut rng = XorShift64::new(0xC0FFEE);
        let mut tested = 0;
        while tested < 60 {
            let y = *rng.choose(&[3u64, 4]);
            let x = rng.gen_range(1, 20);
            let z = rng.gen_range(1, 20);
            let cand = ArrayCandidate::new(x, y, z);
            let pat = Pattern::for_y(y).unwrap();
            if !cand.feasible(&d) || cand.groups() as usize > capacity(&d, pat) {
                continue;
            }
            tested += 1;
            let pd = place_design(&d, cand, pat, kernel(Precision::Int8)).unwrap();
            pd.validate(&d).unwrap();
            assert_eq!(pd.groups.len(), cand.groups() as usize);
            let want_dma = if pat == Pattern::P1 {
                cand.groups().div_ceil(P1_GROUPS_PER_TSHAPE as u64) * BANKS_PER_DMA_BUFFER
            } else {
                0
            };
            assert_eq!(pd.dma_banks, want_dma, "{}", cand.label());
            // Every MatMul core appears exactly once; every group has Y
            // matmuls.
            assert!(pd.groups.iter().all(|g| g.matmuls.len() == y as usize));
        }
    }
}
