//! One placed group: `Y` MatMul cores + 1 adder-tree core, with the
//! memory-module assignment of each MatMul output buffer.

use crate::arch::device::AieDevice;
use crate::arch::topology::{can_access, direct_mem_neighbors, Coord};

/// Shape classification of a placed group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupShape {
    /// All MatMul→adder connections use direct memory sharing.
    Clean,
    /// A P1 "T"-like filler shape: one MatMul output buffer must travel
    /// over DMA through the stream switches (paper Fig. 7).
    TShape,
}

/// A placed group of Y MatMul kernels and their adder tree.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacedGroup {
    /// Group id = flat (x·Z + z) index of the (x, z) output tile.
    pub id: usize,
    /// Tiles running MatMul kernels (length Y).
    pub matmuls: Vec<Coord>,
    /// Tile running the whole adder tree.
    pub adder: Coord,
    /// For each MatMul kernel: the memory module its output buffer lives
    /// in. `None` means the buffer is DMA-connected instead (T-shapes).
    pub out_buf_module: Vec<Option<Coord>>,
    pub shape: GroupShape,
}

impl PlacedGroup {
    /// All cores used by the group (MatMuls + adder).
    pub fn cores(&self) -> Vec<Coord> {
        let mut v = self.matmuls.clone();
        v.push(self.adder);
        v
    }

    /// Number of MatMul output buffers connected over DMA.
    pub fn dma_buffers(&self) -> usize {
        self.out_buf_module.iter().filter(|m| m.is_none()).count()
    }

    /// Validate the group against the direct-sharing rules: every non-DMA
    /// output buffer must live in a module that (a) its producing MatMul
    /// core can access directly and (b) the adder core can access directly.
    pub fn validate(&self, dev: &AieDevice) -> Result<(), String> {
        if self.out_buf_module.len() != self.matmuls.len() {
            return Err(format!(
                "group {}: {} buffers for {} matmuls",
                self.id,
                self.out_buf_module.len(),
                self.matmuls.len()
            ));
        }
        for (k, (mm, buf)) in self.matmuls.iter().zip(&self.out_buf_module).enumerate() {
            match buf {
                Some(module) => {
                    if !can_access(*mm, *module, dev) {
                        return Err(format!(
                            "group {}: matmul {k} at {:?} cannot write module {:?}",
                            self.id, mm, module
                        ));
                    }
                    if !can_access(self.adder, *module, dev) {
                        return Err(format!(
                            "group {}: adder at {:?} cannot read module {:?}",
                            self.id, self.adder, module
                        ));
                    }
                }
                None => {
                    if self.shape != GroupShape::TShape {
                        return Err(format!(
                            "group {}: DMA buffer in a non-T shape",
                            self.id
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Choose a memory module for the output buffer of `mm` reachable by
    /// both `mm` and `adder` (the Fig. 6 placement trick). Returns `None`
    /// if only DMA can connect them.
    pub fn find_shared_module(mm: Coord, adder: Coord, dev: &AieDevice) -> Option<Coord> {
        let adder_reach = direct_mem_neighbors(adder, dev);
        direct_mem_neighbors(mm, dev)
            .into_iter()
            .find(|m| adder_reach.contains(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    #[test]
    fn shared_module_found_for_neighbors() {
        let d = dev();
        // Vertical neighbors share the module in between / own modules.
        let m = PlacedGroup::find_shared_module(Coord::new(1, 3), Coord::new(2, 3), &d);
        assert!(m.is_some());
    }

    #[test]
    fn shared_module_via_one_hop_placement() {
        // Paper Fig. 6 example: MatMul at (1,0) places its output buffer
        // at (1,1)'s module... we reproduce the same *mechanism*: a module
        // neither core owns can connect them.
        let d = dev();
        // (0,2) even row reaches west module (0,1) and north module (1,2);
        // adder (1,1) odd reaches south (0,1) and east (1,2): either module
        // connects them without DMA.
        let mm = Coord::new(0, 2);
        let adder = Coord::new(1, 1);
        let m = PlacedGroup::find_shared_module(mm, adder, &d).unwrap();
        assert!(can_access(mm, m, &d) && can_access(adder, m, &d));
        assert!(m == Coord::new(0, 1) || m == Coord::new(1, 2));
    }

    #[test]
    fn no_shared_module_for_distant_cores() {
        let d = dev();
        let m = PlacedGroup::find_shared_module(Coord::new(0, 0), Coord::new(7, 49), &d);
        assert!(m.is_none());
    }

    #[test]
    fn validate_rejects_bogus_module() {
        let d = dev();
        let g = PlacedGroup {
            id: 0,
            matmuls: vec![Coord::new(0, 0)],
            adder: Coord::new(7, 49),
            out_buf_module: vec![Some(Coord::new(3, 3))],
            shape: GroupShape::Clean,
        };
        assert!(g.validate(&d).is_err());
    }

    #[test]
    fn validate_rejects_dma_in_clean_shape() {
        let d = dev();
        let g = PlacedGroup {
            id: 0,
            matmuls: vec![Coord::new(0, 0)],
            adder: Coord::new(1, 0),
            out_buf_module: vec![None],
            shape: GroupShape::Clean,
        };
        assert!(g.validate(&d).is_err());
    }
}
