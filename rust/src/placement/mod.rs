//! AIE kernel placement (paper §IV-D, Figs. 6–7).
//!
//! Each *group* = `Y` MatMul kernels + one adder-tree core, placed so that
//! every MatMul output buffer is reachable by the adder core through the
//! direct memory-sharing fabric (no DMA) — possibly by placing the buffer
//! in a neighboring tile's memory module (the trick of Fig. 6).
//!
//! Two whole-array patterns are provided:
//! * **P1** (`Y = 4`): pairs of 5-core groups tiling 2-row bands; to fill
//!   the full array a "T"-like shape is needed periodically, each costing
//!   one DMA-connected MatMul output buffer (2 banks, double-buffered).
//! * **P2** (`Y = 3`): 2×2-square groups, tiles the array exactly with
//!   zero DMA.
//!
//! The exact Fig. 7 geometry is under-specified in the paper text; we
//! reproduce its published accounting — `ceil(groups/9)` T-shapes for P1
//! (18 DMA banks for 13×4×6 and 11×4×7, 16 for 12×4×6) — while keeping
//! every placement coordinate-real and legality-checked against the
//! even/odd-row sharing rules (see DESIGN.md §7).

pub mod group;
pub mod pattern;
pub mod placer;

pub use group::{GroupShape, PlacedGroup};
pub use pattern::Pattern;
pub use placer::{place_design, PlacedDesign, PlacementError};
