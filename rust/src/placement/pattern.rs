//! The two whole-array placement patterns of Fig. 7.

use std::fmt;

/// Placement pattern (paper Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// P1: for `Y = 4` groups (5 cores). Needs periodic "T"-like shapes to
    /// fill the array; each T-shape costs one DMA-connected MatMul output
    /// buffer.
    P1,
    /// P2: for `Y = 3` groups (4 cores, 2×2 squares). Tiles the array
    /// exactly; never uses DMA.
    P2,
}

impl Pattern {
    /// The group fan-in `Y` this pattern is designed for.
    pub fn y(self) -> u64 {
        match self {
            Pattern::P1 => 4,
            Pattern::P2 => 3,
        }
    }

    /// Cores per group (Y MatMul + 1 adder).
    pub fn cores_per_group(self) -> usize {
        self.y() as usize + 1
    }

    /// Pick the pattern matching a design's `Y` (paper proposes patterns
    /// only for Y = 3, 4 — the top-ranked tiers).
    pub fn for_y(y: u64) -> Option<Pattern> {
        match y {
            3 => Some(Pattern::P2),
            4 => Some(Pattern::P1),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Option<Pattern> {
        match s.to_ascii_uppercase().as_str() {
            "P1" => Some(Pattern::P1),
            "P2" => Some(Pattern::P2),
            _ => None,
        }
    }
}

impl fmt::Display for Pattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pattern::P1 => write!(f, "P1"),
            Pattern::P2 => write!(f, "P2"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_y_mapping() {
        assert_eq!(Pattern::P1.y(), 4);
        assert_eq!(Pattern::P2.y(), 3);
        assert_eq!(Pattern::P1.cores_per_group(), 5);
        assert_eq!(Pattern::P2.cores_per_group(), 4);
    }

    #[test]
    fn for_y_only_3_and_4() {
        assert_eq!(Pattern::for_y(3), Some(Pattern::P2));
        assert_eq!(Pattern::for_y(4), Some(Pattern::P1));
        assert_eq!(Pattern::for_y(2), None);
        assert_eq!(Pattern::for_y(5), None);
    }

    #[test]
    fn parse_roundtrip() {
        for p in [Pattern::P1, Pattern::P2] {
            assert_eq!(Pattern::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Pattern::parse("P3"), None);
    }
}
