//! # MaxEVA — Maximizing the Efficiency of MatMul on Versal AI Engine
//!
//! Full-stack reproduction of Taka et al., "MaxEVA: Maximizing the Efficiency
//! of Matrix Multiplication on Versal AI Engine" (2023).
//!
//! Because the paper targets AMD/Xilinx Versal VC1902 hardware (VCK190 board,
//! Vitis 2022.1 toolchain), which is not available here, this crate implements
//! the complete substrate in software:
//!
//! * [`arch`] — the Versal AIE array architecture model (tiles, memory banks,
//!   interface tiles, neighbor-sharing rules).
//! * [`kernels`] — calibrated latency/efficiency models for the single-AIE
//!   MatMul and Add kernels (paper Table I).
//! * [`optimizer`] — the MaxEVA analytical model: single-kernel (M,K,N) and
//!   array-level (X,Y,Z) integer-programming exhaustive search (paper §IV-C).
//! * [`placement`] — the P1/P2 kernel placement patterns and the
//!   direct-memory-sharing placement strategy (paper §IV-D).
//! * [`routing`] — the AXI4-Stream circuit-switched router with broadcast
//!   trees and congestion detection.
//! * [`sim`] — an event-driven cycle-approximate simulator of the placed
//!   design (double buffering, PLIO bandwidth, DMA, adder trees).
//! * [`power`] — an XPE-like power model (core active/idle + memory banks).
//! * [`charm`] — the CHARM baseline [Zhuang et al., FPGA'23] mapping.
//! * [`tiling`] — host-side tiling + zero-padding model for arbitrary matrix
//!   sizes (paper Fig. 8) and full-DNN estimates.
//! * [`coordinator`] — the serving layer: a request router / batcher that
//!   tiles large MatMuls and dispatches tile jobs to the PJRT runtime.
//! * [`runtime`] — loads the AOT-compiled JAX/Pallas HLO artifacts and
//!   executes them on the PJRT CPU client (numerics path).
//! * [`config`] — hand-rolled JSON config system (no external deps).
//! * [`report`] — paper-table formatting and paper-vs-measured comparison.
//! * [`workloads`] — workload generators (matrix sweeps, MLP, request traces).

// Lint posture (CI runs `cargo clippy --all-targets -- -D warnings` as
// a blocking gate): these style lints fight idioms this codebase uses
// on purpose and are allowed crate-wide rather than per-site. The same
// allow-list is mirrored in Cargo.toml's `[lints.clippy]` so it also
// reaches tests, benches and examples (crate attributes here only
// cover the lib target).
#![allow(
    // Matrix/placement code indexes rows, columns and blocks explicitly;
    // iterator rewrites of coupled index arithmetic obscure the math.
    clippy::needless_range_loop,
    // Block addressing is inherently many-parameter (dst/src + matrix
    // shape + block position + block shape).
    clippy::too_many_arguments,
    // Serving batches are `(request, operands…)` tuples by design.
    clippy::type_complexity,
    // Paper-calibrated constants keep their published digits.
    clippy::excessive_precision
)]

pub mod arch;
pub mod charm;
pub mod config;
pub mod coordinator;
pub mod kernels;
pub mod optimizer;
pub mod placement;
pub mod power;
pub mod report;
pub mod routing;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;
pub mod workloads;

pub use arch::device::AieDevice;
pub use arch::precision::Precision;
pub use coordinator::ServeError;

/// Everything a typical serving client needs, in one import:
///
/// ```no_run
/// use maxeva::prelude::*;
///
/// # fn main() -> anyhow::Result<()> {
/// let cfg = ServeConfig::builder(DesignConfig::flagship(Precision::Fp32)).build()?;
/// let server = MatMulServer::start(&cfg)?;
/// let req = MatMulRequest::f32(0, 64, 64, 64);
/// let handle: RequestHandle = server.submit(
///     req,
///     Operands::F32 { a: vec![0.0; 64 * 64], b: vec![0.0; 64 * 64] },
/// )?;
/// match handle.wait() {
///     Ok(out) => drop(out.into_f32()?),
///     Err(err) => {
///         if let Some(serve_err) = ServeError::from_anyhow(&err) {
///             eprintln!("typed serving failure: {serve_err}");
///         }
///     }
/// }
/// # Ok(())
/// # }
/// ```
pub mod prelude {
    pub use crate::arch::precision::Precision;
    pub use crate::config::schema::{
        AdmissionPolicy, BackendKind, DesignConfig, PolicyKind, ServeConfig, ServeConfigBuilder,
    };
    pub use crate::coordinator::{
        BreakerSnapshot, BreakerState, Cancelled, MatMulServer, QueueFull, RecoveryStats,
        RequestHandle, RouterStats, ServeError, ServerStats, ShardStats, ShedStats,
    };
    pub use crate::workloads::{MatMulRequest, MatOutput, Operands};
}
