//! Matrix-Vector (GEMV) extension — the special case the paper leaves as
//! future work (§V-B4: "our work can be extended in straightforward
//! fashion to other special cases of MatMul, e.g., Matrix-Vector").
//!
//! GEMV changes the optimization problem qualitatively: the `A` operand
//! is streamed *once per use* (no reuse across a Z dimension — Z ≡ 1), so
//! arithmetic intensity is ~1 MAC/element and the design becomes
//! PLIO-bandwidth-bound instead of compute-bound. The extension keeps the
//! paper's machinery — tile IP, Y-reduction adder trees, broadcast of the
//! vector — and exposes where the bottleneck moves.

use crate::arch::device::AieDevice;
use crate::arch::precision::Precision;

/// One GEMV tile kernel: `c (M) += A (M×K) · b (K)` on one AIE core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatVecKernel {
    pub m: u64,
    pub k: u64,
    pub prec: Precision,
}

impl MatVecKernel {
    pub fn macs(&self) -> u64 {
        self.m * self.k
    }

    /// A-tile bytes (streamed fresh every iteration — the bottleneck).
    pub fn a_bytes(&self) -> u64 {
        self.m * self.k * self.prec.sizeof_input()
    }

    /// b-vector bytes (broadcast, amortized).
    pub fn b_bytes(&self) -> u64 {
        self.k * self.prec.sizeof_input()
    }

    pub fn c_bytes(&self) -> u64 {
        self.m * self.prec.sizeof_output()
    }

    /// eq. (6) analog: double-buffered footprint must fit 14 KB.
    pub fn buffer_bytes(&self) -> u64 {
        self.a_bytes() + self.b_bytes() + self.c_bytes()
    }

    /// Compute-bound latency (cycles).
    pub fn compute_cycles(&self) -> u64 {
        self.macs().div_ceil(self.prec.peak_macs_per_cycle())
    }

    /// Stream-bound latency (cycles): the A tile must arrive over one
    /// PLIO at `bw` B/cyc.
    pub fn stream_cycles(&self, dev: &AieDevice) -> u64 {
        self.a_bytes().div_ceil(dev.bw_io_bytes_per_cycle)
    }

    /// Effective iteration latency: max of compute and stream (double
    /// buffering overlaps them).
    pub fn latency_cycles(&self, dev: &AieDevice) -> u64 {
        self.compute_cycles().max(self.stream_cycles(dev))
    }

    /// Achieved MACs/cycle — exposes the bandwidth bound.
    pub fn throughput_macs_per_cycle(&self, dev: &AieDevice) -> f64 {
        self.macs() as f64 / self.latency_cycles(dev) as f64
    }
}

/// A GEMV array mapping: `X` row-groups × `Y`-deep reduction (Z ≡ 1).
#[derive(Debug, Clone, Copy)]
pub struct MatVecDesign {
    pub kernel: MatVecKernel,
    pub x: u64,
    pub y: u64,
}

impl MatVecDesign {
    /// Kernels (= A-stream PLIOs needed): X·Y.
    pub fn kernels(&self) -> u64 {
        self.x * self.y
    }

    /// PLIO inputs: one A stream per kernel + Y broadcast b streams.
    pub fn plio_in(&self) -> u64 {
        self.x * self.y + self.y
    }

    pub fn plio_out(&self) -> u64 {
        self.x
    }

    pub fn total_cores(&self) -> u64 {
        // One adder-tree core per row-group, unless Y = 1 (no reduction).
        self.kernels() + if self.y > 1 { self.x } else { 0 }
    }

    pub fn feasible(&self, dev: &AieDevice) -> bool {
        self.total_cores() <= dev.total_cores() as u64
            && self.plio_in() <= dev.plio_in as u64
            && self.plio_out() <= dev.plio_out as u64
    }

    /// Steady-state array throughput in ops/s (2 ops/MAC): every kernel
    /// sustains one A-tile per `latency` — PLIO-bound for realistic
    /// sizes.
    pub fn ops_per_sec(&self, dev: &AieDevice) -> f64 {
        let lat = self.kernel.latency_cycles(dev) as f64;
        2.0 * self.kernels() as f64 * self.kernel.macs() as f64 / (lat / dev.freq_hz)
    }
}

/// Exhaustive GEMV DSE: maximize throughput subject to PLIO/core/memory
/// constraints (the paper's eq. 7–9 analog with Z = 1 and per-kernel
/// A streams).
pub fn optimize_matvec(dev: &AieDevice, prec: Precision) -> Vec<MatVecDesign> {
    let mut out = Vec::new();
    let budget = dev.single_buffer_budget_bytes();
    for me in 2..=9u32 {
        for ke in 2..=9u32 {
            let kernel = MatVecKernel { m: 1 << me, k: 1 << ke, prec };
            if kernel.buffer_bytes() > budget {
                continue;
            }
            for y in 1..=8u64 {
                // x bounded by PLIO_in: x·y + y ≤ plio_in.
                let x_max = (dev.plio_in as u64).saturating_sub(y) / y;
                for x in 1..=x_max.max(1) {
                    let d = MatVecDesign { kernel, x, y };
                    if d.feasible(dev) {
                        out.push(d);
                    }
                }
            }
        }
    }
    out.sort_by(|a, b| {
        b.ops_per_sec(dev)
            .partial_cmp(&a.ops_per_sec(dev))
            .unwrap()
            .then(a.total_cores().cmp(&b.total_cores()))
            // Among stream-bound ties prefer bigger tiles (fewer
            // per-invocation overheads on real hardware).
            .then(b.kernel.macs().cmp(&a.kernel.macs()))
    });
    out
}

/// The theoretical GEMV throughput ceiling: every input PLIO saturated
/// streaming A elements (ops/s).
pub fn plio_bound_ops_per_sec(dev: &AieDevice, prec: Precision) -> f64 {
    let elems_per_cyc = dev.bw_io_bytes_per_cycle as f64 / prec.sizeof_input() as f64;
    2.0 * dev.plio_in as f64 * elems_per_cyc * dev.freq_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    #[test]
    fn gemv_is_stream_bound_fp32() {
        // fp32: A stream delivers 1 elem/cyc but the core could do 8
        // MACs/cyc → stream-bound by 8×.
        let k = MatVecKernel { m: 64, k: 64, prec: Precision::Fp32 };
        let d = dev();
        assert!(k.stream_cycles(&d) > k.compute_cycles());
        assert!((k.throughput_macs_per_cycle(&d) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gemv_is_stream_bound_int8() {
        // int8: 4 elems/cyc vs 128 MACs/cyc → stream-bound by 32×.
        let k = MatVecKernel { m: 128, k: 128, prec: Precision::Int8 };
        let d = dev();
        assert!((k.throughput_macs_per_cycle(&d) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn best_design_saturates_plios_not_cores() {
        let d = dev();
        let designs = optimize_matvec(&d, Precision::Fp32);
        let best = designs[0];
        // PLIO_in is the binding constraint: used within 1 stream of max.
        assert!(best.plio_in() >= d.plio_in as u64 - 2, "{}", best.plio_in());
        // Cores are NOT the constraint: far fewer than for MatMul.
        assert!(best.total_cores() < 120);
        // Throughput is within 5% of the PLIO bound …
        let bound = plio_bound_ops_per_sec(&d, Precision::Fp32);
        assert!(best.ops_per_sec(&d) > 0.9 * bound);
        // … and FAR below the MatMul design's 5.44 TFLOPs.
        assert!(best.ops_per_sec(&d) < 0.25e12);
    }

    #[test]
    fn plio_bound_values() {
        // fp32: 78 PLIOs × 1 elem/cyc × 2 ops × 1.25 GHz = 195 GFLOPs.
        let b32 = plio_bound_ops_per_sec(&dev(), Precision::Fp32);
        assert!((b32 - 195e9).abs() < 1e6);
        // int8: 4 elems/cyc → 780 GOPs.
        let b8 = plio_bound_ops_per_sec(&dev(), Precision::Int8);
        assert!((b8 - 780e9).abs() < 1e6);
    }

    #[test]
    fn all_designs_feasible() {
        let d = dev();
        for des in optimize_matvec(&d, Precision::Int8).iter().take(100) {
            assert!(des.feasible(&d));
            assert!(des.kernel.buffer_bytes() <= d.single_buffer_budget_bytes());
        }
    }
}
