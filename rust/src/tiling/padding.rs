//! Zero-padding + tiling model for arbitrary MatMul sizes (Fig. 8).
//!
//! The design's *native* size is `(X·M) × (Y·K) × (Z·N)`; larger problems
//! are tiled in PL (the paper assumes stall-free PL tiling, "commonly
//! attained in practice"), and every dimension is zero-padded up to a
//! multiple of the native size. Effective throughput is the peak device
//! throughput derated by the useful-to-padded MAC ratio.

use crate::optimizer::array::ArrayCandidate;
use crate::kernels::matmul::MatMulKernel;

/// Native whole-array MatMul size of a design.
pub fn native_size(cand: &ArrayCandidate, kernel: &MatMulKernel) -> (u64, u64, u64) {
    (cand.x * kernel.m, cand.y * kernel.k, cand.z * kernel.n)
}

/// A problem-size MatMul tiled onto a design.
#[derive(Debug, Clone, Copy)]
pub struct TiledWorkload {
    /// Problem size.
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// Native design size.
    pub native: (u64, u64, u64),
}

impl TiledWorkload {
    pub fn new(m: u64, k: u64, n: u64, cand: &ArrayCandidate, kernel: &MatMulKernel) -> Self {
        TiledWorkload {
            m,
            k,
            n,
            native: native_size(cand, kernel),
        }
    }

    /// Number of native-size invocations along each dimension.
    pub fn grid(&self) -> (u64, u64, u64) {
        (
            self.m.div_ceil(self.native.0),
            self.k.div_ceil(self.native.1),
            self.n.div_ceil(self.native.2),
        )
    }

    /// Total invocations of the array design.
    pub fn invocations(&self) -> u64 {
        let (gm, gk, gn) = self.grid();
        gm * gk * gn
    }

    /// Padded problem dimensions.
    pub fn padded(&self) -> (u64, u64, u64) {
        let (gm, gk, gn) = self.grid();
        (gm * self.native.0, gk * self.native.1, gn * self.native.2)
    }

    /// Useful MACs / padded MACs ∈ (0, 1] — the Fig. 8 derating factor.
    pub fn useful_ratio(&self) -> f64 {
        let (pm, pk, pn) = self.padded();
        (self.m * self.k * self.n) as f64 / (pm * pk * pn) as f64
    }

    /// Effective throughput in ops/s given the design's peak ops/s on
    /// native-size work (Fig. 8 model: PL tiling is stall-free).
    pub fn effective_ops_per_sec(&self, peak_ops_per_sec: f64) -> f64 {
        peak_ops_per_sec * self.useful_ratio()
    }

    /// Device time (seconds) to run the whole problem, given the iteration
    /// period of the design and the per-invocation iteration count of 1.
    pub fn device_time_s(&self, period_cycles: f64, freq_hz: f64) -> f64 {
        self.invocations() as f64 * period_cycles / freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    fn design_fp32() -> (ArrayCandidate, MatMulKernel) {
        (
            ArrayCandidate::new(13, 4, 6),
            MatMulKernel::paper_kernel(Precision::Fp32),
        )
    }

    fn design_int8() -> (ArrayCandidate, MatMulKernel) {
        (
            ArrayCandidate::new(13, 4, 6),
            MatMulKernel::paper_kernel(Precision::Int8),
        )
    }

    #[test]
    fn native_sizes_match_paper() {
        // §V-B4: 13×4×6 natively computes 416×128×192 (fp32) and
        // 416×512×192 (int8).
        let (c, k) = design_fp32();
        assert_eq!(native_size(&c, &k), (416, 128, 192));
        let (c, k) = design_int8();
        assert_eq!(native_size(&c, &k), (416, 512, 192));
    }

    #[test]
    fn exact_multiple_has_ratio_one() {
        let (c, k) = design_fp32();
        let w = TiledWorkload::new(416 * 2, 128 * 3, 192 * 4, &c, &k);
        assert_eq!(w.useful_ratio(), 1.0);
        assert_eq!(w.invocations(), 24);
    }

    #[test]
    fn small_matrices_heavily_derated() {
        // Fig. 8: small matrices lose throughput to padding.
        let (c, k) = design_fp32();
        let w = TiledWorkload::new(256, 256, 256, &c, &k);
        assert!(w.useful_ratio() < 0.65, "{}", w.useful_ratio());
    }

    #[test]
    fn large_square_converges_to_peak() {
        // Fig. 8: ≥ ~2K square matrices approach peak throughput.
        let (c, k) = design_fp32();
        let w2k = TiledWorkload::new(2048, 2048, 2048, &c, &k);
        assert!(w2k.useful_ratio() > 0.93, "{}", w2k.useful_ratio());
        let w16k = TiledWorkload::new(16384, 16384, 16384, &c, &k);
        assert!(w16k.useful_ratio() > w2k.useful_ratio());
    }

    #[test]
    fn ratio_monotone_pattern_over_power_of_two_sweep() {
        // The Fig. 8 curve: throughput rises with size (modulo the
        // sawtooth from alignment); endpoints must order correctly.
        let (c, k) = design_int8();
        let small = TiledWorkload::new(512, 512, 512, &c, &k).useful_ratio();
        let large = TiledWorkload::new(8192, 8192, 8192, &c, &k).useful_ratio();
        assert!(large > small);
    }

    #[test]
    fn device_time_scales_with_invocations() {
        let (c, k) = design_fp32();
        let w1 = TiledWorkload::new(416, 128, 192, &c, &k);
        let w8 = TiledWorkload::new(832, 256, 384, &c, &k);
        let t1 = w1.device_time_s(4700.0, 1.25e9);
        let t8 = w8.device_time_s(4700.0, 1.25e9);
        assert!((t8 / t1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn padding_never_below_problem() {
        let (c, k) = design_fp32();
        for s in [100u64, 1000, 3000] {
            let w = TiledWorkload::new(s, s, s, &c, &k);
            let (pm, pk, pn) = w.padded();
            assert!(pm >= s && pk >= s && pn >= s);
            assert!(w.useful_ratio() <= 1.0 && w.useful_ratio() > 0.0);
        }
    }
}
