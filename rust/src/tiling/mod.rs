//! Host-side tiling of arbitrary MatMul sizes onto a design's native size
//! (paper §V-B4, Fig. 8), including the zero-padding throughput model and
//! full-DNN (MLP) estimates.

pub mod matvec;
pub mod mlp;
pub mod padding;

pub use padding::{TiledWorkload, native_size};
pub use mlp::{MlpLayer, MlpEstimate, estimate_mlp};
