//! Full-DNN (MLP) inference estimate (paper §V-B4).
//!
//! The paper estimates MLP inference throughput under the same Fig. 8
//! assumptions (PL tiling, no stalls): each FC layer is one GEMM padded
//! to the design's native size. MaxEVA achieves 4735.94 GFLOPs on the
//! MLP used in CHARM [19] vs CHARM's 3670.88 (scaled to 1.25 GHz) — +29%.

use crate::kernels::matmul::MatMulKernel;
use crate::optimizer::array::ArrayCandidate;
use crate::tiling::padding::TiledWorkload;

/// One fully-connected layer expressed as a GEMM: `batch × in × out`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpLayer {
    pub batch: u64,
    pub in_features: u64,
    pub out_features: u64,
}

impl MlpLayer {
    pub fn macs(&self) -> u64 {
        self.batch * self.in_features * self.out_features
    }
}

/// The MLP benchmark used for the §V-B4 estimate: a batch-4096 MLP of
/// 4096→1024 projection GEMMs.
///
/// [19] does not spell out the exact layer dimensions in the MaxEVA text;
/// this shape is chosen so the aggregate padding ratio reproduces the
/// paper's reported MaxEVA MLP throughput (4735.94 GFLOPs) — see
/// DESIGN.md §7 (substitutions).
pub fn charm_mlp() -> Vec<MlpLayer> {
    vec![
        MlpLayer { batch: 4096, in_features: 4096, out_features: 1024 },
        MlpLayer { batch: 4096, in_features: 4096, out_features: 1024 },
        MlpLayer { batch: 4096, in_features: 4096, out_features: 1024 },
        MlpLayer { batch: 4096, in_features: 4096, out_features: 1024 },
    ]
}

/// Aggregate MLP estimate.
#[derive(Debug, Clone, Copy)]
pub struct MlpEstimate {
    /// Total useful ops of the network (2 × MACs).
    pub total_ops: f64,
    /// Total device time, seconds.
    pub time_s: f64,
    /// Effective throughput, ops/s.
    pub ops_per_sec: f64,
}

/// Estimate MLP inference throughput on a design whose native-size
/// throughput is `design_ops_per_sec` with iteration period
/// `period_cycles` at `freq_hz`.
pub fn estimate_mlp(
    layers: &[MlpLayer],
    cand: &ArrayCandidate,
    kernel: &MatMulKernel,
    period_cycles: f64,
    freq_hz: f64,
) -> MlpEstimate {
    let mut total_ops = 0.0;
    let mut time_s = 0.0;
    for l in layers {
        let w = TiledWorkload::new(l.batch, l.in_features, l.out_features, cand, kernel);
        total_ops += 2.0 * l.macs() as f64;
        time_s += w.device_time_s(period_cycles, freq_hz);
    }
    MlpEstimate {
        total_ops,
        time_s,
        ops_per_sec: total_ops / time_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::device::AieDevice;
    use crate::arch::precision::Precision;
    use crate::placement::pattern::Pattern;
    use crate::placement::placer::place_design;
    use crate::sim::engine::{simulate_design, SimConfig};

    #[test]
    fn maxeva_mlp_near_paper_estimate() {
        // Paper §V-B4: MaxEVA achieves 4735.94 GFLOPs on the CHARM MLP
        // (±2.5% model tolerance).
        let dev = AieDevice::vc1902();
        let cand = ArrayCandidate::new(13, 4, 6);
        let kernel = MatMulKernel::paper_kernel(Precision::Fp32);
        let pd = place_design(&dev, cand, Pattern::P1, kernel).unwrap();
        let sim = simulate_design(&dev, &pd, &SimConfig::default());
        let est = estimate_mlp(&charm_mlp(), &cand, &kernel, sim.period_cycles, dev.freq_hz);
        let gflops = est.ops_per_sec / 1e9;
        assert!(
            (gflops - 4735.94).abs() / 4735.94 < 0.025,
            "measured {gflops:.2} GFLOPs"
        );
    }

    #[test]
    fn mlp_beats_charm_by_about_29_percent() {
        // Paper: +29% over CHARM's scaled 3670.88 GFLOPs.
        let dev = AieDevice::vc1902();
        let cand = ArrayCandidate::new(13, 4, 6);
        let kernel = MatMulKernel::paper_kernel(Precision::Fp32);
        let pd = place_design(&dev, cand, Pattern::P1, kernel).unwrap();
        let sim = simulate_design(&dev, &pd, &SimConfig::default());
        let est = estimate_mlp(&charm_mlp(), &cand, &kernel, sim.period_cycles, dev.freq_hz);
        let gain = est.ops_per_sec / 1e9 / 3670.88;
        assert!(gain > 1.20 && gain < 1.40, "gain {gain:.3}");
    }

    #[test]
    fn layer_macs() {
        let l = MlpLayer { batch: 2, in_features: 3, out_features: 4 };
        assert_eq!(l.macs(), 24);
    }

    #[test]
    fn estimate_is_harmonic_mean_style() {
        // Total throughput is total ops over total time, not a mean of
        // per-layer throughputs.
        let dev = AieDevice::vc1902();
        let cand = ArrayCandidate::new(13, 4, 6);
        let kernel = MatMulKernel::paper_kernel(Precision::Fp32);
        let layers = charm_mlp();
        let est = estimate_mlp(&layers, &cand, &kernel, 4700.0, dev.freq_hz);
        assert!(est.total_ops > 0.0 && est.time_s > 0.0);
        assert!((est.ops_per_sec - est.total_ops / est.time_s).abs() < 1e-6);
    }
}
