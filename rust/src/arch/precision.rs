//! Numeric precisions supported by the AIE vector datapath, with the
//! constants the MaxEVA analytical model depends on (paper §IV-C).

use std::fmt;

/// Data precision of a MatMul design.
///
/// The paper targets the two most common DL precisions:
/// * `Int8`  — 8-bit integer inputs with 32-bit integer accumulation.
/// * `Fp32`  — IEEE 32-bit floating point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// int8 inputs, int32 accumulation/output (paper §IV-C1).
    Int8,
    /// IEEE fp32 throughout.
    Fp32,
    /// int16 inputs, int32 accumulation — EXTENSION (not evaluated by the
    /// paper; AM009 lists 32 MACs/cyc).
    Int16,
    /// bfloat16 inputs, fp32 accumulation — EXTENSION (AM009: 16 MACs/cyc).
    Bf16,
}

impl Precision {
    /// Peak MACs per cycle of one AIE vector processor (AM009):
    /// 128 for int8, 8 for fp32.
    pub fn peak_macs_per_cycle(self) -> u64 {
        match self {
            Precision::Int8 => 128,
            Precision::Fp32 => 8,
            Precision::Int16 => 32,
            Precision::Bf16 => 16,
        }
    }

    /// Size in bytes of one *input* element (operand `a` or `b`).
    pub fn sizeof_input(self) -> u64 {
        match self {
            Precision::Int8 => 1,
            Precision::Fp32 => 4,
            Precision::Int16 | Precision::Bf16 => 2,
        }
    }

    /// Size in bytes of one *output* element (operand `c`).
    ///
    /// int8 MatMuls accumulate in 32 bits, so the output element is
    /// 4 bytes in both precisions — this asymmetry is what makes the
    /// int8 constraint eq. (5) bind on `K`.
    pub fn sizeof_output(self) -> u64 {
        4
    }

    /// Human-readable unit for throughput in this precision as used in the
    /// paper's tables (GFLOPs for fp32, TOPs for int8).
    pub fn ops_unit(self) -> &'static str {
        match self {
            Precision::Int8 | Precision::Int16 => "TOPs",
            Precision::Fp32 | Precision::Bf16 => "GFLOPs",
        }
    }

    /// The precisions the paper evaluates (Tables I–III).
    pub fn all() -> [Precision; 2] {
        [Precision::Int8, Precision::Fp32]
    }

    /// All precisions including the int16/bf16 extensions (model
    /// constants for these are engineering estimates, not
    /// paper-calibrated — see DESIGN.md §7).
    pub fn extended() -> [Precision; 4] {
        [Precision::Int8, Precision::Int16, Precision::Bf16, Precision::Fp32]
    }

    /// Parse from a CLI string ("int8" / "fp32", case-insensitive).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "int8" | "i8" => Some(Precision::Int8),
            "fp32" | "f32" | "float32" => Some(Precision::Fp32),
            "int16" | "i16" => Some(Precision::Int16),
            "bf16" | "bfloat16" => Some(Precision::Bf16),
            _ => None,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Precision::Int8 => write!(f, "int8"),
            Precision::Fp32 => write!(f, "fp32"),
            Precision::Int16 => write!(f, "int16"),
            Precision::Bf16 => write!(f, "bf16"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_macs_match_am009() {
        assert_eq!(Precision::Int8.peak_macs_per_cycle(), 128);
        assert_eq!(Precision::Fp32.peak_macs_per_cycle(), 8);
    }

    #[test]
    fn int8_accumulates_in_32_bits() {
        assert_eq!(Precision::Int8.sizeof_input(), 1);
        assert_eq!(Precision::Int8.sizeof_output(), 4);
        assert_eq!(Precision::Fp32.sizeof_input(), 4);
        assert_eq!(Precision::Fp32.sizeof_output(), 4);
    }

    #[test]
    fn parse_roundtrip() {
        for p in Precision::all() {
            assert_eq!(Precision::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Precision::parse("fp64"), None);
        assert_eq!(Precision::parse("bf16"), Some(Precision::Bf16));
    }
}
