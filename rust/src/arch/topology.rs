//! AIE array topology: tile coordinates and the direct memory-sharing
//! neighbor rules of the checkerboarded array (paper §III-B, Fig. 2).
//!
//! Each AIE core can always access the memory module of its north and
//! south neighbors. East/west access alternates with the row parity:
//! cores in **even** rows access the module to their **west**, cores in
//! **odd** rows access the module to their **east** (the memory module is
//! physically placed on alternating sides). A core also accesses its own
//! tile's module, for a total reach of up to 128 KB.

use crate::arch::device::AieDevice;

/// Coordinate of one AIE tile: `row` 0 is the bottom row (adjacent to the
/// interface tiles), `col` 0 is the leftmost column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn new(row: usize, col: usize) -> Self {
        Coord { row, col }
    }

    /// Flat index into a row-major array of tiles.
    pub fn index(&self, dev: &AieDevice) -> usize {
        self.row * dev.cols + self.col
    }
}

/// Which memory modules the core at `c` can access *directly* (no DMA),
/// including its own. Order: own, north, south, east/west (row-parity).
pub fn direct_mem_neighbors(c: Coord, dev: &AieDevice) -> Vec<Coord> {
    let mut v = vec![c];
    if c.row + 1 < dev.rows {
        v.push(Coord::new(c.row + 1, c.col));
    }
    if c.row > 0 {
        v.push(Coord::new(c.row - 1, c.col));
    }
    if c.row % 2 == 0 {
        // Even row: west module.
        if c.col > 0 {
            v.push(Coord::new(c.row, c.col - 1));
        }
    } else {
        // Odd row: east module.
        if c.col + 1 < dev.cols {
            v.push(Coord::new(c.row, c.col + 1));
        }
    }
    v
}

/// True if core `core` can directly access the memory module of tile `mem`
/// (the relation is *not* symmetric in the east/west direction).
pub fn can_access(core: Coord, mem: Coord, dev: &AieDevice) -> bool {
    direct_mem_neighbors(core, dev).contains(&mem)
}

/// True if cores `a` and `b` share at least one directly-accessible memory
/// module — the condition for DMA-free communication between them.
pub fn share_memory(a: Coord, b: Coord, dev: &AieDevice) -> bool {
    let na = direct_mem_neighbors(a, dev);
    direct_mem_neighbors(b, dev).iter().any(|m| na.contains(m))
}

/// Manhattan distance between tiles (used by the router for hop counts).
pub fn manhattan(a: Coord, b: Coord) -> usize {
    a.row.abs_diff(b.row) + a.col.abs_diff(b.col)
}

/// Columns that host an AIE-PL interface tile.
///
/// On the VC1902 only 39 of the 50 columns have PL interface tiles (DS957);
/// we model them as evenly spread across the array, which is how the
/// physical device arranges them (the NoC columns take the remainder).
pub fn interface_columns(dev: &AieDevice) -> Vec<usize> {
    let n = dev.aie_pl_tiles.min(dev.cols);
    if n == 0 {
        return vec![];
    }
    // Evenly spaced selection of n columns out of dev.cols.
    (0..n)
        .map(|i| (i * dev.cols + dev.cols / 2) / n.max(1))
        .map(|c| c.min(dev.cols - 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    #[test]
    fn even_row_accesses_west() {
        let d = dev();
        let c = Coord::new(2, 10);
        assert!(can_access(c, Coord::new(2, 9), &d)); // west
        assert!(!can_access(c, Coord::new(2, 11), &d)); // not east
        assert!(can_access(c, Coord::new(3, 10), &d)); // north
        assert!(can_access(c, Coord::new(1, 10), &d)); // south
        assert!(can_access(c, c, &d)); // own
    }

    #[test]
    fn odd_row_accesses_east() {
        let d = dev();
        let c = Coord::new(3, 10);
        assert!(can_access(c, Coord::new(3, 11), &d)); // east
        assert!(!can_access(c, Coord::new(3, 9), &d)); // not west
    }

    #[test]
    fn edges_have_fewer_neighbors() {
        let d = dev();
        // Bottom-left corner, even row: no south, no west.
        assert_eq!(direct_mem_neighbors(Coord::new(0, 0), &d).len(), 2); // own + north
        // Top-right corner, odd row: no north, no east.
        assert_eq!(direct_mem_neighbors(Coord::new(7, 49), &d).len(), 2); // own + south
        // Interior tile reaches 4 modules = 128KB total.
        assert_eq!(direct_mem_neighbors(Coord::new(4, 25), &d).len(), 4);
    }

    #[test]
    fn vertical_neighbors_share_memory() {
        let d = dev();
        assert!(share_memory(Coord::new(1, 5), Coord::new(2, 5), &d));
        // Two cores two rows apart share the module in between.
        assert!(share_memory(Coord::new(1, 5), Coord::new(3, 5), &d));
        // Far-away cores do not.
        assert!(!share_memory(Coord::new(0, 0), Coord::new(7, 49), &d));
    }

    #[test]
    fn east_west_sharing_follows_parity() {
        let d = dev();
        // Row 2 (even) core at col 6 reaches module (2,5); row 2 core at
        // col 5 owns module (2,5): they share it.
        assert!(share_memory(Coord::new(2, 6), Coord::new(2, 5), &d));
        // Odd row: (3,5) reaches east module (3,6).
        assert!(share_memory(Coord::new(3, 5), Coord::new(3, 6), &d));
    }

    #[test]
    fn interface_columns_count_and_range() {
        let d = dev();
        let cols = interface_columns(&d);
        assert_eq!(cols.len(), 39);
        assert!(cols.iter().all(|&c| c < 50));
        // Strictly increasing (distinct columns).
        assert!(cols.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn manhattan_distance() {
        assert_eq!(manhattan(Coord::new(0, 0), Coord::new(3, 4)), 7);
        assert_eq!(manhattan(Coord::new(2, 2), Coord::new(2, 2)), 0);
    }
}
