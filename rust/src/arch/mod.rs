pub mod device;
pub mod precision;
pub mod topology;
