//! Versal AIE device description.
//!
//! All constants for the VC1902 come from the sources the paper cites:
//! AM009 (AIE architecture manual), DS957 (interface-tile counts), UG1366
//! (VCK190 board). The model is generic: any Versal AIE device can be
//! described by constructing an [`AieDevice`] directly.

use crate::arch::precision::Precision;

/// Static description of one Versal AIE array device.
#[derive(Debug, Clone, PartialEq)]
pub struct AieDevice {
    /// Device name, e.g. "VC1902".
    pub name: String,
    /// Number of AIE tile rows (VC1902: 8).
    pub rows: usize,
    /// Number of AIE tile columns (VC1902: 50).
    pub cols: usize,
    /// Data memory per tile, in bytes (32 KB).
    pub data_mem_bytes: u64,
    /// Number of data-memory banks per tile (8 × 4 KB).
    pub banks_per_tile: u64,
    /// Program memory per tile, in bytes (16 KB).
    pub prog_mem_bytes: u64,
    /// Number of AIE-PL interface tiles on the last row (VC1902: 39).
    pub aie_pl_tiles: usize,
    /// Available input PLIOs (PL → AIE array). VC1902: 78.
    pub plio_in: usize,
    /// Available output PLIOs (AIE array → PL). VC1902: 117.
    pub plio_out: usize,
    /// AIE clock frequency in Hz (VCK190 max: 1.25 GHz).
    pub freq_hz: f64,
    /// PL clock frequency in Hz (recommended: 312.5 MHz).
    pub pl_freq_hz: f64,
    /// Stream / PLIO bandwidth in bytes per AIE cycle (AM009: 4 B/cyc).
    pub bw_io_bytes_per_cycle: u64,
    /// Memory banks reserved per active tile for system use (stack, heap).
    pub system_banks: u64,
    /// Effective AXI4-Stream switch capacity: max concurrent
    /// circuit-switched streams per tile-to-tile direction. This is the
    /// *routable* channel count the PnR tool can realize per direction
    /// (calibrated so every design the paper reports as routable routes,
    /// with ~10% headroom; the hard feasibility cliff the paper reports —
    /// 10×4×8 failing — is reproduced by the DMA/slack rule in
    /// `routing::router`, not by raw channel exhaustion).
    pub switch_capacity_per_dir: u32,
}

impl AieDevice {
    /// The VC1902 device of the VCK190 evaluation board — the paper's
    /// demonstration target.
    pub fn vc1902() -> Self {
        AieDevice {
            name: "VC1902".to_string(),
            rows: 8,
            cols: 50,
            data_mem_bytes: 32 * 1024,
            banks_per_tile: 8,
            prog_mem_bytes: 16 * 1024,
            aie_pl_tiles: 39,
            plio_in: 78,
            plio_out: 117,
            freq_hz: 1.25e9,
            pl_freq_hz: 312.5e6,
            bw_io_bytes_per_cycle: 4,
            system_banks: 1,
            switch_capacity_per_dir: 12,
        }
    }

    /// A hypothetical smaller device (half the VC1902 array) used by tests
    /// to exercise generalization to other Versal parts.
    pub fn half_vc1902() -> Self {
        AieDevice {
            name: "VC1902-half".to_string(),
            rows: 8,
            cols: 25,
            aie_pl_tiles: 19,
            plio_in: 38,
            plio_out: 57,
            ..Self::vc1902()
        }
    }

    /// The VC1802 — the smaller Versal AI Core part (DS950: 300 AIE
    /// tiles as 6 rows × 50 columns, proportionally fewer interface
    /// tiles). Demonstrates the paper's "generalizable to any Versal AIE
    /// device" claim on a real second part.
    pub fn vc1802() -> Self {
        AieDevice {
            name: "VC1802".to_string(),
            rows: 6,
            cols: 50,
            aie_pl_tiles: 39,
            plio_in: 78,
            plio_out: 117,
            ..Self::vc1902()
        }
    }

    /// The VC2802 (Versal AI Edge/Core next-gen class): a larger array
    /// used to study how the MaxEVA constraints shift when cores grow
    /// faster than PLIOs. Parameters are representative, not a datasheet
    /// transcription (the AIE-ML tile architecture differs; we model the
    /// same AIE1-style tile scaled up — see DESIGN.md §7).
    pub fn vc2802_like() -> Self {
        AieDevice {
            name: "VC2802-like".to_string(),
            rows: 8,
            cols: 38,
            aie_pl_tiles: 30,
            plio_in: 60,
            plio_out: 90,
            ..Self::vc1902()
        }
    }

    /// Look up a device preset by name.
    pub fn by_name(name: &str) -> Option<AieDevice> {
        match name {
            "VC1902" => Some(Self::vc1902()),
            "VC1902-half" => Some(Self::half_vc1902()),
            "VC1802" => Some(Self::vc1802()),
            "VC2802-like" => Some(Self::vc2802_like()),
            _ => None,
        }
    }

    /// Total number of AIE cores in the array.
    pub fn total_cores(&self) -> usize {
        self.rows * self.cols
    }

    /// Total number of data-memory banks in the array.
    pub fn total_banks(&self) -> u64 {
        (self.total_cores() as u64) * self.banks_per_tile
    }

    /// Bytes per memory bank.
    pub fn bank_bytes(&self) -> u64 {
        self.data_mem_bytes / self.banks_per_tile
    }

    /// User-usable bytes for kernel buffers on one tile after reserving
    /// system banks (paper: 32 KB − 4 KB = 28 KB).
    pub fn user_mem_bytes(&self) -> u64 {
        self.data_mem_bytes - self.system_banks * self.bank_bytes()
    }

    /// The single-kernel buffer budget from eq. (6): because all MatMul
    /// buffers are double-buffered, each logical buffer set may use at most
    /// half of the user memory (paper: 14 KB).
    pub fn single_buffer_budget_bytes(&self) -> u64 {
        self.user_mem_bytes() / 2
    }

    /// Peak throughput of the whole array in ops/s for `prec`
    /// (2 ops per MAC), assuming every core runs MatMul at peak.
    pub fn peak_ops_per_sec(&self, prec: Precision) -> f64 {
        self.total_cores() as f64 * prec.peak_macs_per_cycle() as f64 * 2.0 * self.freq_hz
    }

    /// Total PLIOs (inputs + outputs) — used for the utilization column of
    /// Tables II/III.
    pub fn total_plios(&self) -> usize {
        self.plio_in + self.plio_out
    }

    /// PLIO width in bits required for AIE/PL rate matching: the PL runs at
    /// `pl_freq_hz`, the AIE stream moves 32 bits/cycle at `freq_hz`, so the
    /// PL-side width must be `32 * freq/pl_freq` bits (paper §V: 128).
    pub fn plio_width_bits(&self) -> u32 {
        (32.0 * self.freq_hz / self.pl_freq_hz).round() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vc1902_matches_paper_constants() {
        let d = AieDevice::vc1902();
        assert_eq!(d.total_cores(), 400);
        assert_eq!(d.total_banks(), 3200);
        assert_eq!(d.bank_bytes(), 4096);
        assert_eq!(d.user_mem_bytes(), 28 * 1024);
        assert_eq!(d.single_buffer_budget_bytes(), 14 * 1024);
        assert_eq!(d.plio_in, 78);
        assert_eq!(d.plio_out, 117);
        assert_eq!(d.total_plios(), 195);
    }

    #[test]
    fn vc1902_peak_throughput_matches_wp506() {
        // Paper intro: 400 cores @1.25GHz = 8 TFLOPs fp32, 128 TOPs int8.
        let d = AieDevice::vc1902();
        assert!((d.peak_ops_per_sec(Precision::Fp32) - 8e12).abs() < 1e6);
        assert!((d.peak_ops_per_sec(Precision::Int8) - 128e12).abs() < 1e6);
    }

    #[test]
    fn plio_rate_matching_width_is_128_bits() {
        // Paper §V: PLIO width 128 bits matches 1.25GHz AIE to 312.5MHz PL.
        assert_eq!(AieDevice::vc1902().plio_width_bits(), 128);
    }

    #[test]
    fn generic_device_scales() {
        let d = AieDevice::half_vc1902();
        assert_eq!(d.total_cores(), 200);
        assert_eq!(d.total_plios(), 95);
    }

    #[test]
    fn device_presets_by_name() {
        for name in ["VC1902", "VC1902-half", "VC1802", "VC2802-like"] {
            let d = AieDevice::by_name(name).unwrap();
            assert_eq!(d.name, name);
            assert!(d.total_cores() > 0);
        }
        assert!(AieDevice::by_name("XCVU9P").is_none());
    }

    #[test]
    fn vc1802_is_6x50() {
        let d = AieDevice::vc1802();
        assert_eq!(d.total_cores(), 300);
        // Peak scales with the array: 300/400 of the VC1902.
        let ratio = d.peak_ops_per_sec(Precision::Int8)
            / AieDevice::vc1902().peak_ops_per_sec(Precision::Int8);
        assert!((ratio - 0.75).abs() < 1e-12);
    }
}
