//! Circuit-switched stream routing over the AXI4-Stream switch fabric
//! (paper §III-B, §IV-B and the PnR-feasibility discussion of §V-B1).
//!
//! MaxEVA uses *only* circuit switching: every `A` and `B` input PLIO is
//! broadcast to its destination MatMul tiles over statically configured
//! switch routes, and every group output streams back to a PLIO. This
//! module builds those broadcast trees, accounts per-link stream usage
//! against the switch port capacities, and reports congestion — it is the
//! stand-in for the AMD AIE PnR/router whose failure on `10×4×8` the
//! paper reports.

pub mod broadcast;
pub mod router;

pub use broadcast::{broadcast_tree, BroadcastTree};
pub use router::{route_design, RouteReport, RoutingError};
