//! Broadcast-tree construction for circuit-switched streams.
//!
//! A broadcast tree starts at an interface tile (row −1, modelled as a
//! virtual row below row 0), runs a vertical trunk up the source column,
//! and branches horizontally along each destination row (standard
//! dimension-ordered routing, which is what the AIE router produces for
//! column-trunk broadcasts). Circuit-switched broadcast duplicates the
//! stream *at the switches*: a link carries one stream regardless of how
//! many destinations lie behind it.

use crate::arch::topology::Coord;
use std::collections::HashSet;

/// A directed inter-switch link. Rows are offset by +1 so the interface
/// row is row 0 and AIE row r is switch row r+1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Link {
    pub from: (usize, usize), // (switch_row, col)
    pub to: (usize, usize),
}

/// A routed broadcast tree: the set of links it occupies.
#[derive(Debug, Clone)]
pub struct BroadcastTree {
    pub source_col: usize,
    pub dests: Vec<Coord>,
    pub links: HashSet<Link>,
}

/// Switch-row of an AIE tile row.
fn srow(aie_row: usize) -> usize {
    aie_row + 1
}

/// Build the broadcast tree from interface column `source_col` to `dests`.
pub fn broadcast_tree(source_col: usize, dests: &[Coord]) -> BroadcastTree {
    let mut links = HashSet::new();
    if !dests.is_empty() {
        // Vertical trunk on the source column up to the highest dest row.
        let top = dests.iter().map(|d| srow(d.row)).max().unwrap();
        for r in 0..top {
            links.insert(Link {
                from: (r, source_col),
                to: (r + 1, source_col),
            });
        }
        // Horizontal branch along each destination row.
        for d in dests {
            let r = srow(d.row);
            let (mut a, b) = (source_col, d.col);
            while a != b {
                let next = if a < b { a + 1 } else { a - 1 };
                links.insert(Link {
                    from: (r, a),
                    to: (r, next),
                });
                a = next;
            }
        }
    }
    BroadcastTree {
        source_col,
        dests: dests.to_vec(),
        links,
    }
}

/// Build the (reverse) route from a source tile down to an interface
/// column: horizontal on the tile's row, then vertical down.
pub fn output_route(from: Coord, dest_col: usize) -> BroadcastTree {
    let mut links = HashSet::new();
    let r = srow(from.row);
    let (mut a, b) = (from.col, dest_col);
    while a != b {
        let next = if a < b { a + 1 } else { a - 1 };
        links.insert(Link {
            from: (r, a),
            to: (r, next),
        });
        a = next;
    }
    for row in (1..=r).rev() {
        links.insert(Link {
            from: (row, dest_col),
            to: (row - 1, dest_col),
        });
    }
    BroadcastTree {
        source_col: dest_col,
        dests: vec![from],
        links,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_dest_tree_is_l_shaped() {
        let t = broadcast_tree(3, &[Coord::new(2, 5)]);
        // Trunk: 3 vertical links (srow 0→3); branch: 2 horizontal.
        assert_eq!(t.links.len(), 3 + 2);
    }

    #[test]
    fn broadcast_shares_trunk() {
        // Two dests on the same column: trunk shared, no horizontal links.
        let t = broadcast_tree(4, &[Coord::new(1, 4), Coord::new(3, 4)]);
        assert_eq!(t.links.len(), 4); // vertical 0→4 only
    }

    #[test]
    fn branches_left_and_right() {
        let t = broadcast_tree(10, &[Coord::new(0, 8), Coord::new(0, 12)]);
        // Trunk 0→1 (1 link) + 2 left + 2 right.
        assert_eq!(t.links.len(), 1 + 2 + 2);
    }

    #[test]
    fn empty_dests_empty_tree() {
        let t = broadcast_tree(0, &[]);
        assert!(t.links.is_empty());
    }

    #[test]
    fn output_route_reaches_interface_row() {
        let t = output_route(Coord::new(3, 7), 5);
        // Horizontal 7→5 on srow 4 (2 links) + vertical 4→0 (4 links).
        assert_eq!(t.links.len(), 2 + 4);
        assert!(t.links.contains(&Link { from: (1, 5), to: (0, 5) }));
    }
}
