//! The design-level router: assigns PLIOs to interface columns, routes all
//! broadcast trees and output streams with load-balanced L-routing,
//! accounts link usage against per-direction switch capacities, and
//! applies the PnR slack rule the paper reports (§V-B1: `10×4×8` fails —
//! DMA routes plus 100% core utilization leave no routing slack).
//!
//! Route construction mimics the AMD router's behaviour at the level that
//! matters for feasibility: every (stream, destination) pair is routed as
//! an L (column-then-row or row-then-column), greedily choosing the
//! variant with the lower maximum link load. Circuit-switched broadcast
//! duplicates at switches, so links shared between destinations of the
//! same stream are counted once.

use crate::arch::device::AieDevice;
use crate::arch::topology::{interface_columns, Coord};
use crate::placement::placer::PlacedDesign;
use crate::routing::broadcast::Link;
// §Perf: per-link loads live in a flat dense array indexed by packed link
// ids (grid position × direction) instead of a hash map, and per-stream
// claimed-link sets are generation-stamped dense arrays — see
// EXPERIMENTS.md §Perf for the step-by-step log. FxHash remains for the
// small column-assignment map.
use rustc_hash::FxHashMap as HashMap;

#[derive(Debug, thiserror::Error)]
pub enum RoutingError {
    #[error("link capacity exceeded on {count} links (max overuse {max_over} streams)")]
    Congested { count: usize, max_over: u32 },
    #[error(
        "no routing slack: design uses DMA ({dma_banks} banks) with 100% core \
         utilization (paper §V-B1: PnR fails on such designs)"
    )]
    NoSlack { dma_banks: u64 },
    #[error("not enough interface columns: need {need}, have {have}")]
    NotEnoughPlios { need: usize, have: usize },
}

/// Routing result: per-link usage statistics.
#[derive(Debug, Clone)]
pub struct RouteReport {
    /// Number of distinct links used.
    pub links_used: usize,
    /// Maximum streams on any single link.
    pub max_link_load: u32,
    /// Mean streams per used link.
    pub mean_link_load: f64,
    /// Total streams routed (A inputs + B inputs + outputs + DMA hops).
    pub streams: usize,
}

/// Per-direction link capacity (AM009 switch master ports): vertical
/// links are the 6-wide north ports, horizontal links 4-wide.
fn capacity(dev: &AieDevice, l: &Link) -> u32 {
    let _ = l; // uniform effective capacity per direction (see AieDevice)
    dev.switch_capacity_per_dir
}

/// Mutable routing state: link loads in a dense array.
///
/// Link id = ((switch_row · cols) + col) · 4 + direction, directions
/// N/S/E/W — O(1) lookups, cache-friendly accumulation.
struct Fabric<'d> {
    dev: &'d AieDevice,
    load: Vec<u32>,
    rows: usize,
    cols: usize,
}

/// Generation-stamped membership set over dense link ids: `clear()` is
/// O(1) (bump the generation), insert/contains are single array slots.
struct Marker {
    stamp: Vec<u32>,
    gen: u32,
}

impl Marker {
    fn new(n: usize) -> Self {
        Marker { stamp: vec![0; n], gen: 1 }
    }
    fn clear(&mut self) {
        self.gen += 1;
    }
    fn contains(&self, id: usize) -> bool {
        self.stamp[id] == self.gen
    }
    /// Returns true if newly inserted.
    fn insert(&mut self, id: usize) -> bool {
        if self.stamp[id] == self.gen {
            false
        } else {
            self.stamp[id] = self.gen;
            true
        }
    }
}

impl<'d> Fabric<'d> {
    fn new(dev: &'d AieDevice) -> Self {
        let rows = dev.rows + 1; // + interface switch row
        let cols = dev.cols;
        Fabric {
            dev,
            load: vec![0; rows * cols * 4],
            rows,
            cols,
        }
    }

    /// Pack a directed link into its dense id.
    #[allow(dead_code)]
    fn link_id(&self, l: &Link) -> usize {
        let (fr, fc) = l.from;
        let (tr, tc) = l.to;
        let dir = if tr > fr {
            0 // north
        } else if tr < fr {
            1 // south
        } else if tc > fc {
            2 // east
        } else {
            3 // west
        };
        (fr * self.cols + fc) * 4 + dir
    }

    /// Unpack a dense id back into a link (diagnostics only).
    fn id_link(&self, id: usize) -> Link {
        let dir = id % 4;
        let cell = id / 4;
        let (r, c) = (cell / self.cols, cell % self.cols);
        let to = match dir {
            0 => (r + 1, c),
            1 => (r - 1, c),
            2 => (r, c + 1),
            _ => (r, c - 1),
        };
        Link { from: (r, c), to }
    }

    /// Visit the dense link ids of an L path (`col_first` selects the
    /// variant) without materializing a Vec — §Perf: the router's hot
    /// inner loop (allocation-free costing).
    fn walk_l<F: FnMut(usize)>(
        &self,
        src: (usize, usize),
        dst: (usize, usize),
        col_first: bool,
        mut f: F,
    ) {
        let cols = self.cols;
        let seg_v = |col: usize, r0: usize, r1: usize, f: &mut F| {
            let (mut a, b) = (r0, r1);
            while a != b {
                let (next, dir) = if a < b { (a + 1, 0) } else { (a - 1, 1) };
                f((a * cols + col) * 4 + dir);
                a = next;
            }
        };
        let seg_h = |row: usize, c0: usize, c1: usize, f: &mut F| {
            let (mut a, b) = (c0, c1);
            while a != b {
                let (next, dir) = if a < b { (a + 1, 2) } else { (a - 1, 3) };
                f((row * cols + a) * 4 + dir);
                a = next;
            }
        };
        if col_first {
            seg_v(src.1, src.0, dst.0, &mut f);
            seg_h(dst.0, src.1, dst.1, &mut f);
        } else {
            seg_h(src.0, src.1, dst.1, &mut f);
            seg_v(dst.1, src.0, dst.0, &mut f);
        }
    }

    /// Route one (source, dest) pair of a stream, choosing the less-loaded
    /// L variant. `mine` accumulates this stream's links.
    fn route_l(
        &mut self,
        src: (usize, usize),
        dst: (usize, usize),
        mine: &mut Marker,
    ) {
        // Cost both L variants allocation-free.
        let mut costs = [(0u32, 0u32); 2];
        for (i, col_first) in [(0usize, true), (1, false)] {
            let (mut max, mut sum) = (0u32, 0u32);
            self.walk_l(src, dst, col_first, |id| {
                if !mine.contains(id) {
                    let u = self.load[id] + 1;
                    max = max.max(u);
                    sum += u;
                }
            });
            costs[i] = (max, sum);
        }
        let col_first = costs[0] <= costs[1];
        // Claim the chosen path (gather into a fixed buffer, then commit —
        // `walk_l` borrows `self` immutably while `load` needs `&mut`).
        let mut ids = [0usize; 128];
        let mut n = 0usize;
        self.walk_l(src, dst, col_first, |id| {
            debug_assert!(n < ids.len(), "path longer than rows+cols");
            ids[n] = id;
            n += 1;
        });
        for &id in &ids[..n] {
            if mine.insert(id) {
                self.load[id] += 1;
            }
        }
    }

    fn congestion(&self) -> Option<(usize, u32)> {
        let mut count = 0;
        let mut max_over = 0;
        for (id, &u) in self.load.iter().enumerate() {
            if u == 0 {
                continue;
            }
            let cap = capacity(self.dev, &self.id_link(id));
            if u > cap {
                count += 1;
                max_over = max_over.max(u - cap);
            }
        }
        let _ = self.rows;
        (count > 0).then_some((count, max_over))
    }
}

/// Switch-row of an AIE tile row (interface row is switch row 0).
fn srow(aie_row: usize) -> usize {
    aie_row + 1
}

/// Assign streams to interface columns nearest their centroid with
/// bounded ports per column.
fn assign_columns(
    centroids: &[f64],
    iface_cols: &[usize],
    per_col: usize,
) -> Result<Vec<usize>, RoutingError> {
    let mut load: HashMap<usize, usize> = HashMap::default();
    let mut out = Vec::with_capacity(centroids.len());
    for &c in centroids {
        let mut best: Option<usize> = None;
        let mut best_d = f64::MAX;
        for &ic in iface_cols {
            if *load.get(&ic).unwrap_or(&0) >= per_col {
                continue;
            }
            let d = (ic as f64 - c).abs();
            if d < best_d {
                best_d = d;
                best = Some(ic);
            }
        }
        let col = best.ok_or(RoutingError::NotEnoughPlios {
            need: centroids.len(),
            have: iface_cols.len() * per_col,
        })?;
        *load.entry(col).or_insert(0) += 1;
        out.push(col);
    }
    Ok(out)
}

fn centroid(coords: &[Coord]) -> f64 {
    if coords.is_empty() {
        return 0.0;
    }
    coords.iter().map(|c| c.col as f64).sum::<f64>() / coords.len() as f64
}

/// Route the whole placed design. Returns usage statistics or a
/// congestion error. This is the reproduction of the paper's PnR
/// feasibility filter.
pub fn route_design(dev: &AieDevice, design: &PlacedDesign) -> Result<RouteReport, RoutingError> {
    // The paper's PnR slack rule: a design that needs DMA routes (pattern
    // P1 T-shapes) on a 100%-utilized array cannot be routed (§V-B1).
    if design.dma_banks > 0 && design.unused_cores(dev) == 0 {
        return Err(RoutingError::NoSlack {
            dma_banks: design.dma_banks,
        });
    }

    let (x, y, z) = (
        design.cand.x as usize,
        design.cand.y as usize,
        design.cand.z as usize,
    );
    let iface = interface_columns(dev);
    let group = |xi: usize, zi: usize| &design.groups[xi * z + zi];

    // A_{x,y} broadcast to the y-th MatMul of every group (x, ·): Z dests.
    let mut in_streams: Vec<Vec<Coord>> = Vec::new();
    for xi in 0..x {
        for yi in 0..y {
            in_streams.push((0..z).map(|zi| group(xi, zi).matmuls[yi]).collect());
        }
    }
    // B_{y,z} broadcast to the y-th MatMul of every group (·, z): X dests.
    for yi in 0..y {
        for zi in 0..z {
            in_streams.push((0..x).map(|xi| group(xi, zi).matmuls[yi]).collect());
        }
    }
    let out_streams: Vec<Coord> = design.groups.iter().map(|g| g.adder).collect();

    let in_per_col = dev.plio_in.div_ceil(iface.len().max(1));
    let out_per_col = dev.plio_out.div_ceil(iface.len().max(1));
    let in_cols = assign_columns(
        &in_streams.iter().map(|d| centroid(d)).collect::<Vec<_>>(),
        &iface,
        in_per_col,
    )?;
    let out_cols = assign_columns(
        &out_streams.iter().map(|c| c.col as f64).collect::<Vec<_>>(),
        &iface,
        out_per_col,
    )?;

    let mut fabric = Fabric::new(dev);
    let mut mine = Marker::new((dev.rows + 1) * dev.cols * 4);
    let mut streams = 0usize;
    for (dests, col) in in_streams.iter().zip(&in_cols) {
        streams += 1;
        mine.clear();
        // Route nearest destinations first so broadcast trunks grow
        // incrementally (shared prefixes reused).
        let mut ds = dests.clone();
        ds.sort_by_key(|d| srow(d.row));
        for d in ds {
            fabric.route_l((0, *col), (srow(d.row), d.col), &mut mine);
        }
    }
    for (src, col) in out_streams.iter().zip(&out_cols) {
        streams += 1;
        mine.clear();
        fabric.route_l((srow(src.row), src.col), (0, *col), &mut mine);
    }
    // DMA connections of T-shapes: a short switch route from the far
    // MatMul to the adder tile.
    for g in &design.groups {
        for (mm, buf) in g.matmuls.iter().zip(&g.out_buf_module) {
            if buf.is_none() {
                streams += 1;
                mine.clear();
                fabric.route_l(
                    (srow(mm.row), mm.col),
                    (srow(g.adder.row), g.adder.col),
                    &mut mine,
                );
            }
        }
    }

    if let Some((count, max_over)) = fabric.congestion() {
        return Err(RoutingError::Congested { count, max_over });
    }

    let links_used = fabric.load.iter().filter(|&&u| u > 0).count();
    let max_link_load = fabric.load.iter().copied().max().unwrap_or(0);
    let mean_link_load = fabric.load.iter().map(|&u| u as f64).sum::<f64>()
        / links_used.max(1) as f64;
    Ok(RouteReport {
        links_used,
        max_link_load,
        mean_link_load,
        streams,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;
    use crate::kernels::matmul::MatMulKernel;
    use crate::optimizer::array::ArrayCandidate;
    use crate::placement::pattern::Pattern;
    use crate::placement::placer::place_design;

    fn dev() -> AieDevice {
        AieDevice::vc1902()
    }

    fn placed(x: u64, y: u64, z: u64, pat: Pattern) -> PlacedDesign {
        place_design(
            &dev(),
            ArrayCandidate::new(x, y, z),
            pat,
            MatMulKernel::paper_kernel(Precision::Fp32),
        )
        .unwrap()
    }

    #[test]
    fn paper_13x4x6_routes() {
        // §V-B1: 13×4×6 "does not present any routing issues".
        let d = dev();
        let r = route_design(&d, &placed(13, 4, 6, Pattern::P1)).unwrap();
        assert!(r.max_link_load <= d.switch_capacity_per_dir);
        assert_eq!(r.streams, 76 + 78 + 9); // PLIO in + out + 9 DMA hops
    }

    #[test]
    fn paper_10x4x8_fails_routing() {
        // §V-B1: the top-ranked 10×4×8 fails PnR: DMA (P1) + 100% cores.
        let d = dev();
        let err = route_design(&d, &placed(10, 4, 8, Pattern::P1)).unwrap_err();
        assert!(matches!(err, RoutingError::NoSlack { .. }), "{err}");
    }

    #[test]
    fn paper_10x3x10_routes_despite_full_array() {
        // §V-B3: 10×3×10 P2 uses all 400 cores but routes fine (no DMA).
        let d = dev();
        route_design(&d, &placed(10, 3, 10, Pattern::P2)).unwrap();
    }

    #[test]
    fn all_other_paper_configs_route() {
        let d = dev();
        for (x, y, z, pat) in [
            (11, 4, 7, Pattern::P1),
            (11, 3, 9, Pattern::P2),
            (12, 4, 6, Pattern::P1),
            (12, 3, 8, Pattern::P2),
        ] {
            route_design(&d, &placed(x, y, z, pat))
                .unwrap_or_else(|e| panic!("{x}x{y}x{z} must route: {e}"));
        }
    }

    #[test]
    fn report_statistics_sane() {
        let d = dev();
        let r = route_design(&d, &placed(12, 3, 8, Pattern::P2)).unwrap();
        assert!(r.links_used > 0);
        assert!(r.mean_link_load >= 1.0);
        assert!(r.mean_link_load <= r.max_link_load as f64);
    }

    #[test]
    fn broadcast_duplication_not_double_counted() {
        // A small design: one A stream feeding Z groups shares its trunk.
        let d = dev();
        let r = route_design(&d, &placed(1, 3, 2, Pattern::P2)).unwrap();
        // 1·3 + 3·2 = 9 input streams + 2 outputs.
        assert_eq!(r.streams, 11);
        assert!(r.max_link_load <= d.switch_capacity_per_dir);
    }
}
