//! Workload generators: the Fig. 8 matrix-size sweep, DNN layer sets and
//! random request traces for the serving coordinator — plus the operand /
//! output containers the precision-generic serving engine moves around.

use crate::arch::precision::Precision;
use crate::util::prng::XorShift64;
use anyhow::{anyhow, Result};

/// A single MatMul request: `C (m×n) = A (m×k) · B (k×n)`, executed in
/// `precision` (per-request dispatch — one server can interleave fp32
/// and int8 requests in the same pipeline window).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatMulRequest {
    pub id: u64,
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// Numeric precision this request runs in. The serving engine
    /// supports [`Precision::Fp32`] and [`Precision::Int8`] (int8
    /// operands, i32 accumulation — the paper's two headline paths).
    pub precision: Precision,
}

impl MatMulRequest {
    /// An fp32 request (the historical default).
    pub fn f32(id: u64, m: u64, k: u64, n: u64) -> Self {
        MatMulRequest { id, m, k, n, precision: Precision::Fp32 }
    }

    /// An int8 request: operands are int8-range values carried as `i32`
    /// (matching [`crate::runtime::Executable::run_i32`]), results are
    /// exact i32 accumulations.
    pub fn int8(id: u64, m: u64, k: u64, n: u64) -> Self {
        MatMulRequest { id, m, k, n, precision: Precision::Int8 }
    }

    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }
}

/// Operands of one request, typed by precision. Int8 operands are
/// int8-range values carried as `i32` — the PJRT int8 artifacts take
/// int32 operands and cast internally, and the i32 carrier keeps the
/// reference backend's accumulation bit-exact.
#[derive(Debug, Clone, PartialEq)]
pub enum Operands {
    F32 { a: Vec<f32>, b: Vec<f32> },
    I32 { a: Vec<i32>, b: Vec<i32> },
}

impl Operands {
    /// The precision these operands are for.
    pub fn precision(&self) -> Precision {
        match self {
            Operands::F32 { .. } => Precision::Fp32,
            Operands::I32 { .. } => Precision::Int8,
        }
    }
}

/// Result of one request, typed by the request's precision (int8
/// requests accumulate and return i32, per the paper's §IV-C1).
#[derive(Debug, Clone, PartialEq)]
pub enum MatOutput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl MatOutput {
    pub fn len(&self) -> usize {
        match self {
            MatOutput::F32(v) => v.len(),
            MatOutput::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            MatOutput::F32(v) => v.is_empty(),
            MatOutput::I32(v) => v.is_empty(),
        }
    }

    /// Unwrap an fp32 result.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            MatOutput::F32(v) => Ok(v),
            MatOutput::I32(_) => Err(anyhow!("output is i32, not f32")),
        }
    }

    /// Unwrap an int8-path (i32-accumulated) result.
    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            MatOutput::I32(v) => Ok(v),
            MatOutput::F32(_) => Err(anyhow!("output is f32, not i32")),
        }
    }
}

/// Fig. 8 sweep: square sizes as powers of two from `lo` to `hi`
/// (inclusive), e.g. 256..=16384.
pub fn square_sweep(lo: u64, hi: u64) -> Vec<u64> {
    assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
    let mut v = Vec::new();
    let mut s = lo;
    while s <= hi {
        v.push(s);
        s *= 2;
    }
    v
}

/// A reproducible random trace of fp32 MatMul requests with sizes drawn
/// from power-of-two buckets weighted toward DL-typical GEMM shapes.
pub fn random_trace(n: usize, seed: u64) -> Vec<MatMulRequest> {
    let mut rng = XorShift64::new(seed);
    let sizes = [128u64, 256, 512, 1024, 2048];
    (0..n)
        .map(|i| {
            let (m, k, n) = (*rng.choose(&sizes), *rng.choose(&sizes), *rng.choose(&sizes));
            MatMulRequest::f32(i as u64, m, k, n)
        })
        .collect()
}

/// A reproducible random trace mixing fp32 and int8 requests (roughly
/// half each) — the dual-precision traffic shape the MaxEVA serving
/// engine is built for.
pub fn mixed_trace(n: usize, seed: u64) -> Vec<MatMulRequest> {
    let mut rng = XorShift64::new(seed);
    let sizes = [64u64, 128, 256, 512];
    (0..n)
        .map(|i| {
            let (m, k, nn) = (*rng.choose(&sizes), *rng.choose(&sizes), *rng.choose(&sizes));
            if rng.gen_range(0, 2) == 0 {
                MatMulRequest::int8(i as u64, m, k, nn)
            } else {
                MatMulRequest::f32(i as u64, m, k, nn)
            }
        })
        .collect()
}

/// Materialize an fp32 request trace into a serving batch: reproducible
/// random f32 operands for each request, ready for
/// [`crate::coordinator::MatMulServer::run_batch`]. Shared by the e2e
/// bench, the serving example and the pipeline equivalence tests so the
/// A/B configurations run byte-identical inputs.
pub fn materialize_batch(
    requests: &[MatMulRequest],
    seed: u64,
) -> Vec<(MatMulRequest, Vec<f32>, Vec<f32>)> {
    let mut rng = XorShift64::new(seed);
    let mut rand_vec = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect()
    };
    requests
        .iter()
        .map(|r| {
            debug_assert_eq!(r.precision, Precision::Fp32, "materialize_batch is fp32-only");
            let a = rand_vec((r.m * r.k) as usize);
            let b = rand_vec((r.k * r.n) as usize);
            (*r, a, b)
        })
        .collect()
}

/// Materialize a mixed-precision trace: f32 operands in `[-1, 1)` for
/// fp32 requests, int8-range integers (carried as i32) for int8
/// requests. Deterministic in `seed`, so A/B engine configurations run
/// byte-identical inputs.
pub fn materialize_mixed(requests: &[MatMulRequest], seed: u64) -> Vec<(MatMulRequest, Operands)> {
    let mut rng = XorShift64::new(seed);
    requests
        .iter()
        .map(|r| {
            let (an, bn) = ((r.m * r.k) as usize, (r.k * r.n) as usize);
            let ops = match r.precision {
                Precision::Int8 => Operands::I32 {
                    a: (0..an).map(|_| rng.gen_range(0, 256) as i32 - 128).collect(),
                    b: (0..bn).map(|_| rng.gen_range(0, 256) as i32 - 128).collect(),
                },
                _ => Operands::F32 {
                    a: (0..an).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect(),
                    b: (0..bn).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect(),
                },
            };
            (*r, ops)
        })
        .collect()
}

/// Batched-GEMM layer sets of a small transformer block (batch×seq = rows)
/// — used as a domain-specific example workload.
pub fn transformer_block_gemms(rows: u64, d_model: u64, d_ff: u64) -> Vec<MatMulRequest> {
    vec![
        // QKV projection (fused): rows × d_model × 3·d_model
        MatMulRequest::f32(0, rows, d_model, 3 * d_model),
        // Attention output projection.
        MatMulRequest::f32(1, rows, d_model, d_model),
        // FFN up / down.
        MatMulRequest::f32(2, rows, d_model, d_ff),
        MatMulRequest::f32(3, rows, d_ff, d_model),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_powers_of_two() {
        let v = square_sweep(256, 16384);
        assert_eq!(v, vec![256, 512, 1024, 2048, 4096, 8192, 16384]);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(random_trace(10, 7), random_trace(10, 7));
        assert_ne!(random_trace(10, 7), random_trace(10, 8));
        assert!(random_trace(10, 7).iter().all(|r| r.precision == Precision::Fp32));
    }

    #[test]
    fn mixed_trace_has_both_precisions() {
        let t = mixed_trace(32, 5);
        assert_eq!(t, mixed_trace(32, 5));
        assert!(t.iter().any(|r| r.precision == Precision::Int8));
        assert!(t.iter().any(|r| r.precision == Precision::Fp32));
    }

    #[test]
    fn transformer_gemm_shapes() {
        let g = transformer_block_gemms(512, 768, 3072);
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].n, 2304);
        assert_eq!(g[2].macs(), 512 * 768 * 3072);
    }

    #[test]
    #[should_panic]
    fn sweep_rejects_non_power_of_two() {
        square_sweep(100, 200);
    }

    #[test]
    fn materialized_batch_deterministic_and_shaped() {
        let reqs = random_trace(4, 3);
        let a = materialize_batch(&reqs, 99);
        let b = materialize_batch(&reqs, 99);
        assert_eq!(a.len(), 4);
        for ((ra, aa, ba), (rb, ab, bb)) in a.iter().zip(&b) {
            assert_eq!(ra, rb);
            assert_eq!(aa, ab);
            assert_eq!(ba, bb);
            assert_eq!(aa.len() as u64, ra.m * ra.k);
            assert_eq!(ba.len() as u64, ra.k * ra.n);
        }
        let c = materialize_batch(&reqs, 100);
        assert_ne!(a[0].1, c[0].1, "different seeds must differ");
    }

    #[test]
    fn materialized_mixed_matches_precision_and_range() {
        let reqs = vec![MatMulRequest::int8(0, 5, 7, 3), MatMulRequest::f32(1, 4, 4, 4)];
        let batch = materialize_mixed(&reqs, 21);
        assert_eq!(batch, materialize_mixed(&reqs, 21));
        match &batch[0].1 {
            Operands::I32 { a, b } => {
                assert_eq!(a.len(), 35);
                assert_eq!(b.len(), 21);
                assert!(a.iter().chain(b).all(|&v| (-128..=127).contains(&v)));
            }
            other => panic!("int8 request got {other:?}"),
        }
        match &batch[1].1 {
            Operands::F32 { a, b } => {
                assert_eq!(a.len(), 16);
                assert_eq!(b.len(), 16);
            }
            other => panic!("fp32 request got {other:?}"),
        }
    }

    #[test]
    fn output_unwrap_paths() {
        assert_eq!(MatOutput::F32(vec![1.0]).into_f32().unwrap(), vec![1.0]);
        assert_eq!(MatOutput::I32(vec![2]).into_i32().unwrap(), vec![2]);
        assert!(MatOutput::F32(vec![]).into_i32().is_err());
        assert!(MatOutput::I32(vec![]).into_f32().is_err());
        assert!(MatOutput::F32(vec![]).is_empty());
        assert_eq!(MatOutput::I32(vec![1, 2, 3]).len(), 3);
        assert_eq!(
            Operands::I32 { a: vec![], b: vec![] }.precision(),
            Precision::Int8
        );
    }
}
