//! Workload generators: the Fig. 8 matrix-size sweep, DNN layer sets and
//! random request traces for the serving coordinator — plus the operand /
//! output containers the precision-generic serving engine moves around.

use crate::arch::precision::Precision;
use crate::util::prng::XorShift64;
use anyhow::{anyhow, Result};
use std::time::Duration;

/// A single MatMul request: `C (m×n) = A (m×k) · B (k×n)`, executed in
/// `precision` (per-request dispatch — one server can interleave fp32
/// and int8 requests in the same pipeline window).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatMulRequest {
    pub id: u64,
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// Numeric precision this request runs in. The serving engine
    /// supports [`Precision::Fp32`] and [`Precision::Int8`] (int8
    /// operands, i32 accumulation — the paper's two headline paths).
    pub precision: Precision,
    /// Priority class for the scheduling policies (`0` = highest;
    /// out-of-range classes clamp to the server's configured class
    /// count). Ignored by the default FIFO policy.
    pub class: u8,
    /// Optional identity of the B (weight) operand for the server's
    /// packed-weight cache: requests sharing a `weight_id` (and shape
    /// and precision) assert byte-identical B matrices, so the server
    /// can reuse the packed tile pool without rehashing the operand.
    /// `None` falls back to a content fingerprint when the cache is
    /// enabled (`ServeConfig::weight_cache_bytes > 0`); with the cache
    /// off the field is ignored entirely.
    pub weight_id: Option<u64>,
    /// Optional completion deadline, measured from admission. A request
    /// still open when the budget elapses resolves with a typed
    /// `DeadlineExceeded` error, its unscheduled tiles are never issued
    /// and its queue/window slots are reclaimed — partial output is
    /// never delivered. `None` (the default) never expires. With
    /// `ServeConfig::slo_admission` enabled the deadline is also
    /// checked at admission against the per-class service-time
    /// estimate, rejecting unattainable requests immediately.
    pub deadline: Option<Duration>,
}

impl MatMulRequest {
    /// An fp32 request (the historical default), class 0.
    pub fn f32(id: u64, m: u64, k: u64, n: u64) -> Self {
        MatMulRequest {
            id,
            m,
            k,
            n,
            precision: Precision::Fp32,
            class: 0,
            weight_id: None,
            deadline: None,
        }
    }

    /// An int8 request: operands are int8-range values carried as `i32`
    /// (matching [`crate::runtime::Executable::run_i32`]), results are
    /// exact i32 accumulations. Class 0.
    pub fn int8(id: u64, m: u64, k: u64, n: u64) -> Self {
        MatMulRequest {
            id,
            m,
            k,
            n,
            precision: Precision::Int8,
            class: 0,
            weight_id: None,
            deadline: None,
        }
    }

    /// The same request in priority class `class`.
    pub fn with_class(mut self, class: u8) -> Self {
        self.class = class;
        self
    }

    /// The same request tagging its B operand with a weight identity
    /// for the server's packed-weight cache (see
    /// [`MatMulRequest::weight_id`]).
    pub fn with_weight_id(mut self, weight_id: u64) -> Self {
        self.weight_id = Some(weight_id);
        self
    }

    /// The same request with a completion deadline, measured from
    /// admission (see [`MatMulRequest::deadline`]).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }
}

/// Operands of one request, typed by precision. Int8 operands are
/// int8-range values carried as `i32` — the PJRT int8 artifacts take
/// int32 operands and cast internally, and the i32 carrier keeps the
/// reference backend's accumulation bit-exact.
#[derive(Debug, Clone, PartialEq)]
pub enum Operands {
    F32 { a: Vec<f32>, b: Vec<f32> },
    I32 { a: Vec<i32>, b: Vec<i32> },
}

impl Operands {
    /// The precision these operands are for.
    pub fn precision(&self) -> Precision {
        match self {
            Operands::F32 { .. } => Precision::Fp32,
            Operands::I32 { .. } => Precision::Int8,
        }
    }
}

/// Result of one request, typed by the request's precision (int8
/// requests accumulate and return i32, per the paper's §IV-C1).
#[derive(Debug, Clone, PartialEq)]
pub enum MatOutput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl MatOutput {
    pub fn len(&self) -> usize {
        match self {
            MatOutput::F32(v) => v.len(),
            MatOutput::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            MatOutput::F32(v) => v.is_empty(),
            MatOutput::I32(v) => v.is_empty(),
        }
    }

    /// Unwrap an fp32 result.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            MatOutput::F32(v) => Ok(v),
            MatOutput::I32(_) => Err(anyhow!("output is i32, not f32")),
        }
    }

    /// Unwrap an int8-path (i32-accumulated) result.
    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            MatOutput::I32(v) => Ok(v),
            MatOutput::F32(_) => Err(anyhow!("output is f32, not i32")),
        }
    }
}

/// Fig. 8 sweep: square sizes as powers of two from `lo` to `hi`
/// (inclusive), e.g. 256..=16384.
pub fn square_sweep(lo: u64, hi: u64) -> Vec<u64> {
    assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
    let mut v = Vec::new();
    let mut s = lo;
    while s <= hi {
        v.push(s);
        s *= 2;
    }
    v
}

/// A reproducible random trace of fp32 MatMul requests with sizes drawn
/// from power-of-two buckets weighted toward DL-typical GEMM shapes.
pub fn random_trace(n: usize, seed: u64) -> Vec<MatMulRequest> {
    let mut rng = XorShift64::new(seed);
    let sizes = [128u64, 256, 512, 1024, 2048];
    (0..n)
        .map(|i| {
            let (m, k, n) = (*rng.choose(&sizes), *rng.choose(&sizes), *rng.choose(&sizes));
            MatMulRequest::f32(i as u64, m, k, n)
        })
        .collect()
}

/// A reproducible random trace mixing fp32 and int8 requests (roughly
/// half each) — the dual-precision traffic shape the MaxEVA serving
/// engine is built for.
pub fn mixed_trace(n: usize, seed: u64) -> Vec<MatMulRequest> {
    let mut rng = XorShift64::new(seed);
    let sizes = [64u64, 128, 256, 512];
    (0..n)
        .map(|i| {
            let (m, k, nn) = (*rng.choose(&sizes), *rng.choose(&sizes), *rng.choose(&sizes));
            if rng.gen_range(0, 2) == 0 {
                MatMulRequest::int8(i as u64, m, k, nn)
            } else {
                MatMulRequest::f32(i as u64, m, k, nn)
            }
        })
        .collect()
}

/// Materialize an fp32 request trace into a serving batch: reproducible
/// random f32 operands for each request, ready for
/// [`crate::coordinator::MatMulServer::run_batch`]. Shared by the e2e
/// bench, the serving example and the pipeline equivalence tests so the
/// A/B configurations run byte-identical inputs.
pub fn materialize_batch(
    requests: &[MatMulRequest],
    seed: u64,
) -> Vec<(MatMulRequest, Vec<f32>, Vec<f32>)> {
    let mut rng = XorShift64::new(seed);
    let mut rand_vec = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect()
    };
    requests
        .iter()
        .map(|r| {
            debug_assert_eq!(r.precision, Precision::Fp32, "materialize_batch is fp32-only");
            let a = rand_vec((r.m * r.k) as usize);
            let b = rand_vec((r.k * r.n) as usize);
            (*r, a, b)
        })
        .collect()
}

/// Materialize a mixed-precision trace: f32 operands in `[-1, 1)` for
/// fp32 requests, int8-range integers (carried as i32) for int8
/// requests. Deterministic in `seed`, so A/B engine configurations run
/// byte-identical inputs.
pub fn materialize_mixed(requests: &[MatMulRequest], seed: u64) -> Vec<(MatMulRequest, Operands)> {
    let mut rng = XorShift64::new(seed);
    requests
        .iter()
        .map(|r| {
            let (an, bn) = ((r.m * r.k) as usize, (r.k * r.n) as usize);
            let ops = match r.precision {
                Precision::Int8 => Operands::I32 {
                    a: (0..an).map(|_| rng.gen_range(0, 256) as i32 - 128).collect(),
                    b: (0..bn).map(|_| rng.gen_range(0, 256) as i32 - 128).collect(),
                },
                _ => Operands::F32 {
                    a: (0..an).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect(),
                    b: (0..bn).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect(),
                },
            };
            (*r, ops)
        })
        .collect()
}

/// An open-loop arrival process: *when* requests hit the server,
/// decoupled from how fast the server drains them (closed-loop
/// submission only ever measures the server at its own pace).
/// Deterministic — Poisson draws come from [`XorShift64`].
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_hz` requests/second.
    Poisson { rate_hz: f64, seed: u64 },
    /// Replay of recorded arrival timestamps (seconds, nondecreasing),
    /// e.g. loaded with [`load_arrival_trace`].
    Trace(Vec<f64>),
}

impl ArrivalProcess {
    /// The first `n` arrival times (seconds from stream start). A trace
    /// shorter than `n` yields all it has — match your request count to
    /// the trace when replaying.
    pub fn arrivals(&self, n: usize) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate_hz, seed } => poisson_arrivals(n, *rate_hz, *seed),
            ArrivalProcess::Trace(times) => times.iter().copied().take(n).collect(),
        }
    }
}

/// `n` Poisson arrival times at `rate_hz` requests/second:
/// exponential inter-arrival gaps, cumulated. Deterministic in `seed`.
pub fn poisson_arrivals(n: usize, rate_hz: f64, seed: u64) -> Vec<f64> {
    assert!(rate_hz > 0.0, "poisson_arrivals: rate must be positive");
    let mut rng = XorShift64::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_hz;
            t
        })
        .collect()
}

/// Parse an arrival trace: one absolute timestamp (seconds) per line,
/// `#`-comments and blank lines ignored. Timestamps must be finite,
/// nonnegative and nondecreasing.
pub fn parse_arrival_trace(text: &str) -> Result<Vec<f64>> {
    let mut times = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let t: f64 = line
            .parse()
            .map_err(|e| anyhow!("arrival trace line {}: {e}", lineno + 1))?;
        if !t.is_finite() || t < 0.0 {
            return Err(anyhow!(
                "arrival trace line {}: timestamp {t} must be finite and >= 0",
                lineno + 1
            ));
        }
        if let Some(&prev) = times.last() {
            if t < prev {
                return Err(anyhow!(
                    "arrival trace line {}: timestamp {t} decreases (previous {prev})",
                    lineno + 1
                ));
            }
        }
        times.push(t);
    }
    Ok(times)
}

/// Load an arrival trace file (see [`parse_arrival_trace`] for the
/// format).
pub fn load_arrival_trace(path: impl AsRef<std::path::Path>) -> Result<Vec<f64>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading arrival trace {}: {e}", path.display()))?;
    parse_arrival_trace(&text)
}

/// Merge several per-stream arrival timelines into one submission
/// order: `(stream index, time)` sorted by time (ties resolved by
/// stream index, so the merge is deterministic).
pub fn merge_arrivals(streams: &[Vec<f64>]) -> Vec<(usize, f64)> {
    let mut merged: Vec<(usize, f64)> = streams
        .iter()
        .enumerate()
        .flat_map(|(s, times)| times.iter().map(move |&t| (s, t)))
        .collect();
    merged.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    merged
}

/// Batched-GEMM layer sets of a small transformer block (batch×seq = rows)
/// — used as a domain-specific example workload.
pub fn transformer_block_gemms(rows: u64, d_model: u64, d_ff: u64) -> Vec<MatMulRequest> {
    vec![
        // QKV projection (fused): rows × d_model × 3·d_model
        MatMulRequest::f32(0, rows, d_model, 3 * d_model),
        // Attention output projection.
        MatMulRequest::f32(1, rows, d_model, d_model),
        // FFN up / down.
        MatMulRequest::f32(2, rows, d_model, d_ff),
        MatMulRequest::f32(3, rows, d_ff, d_model),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_powers_of_two() {
        let v = square_sweep(256, 16384);
        assert_eq!(v, vec![256, 512, 1024, 2048, 4096, 8192, 16384]);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(random_trace(10, 7), random_trace(10, 7));
        assert_ne!(random_trace(10, 7), random_trace(10, 8));
        assert!(random_trace(10, 7).iter().all(|r| r.precision == Precision::Fp32));
    }

    #[test]
    fn mixed_trace_has_both_precisions() {
        let t = mixed_trace(32, 5);
        assert_eq!(t, mixed_trace(32, 5));
        assert!(t.iter().any(|r| r.precision == Precision::Int8));
        assert!(t.iter().any(|r| r.precision == Precision::Fp32));
    }

    #[test]
    fn class_builder_and_default() {
        let r = MatMulRequest::f32(1, 8, 8, 8);
        assert_eq!(r.class, 0);
        assert_eq!(r.weight_id, None);
        assert_eq!(r.deadline, None);
        let hi = r.with_class(3);
        assert_eq!(hi.class, 3);
        // Everything else is untouched.
        assert_eq!((hi.id, hi.m, hi.k, hi.n, hi.precision), (1, 8, 8, 8, Precision::Fp32));
        assert_eq!(hi.weight_id, None);
        assert_eq!(hi.deadline, None);
        assert_eq!(MatMulRequest::int8(2, 4, 4, 4).class, 0);
    }

    #[test]
    fn weight_id_builder() {
        let r = MatMulRequest::int8(5, 8, 16, 8).with_weight_id(42).with_class(1);
        assert_eq!(r.weight_id, Some(42));
        // Builder order is irrelevant and nothing else moves.
        assert_eq!((r.id, r.m, r.k, r.n, r.class), (5, 8, 16, 8, 1));
        assert_eq!(r.precision, Precision::Int8);
    }

    #[test]
    fn deadline_builder() {
        let r = MatMulRequest::f32(9, 8, 8, 8).with_deadline(Duration::from_millis(250));
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        // Nothing else moves, and builder order is irrelevant.
        assert_eq!((r.id, r.m, r.k, r.n, r.class), (9, 8, 8, 8, 0));
        let r2 = r.with_class(2).with_weight_id(7);
        assert_eq!(r2.deadline, Some(Duration::from_millis(250)));
        assert_eq!((r2.class, r2.weight_id), (2, Some(7)));
    }

    #[test]
    fn poisson_arrivals_deterministic_and_calibrated() {
        let a = poisson_arrivals(4000, 100.0, 7);
        assert_eq!(a, poisson_arrivals(4000, 100.0, 7));
        assert_ne!(a, poisson_arrivals(4000, 100.0, 8));
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "arrival times are nondecreasing");
        // Mean inter-arrival ≈ 1/rate (law of large numbers, 10% slack).
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.01).abs() < 0.001, "mean gap {mean_gap}");
        assert_eq!(ArrivalProcess::Poisson { rate_hz: 100.0, seed: 7 }.arrivals(10), a[..10]);
    }

    #[test]
    fn arrival_trace_parses_and_validates() {
        let good = "# trace\n0.0\n0.5 # second request\n\n0.5\n2.25\n";
        assert_eq!(parse_arrival_trace(good).unwrap(), vec![0.0, 0.5, 0.5, 2.25]);
        assert!(parse_arrival_trace("0.0\nnope\n").is_err());
        assert!(parse_arrival_trace("1.0\n0.5\n").is_err(), "decreasing timestamps");
        assert!(parse_arrival_trace("-1.0\n").is_err());
        assert!(parse_arrival_trace("inf\n").is_err());
        // Trace process truncates to n and tolerates short traces.
        let p = ArrivalProcess::Trace(vec![0.0, 1.0, 2.0]);
        assert_eq!(p.arrivals(2), vec![0.0, 1.0]);
        assert_eq!(p.arrivals(10), vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn arrival_trace_file_roundtrip() {
        let dir = std::env::temp_dir().join("maxeva_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("arrivals.txt");
        std::fs::write(&path, "0.001\n0.002\n0.0035\n").unwrap();
        assert_eq!(load_arrival_trace(&path).unwrap(), vec![0.001, 0.002, 0.0035]);
        assert!(load_arrival_trace(dir.join("missing.txt")).is_err());
    }

    #[test]
    fn merged_arrivals_sorted_and_stable() {
        let merged = merge_arrivals(&[vec![0.1, 0.3], vec![0.1, 0.2]]);
        assert_eq!(merged, vec![(0, 0.1), (1, 0.1), (1, 0.2), (0, 0.3)]);
        assert!(merge_arrivals(&[]).is_empty());
    }

    #[test]
    fn transformer_gemm_shapes() {
        let g = transformer_block_gemms(512, 768, 3072);
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].n, 2304);
        assert_eq!(g[2].macs(), 512 * 768 * 3072);
    }

    #[test]
    #[should_panic]
    fn sweep_rejects_non_power_of_two() {
        square_sweep(100, 200);
    }

    #[test]
    fn materialized_batch_deterministic_and_shaped() {
        let reqs = random_trace(4, 3);
        let a = materialize_batch(&reqs, 99);
        let b = materialize_batch(&reqs, 99);
        assert_eq!(a.len(), 4);
        for ((ra, aa, ba), (rb, ab, bb)) in a.iter().zip(&b) {
            assert_eq!(ra, rb);
            assert_eq!(aa, ab);
            assert_eq!(ba, bb);
            assert_eq!(aa.len() as u64, ra.m * ra.k);
            assert_eq!(ba.len() as u64, ra.k * ra.n);
        }
        let c = materialize_batch(&reqs, 100);
        assert_ne!(a[0].1, c[0].1, "different seeds must differ");
    }

    #[test]
    fn materialized_mixed_matches_precision_and_range() {
        let reqs = vec![MatMulRequest::int8(0, 5, 7, 3), MatMulRequest::f32(1, 4, 4, 4)];
        let batch = materialize_mixed(&reqs, 21);
        assert_eq!(batch, materialize_mixed(&reqs, 21));
        match &batch[0].1 {
            Operands::I32 { a, b } => {
                assert_eq!(a.len(), 35);
                assert_eq!(b.len(), 21);
                assert!(a.iter().chain(b).all(|&v| (-128..=127).contains(&v)));
            }
            other => panic!("int8 request got {other:?}"),
        }
        match &batch[1].1 {
            Operands::F32 { a, b } => {
                assert_eq!(a.len(), 16);
                assert_eq!(b.len(), 16);
            }
            other => panic!("fp32 request got {other:?}"),
        }
    }

    #[test]
    fn output_unwrap_paths() {
        assert_eq!(MatOutput::F32(vec![1.0]).into_f32().unwrap(), vec![1.0]);
        assert_eq!(MatOutput::I32(vec![2]).into_i32().unwrap(), vec![2]);
        assert!(MatOutput::F32(vec![]).into_i32().is_err());
        assert!(MatOutput::I32(vec![]).into_f32().is_err());
        assert!(MatOutput::F32(vec![]).is_empty());
        assert_eq!(MatOutput::I32(vec![1, 2, 3]).len(), 3);
        assert_eq!(
            Operands::I32 { a: vec![], b: vec![] }.precision(),
            Precision::Int8
        );
    }
}
