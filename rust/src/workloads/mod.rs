//! Workload generators: the Fig. 8 matrix-size sweep, DNN layer sets and
//! random request traces for the serving coordinator.

use crate::util::prng::XorShift64;

/// A single MatMul request: `C (m×n) = A (m×k) · B (k×n)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatMulRequest {
    pub id: u64,
    pub m: u64,
    pub k: u64,
    pub n: u64,
}

impl MatMulRequest {
    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }
}

/// Fig. 8 sweep: square sizes as powers of two from `lo` to `hi`
/// (inclusive), e.g. 256..=16384.
pub fn square_sweep(lo: u64, hi: u64) -> Vec<u64> {
    assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
    let mut v = Vec::new();
    let mut s = lo;
    while s <= hi {
        v.push(s);
        s *= 2;
    }
    v
}

/// A reproducible random trace of MatMul requests with sizes drawn from
/// power-of-two buckets weighted toward DL-typical GEMM shapes.
pub fn random_trace(n: usize, seed: u64) -> Vec<MatMulRequest> {
    let mut rng = XorShift64::new(seed);
    let sizes = [128u64, 256, 512, 1024, 2048];
    (0..n)
        .map(|i| MatMulRequest {
            id: i as u64,
            m: *rng.choose(&sizes),
            k: *rng.choose(&sizes),
            n: *rng.choose(&sizes),
        })
        .collect()
}

/// Materialize a request trace into a serving batch: reproducible random
/// f32 operands for each request, ready for
/// [`crate::coordinator::MatMulServer::run_batch`]. Shared by the e2e
/// bench, the serving example and the pipeline equivalence tests so the
/// A/B configurations run byte-identical inputs.
pub fn materialize_batch(
    requests: &[MatMulRequest],
    seed: u64,
) -> Vec<(MatMulRequest, Vec<f32>, Vec<f32>)> {
    let mut rng = XorShift64::new(seed);
    let mut rand_vec = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect()
    };
    requests
        .iter()
        .map(|r| {
            let a = rand_vec((r.m * r.k) as usize);
            let b = rand_vec((r.k * r.n) as usize);
            (*r, a, b)
        })
        .collect()
}

/// Batched-GEMM layer sets of a small transformer block (batch×seq = rows)
/// — used as a domain-specific example workload.
pub fn transformer_block_gemms(rows: u64, d_model: u64, d_ff: u64) -> Vec<MatMulRequest> {
    vec![
        // QKV projection (fused): rows × d_model × 3·d_model
        MatMulRequest { id: 0, m: rows, k: d_model, n: 3 * d_model },
        // Attention output projection.
        MatMulRequest { id: 1, m: rows, k: d_model, n: d_model },
        // FFN up / down.
        MatMulRequest { id: 2, m: rows, k: d_model, n: d_ff },
        MatMulRequest { id: 3, m: rows, k: d_ff, n: d_model },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_powers_of_two() {
        let v = square_sweep(256, 16384);
        assert_eq!(v, vec![256, 512, 1024, 2048, 4096, 8192, 16384]);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(random_trace(10, 7), random_trace(10, 7));
        assert_ne!(random_trace(10, 7), random_trace(10, 8));
    }

    #[test]
    fn transformer_gemm_shapes() {
        let g = transformer_block_gemms(512, 768, 3072);
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].n, 2304);
        assert_eq!(g[2].macs(), 512 * 768 * 3072);
    }

    #[test]
    #[should_panic]
    fn sweep_rejects_non_power_of_two() {
        square_sweep(100, 200);
    }

    #[test]
    fn materialized_batch_deterministic_and_shaped() {
        let reqs = random_trace(4, 3);
        let a = materialize_batch(&reqs, 99);
        let b = materialize_batch(&reqs, 99);
        assert_eq!(a.len(), 4);
        for ((ra, aa, ba), (rb, ab, bb)) in a.iter().zip(&b) {
            assert_eq!(ra, rb);
            assert_eq!(aa, ab);
            assert_eq!(ba, bb);
            assert_eq!(aa.len() as u64, ra.m * ra.k);
            assert_eq!(ba.len() as u64, ra.k * ra.n);
        }
        let c = materialize_batch(&reqs, 100);
        assert_ne!(a[0].1, c[0].1, "different seeds must differ");
    }
}
