//! Streaming admission: the bounded open-request gate and the in-flight
//! admission record handed to the scheduler thread.
//!
//! Admission is governed by `ServeConfig::queue_depth` — the maximum
//! number of *open* requests (admitted but not yet retired; `0` =
//! unbounded) — and an [`AdmissionPolicy`](crate::config::schema::AdmissionPolicy):
//! `Block` parks the submitting thread until a slot frees, `Reject`
//! fails fast with [`QueueFull`] so the caller can shed load or retry.
//!
//! # Per-class slot reservation
//!
//! `ServeConfig::class_queue_reserve` (empty = unreserved = the
//! historical single-semaphore gate, bit-for-bit) carves per-class
//! reserved slots out of `queue_depth`: a request of class `c` may
//! always take one of its class's reserved slots, and competes for the
//! **shared** remainder (`queue_depth − Σ reserves`) only once its
//! reserve is full. A saturating bulk class can therefore occupy at
//! most `shared + its own reserve` slots — it can no longer consume the
//! whole admission queue before the scheduler ever sees a
//! latency-class request. Out-of-range classes clamp to the last
//! reserve entry (mirroring `class_weights` clamping); if
//! `Σ reserves > queue_depth` the shared pool is empty and the
//! effective bound is `Σ reserves`. Reserves are ignored while
//! `queue_depth = 0` (unbounded admits everything anyway).

use crate::config::schema::AdmissionPolicy;
use crate::coordinator::handle::Reply;
use crate::workloads::{MatMulRequest, Operands};
use anyhow::{anyhow, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Returned by a [`AdmissionPolicy::Reject`] submission when the
/// request's class cannot open one more request. The payload is the
/// **rejecting class's** open-request bound — its reserved slots plus
/// the shared pool, which is simply `queue_depth` when no reserves are
/// configured. Recover it from the anyhow chain with
/// `err.downcast_ref::<QueueFull>()`.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("admission queue full ({0} open requests for this class)")]
pub struct QueueFull(pub usize);

/// A request admitted by a client thread, in flight to the scheduler.
///
/// `ops`/`reply` are `Option`s taken out on the normal path; the `Drop`
/// impl is the safety net for every other path (scheduler draining, the
/// event channel torn down with admits still queued, send failure): it
/// frees the admission slot and delivers a shutdown error, so a
/// successful `submit` always resolves its handle/callback.
pub(crate) struct Admitted {
    pub(crate) req: MatMulRequest,
    pub(crate) ops: Option<Operands>,
    pub(crate) submitted: Instant,
    pub(crate) reply: Option<Reply>,
    /// Cancellation token minted at submission; [`RequestHandle::cancel`]
    /// (and handle drop) route back to the scheduler through it.
    ///
    /// [`RequestHandle::cancel`]: crate::coordinator::handle::RequestHandle::cancel
    pub(crate) token: u64,
    pub(crate) gate: Arc<Gate>,
}

impl Drop for Admitted {
    fn drop(&mut self) {
        if let Some(reply) = self.reply.take() {
            self.gate.release(self.req.class);
            reply.send(self.req, Err(anyhow!("server is shutting down")));
        }
    }
}

/// The admission gate: a counting semaphore over open requests —
/// optionally with per-class reserved slots (module docs) — and a
/// closed flag so blocked producers wake when the server goes away.
pub(crate) struct Gate {
    /// `0` = unbounded.
    depth: usize,
    /// Reserved slots per class (empty = plain semaphore).
    reserves: Vec<usize>,
    /// Shared slots: `depth − Σ reserves`, saturating at zero.
    shared: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    /// Open requests per reserve class (one bucket when unreserved).
    open: Vec<usize>,
    closed: bool,
}

/// Closes the gate when dropped — even if the scheduler thread unwinds,
/// producers parked in [`Gate::admit`] wake up instead of hanging.
pub(crate) struct GateCloser(pub(crate) Arc<Gate>);

impl Drop for GateCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

impl Gate {
    pub(crate) fn new(depth: usize, reserves: Vec<usize>) -> Self {
        let shared = depth.saturating_sub(reserves.iter().sum());
        let buckets = reserves.len().max(1);
        Gate {
            depth,
            reserves,
            shared,
            state: Mutex::new(GateState { open: vec![0; buckets], closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Reserve bucket a request class lands in (out-of-range classes
    /// clamp to the last configured entry, like `class_weights`).
    fn bucket(&self, class: u8) -> usize {
        if self.reserves.is_empty() {
            0
        } else {
            (class as usize).min(self.reserves.len() - 1)
        }
    }

    fn reserve_of(&self, bucket: usize) -> usize {
        self.reserves.get(bucket).copied().unwrap_or(0)
    }

    /// Open-request bound of one class: its reserve plus the shared
    /// pool (= `queue_depth` when unreserved) — what a [`QueueFull`]
    /// rejection reports.
    fn class_bound(&self, bucket: usize) -> usize {
        self.shared + self.reserve_of(bucket)
    }

    /// Whether one more open request of `bucket` fits: its own reserve
    /// first, then the shared pool (occupancy above a class's reserve
    /// is what counts against shared).
    fn fits(&self, st: &GateState, bucket: usize) -> bool {
        if self.depth == 0 {
            return true;
        }
        if st.open[bucket] < self.reserve_of(bucket) {
            return true;
        }
        let mut shared_used = 0usize;
        for (b, &open) in st.open.iter().enumerate() {
            shared_used += open.saturating_sub(self.reserve_of(b));
        }
        shared_used < self.shared
    }

    pub(crate) fn admit(&self, policy: AdmissionPolicy, class: u8) -> Result<()> {
        let bucket = self.bucket(class);
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(anyhow!("server is shut down"));
            }
            if self.fits(&st, bucket) {
                st.open[bucket] += 1;
                return Ok(());
            }
            match policy {
                AdmissionPolicy::Reject => return Err(QueueFull(self.class_bound(bucket)).into()),
                AdmissionPolicy::Block => st = self.cv.wait(st).unwrap(),
            }
        }
    }

    pub(crate) fn release(&self, class: u8) {
        let bucket = self.bucket(class);
        let mut st = self.state.lock().unwrap();
        st.open[bucket] = st.open[bucket].saturating_sub(1);
        drop(st);
        if self.reserves.is_empty() {
            self.cv.notify_one();
        } else {
            // A freed slot may only be usable by one specific class's
            // waiters; notify_one could wake an ineligible producer
            // that re-parks and swallows the wakeup.
            self.cv.notify_all();
        }
    }

    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Open requests currently admitted and not yet retired, across all
    /// classes — the live load gauge the shard router's least-loaded
    /// fallback compares.
    pub(crate) fn in_flight(&self) -> usize {
        self.state.lock().unwrap().open.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unreserved_gate_is_a_plain_semaphore() {
        let g = Gate::new(2, Vec::new());
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
        g.admit(AdmissionPolicy::Reject, 5).unwrap();
        let err = g.admit(AdmissionPolicy::Reject, 0).unwrap_err();
        assert!(err.downcast_ref::<QueueFull>().is_some());
        assert_eq!(g.in_flight(), 2);
        g.release(5);
        assert_eq!(g.in_flight(), 1);
        g.admit(AdmissionPolicy::Reject, 1).unwrap();
    }

    #[test]
    fn reserved_slots_survive_a_bulk_class_flood() {
        // depth 4, class 0 reserves 2 → bulk class 1 can hold at most
        // the 2 shared slots; class 0 always finds its reserve.
        let g = Gate::new(4, vec![2, 0]);
        g.admit(AdmissionPolicy::Reject, 1).unwrap();
        g.admit(AdmissionPolicy::Reject, 1).unwrap();
        let err = g.admit(AdmissionPolicy::Reject, 1).unwrap_err();
        // The error reports the rejecting class's own bound (the shared
        // pool here — class 1 reserves nothing), not the total depth.
        assert_eq!(err.downcast_ref::<QueueFull>().map(|q| q.0), Some(2));
        // The latency class still admits — twice (its reserve).
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
        // Reserve full + shared full → now class 0 is bounded too.
        assert!(g.admit(AdmissionPolicy::Reject, 0).is_err());
        // Releasing a bulk slot reopens shared capacity for anyone.
        g.release(1);
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
    }

    #[test]
    fn out_of_range_classes_clamp_to_last_reserve() {
        let g = Gate::new(2, vec![0, 1]);
        // Class 7 clamps to bucket 1 (reserve 1): one reserved admit…
        g.admit(AdmissionPolicy::Reject, 7).unwrap();
        // …then the single shared slot (2 − 1)…
        g.admit(AdmissionPolicy::Reject, 7).unwrap();
        // …then full.
        assert!(g.admit(AdmissionPolicy::Reject, 7).is_err());
        assert!(g.admit(AdmissionPolicy::Reject, 0).is_err(), "shared consumed");
        g.release(7);
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
    }

    #[test]
    fn unbounded_depth_ignores_reserves() {
        let g = Gate::new(0, vec![1, 1]);
        for c in 0..16u8 {
            g.admit(AdmissionPolicy::Reject, c).unwrap();
        }
    }

    #[test]
    fn oversubscribed_reserves_bound_each_class_individually() {
        // Σ reserves (3) > depth (2): shared pool is empty, each class
        // is capped by its own reserve.
        let g = Gate::new(2, vec![2, 1]);
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
        g.admit(AdmissionPolicy::Reject, 0).unwrap();
        assert!(g.admit(AdmissionPolicy::Reject, 0).is_err());
        g.admit(AdmissionPolicy::Reject, 1).unwrap();
        let err = g.admit(AdmissionPolicy::Reject, 1).unwrap_err();
        // Empty shared pool: the reported bound is class 1's reserve.
        assert_eq!(err.downcast_ref::<QueueFull>().map(|q| q.0), Some(1));
    }

    #[test]
    fn closed_gate_rejects_and_wakes() {
        let g = Arc::new(Gate::new(1, vec![1]));
        g.admit(AdmissionPolicy::Block, 0).unwrap();
        let g2 = Arc::clone(&g);
        let waiter = std::thread::spawn(move || g2.admit(AdmissionPolicy::Block, 0));
        std::thread::sleep(std::time::Duration::from_millis(10));
        g.close();
        assert!(waiter.join().unwrap().is_err(), "blocked producer must wake on close");
        assert!(g.admit(AdmissionPolicy::Reject, 0).is_err());
    }
}
