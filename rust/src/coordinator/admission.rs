//! Streaming admission: the bounded open-request gate and the in-flight
//! admission record handed to the scheduler thread.
//!
//! Admission is governed by `ServeConfig::queue_depth` — the maximum
//! number of *open* requests (admitted but not yet retired; `0` =
//! unbounded) — and an [`AdmissionPolicy`](crate::config::schema::AdmissionPolicy):
//! `Block` parks the submitting thread until a slot frees, `Reject`
//! fails fast with [`QueueFull`] so the caller can shed load or retry.

use crate::config::schema::AdmissionPolicy;
use crate::coordinator::handle::Reply;
use crate::workloads::{MatMulRequest, Operands};
use anyhow::{anyhow, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Returned by a [`AdmissionPolicy::Reject`] submission when
/// `queue_depth` requests are already open. Recover it from the anyhow
/// chain with `err.downcast_ref::<QueueFull>()`.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("admission queue full ({0} open requests)")]
pub struct QueueFull(pub usize);

/// A request admitted by a client thread, in flight to the scheduler.
///
/// `ops`/`reply` are `Option`s taken out on the normal path; the `Drop`
/// impl is the safety net for every other path (scheduler draining, the
/// event channel torn down with admits still queued, send failure): it
/// frees the admission slot and delivers a shutdown error, so a
/// successful `submit` always resolves its handle/callback.
pub(crate) struct Admitted {
    pub(crate) req: MatMulRequest,
    pub(crate) ops: Option<Operands>,
    pub(crate) submitted: Instant,
    pub(crate) reply: Option<Reply>,
    /// Cancellation token minted at submission; [`RequestHandle::cancel`]
    /// (and handle drop) route back to the scheduler through it.
    ///
    /// [`RequestHandle::cancel`]: crate::coordinator::handle::RequestHandle::cancel
    pub(crate) token: u64,
    pub(crate) gate: Arc<Gate>,
}

impl Drop for Admitted {
    fn drop(&mut self) {
        if let Some(reply) = self.reply.take() {
            self.gate.release();
            reply.send(self.req, Err(anyhow!("server is shutting down")));
        }
    }
}

/// The admission gate: a counting semaphore over open requests with a
/// closed flag so blocked producers wake when the server goes away.
pub(crate) struct Gate {
    /// `0` = unbounded.
    depth: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    open: usize,
    closed: bool,
}

/// Closes the gate when dropped — even if the scheduler thread unwinds,
/// producers parked in [`Gate::admit`] wake up instead of hanging.
pub(crate) struct GateCloser(pub(crate) Arc<Gate>);

impl Drop for GateCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

impl Gate {
    pub(crate) fn new(depth: usize) -> Self {
        Gate {
            depth,
            state: Mutex::new(GateState { open: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn admit(&self, policy: AdmissionPolicy) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(anyhow!("server is shut down"));
            }
            if self.depth == 0 || st.open < self.depth {
                st.open += 1;
                return Ok(());
            }
            match policy {
                AdmissionPolicy::Reject => return Err(QueueFull(self.depth).into()),
                AdmissionPolicy::Block => st = self.cv.wait(st).unwrap(),
            }
        }
    }

    pub(crate) fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = st.open.saturating_sub(1);
        drop(st);
        self.cv.notify_one();
    }

    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}
