//! Host-side tiling: pad + slice row-major matrices into native-size
//! blocks and accumulate partial products — the PL-side dataflow the
//! paper assumes around the AIE array.

/// Tiles `M×K×N` problems into native `(nm, nk, nn)` blocks.
#[derive(Debug, Clone, Copy)]
pub struct Tiler {
    pub nm: usize,
    pub nk: usize,
    pub nn: usize,
}

// Block addressing is inherently 8-parameter (dst/src + matrix shape +
// block position + block shape); a params struct would obscure the call
// sites more than it helps.
#[allow(clippy::too_many_arguments)]
impl Tiler {
    pub fn new(native: (u64, u64, u64)) -> Self {
        Tiler {
            nm: native.0 as usize,
            nk: native.1 as usize,
            nn: native.2 as usize,
        }
    }

    /// Grid of block indices for a problem.
    pub fn grid(&self, m: usize, k: usize, n: usize) -> (usize, usize, usize) {
        (m.div_ceil(self.nm), k.div_ceil(self.nk), n.div_ceil(self.nn))
    }

    /// Extract the zero-padded `(bh × bw)` block at block position
    /// `(bi, bj)` from the row-major `rows × cols` matrix `src`.
    pub fn extract_block<T: Copy + Default>(
        src: &[T],
        rows: usize,
        cols: usize,
        bi: usize,
        bj: usize,
        bh: usize,
        bw: usize,
    ) -> Vec<T> {
        let mut out = vec![T::default(); bh * bw];
        Self::extract_block_into(&mut out, src, rows, cols, bi, bj, bh, bw);
        out
    }

    /// [`Tiler::extract_block`] into a caller-provided `bh × bw` buffer
    /// — the allocation-free form the arena packer
    /// ([`crate::coordinator::pool::TilePool::pack`]) slices into. Every
    /// element of `dst` is written (fringe positions get zeros), so
    /// stale contents are fine.
    pub fn extract_block_into<T: Copy + Default>(
        dst: &mut [T],
        src: &[T],
        rows: usize,
        cols: usize,
        bi: usize,
        bj: usize,
        bh: usize,
        bw: usize,
    ) {
        assert_eq!(src.len(), rows * cols, "matrix shape mismatch");
        assert_eq!(dst.len(), bh * bw, "block shape mismatch");
        let r0 = bi * bh;
        let c0 = bj * bw;
        let rmax = rows.saturating_sub(r0).min(bh);
        let cmax = cols.saturating_sub(c0).min(bw);
        for r in 0..rmax {
            let src_off = (r0 + r) * cols + c0;
            let drow = &mut dst[r * bw..(r + 1) * bw];
            drow[..cmax].copy_from_slice(&src[src_off..src_off + cmax]);
            drow[cmax..].fill(T::default());
        }
        dst[rmax * bw..].fill(T::default());
    }

    /// Accumulate a native-size result block into the `rows × cols` output
    /// at block position `(bi, bj)` (clipping the padded fringe).
    pub fn accumulate_block(
        dst: &mut [f32],
        rows: usize,
        cols: usize,
        bi: usize,
        bj: usize,
        bh: usize,
        bw: usize,
        block: &[f32],
    ) {
        assert_eq!(block.len(), bh * bw, "block shape mismatch");
        let r0 = bi * bh;
        let c0 = bj * bw;
        let rmax = rows.saturating_sub(r0).min(bh);
        let cmax = cols.saturating_sub(c0).min(bw);
        for r in 0..rmax {
            let dst_off = (r0 + r) * cols + c0;
            let src_off = r * bw;
            for c in 0..cmax {
                dst[dst_off + c] += block[src_off + c];
            }
        }
    }

    /// Write a finished native-size block into the `rows × cols` output at
    /// block position `(bi, bj)`, clipping the padded fringe. Unlike
    /// [`Tiler::accumulate_block`] this *overwrites*: the pipelined engine
    /// reduces all `ik` partials of an output block in a dense `bh × bw`
    /// accumulation buffer first, then writes the block back once —
    /// one strided pass over `dst` per block instead of one per tile.
    pub fn write_block<T: Copy>(
        dst: &mut [T],
        rows: usize,
        cols: usize,
        bi: usize,
        bj: usize,
        bh: usize,
        bw: usize,
        block: &[T],
    ) {
        assert_eq!(block.len(), bh * bw, "block shape mismatch");
        let r0 = bi * bh;
        let c0 = bj * bw;
        let rmax = rows.saturating_sub(r0).min(bh);
        let cmax = cols.saturating_sub(c0).min(bw);
        for r in 0..rmax {
            let dst_off = (r0 + r) * cols + c0;
            let src_off = r * bw;
            dst[dst_off..dst_off + cmax].copy_from_slice(&block[src_off..src_off + cmax]);
        }
    }

    // Tile-major packing lives in the memory plane since PR 4: see
    // [`crate::coordinator::pool::TilePool::pack`] / `unpack` — one
    // contiguous arena per matrix instead of the former
    // `pack_tile_major`'s Vec-per-tile.

    /// Accumulate for i32 outputs (int8 designs accumulate int32).
    pub fn accumulate_block_i32(
        dst: &mut [i32],
        rows: usize,
        cols: usize,
        bi: usize,
        bj: usize,
        bh: usize,
        bw: usize,
        block: &[i32],
    ) {
        assert_eq!(block.len(), bh * bw, "block shape mismatch");
        let r0 = bi * bh;
        let c0 = bj * bw;
        let rmax = rows.saturating_sub(r0).min(bh);
        let cmax = cols.saturating_sub(c0).min(bw);
        for r in 0..rmax {
            let dst_off = (r0 + r) * cols + c0;
            let src_off = r * bw;
            for c in 0..cmax {
                dst[dst_off + c] = dst[dst_off + c].wrapping_add(block[src_off + c]);
            }
        }
    }
}

/// Reference row-major matmul used by tests and the verification path.
pub fn matmul_ref_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_ref_f32_into(&mut c, a, b, m, k, n);
    c
}

/// [`matmul_ref_f32`] into a caller-provided `m × n` output slice — the
/// allocation-free form the recycling device backend uses (the buffer
/// comes from a [`crate::coordinator::pool::FreeList`]). `c` is fully
/// overwritten; stale contents are fine.
///
/// Since PR 5 this executes the register-tiled compute plane
/// ([`crate::coordinator::microkernel::matmul_f32`]), which is
/// **bit-identical** to the historical scalar loop (kept as
/// [`crate::coordinator::microkernel::matmul_naive_f32_into`], the
/// oracle of `tests/compute_plane.rs`): same per-element ascending-k
/// summation order, same zero-skip predicate, same mul-then-add ops.
pub fn matmul_ref_f32_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    crate::coordinator::microkernel::matmul_f32(c, a, b, m, k, n);
}

/// Reference row-major matmul for the int8 path: int8-range operands
/// carried as `i32`, i32 accumulation with wrapping adds (bit-exact
/// regardless of tile/reduction order — integer addition is
/// associative, so the pipelined engine's outputs match this reference
/// exactly, not just within a tolerance).
pub fn matmul_ref_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    matmul_ref_i32_into(&mut c, a, b, m, k, n);
    c
}

/// [`matmul_ref_i32`] into a caller-provided `m × n` output slice (see
/// [`matmul_ref_f32_into`]). `c` is fully overwritten. Executes the
/// register-tiled compute plane; exact regardless of blocking because
/// wrapping integer accumulation is order-independent.
pub fn matmul_ref_i32_into(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
    crate::coordinator::microkernel::matmul_i32(c, a, b, m, k, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    #[test]
    fn extract_interior_block() {
        // 4×4 matrix, 2×2 blocks.
        let src: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let b = Tiler::extract_block(&src, 4, 4, 1, 0, 2, 2);
        assert_eq!(b, vec![8.0, 9.0, 12.0, 13.0]);
    }

    #[test]
    fn extract_padded_fringe() {
        // 3×3 matrix, 2×2 blocks: block (1,1) holds one element + zeros.
        let src: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let b = Tiler::extract_block(&src, 3, 3, 1, 1, 2, 2);
        assert_eq!(b, vec![9.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulate_clips_fringe() {
        let mut dst = vec![0.0f32; 9];
        let block = vec![1.0f32; 4];
        Tiler::accumulate_block(&mut dst, 3, 3, 1, 1, 2, 2, &block);
        assert_eq!(dst, vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn tiled_matmul_equals_reference() {
        // Property: for random sizes, tiling through extract/accumulate
        // with a reference per-block matmul equals the direct reference.
        let mut rng = XorShift64::new(42);
        let t = Tiler { nm: 8, nk: 4, nn: 8 };
        for _ in 0..10 {
            let m = rng.gen_range(1, 20) as usize;
            let k = rng.gen_range(1, 12) as usize;
            let n = rng.gen_range(1, 20) as usize;
            let a: Vec<f32> = (0..m * k).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
            let want = matmul_ref_f32(&a, &b, m, k, n);
            let (gm, gk, gn) = t.grid(m, k, n);
            let mut c = vec![0.0f32; m * n];
            for im in 0..gm {
                for ik in 0..gk {
                    let ab = Tiler::extract_block(&a, m, k, im, ik, t.nm, t.nk);
                    for inn in 0..gn {
                        let bb = Tiler::extract_block(&b, k, n, ik, inn, t.nk, t.nn);
                        let cb = matmul_ref_f32(&ab, &bb, t.nm, t.nk, t.nn);
                        Tiler::accumulate_block(&mut c, m, n, im, inn, t.nm, t.nn, &cb);
                    }
                }
            }
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "mismatch {x} vs {y}");
            }
        }
    }

    #[test]
    fn grid_counts() {
        let t = Tiler { nm: 416, nk: 128, nn: 192 };
        assert_eq!(t.grid(416, 128, 192), (1, 1, 1));
        assert_eq!(t.grid(417, 128, 192), (2, 1, 1));
        assert_eq!(t.grid(2048, 2048, 2048), (5, 16, 11));
    }

    // Tile-major pack/unpack round-trip tests moved with the packing
    // code to `coordinator::pool` (TilePool).

    #[test]
    fn extract_block_into_overwrites_stale_contents() {
        // The recycling path hands extract_block_into buffers with
        // stale data; every element — fringe padding included — must
        // be written.
        let src: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let mut dst = vec![f32::NAN; 4];
        Tiler::extract_block_into(&mut dst, &src, 3, 3, 1, 1, 2, 2);
        assert_eq!(dst, vec![9.0, 0.0, 0.0, 0.0]);
        // Fully out-of-range block: all zeros, no stale NaNs.
        let mut dst = vec![f32::NAN; 4];
        Tiler::extract_block_into(&mut dst, &src, 3, 3, 5, 5, 2, 2);
        assert_eq!(dst, vec![0.0; 4]);
    }

    #[test]
    fn matmul_ref_into_matches_wrapper_over_stale_buffers() {
        let mut rng = XorShift64::new(21);
        let (m, k, n) = (7usize, 9usize, 5usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
        let mut c = vec![f32::NAN; m * n];
        matmul_ref_f32_into(&mut c, &a, &b, m, k, n);
        assert_eq!(c, matmul_ref_f32(&a, &b, m, k, n), "stale contents must not leak");

        let ai: Vec<i32> = (0..m * k).map(|_| rng.gen_range(0, 256) as i32 - 128).collect();
        let bi: Vec<i32> = (0..k * n).map(|_| rng.gen_range(0, 256) as i32 - 128).collect();
        let mut ci = vec![i32::MIN; m * n];
        matmul_ref_i32_into(&mut ci, &ai, &bi, m, k, n);
        assert_eq!(ci, matmul_ref_i32(&ai, &bi, m, k, n));
    }

    #[test]
    fn write_block_overwrites_and_clips() {
        let mut dst = vec![7.0f32; 9];
        let block = vec![1.0f32, 2.0, 3.0, 4.0];
        Tiler::write_block(&mut dst, 3, 3, 1, 1, 2, 2, &block);
        // Only the single in-bounds element of block (1,1) lands.
        assert_eq!(dst, vec![7.0, 7.0, 7.0, 7.0, 7.0, 7.0, 7.0, 7.0, 1.0]);
    }

    #[test]
    fn write_block_equals_accumulate_into_zero() {
        // For a zeroed destination, write_block and accumulate_block agree
        // bit-for-bit — the pipelined engine's write-back is a pure
        // strength reduction, not a numerics change.
        let mut rng = XorShift64::new(13);
        let (rows, cols, bh, bw) = (7usize, 11usize, 4usize, 4usize);
        let block: Vec<f32> = (0..bh * bw)
            .map(|_| rng.gen_range_f64(-1.0, 1.0) as f32)
            .collect();
        let mut via_write = vec![0.0f32; rows * cols];
        let mut via_acc = vec![0.0f32; rows * cols];
        Tiler::write_block(&mut via_write, rows, cols, 1, 2, bh, bw, &block);
        Tiler::accumulate_block(&mut via_acc, rows, cols, 1, 2, bh, bw, &block);
        assert_eq!(via_write, via_acc);
    }

    #[test]
    fn i32_accumulate_wraps() {
        let mut dst = vec![i32::MAX; 1];
        Tiler::accumulate_block_i32(&mut dst, 1, 1, 0, 0, 1, 1, &[1]);
        assert_eq!(dst[0], i32::MIN);
    }

    #[test]
    fn i32_tiled_matmul_is_bit_exact() {
        // Integer tiling is exact: extract/accumulate through any block
        // decomposition reproduces the direct reference bit-for-bit.
        let mut rng = XorShift64::new(99);
        let t = Tiler { nm: 4, nk: 8, nn: 4 };
        for _ in 0..8 {
            let m = rng.gen_range(1, 20) as usize;
            let k = rng.gen_range(1, 20) as usize;
            let n = rng.gen_range(1, 20) as usize;
            let a: Vec<i32> = (0..m * k).map(|_| rng.gen_range(0, 256) as i32 - 128).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.gen_range(0, 256) as i32 - 128).collect();
            let want = matmul_ref_i32(&a, &b, m, k, n);
            let (gm, gk, gn) = t.grid(m, k, n);
            let mut c = vec![0i32; m * n];
            for im in 0..gm {
                for ik in 0..gk {
                    let ab = Tiler::extract_block(&a, m, k, im, ik, t.nm, t.nk);
                    for inn in 0..gn {
                        let bb = Tiler::extract_block(&b, k, n, ik, inn, t.nk, t.nn);
                        let cb = matmul_ref_i32(&ab, &bb, t.nm, t.nk, t.nn);
                        Tiler::accumulate_block_i32(&mut c, m, n, im, inn, t.nm, t.nn, &cb);
                    }
                }
            }
            assert_eq!(c, want, "{m}x{k}x{n}");
        }
    }
}
