//! Trace-driven, open-loop serving analysis in *device time*.
//!
//! The live coordinator ([`crate::coordinator::server`]) executes real
//! numerics through PJRT; this module answers the capacity-planning
//! question instead: given the calibrated device model, how does the
//! VCK190 behave under a request *arrival process* — queueing delay,
//! latency percentiles, utilization — without paying CPU emulation cost.
//! (An M/D/1-style simulation: deterministic per-request service derived
//! from the tiling model, stochastic arrivals.)

use crate::kernels::matmul::MatMulKernel;
use crate::optimizer::array::ArrayCandidate;
use crate::tiling::padding::TiledWorkload;
use crate::util::prng::XorShift64;
use crate::util::stats::{mean, percentile};
use crate::workloads::MatMulRequest;

/// One simulated completion.
#[derive(Debug, Clone, Copy)]
pub struct TraceCompletion {
    pub id: u64,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
}

impl TraceCompletion {
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
    pub fn queueing_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Result of a trace replay.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub completions: Vec<TraceCompletion>,
    /// Device busy fraction over the makespan.
    pub utilization: f64,
    /// Offered load: mean arrival work rate / device service rate.
    pub offered_load: f64,
}

impl TraceReport {
    pub fn mean_latency_ms(&self) -> f64 {
        mean(&self.completions.iter().map(|c| c.latency_s() * 1e3).collect::<Vec<_>>())
    }
    pub fn p99_latency_ms(&self) -> f64 {
        percentile(
            &self.completions.iter().map(|c| c.latency_s() * 1e3).collect::<Vec<_>>(),
            99.0,
        )
    }
    pub fn mean_queueing_ms(&self) -> f64 {
        mean(&self.completions.iter().map(|c| c.queueing_s() * 1e3).collect::<Vec<_>>())
    }
}

/// Replay `requests` with Poisson arrivals at `rate_hz` through a device
/// whose iteration period is `period_cycles` at `freq_hz`, FIFO service.
pub fn replay_trace(
    requests: &[MatMulRequest],
    cand: &ArrayCandidate,
    kernel: &MatMulKernel,
    period_cycles: f64,
    freq_hz: f64,
    rate_hz: f64,
    seed: u64,
) -> TraceReport {
    let mut rng = XorShift64::new(seed);
    // Exponential inter-arrivals.
    let mut t = 0.0;
    let arrivals: Vec<f64> = requests
        .iter()
        .map(|_| {
            let u: f64 = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_hz;
            t
        })
        .collect();

    let mut device_free = 0.0f64;
    let mut busy = 0.0f64;
    let mut completions = Vec::with_capacity(requests.len());
    for (req, &arr) in requests.iter().zip(&arrivals) {
        let w = TiledWorkload::new(req.m, req.k, req.n, cand, kernel);
        let service = w.device_time_s(period_cycles, freq_hz);
        let start = device_free.max(arr);
        let finish = start + service;
        device_free = finish;
        busy += service;
        completions.push(TraceCompletion {
            id: req.id,
            arrival_s: arr,
            start_s: start,
            finish_s: finish,
        });
    }
    let makespan = completions.last().map(|c| c.finish_s).unwrap_or(0.0);
    let total_arrival_span = arrivals.last().copied().unwrap_or(0.0).max(1e-12);
    TraceReport {
        utilization: if makespan > 0.0 { busy / makespan } else { 0.0 },
        offered_load: busy / total_arrival_span,
        completions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;
    use crate::workloads::random_trace;

    fn setup() -> (ArrayCandidate, MatMulKernel) {
        (
            ArrayCandidate::new(13, 4, 6),
            MatMulKernel::paper_kernel(Precision::Fp32),
        )
    }

    #[test]
    fn low_load_has_no_queueing() {
        let (cand, kernel) = setup();
        let reqs = random_trace(50, 3);
        // 1 request/s: service times are µs-scale → zero queueing.
        let r = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, 1.0, 9);
        assert!(r.mean_queueing_ms() < 1e-3, "{}", r.mean_queueing_ms());
        assert!(r.utilization < 0.01);
    }

    #[test]
    fn overload_queues_grow() {
        let (cand, kernel) = setup();
        let reqs = random_trace(200, 3);
        // Find a rate far above capacity: mean service of the trace.
        let mean_service: f64 = reqs
            .iter()
            .map(|q| {
                TiledWorkload::new(q.m, q.k, q.n, &cand, &kernel).device_time_s(4700.0, 1.25e9)
            })
            .sum::<f64>()
            / reqs.len() as f64;
        let rate = 3.0 / mean_service; // 3× overload
        let r = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, rate, 9);
        assert!(r.offered_load > 1.5, "{}", r.offered_load);
        assert!(r.utilization > 0.9);
        // Latency dominated by queueing, and p99 >> mean.
        assert!(r.mean_queueing_ms() > 0.5 * r.mean_latency_ms());
        assert!(r.p99_latency_ms() > r.mean_latency_ms());
    }

    #[test]
    fn latency_monotone_in_load() {
        let (cand, kernel) = setup();
        let reqs = random_trace(100, 5);
        let mean_service: f64 = reqs
            .iter()
            .map(|q| {
                TiledWorkload::new(q.m, q.k, q.n, &cand, &kernel).device_time_s(4700.0, 1.25e9)
            })
            .sum::<f64>()
            / reqs.len() as f64;
        let mut last = 0.0;
        for load in [0.3, 0.7, 0.95] {
            let r = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, load / mean_service, 9);
            assert!(
                r.mean_latency_ms() >= last,
                "latency must grow with load ({load})"
            );
            last = r.mean_latency_ms();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cand, kernel) = setup();
        let reqs = random_trace(20, 1);
        let a = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, 1000.0, 4);
        let b = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, 1000.0, 4);
        assert_eq!(a.mean_latency_ms(), b.mean_latency_ms());
    }

    #[test]
    fn fifo_order_preserved() {
        let (cand, kernel) = setup();
        let reqs = random_trace(30, 2);
        let r = replay_trace(&reqs, &cand, &kernel, 4700.0, 1.25e9, 1e6, 4);
        for w in r.completions.windows(2) {
            assert!(w[1].start_s >= w[0].finish_s - 1e-12);
        }
    }
}
