//! Deterministic fault injection for the device plane.
//!
//! The paper's throughput model assumes 400 AIE cores that never
//! misbehave; a serving stack cannot. This module is the chaos half of
//! the fault-tolerant device plane: a seeded [`FaultPlan`] (configured
//! through `ServeConfig::fault_plan`, JSON round-tripped) wraps the
//! reference backend and makes chosen workers error tiles, panic,
//! delay, hang (swallow the completion), or corrupt an output — all
//! **deterministically** per job tag, so a chaos run is exactly
//! reproducible from its seed. The recovery half (deadlines, bounded
//! retry/redispatch, quarantine, respawn) lives in
//! [`crate::coordinator::scheduler`] and [`crate::coordinator::device`];
//! see the "Failure model" section of [`crate::coordinator`] for the
//! end-to-end story.
//!
//! With no plan configured (the default) none of this is on the hot
//! path: workers skip checksumming, the scheduler arms no deadlines,
//! and the steady state allocates and computes exactly what it did
//! before the fault plane existed.

use crate::config::json::Json;
use crate::config::schema::ConfigError;
use crate::util::prng::XorShift64;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// One way a device worker can misbehave.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Complete the tile with an error instead of executing it.
    Error,
    /// Kill the worker thread without sending a completion (a crash:
    /// detected by supervision, the worker is respawned).
    Panic,
    /// Execute normally, `delay_ms` late (a straggler: trips the tile
    /// deadline when one is armed, then the original result arrives
    /// stale and is discarded).
    Delay,
    /// Swallow the job — never send its `TileDone` (a lost completion:
    /// only a tile deadline can recover it).
    Hang,
    /// Execute normally but flip one output element after checksumming,
    /// so the scheduler's verify pass rejects the tile (a transport
    /// fault).
    Corrupt,
    /// Flip one word of a cached packed-weight pool (the memory plane,
    /// not a device worker): a silent-corruption fault the sampled
    /// verify-on-hit path (`ServeConfig::cache_verify_interval`) must
    /// detect, quarantine and transparently re-pack around. Driven at
    /// the scheduler layer, never drawn by the device injector.
    CacheCorrupt,
    /// Kill a whole shard's scheduler thread (the recovery plane's
    /// trigger): the breaker trips, failover re-dispatches open
    /// flights, and — with `ServeConfig::shard_respawn` — the
    /// supervisor rebuilds the shard. Driven at the facade layer,
    /// never drawn by the device injector.
    ShardCrash,
}

impl FaultKind {
    /// Every *device-injectable* kind, in the order the seeded sweep
    /// walks them. The scheduler/facade-plane kinds
    /// ([`FaultKind::CacheCorrupt`], [`FaultKind::ShardCrash`]) are
    /// deliberately excluded so an empty-`kinds` plan keeps drawing the
    /// exact per-tag sequence it drew before they existed.
    pub fn all() -> [FaultKind; 5] {
        [
            FaultKind::Error,
            FaultKind::Panic,
            FaultKind::Delay,
            FaultKind::Hang,
            FaultKind::Corrupt,
        ]
    }

    /// Every kind, including the non-device (memory/recovery plane)
    /// ones — the parse/Display/JSON vocabulary.
    pub fn every() -> [FaultKind; 7] {
        [
            FaultKind::Error,
            FaultKind::Panic,
            FaultKind::Delay,
            FaultKind::Hang,
            FaultKind::Corrupt,
            FaultKind::CacheCorrupt,
            FaultKind::ShardCrash,
        ]
    }

    /// Whether a device worker can inject this kind on a tile job.
    /// `CacheCorrupt` targets the packed-weight cache and `ShardCrash`
    /// a scheduler thread; both are driven above the device plane.
    pub fn device_injectable(self) -> bool {
        !matches!(self, FaultKind::CacheCorrupt | FaultKind::ShardCrash)
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "error" => Some(FaultKind::Error),
            "panic" => Some(FaultKind::Panic),
            "delay" => Some(FaultKind::Delay),
            "hang" => Some(FaultKind::Hang),
            "corrupt" => Some(FaultKind::Corrupt),
            "cache_corrupt" => Some(FaultKind::CacheCorrupt),
            "shard_crash" => Some(FaultKind::ShardCrash),
            _ => None,
        }
    }
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::Delay => "delay",
            FaultKind::Hang => "hang",
            FaultKind::Corrupt => "corrupt",
            FaultKind::CacheCorrupt => "cache_corrupt",
            FaultKind::ShardCrash => "shard_crash",
        })
    }
}

/// A deterministic chaos schedule for the device pool.
///
/// Whether a given job faults — and how — is a pure function of
/// `(plan.seed, job.tag)`: each decision seeds a fresh
/// [`XorShift64`] from the two, so runs are reproducible regardless of
/// worker count, interleaving, or retries (a retried tile carries a new
/// tag and therefore re-rolls — a tile is not doomed to refault
/// forever, which is what makes bounded retry converge).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Chaos seed; every per-tile decision derives from it.
    pub seed: u64,
    /// Probability in `[0, 1]` that an eligible job faults.
    pub rate: f64,
    /// Restrict injection to one worker index (`None` = any worker).
    pub worker: Option<usize>,
    /// Kinds to draw from (uniformly, seeded). Empty = all kinds.
    pub kinds: Vec<FaultKind>,
    /// Added latency for [`FaultKind::Delay`] faults, milliseconds.
    pub delay_ms: u64,
    /// Stop injecting after this many faults (`0` = unlimited) — lets a
    /// chaos run converge to a healthy tail. The budget is claimed
    /// across workers, so *which* tags win it depends on execution
    /// order; per-tag determinism holds only for the unlimited plan.
    pub max_faults: u64,
}

impl FaultPlan {
    /// A plan injecting `kinds` at `rate` from `seed`, on any worker,
    /// with a 20 ms delay and no fault budget.
    pub fn new(seed: u64, rate: f64, kinds: Vec<FaultKind>) -> Self {
        FaultPlan { seed, rate, worker: None, kinds, delay_ms: 20, max_faults: 0 }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("rate".into(), Json::Num(self.rate));
        if let Some(w) = self.worker {
            o.insert("worker".into(), Json::Num(w as f64));
        }
        o.insert(
            "kinds".into(),
            Json::Arr(self.kinds.iter().map(|k| Json::Str(k.to_string())).collect()),
        );
        o.insert("delay_ms".into(), Json::Num(self.delay_ms as f64));
        o.insert("max_faults".into(), Json::Num(self.max_faults as f64));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let rate = v.get("rate").and_then(Json::as_f64).unwrap_or(0.0);
        if !(0.0..=1.0).contains(&rate) {
            return Err(ConfigError::Invalid("fault_plan.rate", rate.to_string()));
        }
        let kinds = match v.get("kinds") {
            None => Vec::new(),
            Some(Json::Arr(a)) => a
                .iter()
                .map(|k| {
                    k.as_str()
                        .and_then(FaultKind::parse)
                        .ok_or_else(|| ConfigError::Invalid("fault_plan.kinds", k.to_string()))
                })
                .collect::<Result<Vec<_>, _>>()?,
            Some(other) => {
                return Err(ConfigError::Invalid("fault_plan.kinds", other.to_string()))
            }
        };
        Ok(FaultPlan {
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            rate,
            worker: v.get("worker").and_then(Json::as_u64).map(|w| w as usize),
            kinds,
            delay_ms: v.get("delay_ms").and_then(Json::as_u64).unwrap_or(20),
            max_faults: v.get("max_faults").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// Live fault-plane counters, shared between the device pool, the
/// scheduler and stats snapshots ([`crate::coordinator::stats::FaultStats`]).
/// The `injected_*` counters are bumped by workers at the moment of
/// injection; the recovery counters (`timeouts`, `retries`, …) by the
/// scheduler.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub injected_errors: AtomicU64,
    pub injected_panics: AtomicU64,
    pub injected_delays: AtomicU64,
    pub injected_hangs: AtomicU64,
    pub injected_corruptions: AtomicU64,
    /// Cached packed-weight pools corrupted by the chaos layer
    /// ([`FaultKind::CacheCorrupt`], injected at the scheduler).
    pub injected_cache_corruptions: AtomicU64,
    /// Scheduler threads killed by the chaos layer
    /// ([`FaultKind::ShardCrash`], injected at the facade).
    pub injected_shard_crashes: AtomicU64,
    /// Tiles whose deadline expired before their completion arrived.
    pub timeouts: AtomicU64,
    /// Tiles re-dispatched after a fault or timeout.
    pub retries: AtomicU64,
    /// Flights failed because a tile exhausted `max_tile_retries`.
    pub retries_exhausted: AtomicU64,
    /// Completions rejected by the output checksum verify pass.
    pub checksum_failures: AtomicU64,
    /// Dead workers detected by supervision.
    pub worker_deaths: AtomicU64,
    /// Dead workers successfully respawned.
    pub respawns: AtomicU64,
    /// Workers quarantined after repeated consecutive faults.
    pub quarantined: AtomicU64,
}

impl FaultCounters {
    /// Total faults injected so far, across kinds.
    pub fn injected(&self) -> u64 {
        self.injected_errors.load(Ordering::Relaxed)
            + self.injected_panics.load(Ordering::Relaxed)
            + self.injected_delays.load(Ordering::Relaxed)
            + self.injected_hangs.load(Ordering::Relaxed)
            + self.injected_corruptions.load(Ordering::Relaxed)
            + self.injected_cache_corruptions.load(Ordering::Relaxed)
            + self.injected_shard_crashes.load(Ordering::Relaxed)
    }

    pub(crate) fn count_injected(&self, kind: FaultKind) {
        let c = match kind {
            FaultKind::Error => &self.injected_errors,
            FaultKind::Panic => &self.injected_panics,
            FaultKind::Delay => &self.injected_delays,
            FaultKind::Hang => &self.injected_hangs,
            FaultKind::Corrupt => &self.injected_corruptions,
            FaultKind::CacheCorrupt => &self.injected_cache_corruptions,
            FaultKind::ShardCrash => &self.injected_shard_crashes,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// The active injector: a [`FaultPlan`] plus its shared budget. Cloned
/// into every device worker (cheap: the budget is an `Arc`'d atomic on
/// the pool's counters).
#[derive(Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Faults granted so far, against `plan.max_faults`.
    granted: std::sync::Arc<AtomicU64>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector { plan, granted: std::sync::Arc::new(AtomicU64::new(0)) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Per-tile decision: does job `tag` on `worker` fault, and how?
    /// Deterministic in `(seed, tag)` — see [`FaultPlan`]. Respects the
    /// worker restriction and the shared `max_faults` budget.
    pub fn decide(&self, tag: u64, worker: usize) -> Option<FaultKind> {
        if self.plan.rate <= 0.0 {
            return None;
        }
        if self.plan.worker.is_some_and(|w| w != worker) {
            return None;
        }
        // Fresh generator per decision: mix the tag into the seed with
        // a golden-ratio stride so consecutive tags decorrelate.
        let mut rng = XorShift64::new(self.plan.seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if rng.next_f64() >= self.plan.rate {
            return None;
        }
        // Non-device kinds (CacheCorrupt, ShardCrash) are driven at the
        // scheduler/facade layers; a worker never draws them. A plan
        // listing only those kinds injects nothing here.
        let all = FaultKind::all();
        let kinds: Vec<FaultKind> = if self.plan.kinds.is_empty() {
            all.to_vec()
        } else {
            self.plan.kinds.iter().copied().filter(|k| k.device_injectable()).collect()
        };
        if kinds.is_empty() {
            return None;
        }
        let kind = *rng.choose(&kinds);
        if self.plan.max_faults > 0 {
            // Claim one unit of budget; back off once it is spent.
            let prev = self.granted.fetch_add(1, Ordering::Relaxed);
            if prev >= self.plan.max_faults {
                return None;
            }
        } else {
            self.granted.fetch_add(1, Ordering::Relaxed);
        }
        Some(kind)
    }

    /// Injection latency for [`FaultKind::Delay`] faults.
    pub fn delay(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.plan.delay_ms)
    }

    /// Deterministically pick the element to flip in a corrupted output
    /// of `len` elements.
    pub fn corrupt_index(&self, tag: u64, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let mut rng = XorShift64::new(self.plan.seed.rotate_left(17) ^ tag.wrapping_add(1));
        (rng.next_u64() % len as u64) as usize
    }
}

/// FNV-1a over a stream of 32-bit words — the output checksum the
/// workers attach to completions in chaos mode and the scheduler
/// re-derives on receipt ([`FaultKind::Corrupt`] detection).
pub fn fnv1a_words(words: impl Iterator<Item = u32>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// A tile failed all of its `1 + max_tile_retries` execution attempts;
/// the flight is failed with this typed error wrapping the last cause.
#[derive(Debug, Clone, thiserror::Error)]
#[error("request {id}: tile failed all {attempts} attempts on shard {shard}; last error: {last}")]
pub struct TileRetriesExhausted {
    /// Failing request's id.
    pub id: u64,
    /// Execution attempts made (initial dispatch + retries).
    pub attempts: u32,
    /// Display of the last attempt's error.
    pub last: String,
    /// Shard whose scheduler gave up on the tile.
    pub shard: usize,
}

/// A tile's completion did not arrive within its deadline (lost,
/// hung, or severely delayed worker).
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("tile deadline expired after {waited_ms} ms (worker {worker}, shard {shard})")]
pub struct TileTimedOut {
    pub worker: usize,
    pub waited_ms: u64,
    /// Shard the worker belongs to (worker indices are shard-local).
    pub shard: usize,
}

/// A completion's payload did not match the checksum computed by the
/// worker (corruption between execution and reduction).
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("tile output failed checksum verification (worker {worker}, shard {shard})")]
pub struct TileCorrupted {
    pub worker: usize,
    /// Shard the worker belongs to.
    pub shard: usize,
}

/// The scheduler thread panicked; every open flight is failed fast
/// with this error so no client blocks on a dead server. With router
/// failover enabled (`ServeConfig::shard_failover`) the facade
/// intercepts this error, records it against shard `shard`'s circuit
/// breaker and re-dispatches the request to a healthy shard — clients
/// only ever observe it once every shard is down (or failover is off).
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("scheduler thread on shard {shard} panicked; request failed fast")]
pub struct SchedulerPanicked {
    /// Shard whose scheduler died.
    pub shard: usize,
}

/// Shutdown's drain deadline expired with this request still open.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("request {id} still in flight on shard {shard} when the shutdown drain deadline expired")]
pub struct DrainDeadlineExpired {
    /// Request still open at expiry.
    pub id: u64,
    /// Shard that was still draining it.
    pub shard: usize,
}

/// The request's own deadline (`MatMulRequest::with_deadline`) expired
/// before it completed. The flight is evicted through the cancellation
/// path: tiles not yet dispatched are never issued, queue and window
/// slots are reclaimed, and no partial output is ever delivered.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("request {id} exceeded its {budget_ms} ms deadline (shard {shard})")]
pub struct DeadlineExceeded {
    pub id: u64,
    /// Shard that expired the request (the admitting shard; for an
    /// M-split request, the shard owning the first band to expire).
    pub shard: usize,
    /// The request's configured deadline budget, milliseconds.
    pub budget_ms: u64,
}

/// The brownout shedder rejected this request at admission: queue
/// occupancy crossed `ServeConfig::shed_watermark` and the request's
/// priority class fell below the current shed floor. Sheds are
/// immediate (no queueing) so callers can retry elsewhere or back off.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error(
    "request {id} (class {class}) shed by brownout on shard {shard}: \
     {open} open requests over watermark"
)]
pub struct RequestShed {
    pub id: u64,
    /// Shard that shed the request.
    pub shard: usize,
    /// The request's priority class (higher = first to shed).
    pub class: u8,
    /// Open requests on the shard at the moment of the shed.
    pub open: usize,
}

/// SLO-aware admission (`ServeConfig::slo_admission`) judged the
/// request's deadline unattainable under current load and rejected it
/// immediately instead of letting it queue and expire.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error(
    "request {id} (class {class}) rejected at admission on shard {shard}: \
     estimated completion {estimated_ms} ms exceeds the {deadline_ms} ms deadline"
)]
pub struct SloUnattainable {
    pub id: u64,
    /// Shard that rejected the request.
    pub shard: usize,
    /// The request's priority class.
    pub class: u8,
    /// Estimated attainable completion under current load, ms.
    pub estimated_ms: u64,
    /// The request's deadline budget, ms.
    pub deadline_ms: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_display_roundtrip() {
        for k in FaultKind::every() {
            assert_eq!(FaultKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(FaultKind::parse("meltdown"), None);
        // The device sweep stays the historical five: adding the
        // memory/recovery-plane kinds to `all()` would shift every
        // seeded draw of an empty-`kinds` plan.
        assert_eq!(FaultKind::all().len(), 5);
        assert!(FaultKind::all().iter().all(|k| k.device_injectable()));
        assert!(!FaultKind::CacheCorrupt.device_injectable());
        assert!(!FaultKind::ShardCrash.device_injectable());
    }

    #[test]
    fn non_device_kinds_roundtrip_through_plan_json() {
        let p = FaultPlan::new(5, 1.0, vec![FaultKind::CacheCorrupt, FaultKind::ShardCrash]);
        let back = FaultPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn device_injector_never_draws_non_device_kinds() {
        // A mixed plan only ever injects its device-injectable subset…
        let mixed = FaultInjector::new(FaultPlan::new(
            13,
            1.0,
            vec![FaultKind::CacheCorrupt, FaultKind::Error, FaultKind::ShardCrash],
        ));
        for tag in 0..128 {
            assert_eq!(mixed.decide(tag, 0), Some(FaultKind::Error));
        }
        // …and a plan of only scheduler/facade kinds injects nothing.
        let none = FaultInjector::new(FaultPlan::new(
            13,
            1.0,
            vec![FaultKind::CacheCorrupt, FaultKind::ShardCrash],
        ));
        for tag in 0..128 {
            assert_eq!(none.decide(tag, 0), None);
        }
    }

    #[test]
    fn plan_json_roundtrip() {
        let mut p = FaultPlan::new(42, 0.25, vec![FaultKind::Error, FaultKind::Hang]);
        p.worker = Some(1);
        p.delay_ms = 7;
        p.max_faults = 3;
        let back = FaultPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // Worker restriction is optional in both directions.
        p.worker = None;
        assert_eq!(FaultPlan::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn plan_json_rejects_bad_values() {
        let v = Json::parse(r#"{"rate": 1.5}"#).unwrap();
        assert!(matches!(
            FaultPlan::from_json(&v),
            Err(ConfigError::Invalid("fault_plan.rate", _))
        ));
        let v = Json::parse(r#"{"rate": 0.1, "kinds": ["error", "meltdown"]}"#).unwrap();
        assert!(matches!(
            FaultPlan::from_json(&v),
            Err(ConfigError::Invalid("fault_plan.kinds", _))
        ));
    }

    #[test]
    fn decisions_are_deterministic_per_tag() {
        let inj_a = FaultInjector::new(FaultPlan::new(7, 0.5, vec![]));
        let inj_b = FaultInjector::new(FaultPlan::new(7, 0.5, vec![]));
        for tag in 0..256 {
            assert_eq!(inj_a.decide(tag, 0), inj_b.decide(tag, 0));
        }
    }

    #[test]
    fn rate_zero_never_faults_rate_one_always_faults() {
        let never = FaultInjector::new(FaultPlan::new(1, 0.0, vec![]));
        let always = FaultInjector::new(FaultPlan::new(1, 1.0, vec![FaultKind::Error]));
        for tag in 0..128 {
            assert_eq!(never.decide(tag, 0), None);
            assert_eq!(always.decide(tag, 0), Some(FaultKind::Error));
        }
    }

    #[test]
    fn worker_restriction_is_respected() {
        let mut plan = FaultPlan::new(3, 1.0, vec![FaultKind::Delay]);
        plan.worker = Some(2);
        let inj = FaultInjector::new(plan);
        for tag in 0..64 {
            assert_eq!(inj.decide(tag, 0), None);
            assert_eq!(inj.decide(tag, 2), Some(FaultKind::Delay));
        }
    }

    #[test]
    fn budget_caps_total_faults() {
        let mut plan = FaultPlan::new(9, 1.0, vec![FaultKind::Error]);
        plan.max_faults = 5;
        let inj = FaultInjector::new(plan);
        let granted = (0..100).filter(|&t| inj.decide(t, 0).is_some()).count();
        assert_eq!(granted, 5);
    }

    #[test]
    fn retagged_retries_reroll() {
        // At rate 0.5 some tag must fault and some other tag must not —
        // i.e. a retry under a fresh tag is not doomed to refault.
        let inj = FaultInjector::new(FaultPlan::new(11, 0.5, vec![]));
        let hits = (0..256).filter(|&t| inj.decide(t, 0).is_some()).count();
        assert!(hits > 0 && hits < 256, "degenerate fault distribution: {hits}/256");
    }

    #[test]
    fn checksum_detects_single_flip() {
        let clean: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let h0 = fnv1a_words(clean.iter().map(|v| v.to_bits()));
        let mut dirty = clean.clone();
        dirty[17] += 1.0;
        let h1 = fnv1a_words(dirty.iter().map(|v| v.to_bits()));
        assert_ne!(h0, h1);
        assert_eq!(h0, fnv1a_words(clean.iter().map(|v| v.to_bits())));
    }

    #[test]
    fn counters_aggregate_by_kind() {
        let c = FaultCounters::default();
        c.count_injected(FaultKind::Error);
        c.count_injected(FaultKind::Hang);
        c.count_injected(FaultKind::Hang);
        c.count_injected(FaultKind::CacheCorrupt);
        c.count_injected(FaultKind::ShardCrash);
        assert_eq!(c.injected(), 5);
        assert_eq!(c.injected_hangs.load(Ordering::Relaxed), 2);
        assert_eq!(c.injected_cache_corruptions.load(Ordering::Relaxed), 1);
        assert_eq!(c.injected_shard_crashes.load(Ordering::Relaxed), 1);
    }
}
