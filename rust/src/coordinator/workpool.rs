//! Persistent pack/compute worker pool — long-lived threads replacing
//! the per-call scoped-thread spawn of parallel operand packing.
//!
//! # Why this layer exists
//!
//! Since PR 5, `TilePool::pack_with` fanned arena extraction out with
//! `std::thread::scope`, spawning and joining `pack_workers − 1` OS
//! threads *per packed matrix*. The spawn/join cost is pure overhead
//! on the packing critical path (now measured separately as
//! `PackStats.pack_spawn_s`), and it grows with request rate — the
//! opposite of how a serving engine should amortize. A [`WorkPool`] is
//! the fix: the scheduler owns one pool of long-lived workers per
//! shard (threads named `maxeva-pack-{shard}-{index}`), packing tasks
//! are fed over a channel, and a per-call latch preserves the scoped
//! semantics callers rely on.
//!
//! # Scoped semantics over 'static workers
//!
//! [`WorkPool::run_scoped`] accepts non-`'static` closures — tasks
//! borrow the operand source and disjoint `&mut` destination chunks of
//! the arena being packed, exactly like the scoped-thread code it
//! replaces. That is sound because the call **does not return until
//! every task has arrived at its completion latch**: one task runs
//! inline on the caller (so `pack_workers = 1` never touches a second
//! thread), the rest are boxed, lifetime-erased, and dispatched to the
//! workers. Each dispatched task arrives at the latch via an RAII
//! guard that fires even if the task panics (workers run tasks under
//! `catch_unwind`), and the caller waits on the latch even if *its*
//! inline task unwinds — so the borrowed environment can never be
//! freed while a worker still holds a reference into it. A dispatched
//! panic is re-raised on the caller after the latch clears, matching
//! `std::thread::scope`'s propagation; the pool itself survives and
//! keeps serving later calls.
//!
//! # Lifecycle
//!
//! Dropping the pool closes the channel and joins every worker —
//! [`crate::coordinator::scheduler`] owns its pool, so shard teardown
//! (and `MatMulServer` drop) leaves no pack threads behind; pinned by
//! the leak probe in `tests/pack_pool_leak.rs`. `ServeConfig` selects
//! between this pool (`pack_persistent = true`, the default) and the
//! legacy scoped-thread fan-out (`false`, kept as the A/B baseline for
//! `benches/e2e_serving.rs`).

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

/// A lifetime-erased packing task (see the module docs for why the
/// `'static` here is never actually relied on).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch for one `run_scoped` call: counts dispatched tasks
/// down to zero and records whether any of them panicked.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch { remaining: Mutex::new(count), cv: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn arrive(&self) {
        let mut left = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap_or_else(PoisonError::into_inner);
        while *left > 0 {
            left = self.cv.wait(left).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Arrives at the latch on drop — the task's completion signal fires
/// whether it returned or unwound.
struct ArriveGuard(Arc<Latch>);

impl Drop for ArriveGuard {
    fn drop(&mut self) {
        self.0.arrive();
    }
}

/// Blocks until the latch clears on drop — keeps the caller's stack
/// frame (and every borrow the dispatched tasks hold into it) alive
/// through an unwind of the caller's own inline task.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

struct Inner {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
}

/// A pool of long-lived worker threads executing borrowed task batches
/// with scoped-join semantics (module docs). `new(0, _)` builds a
/// threadless pool whose `run_scoped` runs everything inline — the
/// serial-packing configuration costs no threads at all.
pub struct WorkPool {
    inner: Option<Inner>,
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only around `recv` — tasks run unlocked so the
        // pool actually executes in parallel.
        let job = {
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            // Channel closed: the pool is being dropped.
            Err(_) => return,
        }
    }
}

impl WorkPool {
    /// Spawn `threads` long-lived workers (named
    /// `maxeva-pack-{shard}-{index}`). Callers size this one *below*
    /// their fan-out width: `run_scoped` runs one task inline, so a
    /// fan-out of W needs W − 1 pool threads for full concurrency.
    pub fn new(threads: usize, shard: usize) -> Self {
        if threads == 0 {
            return WorkPool { inner: None };
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("maxeva-pack-{shard}-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn pack worker thread")
            })
            .collect();
        WorkPool { inner: Some(Inner { tx, handles }) }
    }

    /// Worker threads owned by the pool (`0` = everything inline).
    pub fn threads(&self) -> usize {
        self.inner.as_ref().map_or(0, |i| i.handles.len())
    }

    /// Run a batch of borrowing tasks to completion: the last task
    /// inline on the caller, the rest on the pool workers. Returns
    /// only after **all** tasks finished; panics (on the caller) if
    /// any task panicked — the scoped-thread contract, without the
    /// per-call spawn/join. With no pool threads, or a single task,
    /// every task runs inline in order.
    pub fn run_scoped<'env, F>(&self, mut tasks: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        let Some(last) = tasks.pop() else { return };
        let inner = match &self.inner {
            Some(inner) if !tasks.is_empty() => inner,
            _ => {
                for task in tasks {
                    task();
                }
                last();
                return;
            }
        };
        let latch = Arc::new(Latch::new(tasks.len()));
        for task in tasks {
            let guard = ArriveGuard(Arc::clone(&latch));
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                if panic::catch_unwind(AssertUnwindSafe(task)).is_err() {
                    guard.0.panicked.store(true, Ordering::Relaxed);
                }
            });
            // Safety: the job may borrow `'env` state (the operand
            // source and a disjoint destination chunk). This call does
            // not return before every job has arrived at the latch —
            // arrival is an RAII drop that fires on completion *and*
            // on unwind, and the caller waits through its own unwind
            // via WaitGuard below — so no job can outlive the borrows
            // it captured. The erased 'static is never relied on.
            let job: Job =
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job) };
            if let Err(mpsc::SendError(job)) = inner.tx.send(job) {
                // Workers already gone (teardown race): run inline —
                // the latch still gets its arrival from the guard.
                job();
            }
        }
        {
            let wait = WaitGuard(&latch);
            last();
            drop(wait);
        }
        if latch.panicked.swap(false, Ordering::Relaxed) {
            panic!("a task dispatched to the work pool panicked");
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            // Closing the channel ends every worker's recv loop.
            drop(inner.tx);
            for handle in inner.handles {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn zero_threads_runs_everything_inline() {
        let pool = WorkPool::new(0, 0);
        assert_eq!(pool.threads(), 0);
        let mut hits = vec![false; 3];
        let mut tasks = Vec::new();
        for h in hits.iter_mut() {
            tasks.push(move || *h = true);
        }
        pool.run_scoped(tasks);
        assert!(hits.iter().all(|&h| h), "inline pool must run every task");
        // An empty batch is a no-op, not a hang.
        pool.run_scoped(Vec::<fn()>::new());
        WorkPool::new(2, 0).run_scoped(Vec::<fn()>::new());
    }

    #[test]
    fn scoped_borrows_fill_disjoint_chunks() {
        // The pack_with shape: tasks borrow disjoint &mut chunks of a
        // caller-owned buffer, run_scoped joins before they dangle.
        let pool = WorkPool::new(3, 9);
        let mut data = vec![0u32; 64];
        let mut tasks = Vec::new();
        for (idx, chunk) in data.chunks_mut(16).enumerate() {
            tasks.push(move || {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (idx * 16 + j) as u32;
                }
            });
        }
        pool.run_scoped(tasks);
        let want: Vec<u32> = (0..64).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn single_task_batches_never_need_the_pool() {
        let pool = WorkPool::new(2, 1);
        let ran = AtomicUsize::new(0);
        pool.run_scoped(vec![|| {
            ran.fetch_add(1, Ordering::Relaxed);
        }]);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkPool::new(2, 7);
        let hit = AtomicUsize::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            let mut tasks = Vec::new();
            for i in 0..4 {
                let hit = &hit;
                tasks.push(move || {
                    if i == 1 {
                        panic!("injected pack task failure");
                    }
                    hit.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.run_scoped(tasks);
        }));
        assert!(result.is_err(), "a dispatched panic must reach the caller");
        // The panic is contained to that call: the pool keeps working.
        let n = AtomicUsize::new(0);
        let mut tasks = Vec::new();
        for _ in 0..6 {
            let n = &n;
            tasks.push(move || {
                n.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.run_scoped(tasks);
        assert_eq!(n.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn drop_joins_all_workers() {
        // Drop must close the channel and join; if it wedged, the test
        // harness would hang — and the threads() accessor documents the
        // pool actually had workers to join.
        let pool = WorkPool::new(4, 3);
        assert_eq!(pool.threads(), 4);
        let total = AtomicUsize::new(0);
        let mut tasks = Vec::new();
        for _ in 0..16 {
            let total = &total;
            tasks.push(move || {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.run_scoped(tasks);
        assert_eq!(total.load(Ordering::Relaxed), 16);
        drop(pool);
    }
}
