//! Per-request completion delivery: [`RequestHandle`] (wait /
//! wait_timeout / try_wait / cancel) and the callback reply path.
//!
//! A handle can never hang on a dead server: if the scheduler thread
//! panics, every open flight is resolved fast with a typed
//! [`SchedulerPanicked`](crate::coordinator::fault::SchedulerPanicked)
//! error before the thread exits, and [`RequestHandle::wait_timeout`]
//! bounds any single wait client-side regardless.
//!
//! # Cancellation
//!
//! Every submission mints a private token routed through the scheduler's
//! event channel. [`RequestHandle::cancel`] — or simply dropping an
//! unresolved handle — asks the scheduler to abandon the request: tiles
//! not yet dispatched are never issued, the flight's queue and window
//! slots are reclaimed, and the handle resolves with a [`Cancelled`]
//! error (recover it with `err.downcast_ref::<Cancelled>()`). A request
//! that already retired is unaffected: cancellation after completion is
//! a no-op, and a handle always resolves exactly once.
//!
//! When the shard router splits a request along M
//! (see [`crate::coordinator::shard`]), the handle carries one cancel
//! route per band — `cancel` fans out to every shard that owns a band,
//! and the merged result resolves with [`Cancelled`] unless every band
//! had already retired (in which case the output is delivered whole,
//! exactly like the single-shard race).

use crate::coordinator::scheduler::Event;
use crate::workloads::{MatMulRequest, MatOutput};
use anyhow::{anyhow, Result};
use std::cell::Cell;
use std::sync::mpsc;
use std::time::Duration;

/// The request was cancelled (explicitly or by dropping its handle)
/// before it completed. Carries the request id.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("request {0} was cancelled before completion")]
pub struct Cancelled(pub u64);

/// Per-request completion delivery.
pub(crate) enum Reply {
    Handle(mpsc::Sender<Result<MatOutput>>),
    Callback(Box<dyn FnOnce(MatMulRequest, Result<MatOutput>) + Send>),
}

impl Reply {
    pub(crate) fn send(self, req: MatMulRequest, out: Result<MatOutput>) {
        match self {
            Reply::Handle(tx) => {
                let _ = tx.send(out);
            }
            // User code runs on the scheduler thread; a panicking
            // callback must not take the whole stream down with it.
            Reply::Callback(cb) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| cb(req, out)));
            }
        }
    }
}

/// A completion handle for one admitted request.
///
/// Dropping the handle without resolving it **cancels** the request —
/// an unobserved result is dead weight, so its unscheduled tiles are
/// reclaimed. Call [`RequestHandle::wait`] (or poll
/// [`RequestHandle::try_wait`]) to keep the request running to
/// completion.
pub struct RequestHandle {
    id: u64,
    rx: mpsc::Receiver<Result<MatOutput>>,
    /// One `(scheduler event channel, cancellation token)` per shard
    /// holding a piece of this request — a single entry for whole
    /// routing, one per band for M-split routing.
    routes: Vec<(mpsc::Sender<Event>, u64)>,
    /// Set once the result was received (or the server is known gone) —
    /// suppresses the cancel-on-drop signal.
    resolved: Cell<bool>,
}

impl RequestHandle {
    pub(crate) fn new(
        id: u64,
        rx: mpsc::Receiver<Result<MatOutput>>,
        routes: Vec<(mpsc::Sender<Event>, u64)>,
    ) -> Self {
        RequestHandle { id, rx, routes, resolved: Cell::new(false) }
    }

    /// The submitted request's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the scheduler to abandon this request: not-yet-dispatched
    /// tiles are dropped and the queue/window slots reclaimed. The
    /// handle still resolves — [`RequestHandle::wait`] returns a
    /// [`Cancelled`] error (or the output, if the request won the race
    /// and retired first). Cancelling a completed request is a no-op.
    /// For an M-split request the cancel fans out to every shard that
    /// owns a band.
    pub fn cancel(&self) {
        for (events, token) in &self.routes {
            let _ = events.send(Event::Cancel(*token));
        }
    }

    /// Block until the request retires and take its output.
    pub fn wait(self) -> Result<MatOutput> {
        self.resolved.set(true);
        self.rx
            .recv()
            .map_err(|_| anyhow!("server dropped request {} without replying", self.id))?
    }

    /// Block up to `timeout` for the request to retire. Returns `None`
    /// while the request is still in flight — the handle stays live and
    /// can be waited on again (or cancelled). `Some(Err(..))` covers
    /// both a failed request and a scheduler that died without
    /// replying, so a bounded wait never wedges a client on a lost
    /// completion; pair it with the server-side per-tile deadlines
    /// (`ServeConfig::tile_timeout_mult`) for end-to-end boundedness.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<MatOutput>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => {
                self.resolved.set(true);
                Some(r)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.resolved.set(true);
                Some(Err(anyhow!("server dropped request {} without replying", self.id)))
            }
        }
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    /// `Some(Err(..))` covers both a failed request and a dead server
    /// (channel disconnected) — either way the handle is resolved and
    /// cancel-on-drop is suppressed. Polling never consumes the handle:
    /// after `None` the request keeps running and the handle can still
    /// be waited on, polled again, or cancelled.
    pub fn try_wait(&self) -> Option<Result<MatOutput>> {
        match self.rx.try_recv() {
            Ok(r) => {
                self.resolved.set(true);
                Some(r)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.resolved.set(true);
                Some(Err(anyhow!("server dropped request {} without replying", self.id)))
            }
        }
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        if !self.resolved.get() {
            for (events, token) in &self.routes {
                let _ = events.send(Event::Cancel(*token));
            }
        }
    }
}
