//! The serving coordinator — the L3 runtime path.
//!
//! Arbitrary-size MatMul requests enter through a **streaming admission
//! queue** (bounded by `ServeConfig::queue_depth`, block/reject
//! backpressure), are padded and tiled to their precision's native size
//! ([`tiler`]), packed once into tile-major `Arc`'d block pools, and
//! streamed through a pipelined in-flight window of tagged tile jobs
//! ([`server`]) executed by a pool of device worker threads ([`device`])
//! — the software stand-in for the VCK190's AIE array. Requests carry a
//! per-request precision: fp32 and int8 (i32-accumulating) tiles share
//! one window, mirroring the paper's dual headline designs. The window
//! is the host-side mirror of the paper's ping-pong buffering (eq. 2):
//! host packing/reduction overlaps device execution instead of
//! alternating with it. Python never runs here; the device workers
//! execute the AOT artifacts produced once at build time (or, without
//! the `pjrt` feature/artifacts, a pure-Rust reference backend with
//! identical tile semantics).
//!
//! Device-time accounting: every artifact invocation advances the
//! simulated device clock by the design's iteration period (from
//! [`crate::sim`]), so the coordinator reports both wall-clock (CPU
//! emulation) and device-time (VCK190-equivalent) throughput without
//! conflating them.

pub mod device;
pub mod server;
pub mod stats;
pub mod tiler;
pub mod trace;

pub use device::{
    spawn_device, spawn_device_pool, DeviceHandle, TileDone, TileJob, TileOutput, TilePayload,
};
pub use server::{MatMulServer, QueueFull, RequestHandle, ServerStats};
pub use tiler::Tiler;
