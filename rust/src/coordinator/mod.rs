//! The serving coordinator — the L3 runtime path.
//!
//! # Architecture
//!
//! The facade ([`server::MatMulServer`]) fronts `ServeConfig::shards`
//! independent copies of the engine below (a `shard::Shard` each);
//! the router in [`shard`] places whole requests by weight-affinity
//! rendezvous hashing (least-loaded fallback) and splits large GEMMs
//! along M with a bit-identity-preserving reduction — see
//! [`shard`] for the routing policy and the bit-identity-under-split
//! argument. One shard (the default) bypasses the router entirely:
//!
//! ```text
//!  client threads                     scheduler thread                device pool
//!  ──────────────                     ────────────────               ─────────────
//!  submit / submit_with_callback
//!    │ validate + shard router
//!    │ admission gate (per shard:
//!    │  queue_depth, block/reject)
//!    ├──── Event::Admit ────────────▶ SchedPolicy ◀─ policy knobs
//!    │                                │  Fifo | WeightedFair | Priority
//!  RequestHandle                      │  pick() → flight issues 1 tile
//!    │ wait / try_wait                │  (per-precision tile costs,
//!    │ cancel / drop ── Cancel ─────▶ │   classes, aging)
//!    │                                ▼
//!    │                        in-flight window ──── TileJob ──────▶ worker 0..W
//!    │                        (pipeline_depth)                       fp32 / int8
//!    │                                ▲                              datapaths
//!    │                                │◀─── Event::Done ◀─ forwarder ◀─ TileDone
//!    │                                │ ordered (ascending-ik) reduction
//!    ◀──── output / Cancelled ─────── │ retire: stats, free gate slot
//! ```
//!
//! Arbitrary-size MatMul requests enter through a **streaming admission
//! queue** ([`admission`]; bounded by `ServeConfig::queue_depth`, with
//! optional per-class reserved slots via
//! `ServeConfig::class_queue_reserve`, block/reject backpressure), are
//! padded and tiled to their precision's native size ([`tiler`]),
//! packed once into contiguous tile-major arenas ([`pool`]: one
//! allocation per matrix, extraction optionally fanned out across
//! `ServeConfig::pack_workers` — by default onto the scheduler's
//! persistent [`workpool`] of long-lived pack threads, or legacy
//! per-call scoped threads with `pack_persistent = false` — B
//! optionally served from the byte-budgeted packed-weight cache), and
//! streamed through a
//! pipelined in-flight window of tagged tile jobs ([`scheduler`])
//! executed by a pool of device worker threads ([`device`]) — the
//! software stand-in for the VCK190's AIE array. Tile output and
//! accumulation buffers recycle through per-precision free-lists, so
//! the steady-state hot loop stops allocating.
//! Which flight issues the next tile is a pluggable [`policy`] decision:
//! FIFO round-robin (the default, bit-identical to the pre-policy
//! engine), deficit-round-robin weighted fairness over priority classes
//! with per-precision tile costs, or strict priority with aging.
//! Completions are delivered per request ([`handle`]); dropping or
//! cancelling a handle reclaims the queue and window slots of tiles not
//! yet dispatched.
//!
//! Requests carry a per-request precision: fp32 and int8
//! (i32-accumulating) tiles share one window, mirroring the paper's
//! dual headline designs. The window is the host-side mirror of the
//! paper's ping-pong buffering (eq. 2): host packing/reduction overlaps
//! device execution instead of alternating with it. Python never runs
//! here; the device workers execute the AOT artifacts produced once at
//! build time (or, without the `pjrt` feature/artifacts, the
//! register-tiled host compute plane ([`microkernel`]) with identical
//! tile semantics — bit-identical outputs at vectorized speed).
//!
//! Device-time accounting: every artifact invocation advances the
//! simulated device clock by the design's iteration period (from
//! [`crate::sim`]), so the coordinator reports both wall-clock (CPU
//! emulation) and device-time (VCK190-equivalent) throughput without
//! conflating them.
//!
//! # Failure model
//!
//! The device plane is fault-tolerant (PR 6). Faults it recovers from,
//! and how:
//!
//! * **Tile errors** — a worker returns `Err` for a tile (or, under the
//!   deterministic chaos layer in [`fault`], is *injected* with one).
//!   The tile re-enters the window under a fresh tag, dispatched to a
//!   different worker when one is available, up to
//!   `ServeConfig::max_tile_retries`; only then does the request fail,
//!   with a typed [`TileRetriesExhausted`].
//! * **Lost completions** (hung worker, dropped message) — with
//!   `ServeConfig::tile_timeout_mult` armed, every tile attempt carries
//!   a deadline (multiplier × its precision's simulated tile period,
//!   floored at `tile_timeout_floor_ms`). Expiry counts as a tile fault
//!   and retries; a completion straggling in after expiry is dropped by
//!   a stale-tag set, so a partial can never reduce twice.
//! * **Corrupted outputs** — in chaos mode workers checksum each clean
//!   output (FNV-1a over the element bits); the scheduler re-verifies
//!   on arrival and rejects mismatches into the retry path
//!   ([`TileCorrupted`]).
//! * **Worker deaths** — a panicking worker thread is detected by
//!   supervision (on deadline ticks and on dispatch send-failure) and
//!   respawned in place; if respawn fails the slot is marked dead and
//!   the pool shrinks gracefully. Workers with repeated consecutive
//!   faults are **quarantined**: dispatch prefers healthy peers and
//!   returns to a quarantined worker only when no healthy one remains.
//! * **Scheduler death** — the scheduler loop runs under
//!   `catch_unwind`; if it panics, every open request resolves fast
//!   with [`SchedulerPanicked`] instead of hanging its clients.
//!   [`RequestHandle::wait_timeout`] additionally bounds any single
//!   client-side wait.
//! * **Shutdown stragglers** — `ServeConfig::drain_deadline_ms` bounds
//!   the shutdown drain; requests still open past it fail with
//!   [`DrainDeadlineExpired`] instead of wedging teardown. The facade
//!   stamps one absolute deadline and fans it out, so all shards drain
//!   concurrently against the same instant: shutdown wall time is
//!   bounded by the slowest shard, not the shard count.
//!
//! On top of the tile-level plane, PR 9 adds a **request-level
//! taxonomy** — three distinct, typed ways a request can fail before or
//! instead of completing, each attributable to its shard via
//! [`ServeError::shard`](error::ServeError::shard):
//!
//! * **Deadline** — the client bounded the request
//!   ([`MatMulRequest::with_deadline`]); the budget expired before
//!   completion. The flight is evicted through the cancellation path
//!   (queue and window slots reclaimed, straggling tiles dropped and
//!   recycled) and the handle resolves with [`DeadlineExceeded`] —
//!   never a partial output. A request that arrives at its scheduler
//!   already past its budget is rejected before any tile is scheduled.
//! * **Shed** — the server refused the request at admission to protect
//!   the rest: the brownout shedder (`ServeConfig::shed_watermark`)
//!   rejects the lowest-priority classes first as queue occupancy
//!   climbs past the watermark ([`RequestShed`]; class 0 is never
//!   shed), and SLO-aware admission (`ServeConfig::slo_admission`)
//!   rejects a deadline the class's observed p99 service time says is
//!   unattainable under the current open-request load
//!   ([`SloUnattainable`]). Neither consumes a queue slot or device
//!   time; both are counted in [`ShedStats`].
//! * **Failover** — the request's shard failed underneath it
//!   (`ServeConfig::shard_failover`): a per-shard circuit breaker trips
//!   after `breaker_threshold` consecutive scheduler-level failures,
//!   and open requests that resolved with [`SchedulerPanicked`] are
//!   re-dispatched — whole requests and individual row-bands of
//!   M-split requests alike — to healthy shards under fresh routes.
//!   After `breaker_probe_ms` the breaker half-opens and the next
//!   request probes the shard; a success closes it again (probing is
//!   lazy, piggybacked on routing — no background thread).
//! * **Recovery** — beyond routing *around* a failure, two opt-in
//!   planes repair it. **Shard respawn** (`ServeConfig::shard_respawn`):
//!   a supervisor thread, woken by breaker failures, verifies the
//!   shard's scheduler thread actually died (a drain-deadline trip on a
//!   live shard needs no respawn), rebuilds the engine from the same
//!   `ServeConfig` at the same index and swaps it atomically into the
//!   shard table. State reconciliation is minimal by design: in-flight
//!   requests were already re-dispatched by the failover plane, so
//!   nothing carries over except an optional rewarm of the hottest
//!   packed weights the dying scheduler exported
//!   (`respawn_rewarm_top_k`), each keeping its pre-crash checksum and
//!   fully verifying on first hit. The breaker then walks
//!   Open → HalfOpen → Closed through the normal lazy probe. Attempts
//!   per shard are bounded (`respawn_max_attempts`, linear
//!   `respawn_backoff_ms` backoff); a shard that exhausts them is
//!   permanently removed — exactly the respawn-off end state. **Memory-
//!   plane integrity** (`ServeConfig::cache_verify_interval`): every
//!   packed pool in the weight cache carries an FNV-1a checksum stamped
//!   at insert, and every Nth cache hit re-derives and compares it. A
//!   mismatch evicts and quarantines the entry
//!   (`cache_quarantine_ms`) and the request transparently re-packs
//!   from its own operands — a typed counter
//!   (`RecoveryStats::poisoned_evictions`), never a client-visible
//!   error.
//!
//! **Guarantees.** A recovered run is bit-identical to a fault-free
//! run: retried tiles are rebuilt from the immutable packed arenas and
//! the ascending-`ik` reduction order is preserved, so retries are
//! invisible in the output. Every submitted request resolves exactly
//! once — with its output, a typed fault error, or [`Cancelled`] —
//! under every fault mix the chaos layer can produce. Both guarantees
//! extend across the shard router: an M-split request's bands execute
//! the identical tile walk and `ik` reduction the unsplit request would
//! have for their rows, the merge is pure row-band concatenation (so
//! `shards = N` outputs are bit-identical to `shards = 1` — see
//! [`shard`]), and a split request still resolves exactly once (its
//! first failing band, in band order, decides the error). Every typed
//! failure is classifiable through the single
//! [`ServeError`](error::ServeError) enum re-exported at the crate
//! root. The request-level plane preserves both properties:
//! **exactly-once resolution survives shard failover** — the reply
//! travels between attempts behind a take-once slot, so a request that
//! visited every shard still resolves exactly once — and a recovered
//! request (whole, or split and re-dispatched band by band) re-enters
//! the identical deterministic engine path on its new shard, so its
//! output — including the band-concat merge — is **bit-identical to
//! the fault-free run**. A deadline expiry never delivers partial
//! output. The recovery plane preserves both properties as well: a
//! respawned shard runs the identical deterministic engine (same
//! config, same index), and a quarantined cache entry's re-pack
//! rebuilds the identical arena from the request's own operands — so
//! outputs are **bit-identical across respawn and across cache
//! re-pack**, and **exactly-once resolution survives quarantine** (the
//! re-packed request resolves through its original reply path; the
//! corruption is absorbed as a cache miss). With every robustness and
//! recovery knob at its default, the served bits are identical to the
//! pre-robustness server for both precisions.
//!
//! **Non-guarantees.** Supervision is driven by the scheduler's
//! deadline ticks: with deadlines disabled (`tile_timeout_mult = 0`,
//! the default), dead workers are only noticed when a dispatch to them
//! fails, and a hung worker wedges its in-flight tile forever — exactly
//! the pre-PR 6 behavior. Fault *injection* (the [`fault`] layer) is
//! deterministic per (seed, tag, worker) but the budget `max_faults` is
//! claimed in completion order, which wall-clock timing may reorder.
//! Request deadlines are enforced at scheduler wakeups, not
//! preemptively — but the scheduler's sleep is clamped to the earliest
//! armed deadline among outstanding tiles, open requests' deadlines and
//! the drain budget, so an otherwise-idle scheduler wakes at the
//! deadline itself and expiry latency is wakeup overhead, not a polling
//! interval (pinned by `deadline_expiry_is_prompt_when_idle` in
//! `rust/tests/recovery_plane.rs`). Expiry still cannot interrupt a
//! tile already executing, so under load it is bounded by the longest
//! outstanding tile (arm `tile_timeout_mult` to bound that too).
//! Cancelling through a handle
//! after its request failed over routes to the originally admitted
//! shard only (best-effort; the recovered flight runs to completion
//! and resolves the handle normally). With `shard_respawn` off (the
//! default), failed shards are not respawned:
//! a shard whose scheduler died stays down — its half-open probes fail
//! fast and traffic stays diverted — and once every shard has failed,
//! requests resolve with the final [`SchedulerPanicked`] error rather
//! than queue for a recovery that cannot come; with respawn on, the
//! same end state is reached only after a shard exhausts
//! `respawn_max_attempts`. Respawn **rewarm is best-effort**: only what
//! the dying scheduler managed to export before fail-fast is re-seeded,
//! and a rescue lost to a hard crash costs cache misses, never
//! correctness. A respawned shard starts with **fresh per-shard
//! statistics** — its predecessor's counter history (requests served,
//! cache hits, device time) dies with the old engine and is absent from
//! later [`ShardStats`](stats::ShardStats) snapshots; the recovery
//! plane's own counters ([`RecoveryStats`](stats::RecoveryStats)) live
//! in the facade and survive. SLO admission estimates
//! from observed per-class service history; a class with no history
//! admits optimistically.
//!
//! [`TileRetriesExhausted`]: fault::TileRetriesExhausted
//! [`TileCorrupted`]: fault::TileCorrupted
//! [`SchedulerPanicked`]: fault::SchedulerPanicked
//! [`DrainDeadlineExpired`]: fault::DrainDeadlineExpired
//! [`DeadlineExceeded`]: fault::DeadlineExceeded
//! [`RequestShed`]: fault::RequestShed
//! [`SloUnattainable`]: fault::SloUnattainable
//! [`ShedStats`]: stats::ShedStats
//! [`MatMulRequest::with_deadline`]: crate::workloads::MatMulRequest::with_deadline
//! [`RequestHandle::wait_timeout`]: handle::RequestHandle::wait_timeout

pub mod admission;
pub mod compat;
pub mod device;
pub mod error;
pub mod fault;
pub mod handle;
pub mod microkernel;
pub mod policy;
pub mod pool;
pub(crate) mod scheduler;
pub mod server;
pub mod shard;
pub mod stats;
pub mod tiler;
pub mod trace;
pub mod workpool;

// The canonical re-export surface of the serving layer. These are the
// *only* re-exports (the sibling modules no longer duplicate them);
// `crate::prelude` narrows this list to what a typical client needs.
pub use admission::QueueFull;
pub use device::{
    output_crc, spawn_device, spawn_device_pool, spawn_device_pool_with_faults, DeviceHandle,
    TileDone, TileJob, TileOutput, TilePayload,
};
pub use error::ServeError;
pub use fault::{
    DeadlineExceeded, DrainDeadlineExpired, FaultCounters, FaultKind, FaultPlan, RequestShed,
    SchedulerPanicked, SloUnattainable, TileCorrupted, TileRetriesExhausted, TileTimedOut,
};
pub use handle::{Cancelled, RequestHandle};
pub use microkernel::{
    matmul_blocked, micro_geom, panel_geom, MicroGeom, PanelGeom, MR_F32, MR_I32, NR_F32, NR_I32,
    PANEL_KC, PANEL_MC, PANEL_NC,
};
pub use policy::{Fifo, FlightMeta, Priority, SchedPolicy, TileCosts, WeightedFair};
pub use pool::{
    BufferPool, FreeList, PackCounters, PackTiming, TilePool, TileRef, WeightCache, FREE_LIST_CAP,
    PAR_PACK_MIN_TILES,
};
pub use server::{MatMulServer, ServerStats};
pub use stats::{
    BreakerSnapshot, BreakerState, ClassStats, FaultStats, MemPlaneStats, PackStats,
    RecoveryStats, RouterStats, ShardStats, ShedStats, WorkerHealth,
};
pub use tiler::Tiler;
pub use workpool::WorkPool;
