//! The serving coordinator — the L3 runtime path.
//!
//! # Architecture
//!
//! ```text
//!  client threads                     scheduler thread                device pool
//!  ──────────────                     ────────────────               ─────────────
//!  submit / submit_with_callback
//!    │ validate + admission gate
//!    │ (queue_depth, block/reject)
//!    ├──── Event::Admit ────────────▶ SchedPolicy ◀─ policy knobs
//!    │                                │  Fifo | WeightedFair | Priority
//!  RequestHandle                      │  pick() → flight issues 1 tile
//!    │ wait / try_wait                │  (per-precision tile costs,
//!    │ cancel / drop ── Cancel ─────▶ │   classes, aging)
//!    │                                ▼
//!    │                        in-flight window ──── TileJob ──────▶ worker 0..W
//!    │                        (pipeline_depth)                       fp32 / int8
//!    │                                ▲                              datapaths
//!    │                                │◀─── Event::Done ◀─ forwarder ◀─ TileDone
//!    │                                │ ordered (ascending-ik) reduction
//!    ◀──── output / Cancelled ─────── │ retire: stats, free gate slot
//! ```
//!
//! Arbitrary-size MatMul requests enter through a **streaming admission
//! queue** ([`admission`]; bounded by `ServeConfig::queue_depth`, with
//! optional per-class reserved slots via
//! `ServeConfig::class_queue_reserve`, block/reject backpressure), are
//! padded and tiled to their precision's native size ([`tiler`]),
//! packed once into contiguous tile-major arenas ([`pool`]: one
//! allocation per matrix, extraction optionally fanned out across
//! `ServeConfig::pack_workers` threads, B optionally served from the
//! byte-budgeted packed-weight cache), and streamed through a
//! pipelined in-flight window of tagged tile jobs ([`scheduler`])
//! executed by a pool of device worker threads ([`device`]) — the
//! software stand-in for the VCK190's AIE array. Tile output and
//! accumulation buffers recycle through per-precision free-lists, so
//! the steady-state hot loop stops allocating.
//! Which flight issues the next tile is a pluggable [`policy`] decision:
//! FIFO round-robin (the default, bit-identical to the pre-policy
//! engine), deficit-round-robin weighted fairness over priority classes
//! with per-precision tile costs, or strict priority with aging.
//! Completions are delivered per request ([`handle`]); dropping or
//! cancelling a handle reclaims the queue and window slots of tiles not
//! yet dispatched.
//!
//! Requests carry a per-request precision: fp32 and int8
//! (i32-accumulating) tiles share one window, mirroring the paper's
//! dual headline designs. The window is the host-side mirror of the
//! paper's ping-pong buffering (eq. 2): host packing/reduction overlaps
//! device execution instead of alternating with it. Python never runs
//! here; the device workers execute the AOT artifacts produced once at
//! build time (or, without the `pjrt` feature/artifacts, the
//! register-tiled host compute plane ([`microkernel`]) with identical
//! tile semantics — bit-identical outputs at vectorized speed).
//!
//! Device-time accounting: every artifact invocation advances the
//! simulated device clock by the design's iteration period (from
//! [`crate::sim`]), so the coordinator reports both wall-clock (CPU
//! emulation) and device-time (VCK190-equivalent) throughput without
//! conflating them.

pub mod admission;
pub mod device;
pub mod handle;
pub mod microkernel;
pub mod policy;
pub mod pool;
pub(crate) mod scheduler;
pub mod server;
pub mod stats;
pub mod tiler;
pub mod trace;

pub use admission::QueueFull;
pub use device::{
    spawn_device, spawn_device_pool, DeviceHandle, TileDone, TileJob, TileOutput, TilePayload,
};
pub use handle::{Cancelled, RequestHandle};
pub use microkernel::{micro_geom, MicroGeom, MR_F32, MR_I32, NR_F32, NR_I32};
pub use policy::{Fifo, FlightMeta, Priority, SchedPolicy, TileCosts, WeightedFair};
pub use pool::{
    BufferPool, FreeList, PackCounters, TilePool, TileRef, WeightCache, FREE_LIST_CAP,
    PAR_PACK_MIN_TILES,
};
pub use server::{MatMulServer, ServerStats};
pub use stats::{ClassStats, MemPlaneStats, PackStats};
pub use tiler::Tiler;
