//! The serving coordinator — the L3 runtime path.
//!
//! Arbitrary-size MatMul requests are padded and tiled to the design's
//! native size ([`tiler`]), scheduled as tile jobs with round-robin
//! dynamic batching across in-flight requests ([`server`]), and executed
//! on the PJRT runtime by a dedicated device thread ([`device`]) — the
//! software stand-in for the VCK190's AIE array. Python never runs here;
//! the device thread executes the AOT artifacts produced once at build
//! time.
//!
//! Device-time accounting: every artifact invocation advances the
//! simulated device clock by the design's iteration period (from
//! [`crate::sim`]), so the coordinator reports both wall-clock (CPU
//! emulation) and device-time (VCK190-equivalent) throughput without
//! conflating them.

pub mod device;
pub mod server;
pub mod trace;
pub mod stats;
pub mod tiler;

pub use device::{spawn_device, DeviceHandle};
pub use server::{MatMulServer, ServerStats};
pub use tiler::Tiler;
