//! The unified public error surface of the serving layer.
//!
//! Every typed error the coordinator can deliver — admission rejections
//! ([`QueueFull`], [`RequestShed`], [`SloUnattainable`]), cancellations
//! ([`Cancelled`]), request deadlines ([`DeadlineExceeded`]) and the
//! fault-plane failures ([`TileRetriesExhausted`], [`TileTimedOut`],
//! [`TileCorrupted`], [`SchedulerPanicked`], [`DrainDeadlineExpired`])
//! — is collected under one `#[non_exhaustive]` enum, [`ServeError`],
//! re-exported from the crate root. Failures that happen after shard
//! placement carry the originating shard index
//! ([`ServeError::shard`]), so multi-shard incidents are attributable.
//!
//! The engine still transports errors through `anyhow::Error` with the
//! concrete types attached (so existing
//! `err.downcast_ref::<QueueFull>()` call sites keep compiling
//! unchanged); [`ServeError::from_anyhow`] classifies such an error
//! into the enum when a caller wants one `match` over every serving
//! failure mode instead of a downcast ladder.

use crate::coordinator::admission::QueueFull;
use crate::coordinator::fault::{
    DeadlineExceeded, DrainDeadlineExpired, RequestShed, SchedulerPanicked, SloUnattainable,
    TileCorrupted, TileRetriesExhausted, TileTimedOut,
};
use crate::coordinator::handle::Cancelled;

/// Any typed failure the serving layer can resolve a request with.
///
/// `#[non_exhaustive]`: future PRs may add failure modes (deadline
/// SLOs, shard evacuation, …) without a breaking change — always keep a
/// `_` arm. The `From` impls let existing code that produced or matched
/// the concrete error types lift them into the enum for free.
#[non_exhaustive]
#[derive(Debug, Clone, thiserror::Error)]
pub enum ServeError {
    /// The admission queue could not open one more request
    /// (`AdmissionPolicy::Reject` backpressure).
    #[error(transparent)]
    QueueFull(#[from] QueueFull),
    /// The request was cancelled (explicitly or by dropping its handle)
    /// before it completed.
    #[error(transparent)]
    Cancelled(#[from] Cancelled),
    /// A tile failed every execution attempt (`max_tile_retries`).
    #[error(transparent)]
    TileRetriesExhausted(#[from] TileRetriesExhausted),
    /// A tile's completion missed its armed deadline.
    #[error(transparent)]
    TileTimedOut(#[from] TileTimedOut),
    /// A tile's output failed checksum verification (chaos mode).
    #[error(transparent)]
    TileCorrupted(#[from] TileCorrupted),
    /// The scheduler thread panicked; the request was failed fast.
    #[error(transparent)]
    SchedulerPanicked(#[from] SchedulerPanicked),
    /// The shutdown drain deadline expired with the request still open.
    #[error(transparent)]
    DrainDeadlineExpired(#[from] DrainDeadlineExpired),
    /// The request's own deadline expired before completion.
    #[error(transparent)]
    DeadlineExceeded(#[from] DeadlineExceeded),
    /// The brownout shedder rejected the request at admission.
    #[error(transparent)]
    Shed(#[from] RequestShed),
    /// SLO-aware admission judged the deadline unattainable.
    #[error(transparent)]
    SloUnattainable(#[from] SloUnattainable),
}

impl ServeError {
    /// Classify an `anyhow::Error` delivered by the serving layer into
    /// the typed enum. `None` for untyped failures (validation errors,
    /// shutdown messages, backend errors) — those remain plain anyhow
    /// messages by design.
    pub fn from_anyhow(err: &anyhow::Error) -> Option<ServeError> {
        if let Some(e) = err.downcast_ref::<QueueFull>() {
            return Some(ServeError::QueueFull(*e));
        }
        if let Some(e) = err.downcast_ref::<Cancelled>() {
            return Some(ServeError::Cancelled(*e));
        }
        if let Some(e) = err.downcast_ref::<TileRetriesExhausted>() {
            return Some(ServeError::TileRetriesExhausted(e.clone()));
        }
        if let Some(e) = err.downcast_ref::<TileTimedOut>() {
            return Some(ServeError::TileTimedOut(*e));
        }
        if let Some(e) = err.downcast_ref::<TileCorrupted>() {
            return Some(ServeError::TileCorrupted(*e));
        }
        if let Some(e) = err.downcast_ref::<SchedulerPanicked>() {
            return Some(ServeError::SchedulerPanicked(*e));
        }
        if let Some(e) = err.downcast_ref::<DrainDeadlineExpired>() {
            return Some(ServeError::DrainDeadlineExpired(*e));
        }
        if let Some(e) = err.downcast_ref::<DeadlineExceeded>() {
            return Some(ServeError::DeadlineExceeded(*e));
        }
        if let Some(e) = err.downcast_ref::<RequestShed>() {
            return Some(ServeError::Shed(*e));
        }
        if let Some(e) = err.downcast_ref::<SloUnattainable>() {
            return Some(ServeError::SloUnattainable(*e));
        }
        None
    }

    /// The shard index the failure originated on, when the variant
    /// carries one (`None` for admission rejections and cancellations,
    /// which happen before or independent of shard placement).
    pub fn shard(&self) -> Option<usize> {
        match self {
            ServeError::TileRetriesExhausted(e) => Some(e.shard),
            ServeError::TileTimedOut(e) => Some(e.shard),
            ServeError::TileCorrupted(e) => Some(e.shard),
            ServeError::SchedulerPanicked(e) => Some(e.shard),
            ServeError::DrainDeadlineExpired(e) => Some(e.shard),
            ServeError::DeadlineExceeded(e) => Some(e.shard),
            ServeError::Shed(e) => Some(e.shard),
            ServeError::SloUnattainable(e) => Some(e.shard),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_every_typed_error() {
        let cases: Vec<(anyhow::Error, fn(&ServeError) -> bool)> = vec![
            (QueueFull(4).into(), |e| matches!(e, ServeError::QueueFull(QueueFull(4)))),
            (Cancelled(7).into(), |e| matches!(e, ServeError::Cancelled(Cancelled(7)))),
            (
                TileRetriesExhausted { id: 1, attempts: 3, last: "boom".into(), shard: 2 }.into(),
                |e| matches!(e, ServeError::TileRetriesExhausted(t) if t.attempts == 3),
            ),
            (
                TileTimedOut { worker: 2, waited_ms: 80, shard: 0 }.into(),
                |e| matches!(e, ServeError::TileTimedOut(t) if t.worker == 2),
            ),
            (
                TileCorrupted { worker: 1, shard: 0 }.into(),
                |e| matches!(e, ServeError::TileCorrupted(_)),
            ),
            (
                SchedulerPanicked { shard: 3 }.into(),
                |e| matches!(e, ServeError::SchedulerPanicked(p) if p.shard == 3),
            ),
            (
                DrainDeadlineExpired { id: 9, shard: 1 }.into(),
                |e| matches!(e, ServeError::DrainDeadlineExpired(d) if d.id == 9 && d.shard == 1),
            ),
            (
                DeadlineExceeded { id: 5, shard: 0, budget_ms: 100 }.into(),
                |e| matches!(e, ServeError::DeadlineExceeded(d) if d.budget_ms == 100),
            ),
            (
                RequestShed { id: 6, shard: 2, class: 3, open: 12 }.into(),
                |e| matches!(e, ServeError::Shed(s) if s.class == 3 && s.shard == 2),
            ),
            (
                SloUnattainable { id: 8, shard: 1, class: 0, estimated_ms: 90, deadline_ms: 40 }
                    .into(),
                |e| matches!(e, ServeError::SloUnattainable(s) if s.estimated_ms == 90),
            ),
        ];
        for (err, check) in cases {
            let classified = ServeError::from_anyhow(&err)
                .unwrap_or_else(|| panic!("unclassified: {err}"));
            assert!(check(&classified), "misclassified: {classified}");
            // Display is transparent: the enum shows the inner message.
            assert_eq!(classified.to_string(), err.to_string());
        }
    }

    #[test]
    fn untyped_errors_stay_unclassified() {
        let err = anyhow::anyhow!("request 3: A shape mismatch");
        assert!(ServeError::from_anyhow(&err).is_none());
    }

    #[test]
    fn from_impls_lift_concrete_types() {
        // The From impls are what keep pre-enum call sites compiling:
        // `?` and `.into()` on a concrete error produce the enum.
        let e: ServeError = QueueFull(1).into();
        assert!(matches!(e, ServeError::QueueFull(_)));
        let e: ServeError = Cancelled(0).into();
        assert!(matches!(e, ServeError::Cancelled(_)));
        let e: ServeError = SchedulerPanicked { shard: 0 }.into();
        assert!(matches!(e, ServeError::SchedulerPanicked(_)));
        let e: ServeError = DeadlineExceeded { id: 1, shard: 0, budget_ms: 5 }.into();
        assert!(matches!(e, ServeError::DeadlineExceeded(_)));
    }

    #[test]
    fn shard_attribution_is_exposed() {
        let e: ServeError = SchedulerPanicked { shard: 2 }.into();
        assert_eq!(e.shard(), Some(2));
        let e: ServeError = TileTimedOut { worker: 0, waited_ms: 10, shard: 5 }.into();
        assert_eq!(e.shard(), Some(5));
        let e: ServeError = RequestShed { id: 0, shard: 1, class: 2, open: 8 }.into();
        assert_eq!(e.shard(), Some(1));
        // Pre-placement failures carry no shard.
        assert_eq!(ServeError::from(QueueFull(4)).shard(), None);
        assert_eq!(ServeError::from(Cancelled(1)).shard(), None);
    }
}
