//! Deprecated batch-replay wrappers over the streaming API.
//!
//! `execute` / `run_batch` / `run_batch_mixed` predate streaming
//! admission: they replay a *closed* batch through the engine and block
//! until it finishes. They remain for source compatibility — each is a
//! thin shim over [`MatMulServer::submit_with_policy`] with blocking
//! admission and in-order waits — but new code should submit requests
//! as they arrive ([`MatMulServer::submit`] /
//! [`MatMulServer::submit_with_callback`]) and let the scheduler
//! overlap them.
//!
//! [`MatMulServer::submit`]: crate::coordinator::server::MatMulServer::submit
//! [`MatMulServer::submit_with_callback`]: crate::coordinator::server::MatMulServer::submit_with_callback
//! [`MatMulServer::submit_with_policy`]: crate::coordinator::server::MatMulServer::submit_with_policy

// The wrappers call each other (execute → run_batch → run_batch_mixed);
// those internal calls must not trip the deprecation lint this module
// itself raises.
#![allow(deprecated)]

use crate::config::schema::AdmissionPolicy;
use crate::coordinator::handle::RequestHandle;
use crate::coordinator::server::MatMulServer;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::Result;
use std::time::Instant;

impl MatMulServer {
    /// Execute one fp32 request synchronously (convenience path).
    #[deprecated(
        note = "batch replay is a compatibility shim; use MatMulServer::submit and wait on the handle"
    )]
    pub fn execute(&mut self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let mut out = self.run_batch(vec![(req, a, b)])?;
        Ok(out.pop().unwrap())
    }

    /// Serve a closed fp32 batch through the streaming engine (submit
    /// everything with blocking admission, wait in order). Returns the
    /// outputs in request order. On error the batch's other open
    /// requests are cancelled (see [`MatMulServer::run_batch_mixed`]).
    #[deprecated(
        note = "batch replay is a compatibility shim; use MatMulServer::submit / submit_with_callback"
    )]
    pub fn run_batch(
        &mut self,
        batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
    ) -> Result<Vec<Vec<f32>>> {
        self.run_batch_mixed(
            batch
                .into_iter()
                .map(|(req, a, b)| (req, Operands::F32 { a, b }))
                .collect(),
        )?
        .into_iter()
        .map(MatOutput::into_f32)
        .collect()
    }

    /// Serve a closed mixed-precision batch through the streaming
    /// engine. Returns the outputs in request order.
    ///
    /// On any error — a submission rejected mid-batch or a request
    /// failing — the remaining handles are dropped, which (since PR 3)
    /// **cancels** the batch's other open requests: a failed batch
    /// reclaims its queue/window slots instead of running doomed work
    /// to completion. Those requests land in `stats().cancelled`, not
    /// `requests`.
    #[deprecated(
        note = "batch replay is a compatibility shim; use MatMulServer::submit / submit_with_callback"
    )]
    pub fn run_batch_mixed(
        &mut self,
        batch: Vec<(MatMulRequest, Operands)>,
    ) -> Result<Vec<MatOutput>> {
        let wall0 = Instant::now();
        self.reset_epoch();
        let mut handles = Vec::with_capacity(batch.len());
        for (req, ops) in batch {
            handles.push(self.submit_with_policy(req, ops, AdmissionPolicy::Block)?);
        }
        let outs: Result<Vec<MatOutput>> = handles.into_iter().map(RequestHandle::wait).collect();
        self.add_wall_time(wall0.elapsed().as_secs_f64());
        outs
    }
}
