//! The serving **memory plane**: arena tile pools, the packed-weight
//! cache, and buffer free-lists — everything that lets a long-lived
//! server reach a zero-allocation steady state per tile.
//!
//! MaxEVA's headline numbers come from keeping the AIE array fed; on the
//! host side that means the operand/result buffers around the pipeline
//! must stop costing allocations once traffic is steady. Three layers:
//!
//! * [`TilePool`] — one contiguous allocation per packed matrix
//!   (`Arc<[T]>` + tile stride addressing) instead of one `Vec` per
//!   tile. Packing a `gm×gk` grid is **one** allocation, tile reads are
//!   cache-/prefetch-friendly slices, and a [`TileRef`] (pool + tile
//!   index) is the zero-copy currency tile jobs carry to the device
//!   workers. Since PR 5 extraction can fan out across threads
//!   ([`TilePool::pack_with`], `ServeConfig::pack_workers`) — bit-
//!   identical to the serial pack, so large requests stop serializing
//!   on one core before the pipeline starts. Since PR 8 the fan-out
//!   runs on the scheduler's persistent
//!   [`WorkPool`](crate::coordinator::workpool::WorkPool) by default
//!   ([`TilePool::pack_timed`]), and [`PackCounters`] split the time
//!   spent into the extraction critical path and the fan-out
//!   orchestration overhead ([`PackTiming`]).
//! * [`WeightCache`] — a byte-budgeted LRU of packed **B** (weight)
//!   pools, keyed by [`WeightKey`]: an explicit caller identity
//!   (`MatMulRequest::with_weight_id`) or a content fingerprint
//!   fallback, always qualified by shape and precision. A hit skips B
//!   extraction and packing entirely — for steady weight-reuse serving
//!   (the GotoBLAS2-on-Versal observation, arXiv 2404.15043) that is
//!   the dominant per-request host cost. Budget `0` disables the cache
//!   and reproduces the uncached engine bit-for-bit; a cached pool is
//!   byte-identical to a freshly packed one because
//!   [`TilePool::pack`] is deterministic, so caching never changes
//!   outputs either way. Since PR 10 the cache is also the release-mode
//!   **integrity boundary** of the memory plane: every insert stamps a
//!   64-bit FNV-1a CRC over the packed element bits, hits are
//!   re-verified against the stamp on a sampled cadence
//!   (`ServeConfig::cache_verify_interval`, plus always on the first
//!   hit after a rewarm), and a mismatch **quarantines** the entry —
//!   evicted, key blacklisted for a cooldown
//!   (`ServeConfig::cache_quarantine_ms`), lookup reported as a miss so
//!   the caller transparently re-packs from the source operand. A
//!   poisoned arena therefore costs one repack, never a wrong result.
//! * [`FreeList`] / [`BufferPool`] — per-precision free-lists for the
//!   native-tile-sized working buffers that cycle through the
//!   completion loop (device output tiles, per-block accumulation
//!   buffers). All of a server's tile buffers share one length per
//!   precision (`nm×nn` native), so recycling is a plain stack; the
//!   retained depth is capped ([`FREE_LIST_CAP`]) so cancellation
//!   storms cannot grow it without bound.
//!
//! Counters on all three layers feed
//! [`ServerStats::mem`](crate::coordinator::server::ServerStats) so the
//! e2e bench can attribute the win (cache hit rate, buffers recycled vs
//! allocated).

use crate::arch::precision::Precision;
use crate::coordinator::fault::fnv1a_words as fnv1a64;
use crate::coordinator::tiler::Tiler;
use crate::coordinator::workpool::WorkPool;
use rustc_hash::FxHashMap;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A packed tile-major matrix: every zero-padded `bh×bw` block of a
/// `rows×cols` matrix, stored back to back in **one** contiguous
/// `Arc<[T]>` allocation, blocks ordered row-major over the block grid.
///
/// This replaces the PR 1 `Vec<Vec<T>>` / per-tile `Arc<Vec<T>>`
/// packing: per-request allocations drop from O(tiles) to O(1), and a
/// tile read is a stride-addressed slice into one arena. Cloning a pool
/// (or taking a [`TileRef`]) is an `Arc` bump — submission stays
/// zero-copy.
#[derive(Debug, Clone)]
pub struct TilePool<T> {
    data: Arc<[T]>,
    tile_len: usize,
}

impl<T: Copy + Default> TilePool<T> {
    /// Pack a row-major `rows×cols` matrix into a tile-major pool of
    /// zero-padded `bh×bw` blocks (the packing step of the serving
    /// pipeline, GotoBLAS-style: each block is extracted exactly once
    /// per request). Deterministic: equal inputs yield byte-identical
    /// pools, which is what makes [`WeightCache`] hits exact.
    pub fn pack(src: &[T], rows: usize, cols: usize, bh: usize, bw: usize) -> Self {
        assert_eq!(src.len(), rows * cols, "matrix shape mismatch");
        let gr = rows.div_ceil(bh);
        let gc = cols.div_ceil(bw);
        let tile_len = bh * bw;
        let mut data = vec![T::default(); gr * gc * tile_len];
        for bi in 0..gr {
            for bj in 0..gc {
                let off = (bi * gc + bj) * tile_len;
                Tiler::extract_block_into(
                    &mut data[off..off + tile_len],
                    src,
                    rows,
                    cols,
                    bi,
                    bj,
                    bh,
                    bw,
                );
            }
        }
        TilePool { data: data.into(), tile_len }
    }

    /// [`TilePool::pack`] with the extraction fanned out across up to
    /// `workers` scoped threads (`ServeConfig::pack_workers`): the tile
    /// grid is split into contiguous runs of whole tiles, each thread
    /// fills its disjoint arena slice, and the result is **bit-identical
    /// to the serial pack for every worker count** — every tile is
    /// written by exactly one thread from the same deterministic
    /// extraction, so parallelism is a pure latency knob. `workers <= 1`
    /// (and grids below [`PAR_PACK_MIN_TILES`], where thread spawn would
    /// cost more than the copies) take the serial path, reproducing the
    /// single-threaded engine behavior exactly.
    pub fn pack_with(
        src: &[T],
        rows: usize,
        cols: usize,
        bh: usize,
        bw: usize,
        workers: usize,
    ) -> Self
    where
        T: Send + Sync,
    {
        assert_eq!(src.len(), rows * cols, "matrix shape mismatch");
        let gr = rows.div_ceil(bh);
        let gc = cols.div_ceil(bw);
        let tiles = gr * gc;
        let fanout = pack_fanout(workers, tiles);
        if fanout <= 1 {
            return Self::pack(src, rows, cols, bh, bw);
        }
        let tile_len = bh * bw;
        let mut data = vec![T::default(); tiles * tile_len];
        std::thread::scope(|s| {
            let base = tiles / fanout;
            let extra = tiles % fanout;
            let mut rest = data.as_mut_slice();
            let mut first_tile = 0usize;
            for w in 0..fanout {
                let count = base + usize::from(w < extra);
                let (chunk, tail) = rest.split_at_mut(count * tile_len);
                rest = tail;
                let start = first_tile;
                first_tile += count;
                s.spawn(move || {
                    for (i, dst) in chunk.chunks_mut(tile_len).enumerate() {
                        let t = start + i;
                        Tiler::extract_block_into(dst, src, rows, cols, t / gc, t % gc, bh, bw);
                    }
                });
            }
        });
        TilePool { data: data.into(), tile_len }
    }

    /// [`TilePool::pack_with`] with a wall-time split and an optional
    /// **persistent** worker pool: returns the packed pool plus a
    /// [`PackTiming`] separating the extraction critical path
    /// (`busiest`, the longest single chunk) from the fan-out
    /// orchestration overhead (`spawn_overhead()`). With
    /// `work_pool: Some(_)` the chunks run on the scheduler's
    /// long-lived [`WorkPool`] threads (one chunk stays inline on the
    /// caller); with `None` they run on per-call scoped threads — the
    /// pre-PR 8 behavior, kept as the A/B baseline for
    /// `benches/e2e_serving.rs`. Every mode is **bit-identical** to
    /// the serial [`TilePool::pack`]: the same deterministic
    /// extraction writes every tile exactly once, whichever thread
    /// runs it.
    pub fn pack_timed(
        src: &[T],
        rows: usize,
        cols: usize,
        bh: usize,
        bw: usize,
        workers: usize,
        work_pool: Option<&WorkPool>,
    ) -> (Self, PackTiming)
    where
        T: Send + Sync,
    {
        let t0 = Instant::now();
        assert_eq!(src.len(), rows * cols, "matrix shape mismatch");
        let gr = rows.div_ceil(bh);
        let gc = cols.div_ceil(bw);
        let tiles = gr * gc;
        let fanout = pack_fanout(workers, tiles);
        if fanout <= 1 {
            let pool = Self::pack(src, rows, cols, bh, bw);
            let total = t0.elapsed();
            // Serial: the whole pack *is* the critical path.
            return (pool, PackTiming { total, busiest: total });
        }
        let tile_len = bh * bw;
        let mut data = vec![T::default(); tiles * tile_len];
        let chunk_nanos: Vec<AtomicU64> = (0..fanout).map(|_| AtomicU64::new(0)).collect();
        {
            let base = tiles / fanout;
            let extra = tiles % fanout;
            let mut rest = data.as_mut_slice();
            let mut first_tile = 0usize;
            let mut tasks = Vec::with_capacity(fanout);
            for (w, slot) in chunk_nanos.iter().enumerate() {
                let count = base + usize::from(w < extra);
                let (chunk, tail) = rest.split_at_mut(count * tile_len);
                rest = tail;
                let start = first_tile;
                first_tile += count;
                tasks.push(move || {
                    let c0 = Instant::now();
                    for (i, dst) in chunk.chunks_mut(tile_len).enumerate() {
                        let t = start + i;
                        Tiler::extract_block_into(dst, src, rows, cols, t / gc, t % gc, bh, bw);
                    }
                    slot.store(c0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
            }
            match work_pool {
                Some(pool) => pool.run_scoped(tasks),
                None => {
                    std::thread::scope(|s| {
                        for task in tasks {
                            s.spawn(task);
                        }
                    });
                }
            }
        }
        let total = t0.elapsed();
        let busiest_nanos =
            chunk_nanos.iter().map(|a| a.load(Ordering::Relaxed)).max().unwrap_or(0);
        let busiest = Duration::from_nanos(busiest_nanos).min(total);
        (TilePool { data: data.into(), tile_len }, PackTiming { total, busiest })
    }

    /// A single-tile pool wrapping an already-extracted block (the
    /// synchronous `execute_tile` convenience path and tests).
    pub fn from_tile(tile: Vec<T>) -> Self {
        assert!(!tile.is_empty(), "a tile pool needs a nonzero tile");
        TilePool { tile_len: tile.len(), data: tile.into() }
    }

    /// Inverse of [`TilePool::pack`]: reassemble the row-major
    /// `rows×cols` matrix, dropping the padding.
    pub fn unpack(&self, rows: usize, cols: usize, bh: usize, bw: usize) -> Vec<T> {
        let gr = rows.div_ceil(bh);
        let gc = cols.div_ceil(bw);
        assert_eq!(self.tiles(), gr * gc, "tile count mismatch");
        assert_eq!(self.tile_len, bh * bw, "tile shape mismatch");
        let mut out = vec![T::default(); rows * cols];
        for bi in 0..gr {
            for bj in 0..gc {
                Tiler::write_block(&mut out, rows, cols, bi, bj, bh, bw, self.tile(bi * gc + bj));
            }
        }
        out
    }

    /// Borrow tile `idx` in place (row-major block-grid order).
    pub fn tile(&self, idx: usize) -> &[T] {
        &self.data[idx * self.tile_len..(idx + 1) * self.tile_len]
    }

    /// A shareable handle to tile `idx` (an `Arc` bump, no copy).
    pub fn tile_ref(&self, idx: usize) -> TileRef<T> {
        assert!(idx < self.tiles(), "tile index {idx} out of {}", self.tiles());
        TileRef { pool: self.clone(), tile: idx }
    }

    /// Number of tiles in the pool.
    pub fn tiles(&self) -> usize {
        self.data.len() / self.tile_len
    }

    /// Elements per tile (`bh × bw`).
    pub fn tile_len(&self) -> usize {
        self.tile_len
    }

    /// Resident size of the arena in bytes (the [`WeightCache`] budget
    /// currency).
    pub fn bytes(&self) -> usize {
        std::mem::size_of_val(self.data.as_ref())
    }
}

/// A zero-copy reference to one tile of a [`TilePool`] — what a
/// [`TilePayload`](crate::coordinator::device::TilePayload) carries to
/// the device workers. Holding a `TileRef` keeps the whole arena alive.
#[derive(Debug, Clone)]
pub struct TileRef<T> {
    pool: TilePool<T>,
    tile: usize,
}

impl<T: Copy + Default> TileRef<T> {
    /// Wrap one already-extracted block as a standalone reference.
    pub fn single(tile: Vec<T>) -> Self {
        TilePool::from_tile(tile).tile_ref(0)
    }

    /// The tile's elements, read in place.
    pub fn as_slice(&self) -> &[T] {
        self.pool.tile(self.tile)
    }
}

/// Minimum tile count before [`TilePool::pack_with`] fans extraction
/// out across threads — below this the per-thread spawn cost exceeds
/// the copy work being split.
pub const PAR_PACK_MIN_TILES: usize = 8;

/// Effective fan-out width [`TilePool::pack_with`] uses for a grid of
/// `tiles` tiles when asked for `workers` pack workers (1 = serial).
pub fn pack_fanout(workers: usize, tiles: usize) -> usize {
    if tiles < PAR_PACK_MIN_TILES {
        1
    } else {
        workers.max(1).min(tiles)
    }
}

/// Wall-time split of one [`TilePool::pack_timed`] call.
///
/// `busiest` is the extraction critical path — the longest time any
/// single chunk spent copying tiles (serial packs have exactly one
/// chunk, so there `busiest == total`). Everything else in `total` is
/// fan-out orchestration: building tasks, dispatching them to threads,
/// and waiting for the join — the overhead the persistent [`WorkPool`]
/// exists to shrink, surfaced as `PackStats.pack_spawn_s`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PackTiming {
    /// Wall time of the whole pack call.
    pub total: Duration,
    /// Longest single extraction chunk (the copy critical path).
    pub busiest: Duration,
}

impl PackTiming {
    /// Time spent orchestrating the fan-out rather than copying:
    /// `total − busiest` (saturating — a serial pack reports zero).
    pub fn spawn_overhead(&self) -> Duration {
        self.total.saturating_sub(self.busiest)
    }
}

/// Shared counters of the request-packing stage, published for
/// [`ServerStats::pack`](crate::coordinator::server::ServerStats)
/// snapshots taken from client threads: how many operand matrices were
/// packed into arenas, how many of those packs fanned out across
/// threads, and the wall time the scheduler spent packing — split into
/// the extraction critical path (`nanos`) and the fan-out spawn/join
/// overhead (`spawn_nanos`), the host costs the weight cache,
/// `pack_workers`, and the persistent [`WorkPool`] respectively
/// attack.
#[derive(Debug, Default)]
pub struct PackCounters {
    pub matrices: AtomicU64,
    pub parallel: AtomicU64,
    pub nanos: AtomicU64,
    pub spawn_nanos: AtomicU64,
}

impl PackCounters {
    /// Record one request's packing work: `matrices` arenas built, of
    /// which `parallel` used a multi-thread fan-out, spending `elapsed`
    /// on the extraction critical path and `spawn` on fan-out
    /// orchestration (see [`PackTiming`]).
    pub fn record(
        &self,
        matrices: u64,
        parallel: u64,
        elapsed: std::time::Duration,
        spawn: std::time::Duration,
    ) {
        self.matrices.fetch_add(matrices, Ordering::Relaxed);
        self.parallel.fetch_add(parallel, Ordering::Relaxed);
        self.nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        self.spawn_nanos.fetch_add(spawn.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Maximum buffers a [`FreeList`] retains. All retained buffers are
/// native-tile-sized, so this caps the recycling layer's resident
/// memory at `cap × nm×nn × sizeof(T)` per precision — and bounds the
/// free-list under cancellation storms (probed by
/// `tests/memory_plane.rs`).
pub const FREE_LIST_CAP: usize = 256;

/// A lock-guarded stack of reusable `Vec<T>` buffers with recycle /
/// fresh-allocation counters. Device workers [`take`](FreeList::take)
/// output buffers, the scheduler [`put`](FreeList::put)s them back
/// after reduction — in steady state the loop closes and per-tile heap
/// allocations stop.
#[derive(Debug)]
pub struct FreeList<T> {
    stack: Mutex<Vec<Vec<T>>>,
    cap: usize,
    recycled: AtomicU64,
    allocated: AtomicU64,
}

impl<T: Copy + Default> FreeList<T> {
    pub fn new(cap: usize) -> Self {
        FreeList {
            stack: Mutex::new(Vec::new()),
            cap,
            recycled: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (recycled buffers keep stale data — callers overwrite or
    /// `fill(default)` as needed; `matmul_ref_*_into` and the
    /// accumulation-buffer path both do).
    pub fn take(&self, len: usize) -> Vec<T> {
        let popped = self.stack.lock().unwrap().pop();
        match popped {
            Some(mut v) => {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                v.resize(len, T::default());
                v
            }
            None => {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                vec![T::default(); len]
            }
        }
    }

    /// Return a buffer for reuse. Dropped (truly freed) once the list
    /// holds `cap` buffers, so the list length is bounded no matter how
    /// many stragglers a cancellation storm washes back.
    pub fn put(&self, v: Vec<T>) {
        if v.capacity() == 0 {
            return;
        }
        let mut stack = self.stack.lock().unwrap();
        if stack.len() < self.cap {
            stack.push(v);
        }
    }

    /// Buffers currently parked in the list.
    pub fn free(&self) -> usize {
        self.stack.lock().unwrap().len()
    }

    /// `take` calls served by recycling.
    pub fn recycled(&self) -> u64 {
        self.recycled.load(Ordering::Relaxed)
    }

    /// `take` calls that fell through to a fresh heap allocation.
    pub fn allocated(&self) -> u64 {
        self.allocated.load(Ordering::Relaxed)
    }
}

/// The per-precision free-lists one server's completion loop threads
/// its tile buffers through (fp32 tiles are `Vec<f32>`, int8-path tiles
/// accumulate `Vec<i32>`). Shared `Arc` between the device workers
/// (take) and the scheduler (put).
#[derive(Debug)]
pub struct BufferPool {
    pub fp32: FreeList<f32>,
    pub int8: FreeList<i32>,
}

impl BufferPool {
    pub fn new(cap: usize) -> Self {
        BufferPool { fp32: FreeList::new(cap), int8: FreeList::new(cap) }
    }

    /// Total `take` calls served by recycling, both precisions.
    pub fn recycled(&self) -> u64 {
        self.fp32.recycled() + self.int8.recycled()
    }

    /// Total `take` calls that allocated fresh, both precisions.
    pub fn allocated(&self) -> u64 {
        self.fp32.allocated() + self.int8.allocated()
    }

    /// Buffers currently parked, both precisions.
    pub fn free(&self) -> usize {
        self.fp32.free() + self.int8.free()
    }
}

/// How a cached weight is identified (always further qualified by shape
/// and precision in [`WeightKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightIdent {
    /// Caller-assigned identity
    /// ([`MatMulRequest::with_weight_id`](crate::workloads::MatMulRequest::with_weight_id)):
    /// the caller asserts equal ids ⇒ equal bytes. Preferred — no
    /// per-request hash of the operand.
    Id(u64),
    /// Content fingerprint fallback (128-bit FNV-1a over the element
    /// bits and length) for callers that don't tag weights. Widened
    /// from 64 bits in PR 5 — at 128 bits an accidental collision is
    /// out of reach even for very high-cardinality anonymous weight
    /// sets, and debug builds additionally verify every fingerprint
    /// hit byte-for-byte ([`debug_assert_pool_matches`]). Tag weights
    /// explicitly when serving adversarial inputs.
    Fingerprint(u128),
}

/// Cache key of one packed weight pool: identity × shape × precision.
/// Shape and precision are part of the key because the packed layout
/// depends on them — the same bytes packed under a different tile
/// geometry are a different pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightKey {
    pub ident: WeightIdent,
    pub k: u64,
    pub n: u64,
    pub precision: Precision,
}

/// A cached pool, typed by precision (the key's `precision` field keeps
/// lookups type-correct; [`PoolElem`] bridges the generic packing code).
#[derive(Debug, Clone)]
pub enum CachedPool {
    F32(TilePool<f32>),
    I32(TilePool<i32>),
}

impl CachedPool {
    /// Resident size of the wrapped arena in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            CachedPool::F32(p) => p.bytes(),
            CachedPool::I32(p) => p.bytes(),
        }
    }

    /// 64-bit FNV-1a over the arena's element bits — the integrity
    /// stamp [`WeightCache`] records at insert and re-derives on
    /// sampled hits. Same word hash as the device plane's
    /// [`output_crc`](crate::coordinator::device::output_crc), so both
    /// planes share one corruption-detection primitive.
    pub fn crc64(&self) -> u64 {
        match self {
            CachedPool::F32(p) => fnv1a64(p.data.iter().map(|v| v.to_bits())),
            CachedPool::I32(p) => fnv1a64(p.data.iter().map(|&v| v as u32)),
        }
    }
}

/// One entry of a respawn rewarm hand-off: key, packed pool, and the
/// pool's **original** insert-time CRC stamp. Carrying the stamp (not
/// re-deriving it at rewarm) is what makes the forced first-hit verify
/// after a respawn meaningful: corruption picked up during the crash /
/// export / transfer window still mismatches the pre-crash stamp.
pub type RewarmEntry = (WeightKey, CachedPool, u64);

/// Element types the weight cache can store — the dispatch point
/// between the scheduler's precision-generic packing code and the
/// type-erased cache entries.
pub trait PoolElem: Copy + Default + PartialEq + std::fmt::Debug {
    /// The serving precision this element type carries.
    fn precision() -> Precision;
    /// Content fingerprint over the element bits (FNV-1a 128).
    fn fingerprint(data: &[Self]) -> u128;
    /// The element's 32-bit word image — the unit both integrity
    /// hashes (fingerprint and CRC stamp) consume.
    fn to_word(self) -> u32;
    fn wrap(pool: TilePool<Self>) -> CachedPool;
    fn peek(cached: &CachedPool) -> Option<&TilePool<Self>>;
}

const FNV128_OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
const FNV128_PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

fn fnv1a_words(len: usize, words: impl Iterator<Item = u32>) -> u128 {
    let mut h = FNV128_OFFSET;
    for b in (len as u64).to_le_bytes() {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u128;
            h = h.wrapping_mul(FNV128_PRIME);
        }
    }
    h
}

/// Debug-build collision guard for fingerprint-keyed weight-cache hits:
/// re-extract the raw operand serially and compare the cached arena
/// byte-for-byte. A mismatch means two distinct weight matrices
/// produced the same [`WeightKey`] — a fingerprint collision (or a
/// corrupted cache entry) — which would silently serve wrong results in
/// a release build; here it panics so tests catch it. Called by the
/// scheduler under `cfg(debug_assertions)` only: release serving keeps
/// the cache hit O(1).
pub fn debug_assert_pool_matches<T: PoolElem>(
    cached: &TilePool<T>,
    raw: &[T],
    rows: usize,
    cols: usize,
    bh: usize,
    bw: usize,
) {
    let fresh = TilePool::pack(raw, rows, cols, bh, bw);
    assert!(
        cached.data == fresh.data && cached.tile_len == fresh.tile_len,
        "weight-cache fingerprint hit does not match the raw operand \
         ({rows}x{cols} in {bh}x{bw} tiles): fingerprint collision"
    );
}

impl PoolElem for f32 {
    fn precision() -> Precision {
        Precision::Fp32
    }
    fn fingerprint(data: &[f32]) -> u128 {
        fnv1a_words(data.len(), data.iter().map(|v| v.to_bits()))
    }
    fn to_word(self) -> u32 {
        self.to_bits()
    }
    fn wrap(pool: TilePool<f32>) -> CachedPool {
        CachedPool::F32(pool)
    }
    fn peek(cached: &CachedPool) -> Option<&TilePool<f32>> {
        match cached {
            CachedPool::F32(p) => Some(p),
            CachedPool::I32(_) => None,
        }
    }
}

impl PoolElem for i32 {
    fn precision() -> Precision {
        Precision::Int8
    }
    fn fingerprint(data: &[i32]) -> u128 {
        fnv1a_words(data.len(), data.iter().map(|&v| v as u32))
    }
    fn to_word(self) -> u32 {
        self as u32
    }
    fn wrap(pool: TilePool<i32>) -> CachedPool {
        CachedPool::I32(pool)
    }
    fn peek(cached: &CachedPool) -> Option<&TilePool<i32>> {
        match cached {
            CachedPool::I32(p) => Some(p),
            CachedPool::F32(_) => None,
        }
    }
}

/// Shared hit/miss/evict and residency gauges of one [`WeightCache`],
/// published for [`ServerStats`](crate::coordinator::server::ServerStats)
/// snapshots taken from client threads.
#[derive(Debug, Default)]
pub struct WeightCacheCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
    /// Current resident bytes (gauge).
    pub bytes: AtomicU64,
    /// Current entry count (gauge).
    pub entries: AtomicU64,
    /// Hits whose pool was CRC-verified against its insert stamp
    /// (sampled cadence plus forced first-hit-after-rewarm verifies).
    pub verifications: AtomicU64,
    /// Entries evicted **and quarantined** because a verify caught a
    /// CRC mismatch — the memory-plane silent-corruption detector
    /// firing. Not counted under `evictions` (those are budget
    /// pressure).
    pub poisoned_evictions: AtomicU64,
    /// Entries re-seeded into a respawned shard's cache from the dead
    /// scheduler's rescue export.
    pub rewarmed: AtomicU64,
}

struct CacheEntry {
    pool: CachedPool,
    bytes: usize,
    /// Recency stamp; also this entry's key in the LRU index.
    tick: u64,
    /// FNV-1a CRC over the pool's element bits, stamped at insert —
    /// what sampled verify-on-hit re-derives and compares.
    crc: u64,
    /// Lifetime hit count of this entry — the heat ranking
    /// [`WeightCache::hottest`] uses to pick rewarm candidates.
    hits: u64,
    /// Force a CRC verify on the next hit regardless of the sampling
    /// cadence — set on rewarmed entries so corruption picked up
    /// across a crash/export window is caught before first use.
    verify_on_next_hit: bool,
}

/// Byte-budgeted LRU of packed weight pools (see the module docs).
/// Owned by the scheduler thread — no locking on the lookup path; only
/// the counters are shared.
pub struct WeightCache {
    /// Byte budget; `0` disables the cache entirely (today's per-request
    /// packing behavior, bit-for-bit *and* allocation-for-allocation).
    budget: usize,
    bytes: usize,
    tick: u64,
    entries: FxHashMap<WeightKey, CacheEntry>,
    /// tick → key, ordered oldest-first: O(log n) touch and eviction.
    lru: BTreeMap<u64, WeightKey>,
    counters: Arc<WeightCacheCounters>,
    /// Verify every Nth hit against the insert CRC stamp; `0` (the
    /// default) samples nothing — bit-for-bit *and* work-for-work the
    /// pre-integrity cache.
    verify_interval: u64,
    /// Monotone count of hits, the sampling clock for `verify_interval`.
    hit_serial: u64,
    /// How long a poisoned key stays blacklisted after quarantine.
    quarantine_cooldown: Duration,
    /// Poisoned keys → blacklist expiry. Inserts (and rewarms) of a
    /// quarantined key are refused until the cooldown lapses, so a
    /// corruption source upstream of the cache cannot immediately
    /// re-poison the same slot.
    quarantine: FxHashMap<WeightKey, Instant>,
}

impl WeightCache {
    pub fn new(budget_bytes: usize, counters: Arc<WeightCacheCounters>) -> Self {
        WeightCache {
            budget: budget_bytes,
            bytes: 0,
            tick: 0,
            entries: FxHashMap::default(),
            lru: BTreeMap::new(),
            counters,
            verify_interval: 0,
            hit_serial: 0,
            quarantine_cooldown: Duration::from_millis(5_000),
            quarantine: FxHashMap::default(),
        }
    }

    /// Set the integrity knobs (`ServeConfig::cache_verify_interval`,
    /// `ServeConfig::cache_quarantine_ms`). Separate from `new` so the
    /// constructor keeps its pre-PR 10 shape; the defaults (interval
    /// `0`) perform no verification at all.
    pub fn configure_integrity(&mut self, verify_interval: u64, quarantine_ms: u64) {
        self.verify_interval = verify_interval;
        self.quarantine_cooldown = Duration::from_millis(quarantine_ms);
    }

    /// Whether `key` is currently blacklisted; lazily drops lapsed
    /// quarantine records.
    fn quarantined(&mut self, key: &WeightKey) -> bool {
        match self.quarantine.get(key) {
            Some(&until) if Instant::now() < until => true,
            Some(_) => {
                self.quarantine.remove(key);
                false
            }
            None => false,
        }
    }

    /// Whether caching is on (`weight_cache_bytes > 0`).
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// Resident bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn publish_gauges(&self) {
        self.counters.bytes.store(self.bytes as u64, Ordering::Relaxed);
        self.counters.entries.store(self.entries.len() as u64, Ordering::Relaxed);
    }

    /// Look up a packed pool; counts a hit (touching recency) or a miss.
    /// Always `None` when disabled — without counting, so budget `0`
    /// reports all-zero cache stats.
    ///
    /// With integrity sampling on ([`WeightCache::configure_integrity`])
    /// every `verify_interval`-th hit — plus the first hit on any
    /// rewarmed entry — re-derives the pool's CRC and compares it to
    /// the insert stamp. A mismatch is the poisoned-arena path: the
    /// entry is evicted, its key quarantined for the cooldown, and the
    /// lookup reports a **miss**, so the caller falls through to its
    /// existing repack arm and the request completes correctly with no
    /// client-visible error.
    pub fn get<T: PoolElem>(&mut self, key: &WeightKey) -> Option<TilePool<T>> {
        if !self.enabled() {
            return None;
        }
        let Some(e) = self.entries.get_mut(key) else {
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        self.hit_serial += 1;
        e.hits += 1;
        if e.verify_on_next_hit
            || (self.verify_interval > 0 && self.hit_serial % self.verify_interval == 0)
        {
            self.counters.verifications.fetch_add(1, Ordering::Relaxed);
            if e.pool.crc64() != e.crc {
                let (tick, bytes) = (e.tick, e.bytes);
                self.entries.remove(key);
                self.lru.remove(&tick);
                self.bytes -= bytes;
                self.quarantine.insert(*key, Instant::now() + self.quarantine_cooldown);
                self.counters.poisoned_evictions.fetch_add(1, Ordering::Relaxed);
                self.counters.misses.fetch_add(1, Ordering::Relaxed);
                self.publish_gauges();
                return None;
            }
            e.verify_on_next_hit = false;
        }
        self.lru.remove(&e.tick);
        self.tick += 1;
        e.tick = self.tick;
        self.lru.insert(self.tick, *key);
        let got = T::peek(&e.pool).cloned();
        debug_assert!(got.is_some(), "weight key precision must match its pool type");
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
        got
    }

    /// Insert a freshly packed pool, evicting least-recently-used
    /// entries until it fits. A pool larger than the whole budget is
    /// never cached (it would evict everything for a weight that cannot
    /// stay resident anyway), and a key still under quarantine is
    /// refused until its cooldown lapses. Every accepted insert stamps
    /// the pool's CRC for later verify-on-hit.
    pub fn insert<T: PoolElem>(&mut self, key: WeightKey, pool: &TilePool<T>) {
        if !self.enabled() || self.quarantined(&key) {
            return;
        }
        let bytes = pool.bytes();
        if bytes > self.budget {
            return;
        }
        let crc = fnv1a64(pool.data.iter().map(|v| v.to_word()));
        self.evict_to_fit(&key, bytes);
        self.tick += 1;
        self.entries.insert(
            key,
            CacheEntry {
                pool: T::wrap(pool.clone()),
                bytes,
                tick: self.tick,
                crc,
                hits: 0,
                verify_on_next_hit: false,
            },
        );
        self.lru.insert(self.tick, key);
        self.bytes += bytes;
        self.publish_gauges();
    }

    /// Make room for `bytes` at `key`: drop any old entry under the
    /// same key (replace-in-place), then evict LRU victims until the
    /// new entry fits the budget.
    fn evict_to_fit(&mut self, key: &WeightKey, bytes: usize) {
        if let Some(old) = self.entries.remove(key) {
            self.lru.remove(&old.tick);
            self.bytes -= old.bytes;
        }
        while self.bytes + bytes > self.budget {
            let Some((&tick, &victim)) = self.lru.iter().next() else { break };
            self.lru.remove(&tick);
            if let Some(e) = self.entries.remove(&victim) {
                self.bytes -= e.bytes;
                self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// The `k` hottest resident entries (by lifetime hit count, ties to
    /// the most recently used), with their original insert CRC stamps —
    /// the rescue export a dying scheduler hands the respawn supervisor
    /// so the replacement shard's cache starts warm. Deterministic
    /// order: hit counts then unique recency ticks.
    pub fn hottest(&self, k: usize) -> Vec<RewarmEntry> {
        if k == 0 || self.entries.is_empty() {
            return Vec::new();
        }
        let mut ranked: Vec<(&WeightKey, &CacheEntry)> = self.entries.iter().collect();
        ranked.sort_by(|a, b| b.1.hits.cmp(&a.1.hits).then(b.1.tick.cmp(&a.1.tick)));
        ranked.into_iter().take(k).map(|(key, e)| (*key, e.pool.clone(), e.crc)).collect()
    }

    /// Seed one rescued entry into this (freshly respawned) cache,
    /// keeping the **pre-crash** CRC stamp and arming
    /// `verify_on_next_hit`, so the first hit fully verifies the pool
    /// survived the crash/export window intact. Subject to the same
    /// budget, oversize, and quarantine rules as [`WeightCache::insert`].
    /// Returns whether the entry was admitted.
    pub fn rewarm(&mut self, key: WeightKey, pool: CachedPool, crc: u64) -> bool {
        if !self.enabled() || self.quarantined(&key) {
            return false;
        }
        let bytes = pool.bytes();
        if bytes > self.budget {
            return false;
        }
        self.evict_to_fit(&key, bytes);
        self.tick += 1;
        self.entries.insert(
            key,
            CacheEntry { pool, bytes, tick: self.tick, crc, hits: 0, verify_on_next_hit: true },
        );
        self.lru.insert(self.tick, key);
        self.bytes += bytes;
        self.counters.rewarmed.fetch_add(1, Ordering::Relaxed);
        self.publish_gauges();
        true
    }

    /// Chaos hook behind `FaultKind::CacheCorrupt`: deterministically
    /// flip one stored word (element 0 of the oldest-resident entry's
    /// arena) **without** touching its insert stamp — exactly the
    /// silent at-rest corruption sampled verify-on-hit exists to catch.
    /// The flip rebuilds the arena allocation, so `TileRef`s already in
    /// flight keep the clean bytes; only subsequent cache hits observe
    /// the poison. Returns `false` when the cache holds nothing to
    /// corrupt.
    pub fn chaos_corrupt(&mut self) -> bool {
        let Some((_, &key)) = self.lru.iter().next() else {
            return false;
        };
        let e = self.entries.get_mut(&key).expect("lru index maps to a resident entry");
        e.pool = match &e.pool {
            CachedPool::F32(p) => {
                let mut data: Vec<f32> = p.data.to_vec();
                data[0] = f32::from_bits(data[0].to_bits() ^ 1);
                CachedPool::F32(TilePool { data: data.into(), tile_len: p.tile_len })
            }
            CachedPool::I32(p) => {
                let mut data: Vec<i32> = p.data.to_vec();
                data[0] ^= 1;
                CachedPool::I32(TilePool { data: data.into(), tile_len: p.tile_len })
            }
        };
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    #[test]
    fn pool_matches_per_tile_extraction() {
        // The arena must hold exactly what extract_block would produce
        // on demand — the zero-copy pipeline depends on it.
        let mut rng = XorShift64::new(11);
        for _ in 0..20 {
            let rows = rng.gen_range(1, 40) as usize;
            let cols = rng.gen_range(1, 40) as usize;
            let bh = rng.gen_range(1, 9) as usize;
            let bw = rng.gen_range(1, 9) as usize;
            let src: Vec<f32> = (0..rows * cols)
                .map(|_| rng.gen_range_f64(-1.0, 1.0) as f32)
                .collect();
            let pool = TilePool::pack(&src, rows, cols, bh, bw);
            let gc = cols.div_ceil(bw);
            assert_eq!(pool.tiles(), rows.div_ceil(bh) * gc);
            assert_eq!(pool.tile_len(), bh * bw);
            for bi in 0..rows.div_ceil(bh) {
                for bj in 0..gc {
                    let want = Tiler::extract_block(&src, rows, cols, bi, bj, bh, bw);
                    assert_eq!(pool.tile(bi * gc + bj), &want[..], "block ({bi},{bj})");
                    assert_eq!(pool.tile_ref(bi * gc + bj).as_slice(), &want[..]);
                }
            }
            // Round-trip, padding dropped.
            assert_eq!(pool.unpack(rows, cols, bh, bw), src, "{rows}x{cols} in {bh}x{bw}");
        }
    }

    #[test]
    fn pool_pack_exact_fit() {
        // 4×6 matrix, 2×3 blocks: divides exactly, no padding.
        let src: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 4, 6, 2, 3);
        assert_eq!(pool.tiles(), 4);
        assert_eq!(pool.tile(0), &[0.0, 1.0, 2.0, 6.0, 7.0, 8.0]);
        assert_eq!(pool.bytes(), 24 * 4);
        assert_eq!(pool.unpack(4, 6, 2, 3), src);
    }

    #[test]
    fn single_tile_pool_and_ref() {
        let r = TileRef::single(vec![1i32, 2, 3]);
        assert_eq!(r.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn free_list_recycles_and_counts() {
        let fl: FreeList<f32> = FreeList::new(4);
        let a = fl.take(8);
        assert_eq!(a.len(), 8);
        assert_eq!((fl.allocated(), fl.recycled()), (1, 0));
        fl.put(a);
        assert_eq!(fl.free(), 1);
        // Recycled take resizes to the requested length; contents are
        // unspecified by contract.
        let b = fl.take(6);
        assert_eq!(b.len(), 6);
        assert_eq!((fl.allocated(), fl.recycled()), (1, 1));
        fl.put(b);
        let c = fl.take(10);
        assert_eq!(c.len(), 10);
        assert_eq!(fl.recycled(), 2);
    }

    #[test]
    fn free_list_is_capacity_bounded() {
        let fl: FreeList<i32> = FreeList::new(2);
        for _ in 0..10 {
            fl.put(vec![0; 4]);
        }
        assert_eq!(fl.free(), 2, "puts beyond cap are dropped");
        // Zero-capacity vecs are not worth parking.
        fl.put(Vec::new());
        assert_eq!(fl.free(), 2);
    }

    fn key_id(id: u64, k: u64, n: u64) -> WeightKey {
        WeightKey { ident: WeightIdent::Id(id), k, n, precision: Precision::Fp32 }
    }

    #[test]
    fn weight_cache_hit_miss_and_identity() {
        let counters = Arc::new(WeightCacheCounters::default());
        let mut c = WeightCache::new(1 << 20, Arc::clone(&counters));
        let src: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        let k = key_id(7, 8, 8);
        assert!(c.get::<f32>(&k).is_none());
        c.insert(k, &pool);
        let hit = c.get::<f32>(&k).expect("inserted key must hit");
        // A cached pool is byte-identical to the freshly packed one.
        for t in 0..pool.tiles() {
            assert_eq!(hit.tile(t), pool.tile(t));
        }
        assert_eq!(counters.hits.load(Ordering::Relaxed), 1);
        assert_eq!(counters.misses.load(Ordering::Relaxed), 1);
        assert_eq!(counters.entries.load(Ordering::Relaxed), 1);
        assert_eq!(counters.bytes.load(Ordering::Relaxed), pool.bytes() as u64);
    }

    #[test]
    fn weight_cache_lru_eviction_respects_budget() {
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = vec![1.0; 64];
        let pool = TilePool::pack(&src, 8, 8, 4, 4); // 256 bytes
        // Budget for exactly two pools.
        let mut c = WeightCache::new(2 * pool.bytes(), Arc::clone(&counters));
        c.insert(key_id(1, 8, 8), &pool);
        c.insert(key_id(2, 8, 8), &pool);
        assert_eq!(c.len(), 2);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get::<f32>(&key_id(1, 8, 8)).is_some());
        c.insert(key_id(3, 8, 8), &pool);
        assert_eq!(c.len(), 2);
        assert!(c.bytes() <= 2 * pool.bytes(), "budget is a hard cap");
        assert!(c.get::<f32>(&key_id(1, 8, 8)).is_some(), "recently used survives");
        assert!(c.get::<f32>(&key_id(3, 8, 8)).is_some());
        assert!(c.get::<f32>(&key_id(2, 8, 8)).is_none(), "LRU entry evicted");
        assert_eq!(counters.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn weight_cache_oversize_and_disabled() {
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = vec![1.0; 64];
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        // A pool larger than the whole budget is never cached.
        let mut c = WeightCache::new(pool.bytes() - 1, Arc::clone(&counters));
        c.insert(key_id(1, 8, 8), &pool);
        assert!(c.is_empty());
        // Budget 0 = off: lookups are silent (no miss counting).
        let mut off = WeightCache::new(0, Arc::clone(&counters));
        assert!(!off.enabled());
        off.insert(key_id(1, 8, 8), &pool);
        assert!(off.get::<f32>(&key_id(1, 8, 8)).is_none());
        assert_eq!(counters.misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn weight_cache_reinsert_replaces_in_place() {
        let counters = Arc::new(WeightCacheCounters::default());
        let small = TilePool::pack(&[1.0f32; 16], 4, 4, 4, 4);
        let big = TilePool::pack(&[2.0f32; 64], 8, 8, 4, 4);
        let mut c = WeightCache::new(1 << 20, counters);
        c.insert(key_id(1, 4, 4), &small);
        c.insert(key_id(1, 4, 4), &big);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), big.bytes(), "replacement accounts bytes exactly once");
    }

    #[test]
    fn pack_with_bit_identical_across_worker_counts() {
        // Parallel packing is a pure latency knob: every worker count
        // yields the same bytes as the serial pack, fringe shapes
        // included.
        let mut rng = XorShift64::new(0xACC);
        for _ in 0..12 {
            let rows = rng.gen_range(1, 60) as usize;
            let cols = rng.gen_range(1, 60) as usize;
            let bh = rng.gen_range(1, 9) as usize;
            let bw = rng.gen_range(1, 9) as usize;
            let src: Vec<f32> = (0..rows * cols)
                .map(|_| rng.gen_range_f64(-1.0, 1.0) as f32)
                .collect();
            let serial = TilePool::pack(&src, rows, cols, bh, bw);
            for workers in [1usize, 2, 3, 4, 7] {
                let par = TilePool::pack_with(&src, rows, cols, bh, bw, workers);
                assert_eq!(par.tiles(), serial.tiles());
                for t in 0..serial.tiles() {
                    assert_eq!(
                        par.tile(t),
                        serial.tile(t),
                        "{rows}x{cols} in {bh}x{bw}, workers {workers}, tile {t}"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_timed_bit_identical_across_modes() {
        // The timed path must produce the same bytes as the serial
        // pack in every mode: serial (fanout 1), legacy scoped
        // threads, and the persistent work pool.
        let work_pool = WorkPool::new(3, 0);
        let mut rng = XorShift64::new(0x7137ED);
        for _ in 0..8 {
            let rows = rng.gen_range(1, 60) as usize;
            let cols = rng.gen_range(1, 60) as usize;
            let bh = rng.gen_range(1, 9) as usize;
            let bw = rng.gen_range(1, 9) as usize;
            let src: Vec<f32> = (0..rows * cols)
                .map(|_| rng.gen_range_f64(-1.0, 1.0) as f32)
                .collect();
            let serial = TilePool::pack(&src, rows, cols, bh, bw);
            let modes: [(usize, Option<&WorkPool>); 3] =
                [(1, None), (4, None), (4, Some(&work_pool))];
            for (workers, pool) in modes {
                let (timed, timing) = TilePool::pack_timed(&src, rows, cols, bh, bw, workers, pool);
                assert_eq!(timed.tiles(), serial.tiles());
                for t in 0..serial.tiles() {
                    assert_eq!(
                        timed.tile(t),
                        serial.tile(t),
                        "{rows}x{cols} in {bh}x{bw}, workers {workers}, tile {t}"
                    );
                }
                assert!(timing.total >= timing.busiest, "busiest is clamped to total");
                if pack_fanout(workers, serial.tiles()) <= 1 {
                    assert_eq!(
                        timing.spawn_overhead(),
                        Duration::ZERO,
                        "serial packs report zero fan-out overhead"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_fanout_thresholds() {
        // Tiny grids stay serial (spawn cost > copy work); otherwise
        // the fan-out is capped by both knob and tile count.
        assert_eq!(pack_fanout(4, PAR_PACK_MIN_TILES - 1), 1);
        assert_eq!(pack_fanout(4, PAR_PACK_MIN_TILES), 4);
        assert_eq!(pack_fanout(0, 100), 1);
        assert_eq!(pack_fanout(1, 100), 1);
        assert_eq!(pack_fanout(64, 9), 9);
    }

    #[test]
    fn pack_counters_accumulate() {
        let c = PackCounters::default();
        c.record(2, 1, std::time::Duration::from_micros(5), std::time::Duration::from_micros(2));
        c.record(1, 0, std::time::Duration::from_micros(3), std::time::Duration::ZERO);
        assert_eq!(c.matrices.load(Ordering::Relaxed), 3);
        assert_eq!(c.parallel.load(Ordering::Relaxed), 1);
        assert_eq!(c.nanos.load(Ordering::Relaxed), 8_000);
        assert_eq!(c.spawn_nanos.load(Ordering::Relaxed), 2_000);
    }

    #[test]
    fn fingerprint_is_128_bit_and_collision_guard_fires() {
        // Regression for the PR 4 ROADMAP note: the anonymous-weight
        // fingerprint is now 128-bit (the value genuinely exceeds the
        // old u64 range for ordinary inputs), and debug builds verify
        // fingerprint hits byte-for-byte, so a manufactured collision —
        // two different matrices behind one cache key — panics instead
        // of silently serving the wrong weight.
        let a: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let fp: u128 = <f32 as PoolElem>::fingerprint(&a);
        assert!(fp > u64::MAX as u128, "128-bit offset basis must survive mixing");
        let pool = TilePool::pack(&a, 8, 8, 4, 4);
        // Matching contents pass the guard…
        debug_assert_pool_matches(&pool, &a, 8, 8, 4, 4);
        // …a forged collision does not.
        let mut forged = a.clone();
        forged[13] = -7.0;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            debug_assert_pool_matches(&pool, &forged, 8, 8, 4, 4)
        }));
        assert!(r.is_err(), "collision guard must panic on mismatched contents");
    }

    #[test]
    fn verify_on_hit_detects_corruption_and_quarantines() {
        // The release-mode integrity path end to end: a silently
        // corrupted arena is caught by sampled verify-on-hit, the
        // entry is evicted + quarantined (re-insert refused), the
        // lookup reports a miss so callers repack — and once the
        // cooldown lapses the key is admitted again.
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        let k = key_id(9, 8, 8);
        let mut c = WeightCache::new(1 << 20, Arc::clone(&counters));
        c.configure_integrity(1, 60_000); // verify every hit, long cooldown
        c.insert(k, &pool);
        // Clean entry: verify runs and passes, hit counts normally.
        assert!(c.get::<f32>(&k).is_some());
        assert_eq!(counters.verifications.load(Ordering::Relaxed), 1);
        assert_eq!(counters.poisoned_evictions.load(Ordering::Relaxed), 0);
        // Corrupt at rest (stamp untouched) → next hit detects.
        assert!(c.chaos_corrupt());
        assert!(c.get::<f32>(&k).is_none(), "poisoned entry must read as a miss");
        assert_eq!(counters.poisoned_evictions.load(Ordering::Relaxed), 1);
        assert!(c.is_empty(), "poisoned entry is evicted");
        // Quarantine: the same key is refused while the cooldown runs…
        c.insert(k, &pool);
        assert!(c.is_empty(), "quarantined key must not be re-admitted");
        // …but an unrelated key is unaffected.
        c.insert(key_id(10, 8, 8), &pool);
        assert_eq!(c.len(), 1);
        // Cooldown 0 = already lapsed: the key readmits immediately.
        let mut fast = WeightCache::new(1 << 20, Arc::clone(&counters));
        fast.configure_integrity(1, 0);
        fast.insert(k, &pool);
        assert!(fast.chaos_corrupt());
        assert!(fast.get::<f32>(&k).is_none());
        fast.insert(k, &pool);
        assert!(fast.get::<f32>(&k).is_some(), "lapsed quarantine readmits the key");
    }

    #[test]
    fn verify_interval_samples_every_nth_hit() {
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        let k = key_id(1, 8, 8);
        let mut c = WeightCache::new(1 << 20, Arc::clone(&counters));
        c.configure_integrity(3, 1_000);
        c.insert(k, &pool);
        for _ in 0..9 {
            assert!(c.get::<f32>(&k).is_some());
        }
        // Hits 3, 6, 9 verified.
        assert_eq!(counters.verifications.load(Ordering::Relaxed), 3);
        assert_eq!(counters.hits.load(Ordering::Relaxed), 9);
        // Interval 0 (the default) never verifies.
        let quiet = Arc::new(WeightCacheCounters::default());
        let mut off = WeightCache::new(1 << 20, Arc::clone(&quiet));
        off.insert(k, &pool);
        for _ in 0..5 {
            assert!(off.get::<f32>(&k).is_some());
        }
        assert_eq!(quiet.verifications.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn hottest_ranks_by_hits_and_rewarm_forces_first_hit_verify() {
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        let mut c = WeightCache::new(1 << 20, Arc::clone(&counters));
        for id in 1..=3 {
            c.insert(key_id(id, 8, 8), &pool);
        }
        // Heat: id 2 twice, id 3 once, id 1 never.
        assert!(c.get::<f32>(&key_id(2, 8, 8)).is_some());
        assert!(c.get::<f32>(&key_id(2, 8, 8)).is_some());
        assert!(c.get::<f32>(&key_id(3, 8, 8)).is_some());
        let hot = c.hottest(2);
        assert_eq!(hot.len(), 2);
        assert_eq!(hot[0].0, key_id(2, 8, 8), "hottest first");
        assert_eq!(hot[1].0, key_id(3, 8, 8));
        assert_eq!(c.hottest(0).len(), 0);
        assert!(c.hottest(10).len() == 3, "k beyond len returns all entries");

        // Rewarm into a fresh cache: admitted entries count, the
        // pre-crash stamp rides along, and the first hit verifies even
        // with sampling off (interval 0).
        let rc = Arc::new(WeightCacheCounters::default());
        let mut fresh = WeightCache::new(1 << 20, Arc::clone(&rc));
        for (key, pool, crc) in c.hottest(2) {
            assert!(fresh.rewarm(key, pool, crc));
        }
        assert_eq!(rc.rewarmed.load(Ordering::Relaxed), 2);
        assert!(fresh.get::<f32>(&key_id(2, 8, 8)).is_some());
        assert_eq!(
            rc.verifications.load(Ordering::Relaxed),
            1,
            "rewarmed entry verifies on first hit"
        );
        assert!(fresh.get::<f32>(&key_id(2, 8, 8)).is_some());
        assert_eq!(rc.verifications.load(Ordering::Relaxed), 1, "…and only the first");

        // A rewarmed pool that no longer matches its pre-crash stamp
        // (corruption in the crash/export window) dies on first hit.
        let mut torn = WeightCache::new(1 << 20, Arc::clone(&rc));
        let (key, pool_ok, crc_ok) = c.hottest(1).remove(0);
        assert!(torn.rewarm(key, pool_ok, crc_ok ^ 1));
        assert!(torn.get::<f32>(&key).is_none(), "stamp mismatch caught before first use");
        assert_eq!(rc.poisoned_evictions.load(Ordering::Relaxed), 1);

        // Rewarm respects the disabled cache and the byte budget.
        let (key, pool2, crc2) = c.hottest(1).remove(0);
        let mut off = WeightCache::new(0, Arc::clone(&rc));
        assert!(!off.rewarm(key, pool2.clone(), crc2));
        let mut tiny = WeightCache::new(8, Arc::clone(&rc));
        assert!(!tiny.rewarm(key, pool2, crc2));
    }

    #[test]
    fn chaos_corrupt_targets_oldest_and_spares_inflight_refs() {
        let counters = Arc::new(WeightCacheCounters::default());
        let src: Vec<f32> = (0..64).map(|x| x as f32).collect();
        let pool = TilePool::pack(&src, 8, 8, 4, 4);
        let mut c = WeightCache::new(1 << 20, Arc::clone(&counters));
        assert!(!c.chaos_corrupt(), "empty cache has nothing to corrupt");
        c.insert(key_id(1, 8, 8), &pool);
        c.insert(key_id(2, 8, 8), &pool);
        // Hand out a hit before corrupting: in-flight pools keep the
        // clean bytes (the flip rebuilds the arena allocation).
        c.configure_integrity(1, 1_000);
        let inflight = c.get::<f32>(&key_id(1, 8, 8)).unwrap();
        // After the touch, id 2 is the oldest resident — the victim.
        assert!(c.chaos_corrupt());
        assert_eq!(inflight.tile(0), pool.tile(0), "in-flight ref unaffected");
        assert!(c.get::<f32>(&key_id(1, 8, 8)).is_some(), "untouched entry still verifies");
        assert!(c.get::<f32>(&key_id(2, 8, 8)).is_none(), "victim caught on next hit");
        assert_eq!(counters.poisoned_evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fingerprints_separate_contents_and_lengths() {
        let a: Vec<f32> = (0..32).map(|x| x as f32).collect();
        let mut b = a.clone();
        assert_eq!(<f32 as PoolElem>::fingerprint(&a), <f32 as PoolElem>::fingerprint(&b));
        b[7] += 1.0;
        assert_ne!(<f32 as PoolElem>::fingerprint(&a), <f32 as PoolElem>::fingerprint(&b));
        assert_ne!(
            <f32 as PoolElem>::fingerprint(&a),
            <f32 as PoolElem>::fingerprint(&a[..31])
        );
        let ai: Vec<i32> = (0..32).collect();
        let mut bi = ai.clone();
        bi[0] = -1;
        assert_ne!(<i32 as PoolElem>::fingerprint(&ai), <i32 as PoolElem>::fingerprint(&bi));
    }
}
