//! Pluggable scheduling policies: who gets the next tile slot.
//!
//! The scheduler thread owns the in-flight window; every time a slot
//! frees it asks its [`SchedPolicy`] which open flight issues the next
//! tile. The policy sees one [`FlightMeta`] per schedulable flight
//! (priority class, precision, per-tile cost) and nothing else — all
//! packing/reduction mechanics stay in the scheduler, so policies are
//! tiny, deterministic, and unit-testable without a server.
//!
//! Three implementations ship:
//!
//! * [`Fifo`] — window-level round-robin across flights, the exact
//!   PR 1/2 behavior (and the default): bit-identity and
//!   depth-1-equivalence properties are preserved unchanged.
//! * [`WeightedFair`] — deficit round-robin over priority classes.
//!   Each tile charges its flight's class the flight's **per-precision
//!   cost** ([`TileCosts`], derived from the design's tile geometry —
//!   on the flagship designs an int8 tile is ~4× an fp32 tile), so one
//!   heavy int8 stream cannot starve fp32 traffic of device time.
//! * [`Priority`] — strict priority classes (lower class index wins)
//!   with aging so low classes cannot starve forever.

pub mod fifo;
pub mod priority;
pub mod weighted_fair;

pub use fifo::Fifo;
pub use priority::Priority;
pub use weighted_fair::WeightedFair;

use crate::arch::precision::Precision;
use crate::config::schema::{PolicyKind, ServeConfig};

/// Relative cost of one native tile per serving precision. Since PR 4
/// the primary derivation is the **measured device period** of each
/// precision's placed design ([`TileCosts::from_periods`]): charging
/// cycles-per-tile makes the fair policies split device *time* even
/// when MACs/cycle differ across precisions (int8 runs 128 MACs/cyc to
/// fp32's 8, so geometric MACs overstate int8's time by up to 16×).
/// The geometric MAC derivation remains as the fallback for degenerate
/// simulated periods — on the paper's flagship designs it pins the
/// familiar 4× ratio (int8 32×128×32 vs fp32 32×32×32 kernels).
#[derive(Debug, Clone, Copy)]
pub struct TileCosts {
    pub fp32: u64,
    pub int8: u64,
}

impl TileCosts {
    /// Geometric fallback: costs from the two native tile sizes
    /// `(nm, nk, nn)`, in MACs per native tile.
    pub fn from_native(native_f32: (u64, u64, u64), native_int8: (u64, u64, u64)) -> Self {
        let macs = |(m, k, n): (u64, u64, u64)| (m * k * n).max(1);
        TileCosts { fp32: macs(native_f32), int8: macs(native_int8) }
    }

    /// Costs from the measured per-precision iteration periods (device
    /// cycles per native tile, from the simulator) — the derivation the
    /// server uses. Falls back to [`TileCosts::from_native`] when either
    /// period is degenerate (non-finite or under one cycle, e.g. an
    /// unsimulatable custom design), so a policy always has usable
    /// positive costs.
    pub fn from_periods(
        period_f32: f64,
        period_int8: f64,
        native_f32: (u64, u64, u64),
        native_int8: (u64, u64, u64),
    ) -> Self {
        let healthy = |p: f64| p.is_finite() && p >= 1.0;
        if healthy(period_f32) && healthy(period_int8) {
            TileCosts {
                fp32: period_f32.round() as u64,
                int8: period_int8.round() as u64,
            }
        } else {
            Self::from_native(native_f32, native_int8)
        }
    }

    /// Cost of one tile in `precision`.
    pub fn cost(&self, precision: Precision) -> u64 {
        match precision {
            Precision::Int8 => self.int8,
            _ => self.fp32,
        }
    }

    /// A DRR quantum that always affords at least one tile of either
    /// precision per visit.
    pub fn quantum(&self) -> u64 {
        self.fp32.max(self.int8)
    }
}

/// What a policy knows about one schedulable flight.
#[derive(Debug, Clone, Copy)]
pub struct FlightMeta {
    /// Scheduler-internal flight id (admission order).
    pub fid: u64,
    /// Priority class the request was submitted with (already clamped
    /// to the configured class count).
    pub class: usize,
    pub precision: Precision,
    /// Cost charged per issued tile ([`TileCosts::cost`]).
    pub tile_cost: u64,
}

/// A scheduling policy: the single decision point between "a window
/// slot is free" and "flight X issues its next tile".
///
/// Contract (enforced by the scheduler loop):
/// * [`SchedPolicy::admit`] is called once per schedulable flight;
/// * [`SchedPolicy::pick`] returns a previously admitted flight with
///   unissued tiles, or `None` when nothing is schedulable;
/// * after every pick the scheduler issues exactly one tile and calls
///   [`SchedPolicy::tile_issued`] with `more = false` once the flight's
///   last tile went out;
/// * [`SchedPolicy::remove`] purges a flight wherever it is queued
///   (retire, failure, cancellation).
pub trait SchedPolicy: Send {
    /// Policy name for diagnostics ("fifo", "weighted_fair", …).
    fn name(&self) -> &'static str;

    /// Make a flight schedulable.
    fn admit(&mut self, meta: FlightMeta);

    /// Choose the flight that issues the next tile.
    fn pick(&mut self) -> Option<u64>;

    /// One tile of `fid` was issued; `more` says whether the flight
    /// still has unissued tiles and must remain schedulable.
    fn tile_issued(&mut self, fid: u64, more: bool);

    /// Drop a flight from all queues (no-op if absent).
    fn remove(&mut self, fid: u64);
}

/// Normalized policy configuration: the `ServeConfig` knobs plus the
/// per-precision tile costs the device pool derived from the design.
#[derive(Debug, Clone)]
pub struct PolicyParams {
    pub kind: PolicyKind,
    /// DRR weight per class index (never empty, weights never zero).
    pub class_weights: Vec<u64>,
    /// Picks a flight may wait before [`Priority`] promotes it one
    /// class (`0` disables aging).
    pub aging_threshold: u64,
    pub costs: TileCosts,
}

impl PolicyParams {
    pub fn from_config(cfg: &ServeConfig, costs: TileCosts) -> Self {
        let mut class_weights: Vec<u64> =
            cfg.class_weights.iter().map(|&w| w.max(1)).collect();
        if class_weights.is_empty() {
            class_weights.push(1);
        }
        PolicyParams {
            kind: cfg.policy,
            class_weights,
            aging_threshold: cfg.aging_threshold,
            costs,
        }
    }

    pub fn n_classes(&self) -> usize {
        self.class_weights.len()
    }

    /// Map a request's class byte onto the configured class range.
    pub fn clamp_class(&self, class: u8) -> usize {
        (class as usize).min(self.n_classes() - 1)
    }
}

/// Build the configured policy.
pub fn build(params: &PolicyParams) -> Box<dyn SchedPolicy> {
    match params.kind {
        PolicyKind::Fifo => Box::new(Fifo::new()),
        PolicyKind::WeightedFair => {
            Box::new(WeightedFair::new(&params.class_weights, params.costs.quantum()))
        }
        PolicyKind::Priority => {
            Box::new(Priority::new(params.n_classes(), params.aging_threshold))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_costs_from_flagship_geometry() {
        // fp32 416×128×192 vs int8 416×512×192 → exactly 4×.
        let c = TileCosts::from_native((416, 128, 192), (416, 512, 192));
        assert_eq!(c.int8, 4 * c.fp32);
        assert_eq!(c.quantum(), c.int8);
        assert_eq!(c.cost(Precision::Int8), c.int8);
        assert_eq!(c.cost(Precision::Fp32), c.fp32);
    }

    #[test]
    fn tile_costs_from_periods_and_degenerate_fallback() {
        let nf = (416, 128, 192);
        let ni = (416, 512, 192);
        // Healthy periods: charge cycles per tile, rounded.
        let c = TileCosts::from_periods(4700.4, 9400.6, nf, ni);
        assert_eq!((c.fp32, c.int8), (4700, 9401));
        assert_eq!(c.quantum(), 9401);
        // Degenerate periods (zero, sub-cycle, NaN, infinite) fall back
        // to the geometric MAC derivation — never a zero cost.
        for (pf, pi) in [(0.0, 9400.0), (4700.0, 0.5), (f64::NAN, 9400.0), (4700.0, f64::INFINITY)]
        {
            let c = TileCosts::from_periods(pf, pi, nf, ni);
            let geo = TileCosts::from_native(nf, ni);
            assert_eq!((c.fp32, c.int8), (geo.fp32, geo.int8), "periods {pf}/{pi}");
        }
    }

    #[test]
    fn params_normalize_degenerate_weights() {
        let mut cfg = ServeConfig::new(crate::config::schema::DesignConfig::flagship(
            Precision::Fp32,
        ));
        cfg.class_weights = vec![];
        let p = PolicyParams::from_config(&cfg, TileCosts { fp32: 1, int8: 4 });
        assert_eq!(p.class_weights, vec![1]);
        assert_eq!(p.clamp_class(200), 0);

        cfg.class_weights = vec![0, 3];
        let p = PolicyParams::from_config(&cfg, TileCosts { fp32: 1, int8: 4 });
        assert_eq!(p.class_weights, vec![1, 3]);
        assert_eq!(p.clamp_class(0), 0);
        assert_eq!(p.clamp_class(9), 1);
    }

    #[test]
    fn build_selects_kind() {
        let mut cfg = ServeConfig::new(crate::config::schema::DesignConfig::flagship(
            Precision::Fp32,
        ));
        let costs = TileCosts { fp32: 1, int8: 4 };
        for (kind, name) in [
            (PolicyKind::Fifo, "fifo"),
            (PolicyKind::WeightedFair, "weighted_fair"),
            (PolicyKind::Priority, "priority"),
        ] {
            cfg.policy = kind;
            assert_eq!(build(&PolicyParams::from_config(&cfg, costs)).name(), name);
        }
    }
}
