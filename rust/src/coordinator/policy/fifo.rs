//! [`Fifo`]: window-level round-robin across flights — the original
//! PR 1/2 scheduling, preserved bit-for-bit.

use super::{FlightMeta, SchedPolicy};
use std::collections::VecDeque;

/// Round-robin over ready flights: each pick issues one tile and the
/// flight rotates to the back. Admission order seeds the rotation, so
/// with one flight open this is plain FIFO tile order — the behavior
/// every pipeline-equivalence and bit-identity property test pins down.
#[derive(Debug, Default)]
pub struct Fifo {
    ready: VecDeque<u64>,
}

impl Fifo {
    pub fn new() -> Self {
        Fifo::default()
    }
}

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit(&mut self, meta: FlightMeta) {
        self.ready.push_back(meta.fid);
    }

    fn pick(&mut self) -> Option<u64> {
        self.ready.pop_front()
    }

    fn tile_issued(&mut self, fid: u64, more: bool) {
        if more {
            self.ready.push_back(fid);
        }
    }

    fn remove(&mut self, fid: u64) {
        self.ready.retain(|&x| x != fid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    fn meta(fid: u64) -> FlightMeta {
        FlightMeta { fid, class: 0, precision: Precision::Fp32, tile_cost: 1 }
    }

    #[test]
    fn round_robin_rotation() {
        let mut p = Fifo::new();
        for fid in [1, 2, 3] {
            p.admit(meta(fid));
        }
        let mut picks = Vec::new();
        for _ in 0..6 {
            let fid = p.pick().unwrap();
            picks.push(fid);
            p.tile_issued(fid, true);
        }
        assert_eq!(picks, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn finished_flights_leave_the_rotation() {
        let mut p = Fifo::new();
        p.admit(meta(1));
        p.admit(meta(2));
        let a = p.pick().unwrap();
        p.tile_issued(a, false); // last tile of flight 1
        assert_eq!(p.pick(), Some(2));
        p.tile_issued(2, true);
        assert_eq!(p.pick(), Some(2));
        p.tile_issued(2, false);
        assert_eq!(p.pick(), None);
    }

    #[test]
    fn remove_purges_queued_flight() {
        let mut p = Fifo::new();
        for fid in [1, 2, 3] {
            p.admit(meta(fid));
        }
        p.remove(2);
        assert_eq!(p.pick(), Some(1));
        p.tile_issued(1, true);
        assert_eq!(p.pick(), Some(3));
        p.tile_issued(3, true);
        assert_eq!(p.pick(), Some(1));
    }
}
