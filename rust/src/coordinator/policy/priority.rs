//! [`Priority`]: strict priority classes with aging.
//!
//! Lower class index = higher priority; the highest nonempty class
//! always issues the next tile (round-robin among its flights). Strict
//! priority alone starves low classes under sustained high-priority
//! load, so each waiting flight ages: once it has waited more than
//! `aging_threshold` scheduling decisions at the head of its class it
//! is promoted one class (repeatedly, up to the top), bounding worst-
//! case service delay. `aging_threshold = 0` disables aging (pure
//! strict priority).

use super::{FlightMeta, SchedPolicy};
use rustc_hash::FxHashMap;
use std::collections::VecDeque;

/// Strict classes with head-of-line aging.
pub struct Priority {
    /// `levels[0]` is the highest priority; entries are
    /// `(fid, enqueue_tick)`.
    levels: Vec<VecDeque<(u64, u64)>>,
    /// fid → current level (tracks promotions).
    level_of: FxHashMap<u64, usize>,
    aging_threshold: u64,
    /// Monotone pick counter — the aging clock.
    tick: u64,
}

impl Priority {
    pub fn new(n_classes: usize, aging_threshold: u64) -> Self {
        Priority {
            levels: (0..n_classes.max(1)).map(|_| VecDeque::new()).collect(),
            level_of: FxHashMap::default(),
            aging_threshold,
            tick: 0,
        }
    }

    /// Promote overdue head-of-line flights one level. O(levels) per
    /// pick: only queue heads are inspected, which is where the oldest
    /// entry of every level sits.
    fn age(&mut self) {
        for level in 1..self.levels.len() {
            if let Some(&(fid, enq)) = self.levels[level].front() {
                if self.tick.saturating_sub(enq) >= self.aging_threshold {
                    self.levels[level].pop_front();
                    self.levels[level - 1].push_back((fid, self.tick));
                    self.level_of.insert(fid, level - 1);
                }
            }
        }
    }
}

impl SchedPolicy for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn admit(&mut self, meta: FlightMeta) {
        let level = meta.class.min(self.levels.len() - 1);
        self.level_of.insert(meta.fid, level);
        self.levels[level].push_back((meta.fid, self.tick));
    }

    fn pick(&mut self) -> Option<u64> {
        self.tick += 1;
        if self.aging_threshold > 0 {
            self.age();
        }
        for level in &mut self.levels {
            if let Some((fid, _)) = level.pop_front() {
                return Some(fid);
            }
        }
        None
    }

    fn tile_issued(&mut self, fid: u64, more: bool) {
        if more {
            let level = self.level_of[&fid];
            self.levels[level].push_back((fid, self.tick));
        }
    }

    fn remove(&mut self, fid: u64) {
        if let Some(level) = self.level_of.remove(&fid) {
            self.levels[level].retain(|&(x, _)| x != fid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    fn meta(fid: u64, class: usize) -> FlightMeta {
        FlightMeta { fid, class, precision: Precision::Fp32, tile_cost: 1 }
    }

    #[test]
    fn strict_priority_without_aging() {
        let mut p = Priority::new(3, 0);
        p.admit(meta(30, 2));
        p.admit(meta(10, 0));
        p.admit(meta(20, 1));
        // Class 0 monopolizes while it has tiles.
        for _ in 0..5 {
            assert_eq!(p.pick(), Some(10));
            p.tile_issued(10, true);
        }
        // Retire class 0 → class 1 is next, then class 2.
        assert_eq!(p.pick(), Some(10));
        p.tile_issued(10, false);
        assert_eq!(p.pick(), Some(20));
        p.tile_issued(20, false);
        assert_eq!(p.pick(), Some(30));
        p.tile_issued(30, false);
        assert_eq!(p.pick(), None);
    }

    #[test]
    fn round_robin_within_a_class() {
        let mut p = Priority::new(2, 0);
        p.admit(meta(1, 0));
        p.admit(meta(2, 0));
        let mut picks = Vec::new();
        for _ in 0..4 {
            let fid = p.pick().unwrap();
            picks.push(fid);
            p.tile_issued(fid, true);
        }
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn aging_promotes_starved_flights() {
        // Sustained class-0 load; the class-1 flight must still be
        // served within threshold + a few picks.
        let mut p = Priority::new(2, 3);
        p.admit(meta(1, 0));
        p.admit(meta(9, 1));
        let mut served_at = None;
        for i in 0..10 {
            let fid = p.pick().unwrap();
            p.tile_issued(fid, true);
            if fid == 9 {
                served_at = Some(i);
                break;
            }
        }
        let at = served_at.expect("aged flight must be served");
        assert!(at <= 5, "served only at pick {at}");
        assert_eq!(p.level_of[&9], 0, "flight was promoted to the top class");
    }

    #[test]
    fn remove_purges_and_unknown_is_noop() {
        let mut p = Priority::new(2, 0);
        p.admit(meta(1, 0));
        p.admit(meta(2, 0));
        p.remove(1);
        p.remove(777);
        assert_eq!(p.pick(), Some(2));
        p.tile_issued(2, false);
        assert_eq!(p.pick(), None);
    }
}
