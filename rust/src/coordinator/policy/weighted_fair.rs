//! [`WeightedFair`]: deficit round-robin (DRR) over priority classes
//! with per-precision tile costs.
//!
//! Each class keeps a FIFO rotation of its flights and a *deficit*
//! counter. A class at the front of the rotation may issue tiles while
//! its deficit covers the head flight's per-tile cost; when it cannot
//! afford the next tile it banks one quantum (`weight × base quantum`)
//! and rotates to the back. Because tiles are charged their precision's
//! measured device period ([`TileCosts::from_periods`](super::TileCosts::from_periods);
//! geometric MACs as the degenerate-period fallback), classes split
//! *device time*, not tile counts — a saturating int8 stream gets its
//! weighted share and no more, so fp32 latency stays bounded.

use super::{FlightMeta, SchedPolicy};
use rustc_hash::FxHashMap;
use std::collections::VecDeque;

struct ClassQ {
    weight: u64,
    deficit: u64,
    queue: VecDeque<u64>,
    /// Whether this class index is present in `rotation` (invariant).
    in_rotation: bool,
}

/// Deficit round-robin over priority classes; round-robin over flights
/// within a class.
pub struct WeightedFair {
    classes: Vec<ClassQ>,
    rotation: VecDeque<usize>,
    /// fid → (class, per-tile cost).
    meta: FxHashMap<u64, (usize, u64)>,
    quantum: u64,
}

impl WeightedFair {
    /// `class_weights[i]` is class `i`'s DRR weight (zero-weight classes
    /// are bumped to 1); `quantum` is the base replenishment, normally
    /// [`TileCosts::quantum`](super::TileCosts::quantum) so one visit
    /// always affords at least one tile.
    ///
    /// The empty/zero-weight normalization mirrors
    /// [`PolicyParams::from_config`](super::PolicyParams::from_config):
    /// `build()` passes pre-normalized weights, but this constructor is
    /// public API and must not underflow on direct use — keep the two
    /// rules in sync.
    pub fn new(class_weights: &[u64], quantum: u64) -> Self {
        let weights: Vec<u64> = if class_weights.is_empty() {
            vec![1]
        } else {
            class_weights.iter().map(|&w| w.max(1)).collect()
        };
        WeightedFair {
            classes: weights
                .into_iter()
                .map(|weight| ClassQ {
                    weight,
                    deficit: 0,
                    queue: VecDeque::new(),
                    in_rotation: false,
                })
                .collect(),
            rotation: VecDeque::new(),
            meta: FxHashMap::default(),
            quantum: quantum.max(1),
        }
    }

    fn enqueue(&mut self, class: usize, fid: u64) {
        let cq = &mut self.classes[class];
        cq.queue.push_back(fid);
        if !cq.in_rotation {
            cq.in_rotation = true;
            self.rotation.push_back(class);
        }
    }
}

impl SchedPolicy for WeightedFair {
    fn name(&self) -> &'static str {
        "weighted_fair"
    }

    fn admit(&mut self, meta: FlightMeta) {
        let class = meta.class.min(self.classes.len() - 1);
        self.meta.insert(meta.fid, (class, meta.tile_cost.max(1)));
        self.enqueue(class, meta.fid);
    }

    fn pick(&mut self) -> Option<u64> {
        // Terminates: every unaffordable front visit banks ≥ quantum ≥
        // any tile cost, so a nonempty class issues within two visits.
        loop {
            let &class = self.rotation.front()?;
            let cq = &mut self.classes[class];
            let Some(&fid) = cq.queue.front() else {
                // Idle classes leave the rotation and forfeit their
                // bank — deficits never accumulate while unbacklogged.
                cq.deficit = 0;
                cq.in_rotation = false;
                self.rotation.pop_front();
                continue;
            };
            let cost = self.meta[&fid].1;
            if cq.deficit >= cost {
                cq.deficit -= cost;
                cq.queue.pop_front();
                return Some(fid);
            }
            cq.deficit += cq.weight * self.quantum;
            self.rotation.pop_front();
            self.rotation.push_back(class);
        }
    }

    fn tile_issued(&mut self, fid: u64, more: bool) {
        if more {
            let class = self.meta[&fid].0;
            self.enqueue(class, fid);
        }
    }

    fn remove(&mut self, fid: u64) {
        if let Some((class, _)) = self.meta.remove(&fid) {
            self.classes[class].queue.retain(|&x| x != fid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    fn meta(fid: u64, class: usize, cost: u64) -> FlightMeta {
        let precision = if cost > 1 { Precision::Int8 } else { Precision::Fp32 };
        FlightMeta { fid, class, precision, tile_cost: cost }
    }

    /// Drive `picks` scheduling decisions with every flight always
    /// having more tiles; returns per-fid tile counts.
    fn drive(p: &mut WeightedFair, picks: usize) -> FxHashMap<u64, usize> {
        let mut counts = FxHashMap::default();
        for _ in 0..picks {
            let fid = p.pick().expect("backlogged policy must always pick");
            *counts.entry(fid).or_insert(0) += 1;
            p.tile_issued(fid, true);
        }
        counts
    }

    #[test]
    fn equal_weights_split_cost_not_tiles() {
        // Class 0: one fp32 flight (cost 1). Class 1: one int8 flight
        // (cost 4). Equal weights → equal cost share → fp32 issues 4
        // tiles per int8 tile.
        let mut p = WeightedFair::new(&[1, 1], 4);
        p.admit(meta(10, 0, 1));
        p.admit(meta(20, 1, 4));
        let counts = drive(&mut p, 500);
        assert_eq!(counts[&10], 400);
        assert_eq!(counts[&20], 100);
    }

    #[test]
    fn weights_scale_the_share() {
        // Same costs, class 0 weighted 3× → 3× the cost share.
        let mut p = WeightedFair::new(&[3, 1], 2);
        p.admit(meta(1, 0, 2));
        p.admit(meta(2, 1, 2));
        let counts = drive(&mut p, 400);
        assert_eq!(counts[&1], 300);
        assert_eq!(counts[&2], 100);
    }

    #[test]
    fn heavy_stream_cannot_starve_light_class() {
        // Six saturating int8 flights against one fp32 flight: between
        // any two consecutive fp32 tiles at most one int8 *burst* of
        // quantum/cost tiles fits — bounded service gap, no starvation.
        let mut p = WeightedFair::new(&[1, 1], 4);
        p.admit(meta(1, 0, 1));
        for fid in 10..16 {
            p.admit(meta(fid, 1, 4));
        }
        let mut gap = 0usize;
        let mut max_gap = 0usize;
        for _ in 0..600 {
            let fid = p.pick().unwrap();
            if fid == 1 {
                max_gap = max_gap.max(gap);
                gap = 0;
            } else {
                gap += 1;
            }
            p.tile_issued(fid, true);
        }
        assert!(max_gap <= 2, "fp32 service gap {max_gap} tiles");
    }

    #[test]
    fn flights_within_a_class_round_robin() {
        let mut p = WeightedFair::new(&[1], 1);
        for fid in [1, 2, 3] {
            p.admit(meta(fid, 0, 1));
        }
        let mut picks = Vec::new();
        for _ in 0..6 {
            let fid = p.pick().unwrap();
            picks.push(fid);
            p.tile_issued(fid, true);
        }
        assert_eq!(picks, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn out_of_range_class_clamps_and_remove_purges() {
        let mut p = WeightedFair::new(&[1, 1], 1);
        p.admit(meta(7, 99, 1)); // clamps to class 1
        p.admit(meta(8, 1, 1));
        p.remove(7);
        let counts = drive(&mut p, 4);
        assert_eq!(counts.get(&7), None);
        assert_eq!(counts[&8], 4);
        // Removing an unknown fid is a no-op.
        p.remove(12345);
    }

    #[test]
    fn drains_to_none_and_recovers() {
        let mut p = WeightedFair::new(&[1, 1], 4);
        p.admit(meta(1, 0, 1));
        let fid = p.pick().unwrap();
        p.tile_issued(fid, false); // last tile
        p.remove(fid);
        assert_eq!(p.pick(), None);
        // A later admission reactivates the class cleanly.
        p.admit(meta(2, 0, 1));
        assert_eq!(p.pick(), Some(2));
    }
}
