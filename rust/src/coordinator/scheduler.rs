//! The scheduler thread: a single-threaded state machine owning the
//! device pool, the open flights, the in-flight window — and, since
//! PR 3, a pluggable [`SchedPolicy`] deciding which flight issues the
//! next tile.
//!
//! # The pipeline
//!
//! 1. **Arena packing (zero-copy)** — on first schedule each request's
//!    A and B are packed once into contiguous tile-major arenas
//!    ([`TilePool::pack`]): one allocation per matrix, tiles addressed
//!    by stride; a tile job borrows its two blocks as [`TileRef`]s
//!    (`Arc` bumps). The **B** pool first consults the packed-weight
//!    cache ([`WeightCache`], `ServeConfig::weight_cache_bytes`): a hit
//!    skips B extraction and packing entirely — the dominant
//!    per-request host cost under steady weight reuse. Budget `0`
//!    disables the cache (the pre-PR 4 behavior, bit-for-bit).
//! 2. **Windowed submission** — up to `pipeline_depth` tagged jobs are
//!    kept in flight on one completion channel, overlapping host
//!    pack/reduce with device execution. `pipeline_depth = 1` reproduces
//!    the synchronous engine exactly.
//! 3. **Policy-ordered scheduling** — each flight walks its tiles
//!    k-innermost per `(im, inn)` output block; *which* flight issues
//!    the next tile is the policy's call ([`Fifo`] round-robin by
//!    default, bit-identical to the pre-policy engine).
//! 4. **Buffer recycling** — device output tiles and per-block
//!    accumulation buffers flow through the per-precision free-lists
//!    ([`crate::coordinator::pool::BufferPool`]) threaded around the
//!    completion loop (including the cancellation/straggler paths), so
//!    a long-lived server reaches a zero-allocation steady state per
//!    tile.
//!
//! [`TileRef`]: crate::coordinator::pool::TileRef
//!
//! **Determinism:** completions may arrive out of order, but partials
//! are applied to each output block strictly in ascending `ik` order
//! (late partials park in a per-block reorder map), so outputs are
//! bit-identical for every `pipeline_depth`/`workers`/policy
//! combination and admission interleaving — f32 by ordered summation,
//! i32 trivially (wrapping integer addition is associative).
//!
//! [`Fifo`]: crate::coordinator::policy::Fifo

use crate::arch::precision::Precision;
use crate::config::schema::PolicyKind;
use crate::coordinator::admission::{Admitted, Gate, GateCloser};
use crate::coordinator::device::{DeviceHandle, TileDone, TileJob, TileOutput, TilePayload};
use crate::coordinator::handle::{Cancelled, Reply};
use crate::coordinator::policy::{self, FlightMeta, PolicyParams, SchedPolicy};
use crate::coordinator::pool::{
    pack_fanout, BufferPool, FreeList, PackCounters, PoolElem, TilePool, WeightCache,
    WeightIdent, WeightKey,
};
use crate::coordinator::stats::{Completion, StatsAgg, WindowOcc};
use crate::coordinator::tiler::Tiler;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::anyhow;
use rustc_hash::FxHashMap;
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Scheduler-thread events: admissions, tile completions (forwarded
/// from the device pool), cancellations and control messages share one
/// channel, so the scheduler is a single ordered state machine.
pub(crate) enum Event {
    Admit(Box<Admitted>),
    Done(TileDone),
    /// Cancel the request submitted with this admission token.
    Cancel(u64),
    SetDepth(usize),
    SetPolicy(PolicyKind),
    ResetEpoch,
    Drain,
}

/// State shared between the scheduler thread and client-side snapshots.
pub(crate) struct Shared {
    pub(crate) stats: Mutex<StatsAgg>,
    /// Cumulative window occupancy over the server's lifetime.
    pub(crate) window: Mutex<WindowOcc>,
    /// Occupancy since the last epoch reset (A/B attribution).
    pub(crate) last_window: Mutex<WindowOcc>,
    /// Wall time spent inside `run_batch` calls.
    pub(crate) wall_time_s: Mutex<f64>,
}

/// Element type the reduction machinery is generic over: f32 sums, the
/// int8 path accumulates i32 with wrapping adds (both orderings are
/// fixed by the ascending-`ik` rule; wrapping keeps i32 bit-exact even
/// on overflow).
trait Elem: Copy + Default + Send + Sync + 'static {
    fn acc(&mut self, other: Self);
}

impl Elem for f32 {
    fn acc(&mut self, other: Self) {
        *self += other;
    }
}

impl Elem for i32 {
    fn acc(&mut self, other: Self) {
        *self = self.wrapping_add(other);
    }
}

/// One precision's operand pools and output matrix. Packed pools are
/// contiguous arenas ([`TilePool`]): one allocation per matrix, tiles
/// addressed by stride — A indexed `[im·gk + ik]`, B `[ik·gn + inn]`.
struct Pools<T> {
    /// Raw row-major operands, held until this request's first tile is
    /// scheduled: packing then happens *inside* the pipeline, overlapping
    /// the tiles of earlier requests already executing on the workers.
    raw: Option<(Vec<T>, Vec<T>)>,
    packed: Option<(TilePool<T>, TilePool<T>)>,
    c: Vec<T>,
}

impl<T: Elem + PoolElem> Pools<T> {
    fn fresh(a: Vec<T>, b: Vec<T>, out_len: usize) -> Self {
        Pools { raw: Some((a, b)), packed: None, c: vec![T::default(); out_len] }
    }

    /// First schedule of this request: pack its operands into the
    /// tile-major arenas now — one extract pass per block and one
    /// allocation per matrix, total, overlapping whatever is already in
    /// flight, with extraction fanned out across `pack_workers` threads
    /// for large grids ([`TilePool::pack_with`] — bit-identical to the
    /// serial pack for every worker count). The B (weight) pool goes
    /// through the packed-weight cache: a hit skips extraction and
    /// packing entirely, and since packing is deterministic the cached
    /// pool is byte-identical to what packing would have produced.
    /// `counters` accumulate the packing wall time for
    /// `ServerStats::pack`.
    fn pack(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        t: Tiler,
        weight_id: Option<u64>,
        cache: &mut WeightCache,
        pack_workers: usize,
        counters: &PackCounters,
    ) {
        if let Some((a, b)) = self.raw.take() {
            let mut built = 0u64;
            let mut parallel = 0u64;
            let mut spent = Duration::ZERO;
            // Times each arena build alone: fingerprint hashing, cache
            // lookups and the debug collision guard below never enter
            // `pack_time_s`.
            let mut timed_pack = |src: &[T], rows: usize, cols: usize, bh: usize, bw: usize| {
                let t0 = Instant::now();
                let pool = TilePool::pack_with(src, rows, cols, bh, bw, pack_workers);
                spent += t0.elapsed();
                built += 1;
                parallel += u64::from(pack_fanout(pack_workers, pool.tiles()) > 1);
                pool
            };
            let a_pool = timed_pack(&a, m, k, t.nm, t.nk);
            let b_pool = if cache.enabled() {
                let ident = match weight_id {
                    Some(id) => WeightIdent::Id(id),
                    None => WeightIdent::Fingerprint(T::fingerprint(&b)),
                };
                let key =
                    WeightKey { ident, k: k as u64, n: n as u64, precision: T::precision() };
                match cache.get::<T>(&key) {
                    Some(pool) => {
                        // Debug-build collision guard: an anonymous
                        // (fingerprint-keyed) hit must byte-match the
                        // raw operand it claims to replace.
                        #[cfg(debug_assertions)]
                        if matches!(key.ident, WeightIdent::Fingerprint(_)) {
                            let guard = crate::coordinator::pool::debug_assert_pool_matches;
                            guard(&pool, &b, k, n, t.nk, t.nn);
                        }
                        pool
                    }
                    None => {
                        let pool = timed_pack(&b, k, n, t.nk, t.nn);
                        cache.insert(key, &pool);
                        pool
                    }
                }
            } else {
                timed_pack(&b, k, n, t.nk, t.nn)
            };
            counters.record(built, parallel, spent);
            self.packed = Some((a_pool, b_pool));
        }
    }
}

/// Typed flight data — the only precision-specific part of a flight.
enum FlightData {
    F32(Pools<f32>),
    I32(Pools<i32>),
}

/// One open request's state in the scheduler.
struct Flight {
    req: MatMulRequest,
    /// Admission token — the cancellation address of this flight.
    token: u64,
    /// Priority class, clamped to the configured class count.
    class: usize,
    /// Block grid `(gm, gk, gn)` in this request's precision geometry.
    grid: (usize, usize, usize),
    /// This request's precision tiler (native tile sizes are
    /// per-precision).
    tiler: Tiler,
    data: FlightData,
    /// Cursor into the k-innermost tile walk.
    next_tile: usize,
    total_tiles: usize,
    /// Tiles whose partials have been reduced (in order).
    done_tiles: usize,
    started: Instant,
    /// When the first tile was issued — splits wall latency into
    /// queueing delay and service time for the per-class stats.
    first_issue: Option<Instant>,
    invocations: u64,
    reply: Reply,
}

/// Where a tagged in-flight job lands when it completes.
#[derive(Debug, Clone, Copy)]
struct JobDesc {
    flight: u64,
    im: usize,
    inn: usize,
    ik: usize,
}

/// Per-output-block accumulation state (the "small accumulation buffer
/// per in-flight block").
struct BlockAcc<T> {
    /// Dense `nm×nn` running sum (recycled through the free-list).
    buf: Vec<T>,
    /// Next `ik` to reduce — enforces the bit-exact reduction order.
    next_ik: usize,
    /// Out-of-order partials parked until their turn.
    pending: BTreeMap<usize, Vec<T>>,
}

/// Reduce one completed partial into its output block, preserving
/// ascending-`ik` order; write the block back once full. Consumed
/// partials and retired accumulation buffers return to `free`, closing
/// the recycle loop with the device workers that take from it.
#[allow(clippy::too_many_arguments)]
fn reduce_partial<T: Elem>(
    accs: &mut FxHashMap<(u64, usize, usize), BlockAcc<T>>,
    c: &mut [T],
    done_tiles: &mut usize,
    tiler: Tiler,
    gk: usize,
    m: usize,
    n: usize,
    fid: u64,
    desc: JobDesc,
    partial: Vec<T>,
    free: &FreeList<T>,
) {
    let key = (fid, desc.im, desc.inn);
    let acc = accs.entry(key).or_insert_with(|| {
        let mut buf = free.take(tiler.nm * tiler.nn);
        buf.fill(T::default());
        BlockAcc { buf, next_ik: 0, pending: BTreeMap::new() }
    });
    acc.pending.insert(desc.ik, partial);
    while let Some(p) = acc.pending.remove(&acc.next_ik) {
        for (dst, src) in acc.buf.iter_mut().zip(&p) {
            dst.acc(*src);
        }
        free.put(p);
        acc.next_ik += 1;
        *done_tiles += 1;
    }
    if acc.next_ik == gk {
        let full = accs.remove(&key).unwrap();
        Tiler::write_block(c, m, n, desc.im, desc.inn, tiler.nm, tiler.nn, &full.buf);
        free.put(full.buf);
    }
}

/// Purge one flight's accumulation state, recycling its buffers and
/// parked partials (cancellation/failure path — without this a
/// cancellation storm would leak every in-progress block's buffers).
fn drain_accs<T: Elem>(
    accs: &mut FxHashMap<(u64, usize, usize), BlockAcc<T>>,
    fid: u64,
    free: &FreeList<T>,
) {
    accs.retain(|key, acc| {
        if key.0 != fid {
            return true;
        }
        free.put(std::mem::take(&mut acc.buf));
        for (_, p) in std::mem::take(&mut acc.pending) {
            free.put(p);
        }
        false
    });
}

/// The scheduler state machine (see module docs).
pub(crate) struct Scheduler {
    pub(crate) device: DeviceHandle,
    pub(crate) tiler_f32: Tiler,
    pub(crate) tiler_i32: Tiler,
    pub(crate) gate: Arc<Gate>,
    pub(crate) shared: Arc<Shared>,
    /// Sender cloned into every tile job; a forwarder thread relays
    /// completions into the scheduler's event channel.
    pub(crate) tile_tx: mpsc::Sender<TileDone>,
    pub(crate) depth: usize,
    /// Scheduling decisions are delegated here; see
    /// [`crate::coordinator::policy`].
    pub(crate) policy: Box<dyn SchedPolicy>,
    pub(crate) params: PolicyParams,
    pub(crate) draining: bool,
    /// Packed-weight LRU (scheduler-thread owned, no locks on lookup).
    weight_cache: WeightCache,
    /// Fan-out width for operand arena extraction
    /// (`ServeConfig::pack_workers`; 1 = serial, today's behavior).
    pack_workers: usize,
    /// Packing-stage counters shared with client-side stats snapshots.
    pack_counters: Arc<PackCounters>,
    /// Tile-buffer free-lists shared with the device workers.
    bufs: Arc<BufferPool>,
    flights: FxHashMap<u64, Flight>,
    /// Admission token → flight id (the cancellation route).
    tokens: FxHashMap<u64, u64>,
    descs: FxHashMap<u64, JobDesc>,
    accs_f32: FxHashMap<(u64, usize, usize), BlockAcc<f32>>,
    accs_i32: FxHashMap<(u64, usize, usize), BlockAcc<i32>>,
    next_flight: u64,
    next_tag: u64,
    in_flight: usize,
}

impl Scheduler {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        device: DeviceHandle,
        tiler_f32: Tiler,
        tiler_i32: Tiler,
        gate: Arc<Gate>,
        shared: Arc<Shared>,
        tile_tx: mpsc::Sender<TileDone>,
        depth: usize,
        params: PolicyParams,
        weight_cache: WeightCache,
        pack_workers: usize,
        pack_counters: Arc<PackCounters>,
    ) -> Self {
        let bufs = device.buffer_pool();
        Scheduler {
            device,
            tiler_f32,
            tiler_i32,
            gate,
            shared,
            tile_tx,
            depth: depth.max(1),
            policy: policy::build(&params),
            params,
            draining: false,
            weight_cache,
            pack_workers: pack_workers.max(1),
            pack_counters,
            bufs,
            flights: FxHashMap::default(),
            tokens: FxHashMap::default(),
            descs: FxHashMap::default(),
            accs_f32: FxHashMap::default(),
            accs_i32: FxHashMap::default(),
            next_flight: 0,
            next_tag: 0,
            in_flight: 0,
        }
    }

    pub(crate) fn run(mut self, events: mpsc::Receiver<Event>) {
        // Wake any producer parked on the admission gate when this
        // thread exits — normally or by unwinding.
        let _gate_closer = GateCloser(Arc::clone(&self.gate));
        loop {
            // Fill the window from the policy.
            while self.in_flight < self.depth {
                let Some(fid) = self.policy.pick() else { break };
                self.submit_one(fid);
            }
            if self.draining && self.flights.is_empty() && self.in_flight == 0 {
                break;
            }
            // Block for the next admission, completion or control event.
            let Ok(ev) = events.recv() else { break };
            match ev {
                Event::Admit(adm) => self.handle_admit(adm),
                Event::Done(done) => self.handle_done(done),
                Event::Cancel(token) => self.handle_cancel(token),
                Event::SetDepth(d) => self.depth = d.max(1),
                Event::SetPolicy(kind) => self.set_policy(kind),
                Event::ResetEpoch => {
                    *self.shared.last_window.lock().unwrap() = WindowOcc::default()
                }
                Event::Drain => self.draining = true,
            }
        }
        // `_gate_closer` closes the admission gate as it drops;
        // dropping `self.device` stops the worker pool.
    }

    fn tiler_for(&self, p: Precision) -> Tiler {
        match p {
            Precision::Int8 => self.tiler_i32,
            _ => self.tiler_f32,
        }
    }

    fn flight_meta(&self, fid: u64, f: &Flight) -> FlightMeta {
        FlightMeta {
            fid,
            class: f.class,
            precision: f.req.precision,
            tile_cost: self.params.costs.cost(f.req.precision),
        }
    }

    /// Swap the scheduling policy live: rebuild it and re-admit every
    /// flight that still has unissued tiles, in flight-id (admission)
    /// order so the handover is deterministic.
    fn set_policy(&mut self, kind: PolicyKind) {
        self.params.kind = kind;
        self.policy = policy::build(&self.params);
        let mut open: Vec<u64> = self
            .flights
            .iter()
            .filter(|(_, f)| f.next_tile < f.total_tiles)
            .map(|(&fid, _)| fid)
            .collect();
        open.sort_unstable();
        for fid in open {
            let meta = self.flight_meta(fid, &self.flights[&fid]);
            self.policy.admit(meta);
        }
    }

    fn handle_admit(&mut self, mut adm: Box<Admitted>) {
        if self.draining {
            return; // Admitted::drop frees the slot and errors the reply
        }
        let req = adm.req;
        let token = adm.token;
        let submitted = adm.submitted;
        let ops = adm.ops.take().expect("operands consumed once");
        let reply = adm.reply.take().expect("reply consumed once");
        let class = self.params.clamp_class(req.class);
        let (m, k, n) = (req.m as usize, req.k as usize, req.n as usize);
        let tiler = self.tiler_for(req.precision);
        let grid = tiler.grid(m, k, n);
        let (gm, gk, gn) = grid;
        let total_tiles = gm * gk * gn;
        // Degenerate (zero-tile) requests retire immediately — still
        // recorded, so stats().requests matches the replies delivered.
        if total_tiles == 0 {
            self.shared.stats.lock().unwrap().record(Completion {
                id: req.id,
                macs: req.macs(),
                precision: req.precision,
                class,
                wall: submitted.elapsed(),
                queued: submitted.elapsed(),
                service: Duration::ZERO,
                device_s: 0.0,
                invocations: 0,
            });
            let out = match ops {
                Operands::F32 { .. } => MatOutput::F32(vec![0.0; m * n]),
                Operands::I32 { .. } => MatOutput::I32(vec![0; m * n]),
            };
            self.gate.release(req.class);
            reply.send(req, Ok(out));
            return;
        }
        let data = match ops {
            Operands::F32 { a, b } => FlightData::F32(Pools::fresh(a, b, m * n)),
            Operands::I32 { a, b } => FlightData::I32(Pools::fresh(a, b, m * n)),
        };
        let fid = self.next_flight;
        self.next_flight += 1;
        self.flights.insert(
            fid,
            Flight {
                req,
                token,
                class,
                grid,
                tiler,
                data,
                next_tile: 0,
                total_tiles,
                done_tiles: 0,
                started: submitted,
                first_issue: None,
                invocations: 0,
                reply,
            },
        );
        self.tokens.insert(token, fid);
        let meta = self.flight_meta(fid, &self.flights[&fid]);
        self.policy.admit(meta);
    }

    /// Schedule the next tile of flight `fid` into the window.
    fn submit_one(&mut self, fid: u64) {
        let tag = self.next_tag;
        self.next_tag += 1;
        let (payload, desc, more) = {
            let Some(f) = self.flights.get_mut(&fid) else { return };
            let (_gm, gk, gn) = f.grid;
            let (m, k, n) = (f.req.m as usize, f.req.k as usize, f.req.n as usize);
            let tiler = f.tiler;
            if f.first_issue.is_none() {
                f.first_issue = Some(Instant::now());
            }
            // k-innermost walk: tile t = (im·gn + inn)·gk + ik.
            let t = f.next_tile;
            f.next_tile += 1;
            let ik = t % gk;
            let blk = t / gk;
            let im = blk / gn;
            let inn = blk % gn;
            let weight_id = f.req.weight_id;
            let payload = match &mut f.data {
                FlightData::F32(p) => {
                    p.pack(
                        m,
                        k,
                        n,
                        tiler,
                        weight_id,
                        &mut self.weight_cache,
                        self.pack_workers,
                        &self.pack_counters,
                    );
                    let (ap, bp) = p.packed.as_ref().expect("packed on first schedule");
                    TilePayload::F32 {
                        a: ap.tile_ref(im * gk + ik),
                        b: bp.tile_ref(ik * gn + inn),
                    }
                }
                FlightData::I32(p) => {
                    p.pack(
                        m,
                        k,
                        n,
                        tiler,
                        weight_id,
                        &mut self.weight_cache,
                        self.pack_workers,
                        &self.pack_counters,
                    );
                    let (ap, bp) = p.packed.as_ref().expect("packed on first schedule");
                    TilePayload::I32 {
                        a: ap.tile_ref(im * gk + ik),
                        b: bp.tile_ref(ik * gn + inn),
                    }
                }
            };
            f.invocations += 1;
            (payload, JobDesc { flight: fid, im, inn, ik }, f.next_tile < f.total_tiles)
        };
        self.descs.insert(tag, desc);
        self.policy.tile_issued(fid, more);
        match self.device.submit(TileJob { tag, payload, done: self.tile_tx.clone() }) {
            Ok(()) => self.in_flight += 1,
            Err(e) => {
                self.descs.remove(&tag);
                self.fail_flight(fid, e);
            }
        }
    }

    fn handle_done(&mut self, done: TileDone) {
        // Sample the window as it stood while this tile completed.
        let occ = self.in_flight;
        self.shared.window.lock().unwrap().record(occ);
        self.shared.last_window.lock().unwrap().record(occ);
        self.in_flight = self.in_flight.saturating_sub(1);
        let Some(desc) = self.descs.remove(&done.tag) else {
            return; // stale tag (defensive; tags are scheduler-issued)
        };
        let fid = desc.flight;
        if !self.flights.contains_key(&fid) {
            // Flight failed or was cancelled: the straggler's result is
            // dead weight, but its buffer recycles.
            if let Ok(out) = done.result {
                match out {
                    TileOutput::F32(v) => self.bufs.fp32.put(v),
                    TileOutput::I32(v) => self.bufs.int8.put(v),
                }
            }
            return;
        }
        let output = match done.result {
            Ok(o) => o,
            Err(e) => {
                self.fail_flight(fid, e);
                return;
            }
        };
        let matched = {
            let f = self.flights.get_mut(&fid).unwrap();
            let tiler = f.tiler;
            let (_gm, gk, _gn) = f.grid;
            let (m, n) = (f.req.m as usize, f.req.n as usize);
            match (&mut f.data, output) {
                (FlightData::F32(p), TileOutput::F32(partial)) => {
                    reduce_partial(
                        &mut self.accs_f32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                        &self.bufs.fp32,
                    );
                    true
                }
                (FlightData::I32(p), TileOutput::I32(partial)) => {
                    reduce_partial(
                        &mut self.accs_i32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                        &self.bufs.int8,
                    );
                    true
                }
                _ => false,
            }
        };
        if !matched {
            self.fail_flight(fid, anyhow!("device returned a tile in the wrong precision"));
            return;
        }
        let f = &self.flights[&fid];
        if f.done_tiles == f.total_tiles {
            self.retire(fid);
        }
    }

    /// Deliver a finished flight's output and free its admission slot.
    fn retire(&mut self, fid: u64) {
        let mut f = self.flights.remove(&fid).unwrap();
        self.tokens.remove(&f.token);
        self.policy.remove(fid);
        // Charge the flight exactly its own tiles (period × invocations)
        // — the shared device clock spans concurrently open flights and
        // would double-count overlap.
        let period = self
            .device
            .info_for(f.req.precision)
            .map(|i| i.period_cycles)
            .unwrap_or_default();
        let (queued, service) = match f.first_issue {
            Some(t) => (t.duration_since(f.started), t.elapsed()),
            None => (f.started.elapsed(), Duration::ZERO),
        };
        self.shared.stats.lock().unwrap().record(Completion {
            id: f.req.id,
            macs: f.req.macs(),
            precision: f.req.precision,
            class: f.class,
            wall: f.started.elapsed(),
            queued,
            service,
            device_s: period * f.invocations as f64 / self.device.freq_hz,
            invocations: f.invocations,
        });
        let out = match &mut f.data {
            FlightData::F32(p) => MatOutput::F32(std::mem::take(&mut p.c)),
            FlightData::I32(p) => MatOutput::I32(std::mem::take(&mut p.c)),
        };
        self.gate.release(f.req.class);
        f.reply.send(f.req, Ok(out));
    }

    /// Drop one flight's scheduler state (queues, reduction buffers,
    /// token) and free its admission slot. Tiles already in the window
    /// are dropped on arrival by `handle_done`'s straggler path (which
    /// recycles their buffers); reduction state recycles here.
    fn evict(&mut self, fid: u64) -> Option<Flight> {
        let f = self.flights.remove(&fid)?;
        self.tokens.remove(&f.token);
        self.policy.remove(fid);
        drain_accs(&mut self.accs_f32, fid, &self.bufs.fp32);
        drain_accs(&mut self.accs_i32, fid, &self.bufs.int8);
        self.gate.release(f.req.class);
        Some(f)
    }

    /// Fail one flight without tearing the stream down.
    fn fail_flight(&mut self, fid: u64, err: anyhow::Error) {
        if let Some(f) = self.evict(fid) {
            f.reply.send(f.req, Err(err));
        }
    }

    /// Cancel the flight behind an admission token: unissued tiles are
    /// abandoned, slots reclaimed, and the reply resolves with
    /// [`Cancelled`]. Unknown tokens (already retired, failed, or
    /// cancelled twice) are a no-op — a handle resolves exactly once.
    fn handle_cancel(&mut self, token: u64) {
        let Some(&fid) = self.tokens.get(&token) else { return };
        if let Some(f) = self.evict(fid) {
            self.shared.stats.lock().unwrap().record_cancelled();
            f.reply.send(f.req, Err(Cancelled(f.req.id).into()));
        }
    }
}
