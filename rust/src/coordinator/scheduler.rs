//! The scheduler thread: a single-threaded state machine owning the
//! device pool, the open flights, the in-flight window — and, since
//! PR 3, a pluggable [`SchedPolicy`] deciding which flight issues the
//! next tile.
//!
//! # The pipeline
//!
//! 1. **Arena packing (zero-copy)** — on first schedule each request's
//!    A and B are packed once into contiguous tile-major arenas
//!    ([`TilePool::pack`]): one allocation per matrix, tiles addressed
//!    by stride; a tile job borrows its two blocks as [`TileRef`]s
//!    (`Arc` bumps). The **B** pool first consults the packed-weight
//!    cache ([`WeightCache`], `ServeConfig::weight_cache_bytes`): a hit
//!    skips B extraction and packing entirely — the dominant
//!    per-request host cost under steady weight reuse. Budget `0`
//!    disables the cache (the pre-PR 4 behavior, bit-for-bit).
//! 2. **Windowed submission** — up to `pipeline_depth` tagged jobs are
//!    kept in flight on one completion channel, overlapping host
//!    pack/reduce with device execution. `pipeline_depth = 1` reproduces
//!    the synchronous engine exactly.
//! 3. **Policy-ordered scheduling** — each flight walks its tiles
//!    k-innermost per `(im, inn)` output block; *which* flight issues
//!    the next tile is the policy's call ([`Fifo`] round-robin by
//!    default, bit-identical to the pre-policy engine).
//! 4. **Buffer recycling** — device output tiles and per-block
//!    accumulation buffers flow through the per-precision free-lists
//!    ([`crate::coordinator::pool::BufferPool`]) threaded around the
//!    completion loop (including the cancellation/straggler paths), so
//!    a long-lived server reaches a zero-allocation steady state per
//!    tile.
//!
//! [`TileRef`]: crate::coordinator::pool::TileRef
//!
//! # Fault recovery (the completion loop under failure)
//!
//! Since PR 6 the completion wait is **deadline-aware**: when
//! `ServeConfig::tile_timeout_mult` arms per-tile deadlines, the loop
//! blocks with `recv_timeout` up to the earliest outstanding deadline
//! instead of waiting forever on a completion that may never arrive. An
//! expired, errored, or checksum-failed tile is re-packed from the
//! (immutable) arenas and **re-dispatched under a fresh tag** to a
//! different worker when possible, up to `max_tile_retries`; only then
//! does the flight fail, with a typed
//! [`TileRetriesExhausted`] error. Because retried partials are
//! bit-identical to the originals and reduction stays in ascending-`ik`
//! order, a recovered run equals the fault-free run bit-for-bit. A
//! completion from a timed-out tag that straggles in later is dropped
//! by a stale-tag set (its buffer recycles), so duplicate partials can
//! never double-reduce. Deadline ticks also run worker supervision
//! (dead-worker respawn / pool shrink — see
//! [`crate::coordinator::device`]), and the whole loop body is wrapped
//! in `catch_unwind`: if the scheduler itself panics, every open flight
//! resolves fast with [`SchedulerPanicked`] instead of hanging its
//! clients.
//!
//! **Determinism:** completions may arrive out of order, but partials
//! are applied to each output block strictly in ascending `ik` order
//! (late partials park in a per-block reorder map), so outputs are
//! bit-identical for every `pipeline_depth`/`workers`/policy
//! combination and admission interleaving — f32 by ordered summation,
//! i32 trivially (wrapping integer addition is associative).
//!
//! [`Fifo`]: crate::coordinator::policy::Fifo

use crate::arch::precision::Precision;
use crate::config::schema::PolicyKind;
use crate::coordinator::admission::{Admitted, Gate, GateCloser};
use crate::coordinator::device::{
    output_crc, DeviceHandle, TileDone, TileJob, TileOutput, TilePayload,
};
use crate::coordinator::fault::{
    DeadlineExceeded, DrainDeadlineExpired, FaultCounters, SchedulerPanicked, TileCorrupted,
    TileRetriesExhausted, TileTimedOut,
};
use crate::coordinator::handle::{Cancelled, Reply};
use crate::coordinator::policy::{self, FlightMeta, PolicyParams, SchedPolicy};
use crate::coordinator::pool::{
    pack_fanout, BufferPool, FreeList, PackCounters, PoolElem, RewarmEntry, TilePool,
    WeightCache, WeightIdent, WeightKey,
};
use crate::coordinator::stats::{Completion, ShedCounters, StatsAgg, WindowOcc};
use crate::coordinator::tiler::Tiler;
use crate::coordinator::workpool::WorkPool;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::anyhow;
use rustc_hash::{FxHashMap, FxHashSet};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Scheduler-thread events: admissions, tile completions (forwarded
/// from the device pool), cancellations and control messages share one
/// channel, so the scheduler is a single ordered state machine.
pub(crate) enum Event {
    Admit(Box<Admitted>),
    Done(TileDone),
    /// Cancel the request submitted with this admission token.
    Cancel(u64),
    SetDepth(usize),
    SetPolicy(PolicyKind),
    ResetEpoch,
    /// Stop admitting, serve what is open, then exit — by the absolute
    /// deadline when one is set (stragglers past it fail with
    /// [`DrainDeadlineExpired`] instead of hanging shutdown). The
    /// deadline is absolute so a multi-shard facade can stamp one
    /// instant and fan it out: shards drain *concurrently* against the
    /// same wall-clock budget instead of serially accumulating it.
    Drain(Option<Instant>),
    /// Test hook (`MatMulServer::inject_scheduler_panic`): panic the
    /// scheduler loop to exercise the fail-fast path.
    ChaosPanic,
    /// Chaos hook (`FaultKind::CacheCorrupt`): silently flip one word
    /// in the oldest resident weight-cache entry, leaving its CRC
    /// stamp untouched — the at-rest corruption sampled verify-on-hit
    /// exists to catch.
    ChaosCorruptCache,
    /// Respawn hand-off: seed the (fresh) weight cache with entries
    /// rescued from the dead scheduler's cache, each carrying its
    /// pre-crash CRC stamp and armed to fully verify on first hit.
    Rewarm(Vec<RewarmEntry>),
}

/// State shared between the scheduler thread and client-side snapshots.
pub(crate) struct Shared {
    pub(crate) stats: Mutex<StatsAgg>,
    /// Cumulative window occupancy over the server's lifetime.
    pub(crate) window: Mutex<WindowOcc>,
    /// Occupancy since the last epoch reset (A/B attribution).
    pub(crate) last_window: Mutex<WindowOcc>,
}

/// Fault-plane knobs the scheduler enforces, derived from `ServeConfig`
/// by the server (deadlines are pre-resolved to per-precision
/// durations: `tile_timeout_mult` × simulated period, floored at
/// `tile_timeout_floor_ms`; `None` = deadlines off, the historical
/// wait-forever behavior).
pub(crate) struct Robustness {
    pub(crate) max_tile_retries: u32,
    pub(crate) deadline_f32: Option<Duration>,
    pub(crate) deadline_i32: Option<Duration>,
    pub(crate) quarantine_after: u32,
}

/// Element type the reduction machinery is generic over: f32 sums, the
/// int8 path accumulates i32 with wrapping adds (both orderings are
/// fixed by the ascending-`ik` rule; wrapping keeps i32 bit-exact even
/// on overflow).
trait Elem: Copy + Default + Send + Sync + 'static {
    fn acc(&mut self, other: Self);
}

impl Elem for f32 {
    fn acc(&mut self, other: Self) {
        *self += other;
    }
}

impl Elem for i32 {
    fn acc(&mut self, other: Self) {
        *self = self.wrapping_add(other);
    }
}

/// One precision's operand pools and output matrix. Packed pools are
/// contiguous arenas ([`TilePool`]): one allocation per matrix, tiles
/// addressed by stride — A indexed `[im·gk + ik]`, B `[ik·gn + inn]`.
struct Pools<T> {
    /// Raw row-major operands, held until this request's first tile is
    /// scheduled: packing then happens *inside* the pipeline, overlapping
    /// the tiles of earlier requests already executing on the workers.
    raw: Option<(Vec<T>, Vec<T>)>,
    packed: Option<(TilePool<T>, TilePool<T>)>,
    c: Vec<T>,
}

impl<T: Elem + PoolElem> Pools<T> {
    fn fresh(a: Vec<T>, b: Vec<T>, out_len: usize) -> Self {
        Pools { raw: Some((a, b)), packed: None, c: vec![T::default(); out_len] }
    }

    /// First schedule of this request: pack its operands into the
    /// tile-major arenas now — one extract pass per block and one
    /// allocation per matrix, total, overlapping whatever is already in
    /// flight, with extraction fanned out across `pack_workers` threads
    /// for large grids ([`TilePool::pack_timed`] — bit-identical to the
    /// serial pack for every worker count) — onto the scheduler's
    /// persistent [`WorkPool`] when one is configured
    /// (`pack_persistent`, the default), or legacy per-call scoped
    /// threads otherwise. The B (weight) pool goes
    /// through the packed-weight cache: a hit skips extraction and
    /// packing entirely, and since packing is deterministic the cached
    /// pool is byte-identical to what packing would have produced.
    /// `counters` accumulate the packing wall time for
    /// `ServerStats::pack`, split into extraction critical path and
    /// fan-out orchestration overhead
    /// ([`PackTiming`](crate::coordinator::pool::PackTiming)).
    fn pack(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        t: Tiler,
        weight_id: Option<u64>,
        cache: &mut WeightCache,
        pack_workers: usize,
        work_pool: Option<&WorkPool>,
        counters: &PackCounters,
    ) {
        if let Some((a, b)) = self.raw.take() {
            let mut built = 0u64;
            let mut parallel = 0u64;
            let mut spent = Duration::ZERO;
            let mut spawn = Duration::ZERO;
            // Times each arena build alone: fingerprint hashing, cache
            // lookups and the debug collision guard below never enter
            // `pack_time_s` / `pack_spawn_s`.
            let mut timed_pack = |src: &[T], rows: usize, cols: usize, bh: usize, bw: usize| {
                let (pool, timing) =
                    TilePool::pack_timed(src, rows, cols, bh, bw, pack_workers, work_pool);
                spent += timing.busiest;
                spawn += timing.spawn_overhead();
                built += 1;
                parallel += u64::from(pack_fanout(pack_workers, pool.tiles()) > 1);
                pool
            };
            let a_pool = timed_pack(&a, m, k, t.nm, t.nk);
            let b_pool = if cache.enabled() {
                let ident = match weight_id {
                    Some(id) => WeightIdent::Id(id),
                    None => WeightIdent::Fingerprint(T::fingerprint(&b)),
                };
                let key =
                    WeightKey { ident, k: k as u64, n: n as u64, precision: T::precision() };
                match cache.get::<T>(&key) {
                    Some(pool) => {
                        // Debug-build collision guard: an anonymous
                        // (fingerprint-keyed) hit must byte-match the
                        // raw operand it claims to replace.
                        #[cfg(debug_assertions)]
                        if matches!(key.ident, WeightIdent::Fingerprint(_)) {
                            let guard = crate::coordinator::pool::debug_assert_pool_matches;
                            guard(&pool, &b, k, n, t.nk, t.nn);
                        }
                        pool
                    }
                    None => {
                        let pool = timed_pack(&b, k, n, t.nk, t.nn);
                        cache.insert(key, &pool);
                        pool
                    }
                }
            } else {
                timed_pack(&b, k, n, t.nk, t.nn)
            };
            counters.record(built, parallel, spent, spawn);
            self.packed = Some((a_pool, b_pool));
        }
    }
}

/// Typed flight data — the only precision-specific part of a flight.
enum FlightData {
    F32(Pools<f32>),
    I32(Pools<i32>),
}

/// One open request's state in the scheduler.
struct Flight {
    req: MatMulRequest,
    /// Admission token — the cancellation address of this flight.
    token: u64,
    /// Priority class, clamped to the configured class count.
    class: usize,
    /// Block grid `(gm, gk, gn)` in this request's precision geometry.
    grid: (usize, usize, usize),
    /// This request's precision tiler (native tile sizes are
    /// per-precision).
    tiler: Tiler,
    data: FlightData,
    /// Cursor into the k-innermost tile walk.
    next_tile: usize,
    total_tiles: usize,
    /// Tiles whose partials have been reduced (in order).
    done_tiles: usize,
    started: Instant,
    /// When the first tile was issued — splits wall latency into
    /// queueing delay and service time for the per-class stats.
    first_issue: Option<Instant>,
    /// Absolute request deadline (`MatMulRequest::with_deadline`,
    /// anchored at admission): past it the flight is evicted and
    /// resolves with [`DeadlineExceeded`]. `None` = no deadline.
    deadline: Option<Instant>,
    invocations: u64,
    reply: Reply,
}

/// Where a tagged in-flight job lands when it completes — plus the
/// retry/deadline state the fault plane tracks per attempt.
#[derive(Debug, Clone, Copy)]
struct JobDesc {
    flight: u64,
    im: usize,
    inn: usize,
    ik: usize,
    /// Worker the job was dispatched to (retries avoid it).
    worker: usize,
    /// Execution attempts so far beyond the first.
    retries: u32,
    /// When this attempt was dispatched.
    issued: Instant,
    /// When this attempt is declared lost (`None` = deadlines off).
    deadline: Option<Instant>,
}

/// Build a tile payload for block `(im, inn, ik)` from a flight's
/// packed arenas. The arenas are immutable after the first schedule, so
/// a retry rebuilt here carries bit-identical operand data. `None` only
/// if the flight was never packed (no tile ever issued — cannot happen
/// for a tile that reached the device).
fn payload_from_packed(f: &Flight, im: usize, inn: usize, ik: usize) -> Option<TilePayload> {
    let (_gm, gk, gn) = f.grid;
    match &f.data {
        FlightData::F32(p) => p.packed.as_ref().map(|(ap, bp)| TilePayload::F32 {
            a: ap.tile_ref(im * gk + ik),
            b: bp.tile_ref(ik * gn + inn),
        }),
        FlightData::I32(p) => p.packed.as_ref().map(|(ap, bp)| TilePayload::I32 {
            a: ap.tile_ref(im * gk + ik),
            b: bp.tile_ref(ik * gn + inn),
        }),
    }
}

/// Per-output-block accumulation state (the "small accumulation buffer
/// per in-flight block").
struct BlockAcc<T> {
    /// Dense `nm×nn` running sum (recycled through the free-list).
    buf: Vec<T>,
    /// Next `ik` to reduce — enforces the bit-exact reduction order.
    next_ik: usize,
    /// Out-of-order partials parked until their turn.
    pending: BTreeMap<usize, Vec<T>>,
}

/// Reduce one completed partial into its output block, preserving
/// ascending-`ik` order; write the block back once full. Consumed
/// partials and retired accumulation buffers return to `free`, closing
/// the recycle loop with the device workers that take from it.
#[allow(clippy::too_many_arguments)]
fn reduce_partial<T: Elem>(
    accs: &mut FxHashMap<(u64, usize, usize), BlockAcc<T>>,
    c: &mut [T],
    done_tiles: &mut usize,
    tiler: Tiler,
    gk: usize,
    m: usize,
    n: usize,
    fid: u64,
    desc: JobDesc,
    partial: Vec<T>,
    free: &FreeList<T>,
) {
    let key = (fid, desc.im, desc.inn);
    let acc = accs.entry(key).or_insert_with(|| {
        let mut buf = free.take(tiler.nm * tiler.nn);
        buf.fill(T::default());
        BlockAcc { buf, next_ik: 0, pending: BTreeMap::new() }
    });
    acc.pending.insert(desc.ik, partial);
    while let Some(p) = acc.pending.remove(&acc.next_ik) {
        for (dst, src) in acc.buf.iter_mut().zip(&p) {
            dst.acc(*src);
        }
        free.put(p);
        acc.next_ik += 1;
        *done_tiles += 1;
    }
    if acc.next_ik == gk {
        let full = accs.remove(&key).unwrap();
        Tiler::write_block(c, m, n, desc.im, desc.inn, tiler.nm, tiler.nn, &full.buf);
        free.put(full.buf);
    }
}

/// Purge one flight's accumulation state, recycling its buffers and
/// parked partials (cancellation/failure path — without this a
/// cancellation storm would leak every in-progress block's buffers).
fn drain_accs<T: Elem>(
    accs: &mut FxHashMap<(u64, usize, usize), BlockAcc<T>>,
    fid: u64,
    free: &FreeList<T>,
) {
    accs.retain(|key, acc| {
        if key.0 != fid {
            return true;
        }
        free.put(std::mem::take(&mut acc.buf));
        for (_, p) in std::mem::take(&mut acc.pending) {
            free.put(p);
        }
        false
    });
}

/// The scheduler state machine (see module docs).
pub(crate) struct Scheduler {
    /// Index of the shard this scheduler serves — stamped into every
    /// typed error so multi-shard failures are attributable.
    pub(crate) shard: usize,
    /// Request-level robustness counters shared with the shard's stats
    /// snapshots (this thread bumps `deadline_expired`).
    pub(crate) shed: Arc<ShedCounters>,
    pub(crate) device: DeviceHandle,
    pub(crate) tiler_f32: Tiler,
    pub(crate) tiler_i32: Tiler,
    pub(crate) gate: Arc<Gate>,
    pub(crate) shared: Arc<Shared>,
    /// Sender cloned into every tile job; a forwarder thread relays
    /// completions into the scheduler's event channel.
    pub(crate) tile_tx: mpsc::Sender<TileDone>,
    pub(crate) depth: usize,
    /// Scheduling decisions are delegated here; see
    /// [`crate::coordinator::policy`].
    pub(crate) policy: Box<dyn SchedPolicy>,
    pub(crate) params: PolicyParams,
    pub(crate) draining: bool,
    /// Fault-plane knobs (deadlines, retry budget, quarantine).
    robust: Robustness,
    /// Shared fault counters (the device pool's; scheduler-side
    /// recovery events are recorded here too).
    counters: Arc<FaultCounters>,
    /// Packed-weight LRU (scheduler-thread owned, no locks on lookup).
    weight_cache: WeightCache,
    /// Fan-out width for operand arena extraction
    /// (`ServeConfig::pack_workers`; 1 = serial, today's behavior).
    pack_workers: usize,
    /// Persistent pack workers (`ServeConfig::pack_persistent`, the
    /// default when `pack_workers > 1`); `None` falls back to per-call
    /// scoped threads. Owned here so the pool's threads join when the
    /// scheduler thread winds down — shard teardown leaves no pack
    /// threads behind.
    work_pool: Option<WorkPool>,
    /// Packing-stage counters shared with client-side stats snapshots.
    pack_counters: Arc<PackCounters>,
    /// Tile-buffer free-lists shared with the device workers.
    bufs: Arc<BufferPool>,
    /// Rescue slot shared with the owning [`Shard`]: if this scheduler
    /// panics, it exports its `rewarm_top_k` hottest weight-cache
    /// entries here on the way down so the respawn supervisor can seed
    /// the replacement shard's cache (best-effort — an empty slot just
    /// means a cold start).
    ///
    /// [`Shard`]: crate::coordinator::shard::Shard
    rescue: Arc<Mutex<Option<Vec<RewarmEntry>>>>,
    /// How many hottest entries to export on panic
    /// (`ServeConfig::respawn_rewarm_top_k`; `0` = no rescue).
    rewarm_top_k: usize,
    flights: FxHashMap<u64, Flight>,
    /// Admission token → flight id (the cancellation route).
    tokens: FxHashMap<u64, u64>,
    descs: FxHashMap<u64, JobDesc>,
    /// Tags whose deadline expired: if their completion straggles in
    /// later it is dropped (buffer recycled), never double-reduced.
    stale: FxHashSet<u64>,
    accs_f32: FxHashMap<(u64, usize, usize), BlockAcc<f32>>,
    accs_i32: FxHashMap<(u64, usize, usize), BlockAcc<i32>>,
    next_flight: u64,
    next_tag: u64,
    in_flight: usize,
    /// Absolute drain deadline, armed by [`Event::Drain`].
    drain_by: Option<Instant>,
}

impl Scheduler {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        shard: usize,
        shed: Arc<ShedCounters>,
        device: DeviceHandle,
        tiler_f32: Tiler,
        tiler_i32: Tiler,
        gate: Arc<Gate>,
        shared: Arc<Shared>,
        tile_tx: mpsc::Sender<TileDone>,
        depth: usize,
        params: PolicyParams,
        weight_cache: WeightCache,
        pack_workers: usize,
        work_pool: Option<WorkPool>,
        pack_counters: Arc<PackCounters>,
        robust: Robustness,
        rescue: Arc<Mutex<Option<Vec<RewarmEntry>>>>,
        rewarm_top_k: usize,
    ) -> Self {
        let bufs = device.buffer_pool();
        let counters = device.fault_counters();
        Scheduler {
            shard,
            shed,
            device,
            tiler_f32,
            tiler_i32,
            gate,
            shared,
            tile_tx,
            depth: depth.max(1),
            policy: policy::build(&params),
            params,
            draining: false,
            robust,
            counters,
            weight_cache,
            pack_workers: pack_workers.max(1),
            work_pool,
            pack_counters,
            bufs,
            rescue,
            rewarm_top_k,
            flights: FxHashMap::default(),
            tokens: FxHashMap::default(),
            descs: FxHashMap::default(),
            stale: FxHashSet::default(),
            accs_f32: FxHashMap::default(),
            accs_i32: FxHashMap::default(),
            next_flight: 0,
            next_tag: 0,
            in_flight: 0,
            drain_by: None,
        }
    }

    pub(crate) fn run(mut self, events: mpsc::Receiver<Event>) {
        // Wake any producer parked on the admission gate when this
        // thread exits — normally or by unwinding.
        let _gate_closer = GateCloser(Arc::clone(&self.gate));
        // Clients must never block forever on a dead scheduler: if the
        // loop panics, resolve every open flight fast instead of
        // leaving the handles to a disconnect error on teardown.
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.run_loop(&events)
        }))
        .is_err();
        if panicked {
            // Best-effort rescue for the respawn supervisor: export the
            // hottest cached weights (with their pre-crash CRC stamps)
            // before resolving the open flights. The cache itself is
            // plain scheduler-thread state — no mutex to be poisoned by
            // the panic that brought us here.
            if self.rewarm_top_k > 0 {
                let hot = self.weight_cache.hottest(self.rewarm_top_k);
                if !hot.is_empty() {
                    if let Ok(mut slot) = self.rescue.lock() {
                        *slot = Some(hot);
                    }
                }
            }
            self.fail_all_open();
        }
        // `_gate_closer` closes the admission gate as it drops;
        // dropping `self.device` stops the worker pool.
    }

    fn run_loop(&mut self, events: &mpsc::Receiver<Event>) {
        loop {
            // Fill the window from the policy.
            while self.in_flight < self.depth {
                let Some(fid) = self.policy.pick() else { break };
                self.submit_one(fid);
            }
            if self.draining && self.flights.is_empty() && self.in_flight == 0 {
                break;
            }
            // Shutdown's drain budget: past it, fail stragglers typed
            // instead of waiting on them.
            if let Some(by) = self.drain_by {
                if Instant::now() >= by {
                    self.expire_drain();
                    break;
                }
            }
            // Block for the next admission, completion or control
            // event — bounded by the earliest tile/drain deadline when
            // one is armed (the historical wait was unbounded: a lost
            // completion stalled the stream forever).
            let ev = match self.next_wakeup() {
                None => match events.recv() {
                    Ok(ev) => ev,
                    Err(_) => break,
                },
                Some(when) => {
                    let wait = when.saturating_duration_since(Instant::now());
                    match events.recv_timeout(wait) {
                        Ok(ev) => ev,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            self.handle_deadlines();
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            };
            match ev {
                Event::Admit(adm) => self.handle_admit(adm),
                Event::Done(done) => self.handle_done(done),
                Event::Cancel(token) => self.handle_cancel(token),
                Event::SetDepth(d) => self.depth = d.max(1),
                Event::SetPolicy(kind) => self.set_policy(kind),
                Event::ResetEpoch => {
                    *self.shared.last_window.lock().unwrap() = WindowOcc::default()
                }
                Event::Drain(by) => {
                    self.draining = true;
                    self.drain_by = by;
                }
                Event::ChaosPanic => panic!("injected scheduler panic (chaos test hook)"),
                Event::ChaosCorruptCache => {
                    if self.weight_cache.chaos_corrupt() {
                        self.counters
                            .injected_cache_corruptions
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
                Event::Rewarm(entries) => {
                    for (key, pool, crc) in entries {
                        self.weight_cache.rewarm(key, pool, crc);
                    }
                }
            }
        }
    }

    /// Earliest armed deadline among outstanding tiles, open flights'
    /// request deadlines and the drain budget (`None` = nothing armed,
    /// block indefinitely). The desc map is bounded by the window depth
    /// and the flight map by the admission gate, so the scan is cheap.
    fn next_wakeup(&self) -> Option<Instant> {
        let mut when = self.drain_by;
        let mut fold = |dl: Instant| {
            when = Some(match when {
                Some(w) if w <= dl => w,
                _ => dl,
            });
        };
        for d in self.descs.values() {
            if let Some(dl) = d.deadline {
                fold(dl);
            }
        }
        for f in self.flights.values() {
            if let Some(dl) = f.deadline {
                fold(dl);
            }
        }
        when
    }

    /// A deadline tick: expire overdue tiles into the retry path and
    /// sweep for dead workers.
    fn handle_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .descs
            .iter()
            .filter(|(_, d)| d.deadline.is_some_and(|dl| now >= dl))
            .map(|(&tag, _)| tag)
            .collect();
        for tag in expired {
            let desc = self.descs.remove(&tag).unwrap();
            // The completion may still straggle in — drop it then.
            self.stale.insert(tag);
            self.in_flight = self.in_flight.saturating_sub(1);
            self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
            self.device.record_fault(desc.worker, self.robust.quarantine_after);
            let waited_ms = now.saturating_duration_since(desc.issued).as_millis() as u64;
            let err = anyhow::Error::new(TileTimedOut {
                worker: desc.worker,
                waited_ms,
                shard: self.shard,
            });
            self.retry_or_fail(desc, err);
        }
        // Request deadlines: evict every flight past its budget and
        // resolve it typed. Exactly the cancellation path — tiles still
        // in the window straggle into `handle_done`'s flight-missing
        // arm, which frees their slots and recycles their buffers — so
        // no partial output can ever be delivered.
        let overdue: Vec<u64> = self
            .flights
            .iter()
            .filter(|(_, f)| f.deadline.is_some_and(|dl| now >= dl))
            .map(|(&fid, _)| fid)
            .collect();
        for fid in overdue {
            if let Some(f) = self.evict(fid) {
                self.shed.deadline_expired.fetch_add(1, Ordering::Relaxed);
                let budget_ms = f.req.deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
                let err = DeadlineExceeded { id: f.req.id, shard: self.shard, budget_ms };
                f.reply.send(f.req, Err(anyhow::Error::new(err)));
            }
        }
        // Reap dead worker threads (cheap when everyone is alive). A
        // hung worker keeps its thread — repeated timeouts quarantine
        // it instead.
        self.device.supervise();
    }

    /// The drain budget expired: fail every still-open flight with a
    /// typed error so shutdown returns instead of hanging on lost
    /// tiles.
    fn expire_drain(&mut self) {
        let open: Vec<u64> = self.flights.keys().copied().collect();
        for fid in open {
            let id = self.flights[&fid].req.id;
            let err = DrainDeadlineExpired { id, shard: self.shard };
            self.fail_flight(fid, anyhow::Error::new(err));
        }
    }

    /// The scheduler loop panicked: resolve every open flight with
    /// [`SchedulerPanicked`] and free its admission slot. Deliberately
    /// touches nothing else — stats mutexes may be poisoned by the very
    /// panic that brought us here, and the policy/accumulator state
    /// dies with the thread anyway.
    fn fail_all_open(&mut self) {
        let open: Vec<u64> = self.flights.keys().copied().collect();
        for fid in open {
            if let Some(f) = self.flights.remove(&fid) {
                self.gate.release(f.req.class);
                let err = SchedulerPanicked { shard: self.shard };
                f.reply.send(f.req, Err(anyhow::Error::new(err)));
            }
        }
    }

    fn tiler_for(&self, p: Precision) -> Tiler {
        match p {
            Precision::Int8 => self.tiler_i32,
            _ => self.tiler_f32,
        }
    }

    /// Deadline for a tile dispatched now, per its precision.
    fn deadline_for(&self, p: Precision) -> Option<Instant> {
        let d = match p {
            Precision::Int8 => self.robust.deadline_i32,
            _ => self.robust.deadline_f32,
        };
        d.map(|d| Instant::now() + d)
    }

    fn flight_meta(&self, fid: u64, f: &Flight) -> FlightMeta {
        FlightMeta {
            fid,
            class: f.class,
            precision: f.req.precision,
            tile_cost: self.params.costs.cost(f.req.precision),
        }
    }

    /// Swap the scheduling policy live: rebuild it and re-admit every
    /// flight that still has unissued tiles, in flight-id (admission)
    /// order so the handover is deterministic.
    fn set_policy(&mut self, kind: PolicyKind) {
        self.params.kind = kind;
        self.policy = policy::build(&self.params);
        let mut open: Vec<u64> = self
            .flights
            .iter()
            .filter(|(_, f)| f.next_tile < f.total_tiles)
            .map(|(&fid, _)| fid)
            .collect();
        open.sort_unstable();
        for fid in open {
            let meta = self.flight_meta(fid, &self.flights[&fid]);
            self.policy.admit(meta);
        }
    }

    fn handle_admit(&mut self, mut adm: Box<Admitted>) {
        if self.draining {
            return; // Admitted::drop frees the slot and errors the reply
        }
        let req = adm.req;
        let token = adm.token;
        let submitted = adm.submitted;
        let ops = adm.ops.take().expect("operands consumed once");
        let reply = adm.reply.take().expect("reply consumed once");
        let class = self.params.clamp_class(req.class);
        // A request that arrives already past its deadline (it sat in
        // the admission queue too long) resolves typed immediately —
        // never scheduled, no partial work.
        let deadline = req.deadline.map(|d| submitted + d);
        if deadline.is_some_and(|dl| Instant::now() >= dl) {
            self.shed.deadline_expired.fetch_add(1, Ordering::Relaxed);
            let budget_ms = req.deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
            let err = DeadlineExceeded { id: req.id, shard: self.shard, budget_ms };
            self.gate.release(req.class);
            reply.send(req, Err(anyhow::Error::new(err)));
            return;
        }
        let (m, k, n) = (req.m as usize, req.k as usize, req.n as usize);
        let tiler = self.tiler_for(req.precision);
        let grid = tiler.grid(m, k, n);
        let (gm, gk, gn) = grid;
        let total_tiles = gm * gk * gn;
        // Degenerate (zero-tile) requests retire immediately — still
        // recorded, so stats().requests matches the replies delivered.
        if total_tiles == 0 {
            self.shared.stats.lock().unwrap().record(Completion {
                id: req.id,
                macs: req.macs(),
                precision: req.precision,
                class,
                wall: submitted.elapsed(),
                queued: submitted.elapsed(),
                service: Duration::ZERO,
                device_s: 0.0,
                invocations: 0,
            });
            let out = match ops {
                Operands::F32 { .. } => MatOutput::F32(vec![0.0; m * n]),
                Operands::I32 { .. } => MatOutput::I32(vec![0; m * n]),
            };
            self.gate.release(req.class);
            reply.send(req, Ok(out));
            return;
        }
        let data = match ops {
            Operands::F32 { a, b } => FlightData::F32(Pools::fresh(a, b, m * n)),
            Operands::I32 { a, b } => FlightData::I32(Pools::fresh(a, b, m * n)),
        };
        let fid = self.next_flight;
        self.next_flight += 1;
        self.flights.insert(
            fid,
            Flight {
                req,
                token,
                class,
                grid,
                tiler,
                data,
                next_tile: 0,
                total_tiles,
                done_tiles: 0,
                started: submitted,
                first_issue: None,
                deadline,
                invocations: 0,
                reply,
            },
        );
        self.tokens.insert(token, fid);
        let meta = self.flight_meta(fid, &self.flights[&fid]);
        self.policy.admit(meta);
    }

    /// Schedule the next tile of flight `fid` into the window.
    fn submit_one(&mut self, fid: u64) {
        let tag = self.next_tag;
        self.next_tag += 1;
        let (payload, desc, more) = {
            let Some(f) = self.flights.get_mut(&fid) else { return };
            let (_gm, gk, gn) = f.grid;
            let (m, k, n) = (f.req.m as usize, f.req.k as usize, f.req.n as usize);
            let tiler = f.tiler;
            if f.first_issue.is_none() {
                f.first_issue = Some(Instant::now());
            }
            // k-innermost walk: tile t = (im·gn + inn)·gk + ik.
            let t = f.next_tile;
            f.next_tile += 1;
            let ik = t % gk;
            let blk = t / gk;
            let im = blk / gn;
            let inn = blk % gn;
            let weight_id = f.req.weight_id;
            match &mut f.data {
                FlightData::F32(p) => p.pack(
                    m,
                    k,
                    n,
                    tiler,
                    weight_id,
                    &mut self.weight_cache,
                    self.pack_workers,
                    self.work_pool.as_ref(),
                    &self.pack_counters,
                ),
                FlightData::I32(p) => p.pack(
                    m,
                    k,
                    n,
                    tiler,
                    weight_id,
                    &mut self.weight_cache,
                    self.pack_workers,
                    self.work_pool.as_ref(),
                    &self.pack_counters,
                ),
            }
            let payload =
                payload_from_packed(f, im, inn, ik).expect("packed on first schedule");
            f.invocations += 1;
            let desc = JobDesc {
                flight: fid,
                im,
                inn,
                ik,
                worker: 0,
                retries: 0,
                issued: Instant::now(),
                deadline: None,
            };
            (payload, desc, f.next_tile < f.total_tiles)
        };
        let mut desc = desc;
        desc.deadline = self.deadline_for(self.flights[&fid].req.precision);
        self.descs.insert(tag, desc);
        self.policy.tile_issued(fid, more);
        match self.device.dispatch(TileJob { tag, payload, done: self.tile_tx.clone() }, None) {
            Ok(w) => {
                self.in_flight += 1;
                if let Some(d) = self.descs.get_mut(&tag) {
                    d.worker = w;
                }
            }
            Err(e) => {
                self.descs.remove(&tag);
                self.fail_flight(fid, e);
            }
        }
    }

    /// A tile attempt failed (device error, deadline expiry, or
    /// checksum rejection): re-dispatch it under a fresh tag — on a
    /// different worker when one is available — or fail the flight once
    /// the retry budget is spent. The retried partial is rebuilt from
    /// the immutable packed arenas, so a recovered flight's output is
    /// bit-identical to a fault-free run.
    fn retry_or_fail(&mut self, desc: JobDesc, err: anyhow::Error) {
        let fid = desc.flight;
        // Flight already gone (cancelled or failed on another tile):
        // nothing to recover. The attempt's window slot was freed by
        // the caller.
        let Some(f) = self.flights.get(&fid) else { return };
        if desc.retries >= self.robust.max_tile_retries {
            let exhausted = TileRetriesExhausted {
                id: f.req.id,
                attempts: desc.retries + 1,
                last: format!("{err:#}"),
                shard: self.shard,
            };
            self.counters.retries_exhausted.fetch_add(1, Ordering::Relaxed);
            self.fail_flight(fid, anyhow::Error::new(exhausted));
            return;
        }
        let precision = f.req.precision;
        let Some(payload) = payload_from_packed(f, desc.im, desc.inn, desc.ik) else {
            // Unreachable in practice: a tile that reached the device
            // implies its flight packed on first schedule.
            self.fail_flight(fid, err.context("tile faulted before its flight was packed"));
            return;
        };
        let tag = self.next_tag;
        self.next_tag += 1;
        let mut retried = desc;
        retried.retries += 1;
        retried.issued = Instant::now();
        retried.deadline = self.deadline_for(precision);
        self.counters.retries.fetch_add(1, Ordering::Relaxed);
        self.descs.insert(tag, retried);
        // The policy already charged this tile at first issue; only the
        // device-time attribution counts the re-execution.
        match self
            .device
            .dispatch(TileJob { tag, payload, done: self.tile_tx.clone() }, Some(desc.worker))
        {
            Ok(w) => {
                self.in_flight += 1;
                if let Some(d) = self.descs.get_mut(&tag) {
                    d.worker = w;
                }
                if let Some(f) = self.flights.get_mut(&fid) {
                    f.invocations += 1;
                }
            }
            Err(e) => {
                self.descs.remove(&tag);
                self.fail_flight(fid, e);
            }
        }
    }

    fn recycle_output(&self, out: TileOutput) {
        match out {
            TileOutput::F32(v) => self.bufs.fp32.put(v),
            TileOutput::I32(v) => self.bufs.int8.put(v),
        }
    }

    fn handle_done(&mut self, done: TileDone) {
        // A stale tag: its deadline expired and the slot was already
        // freed (and possibly re-dispatched). Drop the straggler —
        // recycling its buffer — so a partial can never double-reduce.
        if self.stale.remove(&done.tag) {
            if let Ok(out) = done.result {
                self.recycle_output(out);
            }
            return;
        }
        // Sample the window as it stood while this tile completed.
        let occ = self.in_flight;
        self.shared.window.lock().unwrap().record(occ);
        self.shared.last_window.lock().unwrap().record(occ);
        self.in_flight = self.in_flight.saturating_sub(1);
        let Some(desc) = self.descs.remove(&done.tag) else {
            // Unknown tag (defensive; tags are scheduler-issued) — the
            // buffer still recycles.
            if let Ok(out) = done.result {
                self.recycle_output(out);
            }
            return;
        };
        let fid = desc.flight;
        if !self.flights.contains_key(&fid) {
            // Flight failed or was cancelled: the straggler's result is
            // dead weight, but its buffer recycles.
            if let Ok(out) = done.result {
                self.recycle_output(out);
            }
            return;
        }
        // Verify the checksum when the pool attached one (chaos mode):
        // a corrupted payload is rejected here and enters the retry
        // path like any other tile fault.
        let result = match (done.result, done.crc) {
            (Ok(out), Some(crc)) if output_crc(&out) != crc => {
                self.counters.checksum_failures.fetch_add(1, Ordering::Relaxed);
                self.recycle_output(out);
                Err(anyhow::Error::new(TileCorrupted { worker: done.worker, shard: self.shard }))
            }
            (r, _) => r,
        };
        let output = match result {
            Ok(o) => {
                self.device.record_ok(done.worker);
                o
            }
            Err(e) => {
                self.device.record_fault(done.worker, self.robust.quarantine_after);
                self.retry_or_fail(desc, e);
                return;
            }
        };
        let matched = {
            let f = self.flights.get_mut(&fid).unwrap();
            let tiler = f.tiler;
            let (_gm, gk, _gn) = f.grid;
            let (m, n) = (f.req.m as usize, f.req.n as usize);
            match (&mut f.data, output) {
                (FlightData::F32(p), TileOutput::F32(partial)) => {
                    reduce_partial(
                        &mut self.accs_f32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                        &self.bufs.fp32,
                    );
                    true
                }
                (FlightData::I32(p), TileOutput::I32(partial)) => {
                    reduce_partial(
                        &mut self.accs_i32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                        &self.bufs.int8,
                    );
                    true
                }
                _ => false,
            }
        };
        if !matched {
            self.fail_flight(fid, anyhow!("device returned a tile in the wrong precision"));
            return;
        }
        let f = &self.flights[&fid];
        if f.done_tiles == f.total_tiles {
            self.retire(fid);
        }
    }

    /// Deliver a finished flight's output and free its admission slot.
    fn retire(&mut self, fid: u64) {
        let mut f = self.flights.remove(&fid).unwrap();
        self.tokens.remove(&f.token);
        self.policy.remove(fid);
        // Charge the flight exactly its own tiles (period × invocations)
        // — the shared device clock spans concurrently open flights and
        // would double-count overlap. Retries count as invocations: the
        // device (modulo injected non-executing faults) ran them.
        let period = self
            .device
            .info_for(f.req.precision)
            .map(|i| i.period_cycles)
            .unwrap_or_default();
        let (queued, service) = match f.first_issue {
            Some(t) => (t.duration_since(f.started), t.elapsed()),
            None => (f.started.elapsed(), Duration::ZERO),
        };
        self.shared.stats.lock().unwrap().record(Completion {
            id: f.req.id,
            macs: f.req.macs(),
            precision: f.req.precision,
            class: f.class,
            wall: f.started.elapsed(),
            queued,
            service,
            device_s: period * f.invocations as f64 / self.device.freq_hz,
            invocations: f.invocations,
        });
        let out = match &mut f.data {
            FlightData::F32(p) => MatOutput::F32(std::mem::take(&mut p.c)),
            FlightData::I32(p) => MatOutput::I32(std::mem::take(&mut p.c)),
        };
        self.gate.release(f.req.class);
        f.reply.send(f.req, Ok(out));
    }

    /// Drop one flight's scheduler state (queues, reduction buffers,
    /// token) and free its admission slot. Tiles already in the window
    /// are dropped on arrival by `handle_done`'s straggler path (which
    /// recycles their buffers); reduction state recycles here.
    fn evict(&mut self, fid: u64) -> Option<Flight> {
        let f = self.flights.remove(&fid)?;
        self.tokens.remove(&f.token);
        self.policy.remove(fid);
        drain_accs(&mut self.accs_f32, fid, &self.bufs.fp32);
        drain_accs(&mut self.accs_i32, fid, &self.bufs.int8);
        self.gate.release(f.req.class);
        Some(f)
    }

    /// Fail one flight without tearing the stream down.
    fn fail_flight(&mut self, fid: u64, err: anyhow::Error) {
        if let Some(f) = self.evict(fid) {
            f.reply.send(f.req, Err(err));
        }
    }

    /// Cancel the flight behind an admission token: unissued tiles are
    /// abandoned, slots reclaimed, and the reply resolves with
    /// [`Cancelled`]. Unknown tokens (already retired, failed, or
    /// cancelled twice) are a no-op — a handle resolves exactly once.
    fn handle_cancel(&mut self, token: u64) {
        let Some(&fid) = self.tokens.get(&token) else { return };
        if let Some(f) = self.evict(fid) {
            self.shared.stats.lock().unwrap().record_cancelled();
            f.reply.send(f.req, Err(Cancelled(f.req.id).into()));
        }
    }
}
