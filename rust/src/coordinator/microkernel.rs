//! The host **compute plane**: register-tiled GEMM microkernels, the
//! GotoBLAS2 packed-panel loop nest above them, and (behind the `simd`
//! feature) explicit AVX2/NEON kernels — the layer that turns the naive
//! scalar tile loop into the cache → register blocking hierarchy the
//! paper's whole thesis is built on.
//!
//! # Why this layer exists
//!
//! MaxEVA wins MatMul throughput by blocking at every level of the
//! memory hierarchy: the AIE kernel computes an `m×k×n` register tile
//! (fp32 32×32×32, int8 32×128×32), the X×Y×Z array aggregates kernels
//! into a native device tile, and the host tiles arbitrary problems
//! over that native size. Our serving engine mirrors the outer two
//! levels (the [`Tiler`] grid and the [`TilePool`] arenas); this module
//! is the innermost levels — how one native tile is actually multiplied
//! on the host. The GotoBLAS2-on-Versal mapping (Lei &
//! Quintana-Ortí, arXiv 2404.15043) and the Ryzen-AI GEMM study (Taka
//! et al., 2025) both land on the same structure: packed operand panels
//! feeding a small MR×NR microkernel whose accumulators live in
//! registers. Mapped onto MaxEVA's terms:
//!
//! | MaxEVA level                  | host compute plane              |
//! |-------------------------------|---------------------------------|
//! | AIE register tile (`m×k×n`)   | MR×NR accumulator block         |
//! | AIE memory-tile / PL buffers  | packed MC×KC / KC×NC panels     |
//! | array native tile (X·m,Y·k,Z·n) | one `matmul_*` call on a packed tile |
//! | PL tiling / zero-padding      | [`TilePool`] arenas + [`Tiler`] grid |
//!
//! # The MR×NR microkernel
//!
//! [`matmul_mk`] walks the output in MR×NR blocks. Each block keeps an
//! `[[T; NR]; MR]` accumulator in fixed-size arrays — small enough to
//! live entirely in vector registers — and runs **k innermost,
//! ascending**: for every k step it broadcasts `A[i][k]` against a
//! contiguous NR-wide row slice of `B`. The fixed NR trip count lets
//! the compiler unroll and vectorize the update, and the accumulators
//! are loaded/stored exactly once per block instead of once per k step
//! (the naive loop's O(k) traffic on `C` is the strength reduction).
//! Partial blocks at the m/n fringe run the same loop with runtime
//! `mr ≤ MR`, `nr ≤ NR` bounds, so every shape is handled without a
//! separate scalar path.
//!
//! # The packed-panel (GotoBLAS2) nest
//!
//! A native tile can be far larger than cache (fp32 flagship:
//! 416×128×192 ≈ 10 MB of streamed operands), so the flat MR×NR walk
//! re-streams whole operand rows from memory on every pass.
//! [`matmul_blocked`] wraps the microkernel in the GotoBLAS2 loop
//! nest: K is carved into KC chunks (outermost), N into NC chunks, M
//! into MC chunks, and each operand strip is **packed** into a dense
//! panel before the micro-tile walk runs over it:
//!
//! ```text
//! for pc in (0..k).step_by(KC)          // outermost: ascending k chunks
//!   for jc in (0..n).step_by(NC)        //   pack B[pc.., jc..] → KC×NC panel
//!     for ic in (0..m).step_by(MC)      //     pack A[ic.., pc..] → MC×KC panel
//!       for (i0, j0) in MC×NC by MR×NR  //       C[..] += Apanel · Bpanel
//!                                       //       (accumulators in registers)
//! ```
//!
//! [`PANEL_MC`]·[`PANEL_KC`]·4 B ≈ 64 KiB keeps the A panel resident
//! in L2 while a whole row of micro-tiles streams over it;
//! [`PANEL_KC`]·[`PANEL_NC`]·4 B ≈ 1 MiB holds the B panel in L3/L2
//! across all MC strips (both precisions store 4-byte elements).
//! [`panel_geom`] reports the bounds per precision, and
//! `benches/microkernel.rs --json` sweeps KC/MC/NC (the `block_sweep`
//! section of the `microkernel-gflops` CI artifact) so the constants
//! can be retuned per host.
//!
//! **Blocking never changes bits.** The KC chunk loop is *outermost*,
//! so each output element still receives its `A[i][kk]·B[kk][j]` terms
//! in ascending `kk` — now accumulated through `C` (pre-zeroed, loaded
//! and stored once per chunk) instead of a register kept live across
//! all of k. An f32 store/load round-trip is bit-exact (NaN payloads
//! included), packing copies preserve element bits (so the zero-skip
//! predicate sees identical values), and each term stays a separate
//! multiply-then-add. The per-element operation sequence is therefore
//! *identical* to the flat kernel's, and [`matmul_blocked`] is
//! bit-identical to [`matmul_mk`] for every shape and every panel
//! geometry — pinned here and in `tests/compute_plane.rs` over panel
//! bounds that do not divide m/k/n.
//!
//! # Bit-identity (the ascending-ik contract)
//!
//! The serving engine's fp32 determinism rests on every output element
//! being the **same sequence of f32 operations** regardless of path.
//! Every kernel in this module preserves that sequence exactly:
//!
//! * per element `(i, j)` the accumulator starts at `0.0` and adds
//!   `A[i][kk] * B[kk][j]` for `kk` **ascending** — the naive reference
//!   ([`matmul_naive_f32_into`]) orders the same element's terms
//!   identically (its `kk` loop is also ascending);
//! * terms with `A[i][kk] == 0.0` are skipped under the identical
//!   predicate in both kernels (the skip is observable in IEEE 754:
//!   `-0.0 + 0.0·b` flips the sign of a `-0.0` accumulator, and
//!   `0.0·inf` is NaN — so both kernels must agree on it);
//! * each product is a separate multiply-then-add (Rust never contracts
//!   to FMA implicitly), in both kernels.
//!
//! Hence `matmul_f32` is bit-identical to the naive loop for every
//! shape — pinned by `tests/compute_plane.rs` over exhaustive fringe
//! shapes — and the engine-wide ascending-`ik` reduction contract from
//! PRs 1–4 survives untouched. The int8 path (i32 carriers, wrapping
//! adds) is order-independent and therefore trivially exact.
//!
//! The explicit-SIMD kernels (`simd` submodule, `--features simd`)
//! uphold the *same* contract, and strictly: because the microkernel
//! broadcasts `A[i][kk]` across output columns, SIMD lanes are
//! independent output elements — there is **no lane reduction** whose
//! order could differ from scalar code. The SIMD path is bit-identical
//! to the scalar path, not merely ULP-close; see the submodule docs.
//!
//! # Dispatch
//!
//! [`matmul_f32`] / [`matmul_i32`] are the per-precision entry points,
//! compiled at [`MR_F32`]×[`NR_F32`] / [`MR_I32`]×[`NR_I32`] (chosen
//! so one block's accumulators fit the 16 vector registers of
//! mainstream SIMD ISAs with room for the broadcast and B-row
//! operands); [`micro_geom`] reports those geometries per precision.
//! A tile routes to the packed-panel nest when any dimension exceeds
//! its panel bound ([`panel_geom`]) and to the flat walk otherwise;
//! with the `simd` feature enabled and a capable CPU, the same nests
//! run with the AVX2/NEON panel kernels plugged in. Every route is
//! bit-identical, so dispatch is purely a performance decision.
//! `benches/microkernel.rs` sweeps alternative geometries, panel
//! bounds, and scalar-vs-SIMD kernels and reports GFLOP/s / GOP/s so
//! the defaults stay honest on real hardware.
//!
//! [`Tiler`]: crate::coordinator::tiler::Tiler
//! [`TilePool`]: crate::coordinator::pool::TilePool

use crate::arch::precision::Precision;

/// Rows of one fp32 accumulator block.
pub const MR_F32: usize = 4;
/// Columns of one fp32 accumulator block (4×16 f32 = 8 256-bit
/// registers of accumulator, leaving half the file for the broadcast
/// A value and the streamed B row).
pub const NR_F32: usize = 16;
/// Rows of one i32 accumulator block.
pub const MR_I32: usize = 4;
/// Columns of one i32 accumulator block.
pub const NR_I32: usize = 16;

/// Rows of one packed A panel (the MC in MC×KC): with [`PANEL_KC`],
/// 64×256 4-byte elements = 64 KiB — comfortably L2-resident under
/// the streamed B panel.
pub const PANEL_MC: usize = 64;
/// Depth of one K chunk (the KC in MC×KC / KC×NC): the unit of the
/// outermost loop, sized so an A panel row strip stays in L1/L2.
pub const PANEL_KC: usize = 256;
/// Columns of one packed B panel (the NC in KC×NC): 256×1024 4-byte
/// elements = 1 MiB, sized for L3 (or a large L2) so every MC strip
/// of A reuses the same resident B panel.
pub const PANEL_NC: usize = 1024;

/// Element types the microkernel multiplies: the fp32 datapath and the
/// int8 datapath's i32 carrier. `mul_acc` is one multiply-then-add in
/// the type's serving semantics (f32 IEEE add, i32 wrapping), and
/// `is_zero` is the A-operand skip predicate — both must match the
/// naive reference exactly for the bit-identity argument above.
pub trait MicroElem: Copy + Default + PartialEq + Send + Sync + 'static {
    fn mul_acc(acc: Self, a: Self, b: Self) -> Self;
    fn is_zero(self) -> bool;
}

impl MicroElem for f32 {
    #[inline(always)]
    fn mul_acc(acc: f32, a: f32, b: f32) -> f32 {
        acc + a * b
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0.0
    }
}

impl MicroElem for i32 {
    #[inline(always)]
    fn mul_acc(acc: i32, a: i32, b: i32) -> i32 {
        acc.wrapping_add(a.wrapping_mul(b))
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
}

/// Microkernel geometry of one precision's dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroGeom {
    pub mr: usize,
    pub nr: usize,
}

/// The MR×NR geometry [`matmul_f32`] / [`matmul_i32`] run a serving
/// precision with (int8-path tiles accumulate in i32, so they use the
/// i32 geometry).
pub fn micro_geom(p: Precision) -> MicroGeom {
    match p {
        Precision::Int8 => MicroGeom { mr: MR_I32, nr: NR_I32 },
        _ => MicroGeom { mr: MR_F32, nr: NR_F32 },
    }
}

/// Panel bounds of the GotoBLAS2 nest (the MC/KC/NC of the module
/// docs' diagram). All three must be > 0; none needs to divide the
/// problem shape — fringe panels shrink to fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelGeom {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

/// The panel bounds [`matmul_f32`] / [`matmul_i32`] block a serving
/// precision with. Both precisions move 4-byte elements, so they share
/// [`PANEL_MC`]/[`PANEL_KC`]/[`PANEL_NC`] today; the per-precision
/// split exists so the block-size sweep in `benches/microkernel.rs`
/// can retune them independently later.
pub fn panel_geom(p: Precision) -> PanelGeom {
    match p {
        Precision::Int8 => PanelGeom { mc: PANEL_MC, kc: PANEL_KC, nc: PANEL_NC },
        _ => PanelGeom { mc: PANEL_MC, kc: PANEL_KC, nc: PANEL_NC },
    }
}

/// Whether a problem is big enough for the packed-panel nest: any
/// dimension overflowing its panel bound means the flat walk would
/// re-stream operands through cache once per pass.
fn wants_blocking(m: usize, k: usize, n: usize, pg: PanelGeom) -> bool {
    m > pg.mc || k > pg.kc || n > pg.nc
}

/// One full MR×NR output block: accumulators in fixed-size arrays
/// (registers), k innermost ascending, A-zero skip — see the module
/// docs for why this exact shape is both fast and bit-identical.
#[inline]
fn block_full<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
) {
    let mut acc = [[T::default(); NR]; MR];
    for kk in 0..k {
        let boff = kk * n + j0;
        let brow = &b[boff..boff + NR];
        for (i, arow) in acc.iter_mut().enumerate() {
            let av = a[(i0 + i) * k + kk];
            if av.is_zero() {
                continue;
            }
            for j in 0..NR {
                arow[j] = T::mul_acc(arow[j], av, brow[j]);
            }
        }
    }
    for (i, arow) in acc.iter().enumerate() {
        let off = (i0 + i) * n + j0;
        c[off..off + NR].copy_from_slice(arow);
    }
}

/// A partial block at the m/n fringe: the same loop with runtime
/// `mr ≤ MR`, `nr ≤ NR` bounds (the accumulator array stays fixed-size;
/// only its `mr×nr` prefix is used and written back).
#[inline]
fn block_fringe<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[T::default(); NR]; MR];
    for kk in 0..k {
        let boff = kk * n + j0;
        let brow = &b[boff..boff + nr];
        for (i, arow) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i0 + i) * k + kk];
            if av.is_zero() {
                continue;
            }
            for (dst, &bv) in arow[..nr].iter_mut().zip(brow) {
                *dst = T::mul_acc(*dst, av, bv);
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let off = (i0 + i) * n + j0;
        c[off..off + nr].copy_from_slice(&arow[..nr]);
    }
}

/// Register-tiled row-major GEMM: `C (m×n) = A (m×k) · B (k×n)` through
/// MR×NR accumulator blocks — the **flat** walk (no panel packing).
/// `c` is fully overwritten (stale contents are fine — the recycling
/// free-lists hand these kernels dirty buffers). Outputs are
/// bit-identical to the naive reference loop for every shape, in both
/// element types (module docs).
pub fn matmul_mk<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
) {
    assert!(MR > 0 && NR > 0, "degenerate microkernel geometry");
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "output shape mismatch");
    let mut i0 = 0;
    while i0 < m {
        let mr = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nr = (n - j0).min(NR);
            if mr == MR && nr == NR {
                block_full::<T, MR, NR>(c, a, b, k, n, i0, j0);
            } else {
                block_fringe::<T, MR, NR>(c, a, b, k, n, i0, j0, mr, nr);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// An accumulating panel kernel: adds the `mr×nr` product of an A
/// strip and a B strip into a C sub-block, `kk` ascending, reading
/// A rows at stride `lda` from `a[a0..]`, B rows at stride `ldb` from
/// `b[b0..]`, and loading/storing C rows at stride `ldc` from
/// `c[c0..]`. The blocked and flat drivers are generic over this shape
/// so the SIMD kernels plug into the identical loop nest.
type PanelKernel<T> = fn(
    c: &mut [T],
    ldc: usize,
    c0: usize,
    a: &[T],
    lda: usize,
    a0: usize,
    b: &[T],
    ldb: usize,
    b0: usize,
    kc: usize,
    mr: usize,
    nr: usize,
);

/// The scalar [`PanelKernel`]: [`block_full`]/[`block_fringe`] with
/// the epilogue changed from overwrite to load-accumulate-store. The
/// per-element operation sequence (ascending `kk`, A-zero skip,
/// separate multiply-then-add) is exactly the flat kernels'.
#[inline]
fn accum_block<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    ldc: usize,
    c0: usize,
    a: &[T],
    lda: usize,
    a0: usize,
    b: &[T],
    ldb: usize,
    b0: usize,
    kc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[T::default(); NR]; MR];
    for (i, arow) in acc.iter_mut().enumerate().take(mr) {
        let off = c0 + i * ldc;
        arow[..nr].copy_from_slice(&c[off..off + nr]);
    }
    for kk in 0..kc {
        let boff = b0 + kk * ldb;
        let brow = &b[boff..boff + nr];
        for (i, arow) in acc.iter_mut().enumerate().take(mr) {
            let av = a[a0 + i * lda + kk];
            if av.is_zero() {
                continue;
            }
            for (dst, &bv) in arow[..nr].iter_mut().zip(brow) {
                *dst = T::mul_acc(*dst, av, bv);
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let off = c0 + i * ldc;
        c[off..off + nr].copy_from_slice(&arow[..nr]);
    }
}

/// Copy the `rows×cols` submatrix of row-major `src` (row stride
/// `stride`, origin `(r0, c0)`) into the dense row-major panel
/// `dst[..rows*cols]`. A verbatim bit copy: packed panels preserve
/// exact element bits, so the kernels' zero-skip predicate and f32
/// term values are unchanged by packing.
fn pack_panel<T: Copy>(
    dst: &mut [T],
    src: &[T],
    stride: usize,
    r0: usize,
    c0: usize,
    rows: usize,
    cols: usize,
) {
    for r in 0..rows {
        let s = (r0 + r) * stride + c0;
        dst[r * cols..(r + 1) * cols].copy_from_slice(&src[s..s + cols]);
    }
}

/// The GotoBLAS2 nest of the module docs, generic over the panel
/// kernel: zero `c`, then `pc → jc → ic → (i0, j0)` with packed A/B
/// panels. `pc` outermost keeps per-element `kk` ascending across
/// chunks — the whole bit-identity argument.
fn run_blocked<T: MicroElem>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
    pg: PanelGeom,
    mr_max: usize,
    nr_max: usize,
    kernel: PanelKernel<T>,
) {
    assert!(pg.mc > 0 && pg.kc > 0 && pg.nc > 0, "degenerate panel geometry");
    assert!(mr_max > 0 && nr_max > 0, "degenerate microkernel geometry");
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(T::default());
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let mut apack = vec![T::default(); pg.mc.min(m) * pg.kc.min(k)];
    let mut bpack = vec![T::default(); pg.kc.min(k) * pg.nc.min(n)];
    let mut pc = 0;
    while pc < k {
        let kc = (k - pc).min(pg.kc);
        let mut jc = 0;
        while jc < n {
            let nc = (n - jc).min(pg.nc);
            pack_panel(&mut bpack[..kc * nc], b, n, pc, jc, kc, nc);
            let mut ic = 0;
            while ic < m {
                let mc = (m - ic).min(pg.mc);
                pack_panel(&mut apack[..mc * kc], a, k, ic, pc, mc, kc);
                let mut i0 = 0;
                while i0 < mc {
                    let mr = (mc - i0).min(mr_max);
                    let mut j0 = 0;
                    while j0 < nc {
                        let nr = (nc - j0).min(nr_max);
                        kernel(
                            c,
                            n,
                            (ic + i0) * n + jc + j0,
                            &apack,
                            kc,
                            i0 * kc,
                            &bpack,
                            nc,
                            j0,
                            kc,
                            mr,
                            nr,
                        );
                        j0 += nr_max;
                    }
                    i0 += mr_max;
                }
                ic += pg.mc;
            }
            jc += pg.nc;
        }
        pc += pg.kc;
    }
}

/// The flat walk generic over the panel kernel: zero `c`, one
/// accumulate pass per MR×NR block with the full operands as the
/// "panels" (`kc = k`). Used by the SIMD dispatch for problems below
/// the blocking threshold; bit-identical to [`matmul_mk`]. (The
/// scalar dispatch prefers [`matmul_mk`] directly — its overwrite
/// epilogue skips the load of `C` — so this driver is only reachable
/// with the `simd` feature.)
#[cfg_attr(not(feature = "simd"), allow(dead_code))]
fn run_flat<T: MicroElem>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
    mr_max: usize,
    nr_max: usize,
    kernel: PanelKernel<T>,
) {
    assert!(mr_max > 0 && nr_max > 0, "degenerate microkernel geometry");
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(T::default());
    let mut i0 = 0;
    while i0 < m {
        let mr = (m - i0).min(mr_max);
        let mut j0 = 0;
        while j0 < n {
            let nr = (n - j0).min(nr_max);
            kernel(c, n, i0 * n + j0, a, k, i0 * k, b, n, j0, k, mr, nr);
            j0 += nr_max;
        }
        i0 += mr_max;
    }
}

/// Cache-blocked row-major GEMM: the packed-panel nest over the scalar
/// MR×NR microkernel, with explicit panel bounds. Bit-identical to
/// [`matmul_mk`] (and hence to the naive reference) for every shape
/// and every valid `pg` — see the module docs' blocking argument.
/// `c` is fully overwritten.
pub fn matmul_blocked<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
    pg: PanelGeom,
) {
    assert!(MR > 0 && NR > 0, "degenerate microkernel geometry");
    run_blocked(c, a, b, m, k, n, pg, MR, NR, accum_block::<T, MR, NR>);
}

/// The fp32 compute-plane entry point — what the reference device
/// workers and [`matmul_ref_f32_into`] execute per native tile. Routes
/// to the packed-panel nest for above-panel shapes, the flat walk
/// otherwise, and (with `--features simd` on a capable CPU) the
/// explicit-SIMD kernels — all bit-identical, so dispatch is purely a
/// performance decision.
///
/// [`matmul_ref_f32_into`]: crate::coordinator::tiler::matmul_ref_f32_into
pub fn matmul_f32(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd")]
    if simd::available() {
        simd::matmul_f32(c, a, b, m, k, n);
        return;
    }
    let pg = panel_geom(Precision::Fp32);
    if wants_blocking(m, k, n, pg) {
        matmul_blocked::<f32, MR_F32, NR_F32>(c, a, b, m, k, n, pg);
    } else {
        matmul_mk::<f32, MR_F32, NR_F32>(c, a, b, m, k, n);
    }
}

/// The i32 (int8-path) compute-plane entry point, with the same
/// blocked/flat/SIMD dispatch as [`matmul_f32`]. Wrapping arithmetic:
/// exact under any order, like the naive loop.
pub fn matmul_i32(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd")]
    if simd::available() {
        simd::matmul_i32(c, a, b, m, k, n);
        return;
    }
    let pg = panel_geom(Precision::Int8);
    if wants_blocking(m, k, n, pg) {
        matmul_blocked::<i32, MR_I32, NR_I32>(c, a, b, m, k, n, pg);
    } else {
        matmul_mk::<i32, MR_I32, NR_I32>(c, a, b, m, k, n);
    }
}

/// The pre-compute-plane scalar `ikj` loop, kept verbatim as the
/// bit-identity **oracle**: property tests pin `matmul_f32` /
/// `matmul_i32` against it over exhaustive fringe shapes, and the
/// microkernel bench reports its GFLOP/s as the baseline. `c` is fully
/// overwritten.
pub fn matmul_naive_f32_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// [`matmul_naive_f32_into`]'s i32 sibling (wrapping adds, the int8
/// path's exact semantics).
pub fn matmul_naive_i32_into(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv = cv.wrapping_add(av.wrapping_mul(bv));
            }
        }
    }
}

/// Explicit-SIMD panel kernels (`--features simd`): AVX2 on x86_64,
/// NEON on aarch64, runtime-detected, with the scalar microkernel as
/// the universal fallback.
///
/// # Channel strategy
///
/// `std::simd` is still nightly-only, so this module is written
/// against the **stable `core::arch` intrinsics** instead — the `simd`
/// feature builds on the same stable/MSRV toolchains as the rest of
/// the crate (no nightly leg in CI, see ci.yml). On targets with
/// neither ISA, or hosts whose CPU lacks it at runtime, [`available`]
/// reports `false` and dispatch falls back to the scalar kernels —
/// enabling the feature is always safe.
///
/// # Reduction order: exactly the scalar sequence
///
/// The microkernel broadcasts `A[i][kk]` against a contiguous row of
/// B, so SIMD lanes are **independent output columns**, never partial
/// sums of one element — there is no lane reduction to reorder. Each
/// lane performs the identical ascending-`kk` multiply-then-add
/// sequence as the scalar kernel (separate `mul`/`add` intrinsics;
/// FMA would contract the rounding step and change bits, so it is
/// deliberately not used), and the A-zero skip is the same scalar
/// predicate per row. These kernels are therefore **bit-identical** to
/// the scalar microkernel for fp32 — stronger than the ULP-bounded
/// contract the serving layer would tolerate — and exact for i32
/// (wrapping `mullo`/`add`). Pinned by the `simd_*` tests in this
/// module over flat, blocked, and fringe shapes.
#[cfg(feature = "simd")]
pub mod simd {
    use super::*;

    #[cfg(target_arch = "x86_64")]
    fn detect() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[cfg(target_arch = "aarch64")]
    fn detect() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    fn detect() -> bool {
        false
    }

    /// `true` when the running CPU supports the ISA the arch kernels
    /// target (AVX2 on x86_64, NEON on aarch64). `std` caches the
    /// detection, so this is an atomic load after the first call.
    pub fn available() -> bool {
        detect()
    }

    /// Panel kernel with the SIMD full-block fast path; fringe blocks
    /// (`mr < MR`, `nr < NR`) and non-SIMD hosts take the scalar
    /// accumulate path — identical bits either way.
    fn kernel_f32(
        c: &mut [f32],
        ldc: usize,
        c0: usize,
        a: &[f32],
        lda: usize,
        a0: usize,
        b: &[f32],
        ldb: usize,
        b0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if mr == MR_F32 && nr == NR_F32 && detect() {
            // Safety: AVX2 presence verified by `detect()` above.
            unsafe { x86::panel_f32_4x16(c, ldc, c0, a, lda, a0, b, ldb, b0, kc) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if mr == MR_F32 && nr == NR_F32 && detect() {
            // Safety: NEON presence verified by `detect()` above.
            unsafe { neon::panel_f32_4x16(c, ldc, c0, a, lda, a0, b, ldb, b0, kc) };
            return;
        }
        accum_block::<f32, MR_F32, NR_F32>(c, ldc, c0, a, lda, a0, b, ldb, b0, kc, mr, nr);
    }

    /// [`kernel_f32`]'s i32 sibling.
    fn kernel_i32(
        c: &mut [i32],
        ldc: usize,
        c0: usize,
        a: &[i32],
        lda: usize,
        a0: usize,
        b: &[i32],
        ldb: usize,
        b0: usize,
        kc: usize,
        mr: usize,
        nr: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if mr == MR_I32 && nr == NR_I32 && detect() {
            // Safety: AVX2 presence verified by `detect()` above.
            unsafe { x86::panel_i32_4x16(c, ldc, c0, a, lda, a0, b, ldb, b0, kc) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if mr == MR_I32 && nr == NR_I32 && detect() {
            // Safety: NEON presence verified by `detect()` above.
            unsafe { neon::panel_i32_4x16(c, ldc, c0, a, lda, a0, b, ldb, b0, kc) };
            return;
        }
        accum_block::<i32, MR_I32, NR_I32>(c, ldc, c0, a, lda, a0, b, ldb, b0, kc, mr, nr);
    }

    /// The SIMD fp32 entry: the same blocked/flat dispatch as the
    /// scalar [`matmul_f32`](super::matmul_f32) with the AVX2/NEON
    /// panel kernel plugged in. Bit-identical to the scalar path
    /// (module docs); correct (via scalar fallback blocks) even when
    /// [`available`] is `false`.
    pub fn matmul_f32(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        let pg = panel_geom(Precision::Fp32);
        if wants_blocking(m, k, n, pg) {
            run_blocked(c, a, b, m, k, n, pg, MR_F32, NR_F32, kernel_f32);
        } else {
            run_flat(c, a, b, m, k, n, MR_F32, NR_F32, kernel_f32);
        }
    }

    /// The SIMD i32 entry, mirroring [`matmul_f32`](self::matmul_f32).
    pub fn matmul_i32(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
        let pg = panel_geom(Precision::Int8);
        if wants_blocking(m, k, n, pg) {
            run_blocked(c, a, b, m, k, n, pg, MR_I32, NR_I32, kernel_i32);
        } else {
            run_flat(c, a, b, m, k, n, MR_I32, NR_I32, kernel_i32);
        }
    }

    #[cfg(target_arch = "x86_64")]
    mod x86 {
        use std::arch::x86_64::*;

        /// Full 4×16 fp32 accumulate block: 8 AVX2 accumulator
        /// registers (4 rows × 2 `__m256`), ascending `kk`, scalar
        /// A-zero skip, separate `mul`+`add` (never FMA — contraction
        /// would change bits). All memory access is through
        /// bounds-checked slices; only the ISA contract is unsafe.
        ///
        /// # Safety
        /// The caller must have verified AVX2 support at runtime.
        #[target_feature(enable = "avx2")]
        pub unsafe fn panel_f32_4x16(
            c: &mut [f32],
            ldc: usize,
            c0: usize,
            a: &[f32],
            lda: usize,
            a0: usize,
            b: &[f32],
            ldb: usize,
            b0: usize,
            kc: usize,
        ) {
            let mut acc = [[_mm256_setzero_ps(); 2]; 4];
            for (i, row) in acc.iter_mut().enumerate() {
                let off = c0 + i * ldc;
                row[0] = _mm256_loadu_ps(c[off..off + 8].as_ptr());
                row[1] = _mm256_loadu_ps(c[off + 8..off + 16].as_ptr());
            }
            for kk in 0..kc {
                let boff = b0 + kk * ldb;
                let blo = _mm256_loadu_ps(b[boff..boff + 8].as_ptr());
                let bhi = _mm256_loadu_ps(b[boff + 8..boff + 16].as_ptr());
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = a[a0 + i * lda + kk];
                    if av == 0.0 {
                        continue;
                    }
                    let avv = _mm256_set1_ps(av);
                    row[0] = _mm256_add_ps(row[0], _mm256_mul_ps(avv, blo));
                    row[1] = _mm256_add_ps(row[1], _mm256_mul_ps(avv, bhi));
                }
            }
            for (i, row) in acc.iter().enumerate() {
                let off = c0 + i * ldc;
                _mm256_storeu_ps(c[off..off + 8].as_mut_ptr(), row[0]);
                _mm256_storeu_ps(c[off + 8..off + 16].as_mut_ptr(), row[1]);
            }
        }

        /// Full 4×16 i32 accumulate block: wrapping `mullo`/`add`
        /// lanes — exactly the scalar wrapping semantics.
        ///
        /// # Safety
        /// The caller must have verified AVX2 support at runtime.
        #[target_feature(enable = "avx2")]
        pub unsafe fn panel_i32_4x16(
            c: &mut [i32],
            ldc: usize,
            c0: usize,
            a: &[i32],
            lda: usize,
            a0: usize,
            b: &[i32],
            ldb: usize,
            b0: usize,
            kc: usize,
        ) {
            let mut acc = [[_mm256_setzero_si256(); 2]; 4];
            for (i, row) in acc.iter_mut().enumerate() {
                let off = c0 + i * ldc;
                row[0] = _mm256_loadu_si256(c[off..off + 8].as_ptr() as *const __m256i);
                row[1] = _mm256_loadu_si256(c[off + 8..off + 16].as_ptr() as *const __m256i);
            }
            for kk in 0..kc {
                let boff = b0 + kk * ldb;
                let blo = _mm256_loadu_si256(b[boff..boff + 8].as_ptr() as *const __m256i);
                let bhi = _mm256_loadu_si256(b[boff + 8..boff + 16].as_ptr() as *const __m256i);
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = a[a0 + i * lda + kk];
                    if av == 0 {
                        continue;
                    }
                    let avv = _mm256_set1_epi32(av);
                    row[0] = _mm256_add_epi32(row[0], _mm256_mullo_epi32(avv, blo));
                    row[1] = _mm256_add_epi32(row[1], _mm256_mullo_epi32(avv, bhi));
                }
            }
            for (i, row) in acc.iter().enumerate() {
                let off = c0 + i * ldc;
                _mm256_storeu_si256(c[off..off + 8].as_mut_ptr() as *mut __m256i, row[0]);
                _mm256_storeu_si256(c[off + 8..off + 16].as_mut_ptr() as *mut __m256i, row[1]);
            }
        }
    }

    #[cfg(target_arch = "aarch64")]
    mod neon {
        use std::arch::aarch64::*;

        /// Full 4×16 fp32 accumulate block on NEON: 16 `float32x4_t`
        /// accumulators (4 rows × 4 quads), ascending `kk`, scalar
        /// A-zero skip, separate `vmul`+`vadd` (never `vfma` —
        /// contraction would change bits).
        ///
        /// # Safety
        /// The caller must have verified NEON support at runtime.
        #[target_feature(enable = "neon")]
        pub unsafe fn panel_f32_4x16(
            c: &mut [f32],
            ldc: usize,
            c0: usize,
            a: &[f32],
            lda: usize,
            a0: usize,
            b: &[f32],
            ldb: usize,
            b0: usize,
            kc: usize,
        ) {
            let mut acc = [[vdupq_n_f32(0.0); 4]; 4];
            for (i, row) in acc.iter_mut().enumerate() {
                let off = c0 + i * ldc;
                for (q, lane) in row.iter_mut().enumerate() {
                    *lane = vld1q_f32(c[off + 4 * q..off + 4 * q + 4].as_ptr());
                }
            }
            for kk in 0..kc {
                let boff = b0 + kk * ldb;
                let mut brow = [vdupq_n_f32(0.0); 4];
                for (q, lane) in brow.iter_mut().enumerate() {
                    *lane = vld1q_f32(b[boff + 4 * q..boff + 4 * q + 4].as_ptr());
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = a[a0 + i * lda + kk];
                    if av == 0.0 {
                        continue;
                    }
                    let avv = vdupq_n_f32(av);
                    for (dst, &bq) in row.iter_mut().zip(brow.iter()) {
                        *dst = vaddq_f32(*dst, vmulq_f32(avv, bq));
                    }
                }
            }
            for (i, row) in acc.iter().enumerate() {
                let off = c0 + i * ldc;
                for (q, lane) in row.iter().enumerate() {
                    vst1q_f32(c[off + 4 * q..off + 4 * q + 4].as_mut_ptr(), *lane);
                }
            }
        }

        /// Full 4×16 i32 accumulate block on NEON: wrapping
        /// `vmul`/`vadd` lanes — exactly the scalar wrapping
        /// semantics.
        ///
        /// # Safety
        /// The caller must have verified NEON support at runtime.
        #[target_feature(enable = "neon")]
        pub unsafe fn panel_i32_4x16(
            c: &mut [i32],
            ldc: usize,
            c0: usize,
            a: &[i32],
            lda: usize,
            a0: usize,
            b: &[i32],
            ldb: usize,
            b0: usize,
            kc: usize,
        ) {
            let mut acc = [[vdupq_n_s32(0); 4]; 4];
            for (i, row) in acc.iter_mut().enumerate() {
                let off = c0 + i * ldc;
                for (q, lane) in row.iter_mut().enumerate() {
                    *lane = vld1q_s32(c[off + 4 * q..off + 4 * q + 4].as_ptr());
                }
            }
            for kk in 0..kc {
                let boff = b0 + kk * ldb;
                let mut brow = [vdupq_n_s32(0); 4];
                for (q, lane) in brow.iter_mut().enumerate() {
                    *lane = vld1q_s32(b[boff + 4 * q..boff + 4 * q + 4].as_ptr());
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = a[a0 + i * lda + kk];
                    if av == 0 {
                        continue;
                    }
                    let avv = vdupq_n_s32(av);
                    for (dst, &bq) in row.iter_mut().zip(brow.iter()) {
                        *dst = vaddq_s32(*dst, vmulq_s32(avv, bq));
                    }
                }
            }
            for (i, row) in acc.iter().enumerate() {
                let off = c0 + i * ldc;
                for (q, lane) in row.iter().enumerate() {
                    vst1q_s32(c[off + 4 * q..off + 4 * q + 4].as_mut_ptr(), *lane);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    /// Random operands with a deliberate sprinkling of exact zeros in A
    /// so the zero-skip predicate is exercised, not just dead code.
    fn rand_f32(len: usize, rng: &mut XorShift64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.gen_range(0, 4) == 0 {
                    0.0
                } else {
                    rng.gen_range_f64(-1.0, 1.0) as f32
                }
            })
            .collect()
    }

    fn rand_i32(len: usize, rng: &mut XorShift64) -> Vec<i32> {
        (0..len)
            .map(|_| {
                if rng.gen_range(0, 4) == 0 {
                    0
                } else {
                    rng.gen_range(0, 256) as i32 - 128
                }
            })
            .collect()
    }

    #[test]
    fn microkernel_bit_identical_to_naive_random_shapes() {
        let mut rng = XorShift64::new(0x5EED);
        for _ in 0..40 {
            let m = rng.gen_range(1, 40) as usize;
            let k = rng.gen_range(1, 24) as usize;
            let n = rng.gen_range(1, 40) as usize;
            let a = rand_f32(m * k, &mut rng);
            let b = rand_f32(k * n, &mut rng);
            let mut want = vec![f32::NAN; m * n];
            let mut got = vec![f32::NAN; m * n];
            matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
            matmul_f32(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "fp32 {m}x{k}x{n} must be bit-identical");

            let ai = rand_i32(m * k, &mut rng);
            let bi = rand_i32(k * n, &mut rng);
            let mut wi = vec![i32::MIN; m * n];
            let mut gi = vec![i32::MIN; m * n];
            matmul_naive_i32_into(&mut wi, &ai, &bi, m, k, n);
            matmul_i32(&mut gi, &ai, &bi, m, k, n);
            assert_eq!(gi, wi, "i32 {m}x{k}x{n} must be exact");
        }
    }

    #[test]
    fn blocked_nest_bit_identical_to_flat_over_odd_panels() {
        // Panel bounds deliberately NOT dividing m/k/n — pathological
        // {1,1,1}, coprime odd bounds, a nest that only blocks one
        // dimension, and the production geometry — against the flat
        // kernel. fp32 equality is exact (==): the pc-outermost nest
        // preserves each element's ascending-kk operation sequence.
        let geoms = [
            PanelGeom { mc: 1, kc: 1, nc: 1 },
            PanelGeom { mc: 5, kc: 3, nc: 7 },
            PanelGeom { mc: 64, kc: 2, nc: 1024 },
            panel_geom(Precision::Fp32),
        ];
        let mut rng = XorShift64::new(0x90B5);
        for _ in 0..12 {
            let m = rng.gen_range(1, 34) as usize;
            let k = rng.gen_range(1, 26) as usize;
            let n = rng.gen_range(1, 34) as usize;
            let a = rand_f32(m * k, &mut rng);
            let b = rand_f32(k * n, &mut rng);
            let mut want = vec![f32::NAN; m * n];
            matmul_mk::<f32, MR_F32, NR_F32>(&mut want, &a, &b, m, k, n);
            let ai = rand_i32(m * k, &mut rng);
            let bi = rand_i32(k * n, &mut rng);
            let mut wi = vec![i32::MIN; m * n];
            matmul_mk::<i32, MR_I32, NR_I32>(&mut wi, &ai, &bi, m, k, n);
            for pg in geoms {
                let mut got = vec![f32::NAN; m * n];
                matmul_blocked::<f32, MR_F32, NR_F32>(&mut got, &a, &b, m, k, n, pg);
                assert_eq!(got, want, "fp32 {m}x{k}x{n} under {pg:?}");
                let mut gi = vec![i32::MIN; m * n];
                matmul_blocked::<i32, MR_I32, NR_I32>(&mut gi, &ai, &bi, m, k, n, pg);
                assert_eq!(gi, wi, "i32 {m}x{k}x{n} under {pg:?}");
            }
        }
    }

    #[test]
    fn dispatched_blocked_path_matches_naive_above_panel_bounds() {
        // Shapes that overflow a panel bound route matmul_f32/i32
        // through the blocked nest — the entry points must still be
        // bit-identical to the naive oracle there.
        let mut rng = XorShift64::new(0xB10C);
        for &(m, k, n) in &[
            (PANEL_MC + 7, 19, 33),       // m overflows MC
            (9, PANEL_KC + 5, 12),        // k overflows KC
            (6, 11, PANEL_NC + 3),        // n overflows NC
            (PANEL_MC + 1, PANEL_KC + 1, 40), // two dimensions at once
        ] {
            let a = rand_f32(m * k, &mut rng);
            let b = rand_f32(k * n, &mut rng);
            let mut want = vec![f32::NAN; m * n];
            let mut got = vec![f32::NAN; m * n];
            matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
            matmul_f32(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "fp32 {m}x{k}x{n}");

            let ai = rand_i32(m * k, &mut rng);
            let bi = rand_i32(k * n, &mut rng);
            let mut wi = vec![i32::MIN; m * n];
            let mut gi = vec![i32::MAX; m * n];
            matmul_naive_i32_into(&mut wi, &ai, &bi, m, k, n);
            matmul_i32(&mut gi, &ai, &bi, m, k, n);
            assert_eq!(gi, wi, "i32 {m}x{k}x{n}");
        }
    }

    #[test]
    fn zero_skip_semantics_match_exactly() {
        // The observable IEEE edge: a zero A value must be *skipped*
        // (matching the naive loop), not multiplied through — otherwise
        // 0·inf would poison the accumulator with NaN.
        let a = vec![0.0f32, 1.0];
        let b = vec![f32::INFINITY, 2.0];
        let mut got = vec![f32::NAN; 1];
        let mut want = vec![f32::NAN; 1];
        matmul_f32(&mut got, &a, &b, 1, 2, 1);
        matmul_naive_f32_into(&mut want, &a, &b, 1, 2, 1);
        assert_eq!(got, want);
        assert_eq!(got[0], 2.0, "the inf paired with a==0 is skipped in both kernels");
        // And through the blocked nest: the packed copy preserves the
        // exact zero, so the skip fires identically there.
        let mut blocked = vec![f32::NAN; 1];
        matmul_blocked::<f32, MR_F32, NR_F32>(
            &mut blocked,
            &a,
            &b,
            1,
            2,
            1,
            PanelGeom { mc: 1, kc: 1, nc: 1 },
        );
        assert_eq!(blocked, want);
    }

    #[test]
    fn degenerate_shapes_overwrite_everything() {
        // k = 0: pure zero fill over stale contents.
        let mut c = vec![f32::NAN; 6];
        matmul_f32(&mut c, &[], &[], 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
        // m or n = 0: empty output, no panic.
        let mut empty: Vec<f32> = Vec::new();
        matmul_f32(&mut empty, &[], &[1.0, 2.0], 0, 1, 2);
        matmul_f32(&mut empty, &[1.0, 2.0], &[], 2, 1, 0);
        // The blocked nest handles the same degenerate shapes.
        let mut c = vec![f32::NAN; 6];
        matmul_blocked::<f32, MR_F32, NR_F32>(&mut c, &[], &[], 2, 0, 3, panel_geom(Precision::Fp32));
        assert_eq!(c, vec![0.0; 6]);
    }

    #[test]
    fn alternate_geometries_stay_bit_identical() {
        // The bit-identity argument is geometry-independent (per-element
        // order never depends on MR/NR); pin it for the sweep geometries
        // the bench exercises.
        let mut rng = XorShift64::new(0xBE57);
        let (m, k, n) = (19usize, 13usize, 23usize);
        let a = rand_f32(m * k, &mut rng);
        let b = rand_f32(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
        let mut got = vec![f32::NAN; m * n];
        matmul_mk::<f32, 1, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 2, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 8, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 8, 16>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
    }

    #[test]
    fn dispatch_geometry_per_precision() {
        assert_eq!(micro_geom(Precision::Fp32), MicroGeom { mr: MR_F32, nr: NR_F32 });
        assert_eq!(micro_geom(Precision::Int8), MicroGeom { mr: MR_I32, nr: NR_I32 });
        assert_eq!(
            panel_geom(Precision::Fp32),
            PanelGeom { mc: PANEL_MC, kc: PANEL_KC, nc: PANEL_NC }
        );
        assert_eq!(
            panel_geom(Precision::Int8),
            PanelGeom { mc: PANEL_MC, kc: PANEL_KC, nc: PANEL_NC }
        );
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_path_bit_identical_to_scalar() {
        // The headline SIMD contract: not ULP-close — *bit-identical*.
        // Lanes are independent output columns (no lane reduction), so
        // the SIMD entries must reproduce the scalar kernels' bits
        // exactly, over flat shapes, fringe shapes, and shapes that
        // route through the blocked nest. On hosts without the ISA the
        // SIMD entries fall back to the scalar blocks and the equality
        // holds trivially; with it, the AVX2/NEON kernels are on trial.
        let mut rng = XorShift64::new(0x51D0);
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 8, 16),                 // exactly one full SIMD block
            (7, 5, 19),                 // fringe rows and columns
            (40, 33, 48),
            (PANEL_MC + 3, 21, 37),     // blocked nest, m fringe
            (10, PANEL_KC + 9, 24),     // blocked nest, k chunks
        ];
        for &(m, k, n) in &shapes {
            let a = rand_f32(m * k, &mut rng);
            let b = rand_f32(k * n, &mut rng);
            let mut want = vec![f32::NAN; m * n];
            let mut got = vec![f32::NAN; m * n];
            matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
            simd::matmul_f32(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "fp32 simd {m}x{k}x{n}");

            let ai = rand_i32(m * k, &mut rng);
            let bi = rand_i32(k * n, &mut rng);
            let mut wi = vec![i32::MIN; m * n];
            let mut gi = vec![i32::MAX; m * n];
            matmul_naive_i32_into(&mut wi, &ai, &bi, m, k, n);
            simd::matmul_i32(&mut gi, &ai, &bi, m, k, n);
            assert_eq!(gi, wi, "i32 simd {m}x{k}x{n}");
        }
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_zero_skip_matches_scalar_exactly() {
        // 0·inf must be skipped (scalar predicate) in the SIMD kernels
        // too — a full 4×16 block with an inf column and zeros in A.
        let (m, k, n) = (4usize, 2usize, 16usize);
        let mut a = vec![1.0f32; m * k];
        a[0] = 0.0; // row 0 skips kk = 0
        let mut b = vec![2.0f32; k * n];
        b[0] = f32::INFINITY; // kk = 0 row of B carries an inf
        let mut want = vec![f32::NAN; m * n];
        let mut got = vec![f32::NAN; m * n];
        matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
        simd::matmul_f32(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        assert!(got[0].is_finite(), "skipped 0·inf must not poison the lane");
        assert!(want[n].is_infinite(), "rows without the zero do see the inf");
    }
}
