//! The host **compute plane**: register-tiled, autovectorization-friendly
//! GEMM microkernels for the reference backend — the layer that turns
//! the naive scalar tile loop into the packed-panel → register-block
//! hierarchy the paper's whole thesis is built on.
//!
//! # Why this layer exists
//!
//! MaxEVA wins MatMul throughput by blocking at every level of the
//! memory hierarchy: the AIE kernel computes an `m×k×n` register tile
//! (fp32 32×32×32, int8 32×128×32), the X×Y×Z array aggregates kernels
//! into a native device tile, and the host tiles arbitrary problems
//! over that native size. Our serving engine mirrors the outer two
//! levels (the [`Tiler`] grid and the [`TilePool`] arenas), but until
//! this module the innermost level — how one native tile is actually
//! multiplied on the host — was a naive scalar `ikj` triple loop that
//! reloaded and re-stored a full row of `C` on every k step. The
//! GotoBLAS2-on-Versal mapping (Lei & Quintana-Ortí, arXiv 2404.15043)
//! and the Ryzen-AI GEMM study (Taka et al., 2025) both land on the
//! same structure: packed operand panels feeding a small MR×NR
//! microkernel whose accumulators live in registers. This module is
//! that microkernel, mapped onto MaxEVA's terms:
//!
//! | MaxEVA level                  | host compute plane              |
//! |-------------------------------|---------------------------------|
//! | AIE register tile (`m×k×n`)   | MR×NR accumulator block         |
//! | array native tile (X·m,Y·k,Z·n) | one `matmul_*` call on a packed tile |
//! | PL tiling / zero-padding      | [`TilePool`] arenas + [`Tiler`] grid |
//!
//! # The MR×NR microkernel
//!
//! [`matmul_mk`] walks the output in MR×NR blocks. Each block keeps an
//! `[[T; NR]; MR]` accumulator in fixed-size arrays — small enough to
//! live entirely in vector registers — and runs **k innermost,
//! ascending**: for every k step it broadcasts `A[i][k]` against a
//! contiguous NR-wide row slice of `B`. The fixed NR trip count lets
//! the compiler unroll and vectorize the update, and the accumulators
//! are loaded/stored exactly once per block instead of once per k step
//! (the naive loop's O(k) traffic on `C` is the strength reduction).
//! Partial blocks at the m/n fringe run the same loop with runtime
//! `mr ≤ MR`, `nr ≤ NR` bounds, so every shape is handled without a
//! separate scalar path.
//!
//! # Bit-identity (the ascending-ik contract)
//!
//! The serving engine's fp32 determinism rests on every output element
//! being the **same sequence of f32 operations** regardless of path.
//! The microkernel preserves that sequence exactly:
//!
//! * per element `(i, j)` the accumulator starts at `0.0` and adds
//!   `A[i][kk] * B[kk][j]` for `kk` **ascending** — the naive reference
//!   ([`matmul_naive_f32_into`]) orders the same element's terms
//!   identically (its `kk` loop is also ascending);
//! * terms with `A[i][kk] == 0.0` are skipped under the identical
//!   predicate in both kernels (the skip is observable in IEEE 754:
//!   `-0.0 + 0.0·b` flips the sign of a `-0.0` accumulator, and
//!   `0.0·inf` is NaN — so both kernels must agree on it);
//! * each product is a separate multiply-then-add (Rust never contracts
//!   to FMA implicitly), in both kernels.
//!
//! Hence `matmul_f32` is bit-identical to the naive loop for every
//! shape — pinned by `tests/compute_plane.rs` over exhaustive fringe
//! shapes — and the engine-wide ascending-`ik` reduction contract from
//! PRs 1–4 survives untouched. The int8 path (i32 carriers, wrapping
//! adds) is order-independent and therefore trivially exact.
//!
//! # Dispatch
//!
//! [`matmul_f32`] / [`matmul_i32`] are the per-precision entry points,
//! compiled at [`MR_F32`]×[`NR_F32`] / [`MR_I32`]×[`NR_I32`] (chosen
//! so one block's accumulators fit the 16 vector registers of
//! mainstream SIMD ISAs with room for the broadcast and B-row
//! operands); [`micro_geom`] reports those geometries per precision.
//! `benches/microkernel.rs` sweeps alternative geometries against them
//! and reports GFLOP/s / GOP/s so the defaults stay honest on real
//! hardware.
//!
//! [`Tiler`]: crate::coordinator::tiler::Tiler
//! [`TilePool`]: crate::coordinator::pool::TilePool

use crate::arch::precision::Precision;

/// Rows of one fp32 accumulator block.
pub const MR_F32: usize = 4;
/// Columns of one fp32 accumulator block (4×16 f32 = 8 256-bit
/// registers of accumulator, leaving half the file for the broadcast
/// A value and the streamed B row).
pub const NR_F32: usize = 16;
/// Rows of one i32 accumulator block.
pub const MR_I32: usize = 4;
/// Columns of one i32 accumulator block.
pub const NR_I32: usize = 16;

/// Element types the microkernel multiplies: the fp32 datapath and the
/// int8 datapath's i32 carrier. `mul_acc` is one multiply-then-add in
/// the type's serving semantics (f32 IEEE add, i32 wrapping), and
/// `is_zero` is the A-operand skip predicate — both must match the
/// naive reference exactly for the bit-identity argument above.
pub trait MicroElem: Copy + Default + PartialEq + Send + Sync + 'static {
    fn mul_acc(acc: Self, a: Self, b: Self) -> Self;
    fn is_zero(self) -> bool;
}

impl MicroElem for f32 {
    #[inline(always)]
    fn mul_acc(acc: f32, a: f32, b: f32) -> f32 {
        acc + a * b
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0.0
    }
}

impl MicroElem for i32 {
    #[inline(always)]
    fn mul_acc(acc: i32, a: i32, b: i32) -> i32 {
        acc.wrapping_add(a.wrapping_mul(b))
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
}

/// Microkernel geometry of one precision's dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroGeom {
    pub mr: usize,
    pub nr: usize,
}

/// The MR×NR geometry [`matmul_f32`] / [`matmul_i32`] run a serving
/// precision with (int8-path tiles accumulate in i32, so they use the
/// i32 geometry).
pub fn micro_geom(p: Precision) -> MicroGeom {
    match p {
        Precision::Int8 => MicroGeom { mr: MR_I32, nr: NR_I32 },
        _ => MicroGeom { mr: MR_F32, nr: NR_F32 },
    }
}

/// One full MR×NR output block: accumulators in fixed-size arrays
/// (registers), k innermost ascending, A-zero skip — see the module
/// docs for why this exact shape is both fast and bit-identical.
#[inline]
fn block_full<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
) {
    let mut acc = [[T::default(); NR]; MR];
    for kk in 0..k {
        let boff = kk * n + j0;
        let brow = &b[boff..boff + NR];
        for (i, arow) in acc.iter_mut().enumerate() {
            let av = a[(i0 + i) * k + kk];
            if av.is_zero() {
                continue;
            }
            for j in 0..NR {
                arow[j] = T::mul_acc(arow[j], av, brow[j]);
            }
        }
    }
    for (i, arow) in acc.iter().enumerate() {
        let off = (i0 + i) * n + j0;
        c[off..off + NR].copy_from_slice(arow);
    }
}

/// A partial block at the m/n fringe: the same loop with runtime
/// `mr ≤ MR`, `nr ≤ NR` bounds (the accumulator array stays fixed-size;
/// only its `mr×nr` prefix is used and written back).
#[inline]
fn block_fringe<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[T::default(); NR]; MR];
    for kk in 0..k {
        let boff = kk * n + j0;
        let brow = &b[boff..boff + nr];
        for (i, arow) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i0 + i) * k + kk];
            if av.is_zero() {
                continue;
            }
            for (dst, &bv) in arow[..nr].iter_mut().zip(brow) {
                *dst = T::mul_acc(*dst, av, bv);
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let off = (i0 + i) * n + j0;
        c[off..off + nr].copy_from_slice(&arow[..nr]);
    }
}

/// Register-tiled row-major GEMM: `C (m×n) = A (m×k) · B (k×n)` through
/// MR×NR accumulator blocks. `c` is fully overwritten (stale contents
/// are fine — the recycling free-lists hand these kernels dirty
/// buffers). Outputs are bit-identical to the naive reference loop for
/// every shape, in both element types (module docs).
pub fn matmul_mk<T: MicroElem, const MR: usize, const NR: usize>(
    c: &mut [T],
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
) {
    assert!(MR > 0 && NR > 0, "degenerate microkernel geometry");
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "output shape mismatch");
    let mut i0 = 0;
    while i0 < m {
        let mr = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let nr = (n - j0).min(NR);
            if mr == MR && nr == NR {
                block_full::<T, MR, NR>(c, a, b, k, n, i0, j0);
            } else {
                block_fringe::<T, MR, NR>(c, a, b, k, n, i0, j0, mr, nr);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// The fp32 microkernel at its dispatched geometry — what the reference
/// device workers and [`matmul_ref_f32_into`] execute per native tile.
///
/// [`matmul_ref_f32_into`]: crate::coordinator::tiler::matmul_ref_f32_into
pub fn matmul_f32(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    matmul_mk::<f32, MR_F32, NR_F32>(c, a, b, m, k, n);
}

/// The i32 (int8-path) microkernel at its dispatched geometry.
/// Wrapping arithmetic: exact under any order, like the naive loop.
pub fn matmul_i32(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
    matmul_mk::<i32, MR_I32, NR_I32>(c, a, b, m, k, n);
}

/// The pre-compute-plane scalar `ikj` loop, kept verbatim as the
/// bit-identity **oracle**: property tests pin `matmul_f32` /
/// `matmul_i32` against it over exhaustive fringe shapes, and the
/// microkernel bench reports its GFLOP/s as the baseline. `c` is fully
/// overwritten.
pub fn matmul_naive_f32_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// [`matmul_naive_f32_into`]'s i32 sibling (wrapping adds, the int8
/// path's exact semantics).
pub fn matmul_naive_i32_into(c: &mut [i32], a: &[i32], b: &[i32], m: usize, k: usize, n: usize) {
    assert_eq!(c.len(), m * n, "output shape mismatch");
    c.fill(0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv = cv.wrapping_add(av.wrapping_mul(bv));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    /// Random operands with a deliberate sprinkling of exact zeros in A
    /// so the zero-skip predicate is exercised, not just dead code.
    fn rand_f32(len: usize, rng: &mut XorShift64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.gen_range(0, 4) == 0 {
                    0.0
                } else {
                    rng.gen_range_f64(-1.0, 1.0) as f32
                }
            })
            .collect()
    }

    fn rand_i32(len: usize, rng: &mut XorShift64) -> Vec<i32> {
        (0..len)
            .map(|_| {
                if rng.gen_range(0, 4) == 0 {
                    0
                } else {
                    rng.gen_range(0, 256) as i32 - 128
                }
            })
            .collect()
    }

    #[test]
    fn microkernel_bit_identical_to_naive_random_shapes() {
        let mut rng = XorShift64::new(0x5EED);
        for _ in 0..40 {
            let m = rng.gen_range(1, 40) as usize;
            let k = rng.gen_range(1, 24) as usize;
            let n = rng.gen_range(1, 40) as usize;
            let a = rand_f32(m * k, &mut rng);
            let b = rand_f32(k * n, &mut rng);
            let mut want = vec![f32::NAN; m * n];
            let mut got = vec![f32::NAN; m * n];
            matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
            matmul_f32(&mut got, &a, &b, m, k, n);
            assert_eq!(got, want, "fp32 {m}x{k}x{n} must be bit-identical");

            let ai = rand_i32(m * k, &mut rng);
            let bi = rand_i32(k * n, &mut rng);
            let mut wi = vec![i32::MIN; m * n];
            let mut gi = vec![i32::MIN; m * n];
            matmul_naive_i32_into(&mut wi, &ai, &bi, m, k, n);
            matmul_i32(&mut gi, &ai, &bi, m, k, n);
            assert_eq!(gi, wi, "i32 {m}x{k}x{n} must be exact");
        }
    }

    #[test]
    fn zero_skip_semantics_match_exactly() {
        // The observable IEEE edge: a zero A value must be *skipped*
        // (matching the naive loop), not multiplied through — otherwise
        // 0·inf would poison the accumulator with NaN.
        let a = vec![0.0f32, 1.0];
        let b = vec![f32::INFINITY, 2.0];
        let mut got = vec![f32::NAN; 1];
        let mut want = vec![f32::NAN; 1];
        matmul_f32(&mut got, &a, &b, 1, 2, 1);
        matmul_naive_f32_into(&mut want, &a, &b, 1, 2, 1);
        assert_eq!(got, want);
        assert_eq!(got[0], 2.0, "the inf paired with a==0 is skipped in both kernels");
    }

    #[test]
    fn degenerate_shapes_overwrite_everything() {
        // k = 0: pure zero fill over stale contents.
        let mut c = vec![f32::NAN; 6];
        matmul_f32(&mut c, &[], &[], 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
        // m or n = 0: empty output, no panic.
        let mut empty: Vec<f32> = Vec::new();
        matmul_f32(&mut empty, &[], &[1.0, 2.0], 0, 1, 2);
        matmul_f32(&mut empty, &[1.0, 2.0], &[], 2, 1, 0);
    }

    #[test]
    fn alternate_geometries_stay_bit_identical() {
        // The bit-identity argument is geometry-independent (per-element
        // order never depends on MR/NR); pin it for the sweep geometries
        // the bench exercises.
        let mut rng = XorShift64::new(0xBE57);
        let (m, k, n) = (19usize, 13usize, 23usize);
        let a = rand_f32(m * k, &mut rng);
        let b = rand_f32(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        matmul_naive_f32_into(&mut want, &a, &b, m, k, n);
        let mut got = vec![f32::NAN; m * n];
        matmul_mk::<f32, 1, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 2, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 8, 8>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
        matmul_mk::<f32, 8, 16>(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
    }

    #[test]
    fn dispatch_geometry_per_precision() {
        assert_eq!(micro_geom(Precision::Fp32), MicroGeom { mr: MR_F32, nr: NR_F32 });
        assert_eq!(micro_geom(Precision::Int8), MicroGeom { mr: MR_I32, nr: NR_I32 });
    }
}
