//! The sharded serving plane: N independent scheduler + device-pool +
//! memory-plane engines (`Shard`, crate-internal) behind one
//! [`MatMulServer`] facade, plus the front-end router that places
//! requests on them.
//!
//! MaxEVA scales MatMul by replicating the kernel across the AIE array;
//! the serving-side analogue is replicating the whole engine across
//! shards (`ServeConfig::shards`, default 1 = the single-engine server,
//! bit-for-bit). Each shard owns a private scheduler thread, device
//! worker pool, admission gate, packed-weight cache and tile-buffer
//! free-lists — shards share nothing, so they scale without contending
//! on a lock.
//!
//! # Routing policy
//!
//! * **Whole requests with a `weight_id`** are placed by rendezvous
//!   (highest-random-weight) hashing on the id when
//!   `ServeConfig::shard_affinity` is on: every repeat of a weight
//!   lands on the shard whose [`WeightCache`] already holds its packed
//!   panels — the working-set-locality argument for packed B panels,
//!   now applied across engines. Rendezvous hashing is stable under
//!   resizing: growing from N to N+1 shards only moves keys *to* the
//!   new shard, never between survivors.
//! * **Anonymous requests** (no `weight_id`, or affinity disabled) go
//!   to the least-loaded shard — fewest open requests, ties to the
//!   lowest index.
//! * **Large GEMMs** — at least `ServeConfig::shard_split_tiles` M-tile
//!   rows (`⌈m/nm⌉`) — split along M into one contiguous row band per
//!   shard and merge in a reduction stage on completion. Bands are cut
//!   on native tile boundaries, so no tile ever straddles two shards.
//!
//! # Bit-identity under split
//!
//! Splitting along M cannot change a single output bit, for either
//! precision. Each output element `C[i][j]` is produced by exactly one
//! row band; within that band the operand tiles, the k-tile walk and
//! the ascending-`ik` reduction order (f32 ordered sums, i32 wrapping
//! adds) are identical to what the unsplit request would have executed
//! for those rows, because bands are cut on `nm` boundaries and B is
//! replicated whole. The merge is pure row-band concatenation in band
//! order — no arithmetic — so `shards = N` outputs are bit-identical
//! to `shards = 1` (see `rust/tests/shard_routing.rs`).
//!
//! The cost of a split is one copy of each A row band (the bands
//! partition A) plus one clone of B per band: splitting pays B
//! replication for M-parallelism, which is why small requests route
//! whole.
//!
//! [`MatMulServer`]: crate::coordinator::server::MatMulServer
//! [`WeightCache`]: crate::coordinator::pool::WeightCache

use crate::arch::precision::Precision;
use crate::config::schema::{AdmissionPolicy, ServeConfig};
use crate::coordinator::admission::{Admitted, Gate};
use crate::coordinator::device::{
    spawn_device_pool_with_faults, PoolHealth, PrecisionInfo, TileDone,
};
use crate::coordinator::fault::{FaultCounters, FaultKind, RequestShed, SloUnattainable};
use crate::coordinator::handle::Reply;
use crate::coordinator::policy::{PolicyParams, TileCosts};
use crate::coordinator::pool::{
    BufferPool, PackCounters, RewarmEntry, WeightCache, WeightCacheCounters,
};
use crate::coordinator::scheduler::{Event, Robustness, Scheduler, Shared};
use crate::coordinator::stats::{
    FaultStats, MemPlaneStats, PackStats, RouterStats, ShardStats, ShedCounters, StatsAgg,
    WindowOcc,
};
use crate::coordinator::tiler::Tiler;
use crate::coordinator::workpool::WorkPool;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One self-contained serving engine: a scheduler thread, a device
/// worker pool, an admission gate and a private memory plane. The
/// facade owns a `Vec<Shard>` and the router decides which shard (or
/// shards) a request reaches.
pub(crate) struct Shard {
    pub(crate) index: usize,
    pub(crate) events: mpsc::Sender<Event>,
    sched: Option<JoinHandle<()>>,
    forwarder: Option<JoinHandle<()>>,
    pub(crate) gate: Arc<Gate>,
    pub(crate) shared: Arc<Shared>,
    pub(crate) cycles: Arc<AtomicU64>,
    pub(crate) invocations: Arc<AtomicU64>,
    pub(crate) info_f32: PrecisionInfo,
    pub(crate) info_int8: PrecisionInfo,
    pub(crate) freq_hz: f64,
    pub(crate) backend: &'static str,
    pub(crate) workers: usize,
    cache_counters: Arc<WeightCacheCounters>,
    pack_counters: Arc<PackCounters>,
    bufs: Arc<BufferPool>,
    fault_counters: Arc<FaultCounters>,
    health: Arc<PoolHealth>,
    /// Request-level robustness counters (sheds, deadline expiries),
    /// shared with this shard's scheduler thread.
    shed: Arc<ShedCounters>,
    /// Brownout watermark (`ServeConfig::shed_watermark`; 0 = off).
    shed_watermark: f64,
    /// SLO-aware admission (`ServeConfig::slo_admission`).
    slo_admission: bool,
    /// Admission queue depth (the brownout occupancy denominator;
    /// 0 = unbounded, brownout inert).
    queue_depth: usize,
    /// Configured priority-class count (≥ 1).
    classes: usize,
    /// Admission-token mint (cancellation addresses are shard-local:
    /// a cancel route pairs this shard's event channel with a token).
    /// `Arc` so a detached [`ShardClient`] can mint from the same
    /// sequence.
    next_token: Arc<AtomicU64>,
    /// Rescue slot shared with the scheduler thread: on a scheduler
    /// panic it exports its hottest weight-cache entries here (see
    /// `ServeConfig::respawn_rewarm_top_k`) for the respawn supervisor
    /// to seed into the replacement shard.
    rescue: Arc<Mutex<Option<Vec<RewarmEntry>>>>,
}

impl Shard {
    /// Spawn one engine: device pool, completion forwarder and
    /// scheduler thread, all tagged with the shard index. Every
    /// per-engine `ServeConfig` knob (workers, queue depth, cache
    /// budget, fault plan, …) applies to each shard independently.
    pub(crate) fn start(cfg: &ServeConfig, index: usize) -> Result<Shard> {
        let device = spawn_device_pool_with_faults(
            cfg.artifacts_dir.clone().into(),
            cfg.design.clone(),
            cfg.backend,
            cfg.workers,
            cfg.fault_plan.clone(),
        )?;
        let (cycles, invocations) = device.counters();
        let fault_counters = device.fault_counters();
        let health = device.pool_health();
        let info_f32 = device.info_for(Precision::Fp32)?;
        let info_int8 = device.info_for(Precision::Int8)?;
        let freq_hz = device.freq_hz;
        let backend = device.backend;
        let workers = device.workers;

        let gate = Arc::new(Gate::new(
            cfg.queue_depth,
            cfg.class_queue_reserve.iter().map(|&r| r as usize).collect(),
        ));
        let shared = Arc::new(Shared {
            stats: Mutex::new(StatsAgg::default()),
            window: Mutex::new(WindowOcc::default()),
            last_window: Mutex::new(WindowOcc::default()),
        });
        let (events_tx, events_rx) = mpsc::channel::<Event>();
        let (tile_tx, tile_rx) = mpsc::channel::<TileDone>();

        // Tile completions → scheduler events (std mpsc has no select;
        // a relay thread keeps the scheduler single-channel).
        let fwd_events = events_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name(format!("maxeva-compl-{index}"))
            .spawn(move || {
                while let Ok(done) = tile_rx.recv() {
                    if fwd_events.send(Event::Done(done)).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning completion forwarder {index}: {e}"))?;

        // Per-precision tile costs charge the *measured* device period
        // per tile (falling back to the geometric MAC ratio when the
        // simulated periods are degenerate): this is what makes
        // WeightedFair split device time, not tiles — even when
        // MACs/cycle differ across precisions.
        let costs = TileCosts::from_periods(
            info_f32.period_cycles,
            info_int8.period_cycles,
            info_f32.native,
            info_int8.native,
        );
        let params = PolicyParams::from_config(cfg, costs);
        let cache_counters = Arc::new(WeightCacheCounters::default());
        let mut weight_cache =
            WeightCache::new(cfg.weight_cache_bytes, Arc::clone(&cache_counters));
        weight_cache.configure_integrity(cfg.cache_verify_interval, cfg.cache_quarantine_ms);
        let pack_counters = Arc::new(PackCounters::default());
        let bufs = device.buffer_pool();
        // Resolve the per-tile deadline once per precision: multiplier ×
        // the precision's simulated tile period, floored so a deadline
        // is never shorter than scheduling noise. Multiplier 0 keeps
        // the historical wait-forever completion loop.
        let tile_deadline = |period_cycles: f64| -> Option<Duration> {
            if cfg.tile_timeout_mult <= 0.0 {
                return None;
            }
            let secs = (cfg.tile_timeout_mult * period_cycles / freq_hz)
                .max(cfg.tile_timeout_floor_ms as f64 / 1e3);
            Some(Duration::from_secs_f64(secs))
        };
        let robust = Robustness {
            max_tile_retries: cfg.max_tile_retries,
            deadline_f32: tile_deadline(info_f32.period_cycles),
            deadline_i32: tile_deadline(info_int8.period_cycles),
            quarantine_after: cfg.quarantine_after,
        };
        // Persistent pack workers (sized one below the fan-out width:
        // `run_scoped` keeps one chunk inline on the scheduler thread).
        // Owned by the scheduler, so its drop joins them — `None` (knob
        // off, or serial packing) keeps the legacy per-call scoped
        // threads.
        let work_pool = (cfg.pack_persistent && cfg.pack_workers > 1)
            .then(|| WorkPool::new(cfg.pack_workers - 1, index));
        let shed = Arc::new(ShedCounters::default());
        let rescue: Arc<Mutex<Option<Vec<RewarmEntry>>>> = Arc::new(Mutex::new(None));
        let sched = Scheduler::new(
            index,
            Arc::clone(&shed),
            device,
            Tiler::new(info_f32.native),
            Tiler::new(info_int8.native),
            Arc::clone(&gate),
            Arc::clone(&shared),
            tile_tx,
            cfg.pipeline_depth,
            params,
            weight_cache,
            cfg.pack_workers,
            work_pool,
            Arc::clone(&pack_counters),
            robust,
            Arc::clone(&rescue),
            cfg.respawn_rewarm_top_k,
        );
        let sched = std::thread::Builder::new()
            .name(format!("maxeva-sched-{index}"))
            .spawn(move || sched.run(events_rx))
            .map_err(|e| anyhow!("spawning scheduler {index}: {e}"))?;

        Ok(Shard {
            index,
            events: events_tx,
            sched: Some(sched),
            forwarder: Some(forwarder),
            gate,
            shared,
            cycles,
            invocations,
            info_f32,
            info_int8,
            freq_hz,
            backend,
            workers,
            cache_counters,
            pack_counters,
            bufs,
            fault_counters,
            health,
            shed,
            shed_watermark: cfg.shed_watermark,
            slo_admission: cfg.slo_admission,
            queue_depth: cfg.queue_depth,
            classes: cfg.class_weights.len().max(1),
            next_token: Arc::new(AtomicU64::new(0)),
            rescue,
        })
    }

    /// Admit one (already validated) request into this shard's gate and
    /// hand it to its scheduler. Returns the cancellation token; the
    /// caller pairs it with this shard's event channel to form a cancel
    /// route.
    pub(crate) fn submit(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        reply: Reply,
    ) -> Result<u64> {
        self.check_admission(&req)?;
        self.client().submit(req, ops, policy, reply)
    }

    /// Request-level admission control, ahead of the queue-slot gate:
    /// the brownout shedder and SLO-aware admission, both off at the
    /// default knobs. A rejection here is typed and never consumes a
    /// queue slot. The failover plane's front-door dispatch calls this
    /// against the preferred shard before entering the re-dispatch
    /// machinery (re-submissions deliberately skip it — the request was
    /// already admitted once).
    pub(crate) fn check_admission(&self, req: &MatMulRequest) -> Result<()> {
        let class = (req.class as usize).min(self.classes - 1);
        // Brownout: past the occupancy watermark, shed the lowest
        // classes first and more of them the deeper into the red zone
        // — class 0 is never shed (with a single configured class
        // nothing is: there is no lower-priority traffic to sacrifice).
        if self.shed_watermark > 0.0 && self.queue_depth > 0 {
            let open = self.gate.in_flight();
            let occ = open as f64 / self.queue_depth as f64;
            if occ >= self.shed_watermark {
                let frac = if self.shed_watermark >= 1.0 {
                    1.0
                } else {
                    ((occ - self.shed_watermark) / (1.0 - self.shed_watermark)).clamp(0.0, 1.0)
                };
                let cut = ((frac * (self.classes - 1) as f64).ceil() as usize).max(1);
                let shed_floor = (self.classes - 1).saturating_sub(cut);
                if class > shed_floor {
                    self.shed.shed_brownout.fetch_add(1, Ordering::Relaxed);
                    let err =
                        RequestShed { id: req.id, shard: self.index, class: req.class, open };
                    return Err(anyhow::Error::new(err));
                }
            }
        }
        // SLO-aware admission: estimate attainable completion from the
        // class's observed p99 service time scaled by the open requests
        // already ahead — a deadline the estimate cannot meet is
        // rejected now instead of burning device time to miss it. No
        // class history yet = admit optimistically.
        if self.slo_admission {
            if let Some(deadline) = req.deadline {
                let p99 = self
                    .shared
                    .stats
                    .lock()
                    .unwrap()
                    .class_stats()
                    .iter()
                    .find(|c| c.class == class)
                    .map(|c| c.service_p99_ms)
                    .unwrap_or(0.0);
                if p99 > 0.0 {
                    let open = self.gate.in_flight();
                    let estimated_ms = (p99 * (open as f64 + 1.0)).ceil() as u64;
                    let deadline_ms = deadline.as_millis() as u64;
                    if estimated_ms > deadline_ms {
                        self.shed.shed_slo.fetch_add(1, Ordering::Relaxed);
                        let err = SloUnattainable {
                            id: req.id,
                            shard: self.index,
                            class: req.class,
                            estimated_ms,
                            deadline_ms,
                        };
                        return Err(anyhow::Error::new(err));
                    }
                }
            }
        }
        Ok(())
    }

    /// A detached submission handle onto this shard (see
    /// [`ShardClient`]).
    pub(crate) fn client(&self) -> ShardClient {
        ShardClient {
            shard: self.index,
            events: self.events.clone(),
            gate: Arc::clone(&self.gate),
            next_token: Arc::clone(&self.next_token),
        }
    }

    /// Open requests on this shard (the router's least-loaded gauge).
    pub(crate) fn in_flight(&self) -> usize {
        self.gate.in_flight()
    }

    /// Ask the scheduler to stop admitting, serve what is open and exit
    /// — by the absolute deadline when one is set. The facade stamps
    /// one instant and fans it out, so all shards drain concurrently
    /// against the same wall-clock budget.
    pub(crate) fn drain(&self, by: Option<Instant>) {
        let _ = self.events.send(Event::Drain(by));
    }

    /// Join the engine threads (after [`Shard::drain`]).
    pub(crate) fn join(&mut self) {
        if let Some(j) = self.sched.take() {
            let _ = j.join();
        }
        if let Some(j) = self.forwarder.take() {
            let _ = j.join();
        }
    }

    /// Whether this shard's scheduler thread has exited (panicked or
    /// otherwise). The respawn supervisor's liveness probe: a breaker
    /// trip on a shard whose scheduler is still running (e.g. a drain
    /// deadline expiry) needs no respawn.
    pub(crate) fn sched_dead(&self) -> bool {
        self.sched.as_ref().map(|j| j.is_finished()).unwrap_or(true)
    }

    /// Take the dead scheduler's rescue export, if it left one (set on
    /// the panic path when `respawn_rewarm_top_k > 0`).
    pub(crate) fn take_rescue(&self) -> Option<Vec<RewarmEntry>> {
        self.rescue.lock().unwrap_or_else(std::sync::PoisonError::into_inner).take()
    }

    /// Seed this (freshly started) shard's weight cache with entries
    /// rescued from its predecessor — each keeps its pre-crash CRC
    /// stamp and fully verifies on first hit.
    pub(crate) fn rewarm(&self, entries: Vec<RewarmEntry>) {
        let _ = self.events.send(Event::Rewarm(entries));
    }

    /// Charge one injected fault to this shard's fault counters (the
    /// facade-level chaos hooks — `ShardCrash` — count here; device
    /// and cache injections count at their injection sites).
    pub(crate) fn count_injected(&self, kind: FaultKind) {
        self.fault_counters.count_injected(kind);
    }

    /// Snapshot this shard's serving statistics.
    pub(crate) fn stats(&self) -> ShardStats {
        let stats = self.shared.stats.lock().unwrap();
        let window = self.shared.window.lock().unwrap();
        let mem = MemPlaneStats {
            weight_cache_hits: self.cache_counters.hits.load(Ordering::Relaxed),
            weight_cache_misses: self.cache_counters.misses.load(Ordering::Relaxed),
            weight_cache_evictions: self.cache_counters.evictions.load(Ordering::Relaxed),
            weight_cache_bytes: self.cache_counters.bytes.load(Ordering::Relaxed),
            weight_cache_entries: self.cache_counters.entries.load(Ordering::Relaxed),
            cache_verifications: self.cache_counters.verifications.load(Ordering::Relaxed),
            poisoned_evictions: self.cache_counters.poisoned_evictions.load(Ordering::Relaxed),
            rewarmed_entries: self.cache_counters.rewarmed.load(Ordering::Relaxed),
            tile_buffers_recycled: self.bufs.recycled(),
            tile_buffers_allocated: self.bufs.allocated(),
            tile_buffers_free: self.bufs.free(),
        };
        let pack = PackStats {
            matrices_packed: self.pack_counters.matrices.load(Ordering::Relaxed),
            parallel_packs: self.pack_counters.parallel.load(Ordering::Relaxed),
            pack_time_s: self.pack_counters.nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            pack_spawn_s: self.pack_counters.spawn_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        };
        let fc = &self.fault_counters;
        let faults = FaultStats {
            injected_errors: fc.injected_errors.load(Ordering::Relaxed),
            injected_panics: fc.injected_panics.load(Ordering::Relaxed),
            injected_delays: fc.injected_delays.load(Ordering::Relaxed),
            injected_hangs: fc.injected_hangs.load(Ordering::Relaxed),
            injected_corruptions: fc.injected_corruptions.load(Ordering::Relaxed),
            timeouts: fc.timeouts.load(Ordering::Relaxed),
            retries: fc.retries.load(Ordering::Relaxed),
            retries_exhausted: fc.retries_exhausted.load(Ordering::Relaxed),
            checksum_failures: fc.checksum_failures.load(Ordering::Relaxed),
            worker_deaths: fc.worker_deaths.load(Ordering::Relaxed),
            respawns: fc.respawns.load(Ordering::Relaxed),
            quarantined: fc.quarantined.load(Ordering::Relaxed),
            injected_cache_corruptions: fc.injected_cache_corruptions.load(Ordering::Relaxed),
            injected_shard_crashes: fc.injected_shard_crashes.load(Ordering::Relaxed),
        };
        ShardStats {
            shard: self.index,
            requests: stats.count(),
            requests_fp32: stats.count_by(Precision::Fp32),
            requests_int8: stats.count_by(Precision::Int8),
            cancelled: stats.cancelled(),
            invocations: self.invocations.load(Ordering::Relaxed),
            mean_latency_ms: stats.mean_latency_ms(),
            p99_latency_ms: stats.p99_latency_ms(),
            classes: stats.class_stats(),
            device_ops_per_sec: stats.device_ops_per_sec(),
            device_time_s: self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz,
            mean_in_flight: window.mean(),
            max_in_flight: window.max(),
            open_requests: self.gate.in_flight(),
            mem,
            pack,
            faults,
            shed: self.shed.snapshot(),
            worker_health: self.health.snapshot(),
            // The facade fills this in when a failover plane exists;
            // a bare shard has no breaker.
            breaker: None,
        }
    }
}

/// One slot of the facade's shard table: a [`Shard`] behind an
/// `RwLock` so the respawn supervisor can swap in a replacement engine
/// while request threads keep routing. Reads (routing, submission,
/// stats) are short and shared; the only writer is the supervisor's
/// atomic [`ShardSlot::replace`] swap, so the lock is uncontended in
/// steady state — and with `shard_respawn` off it is never written at
/// all.
pub(crate) struct ShardSlot {
    inner: RwLock<Shard>,
}

impl ShardSlot {
    pub(crate) fn new(shard: Shard) -> Self {
        ShardSlot { inner: RwLock::new(shard) }
    }

    /// Shared read access to the resident shard. Poison is ignored: a
    /// panic under a read guard cannot leave the `Shard` handle in a
    /// torn state (all its fields are internally synchronized), and
    /// serving must outlive any one panicking thread.
    pub(crate) fn read(&self) -> RwLockReadGuard<'_, Shard> {
        self.inner.read().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Exclusive access (shutdown joins the engine threads in place).
    pub(crate) fn write(&self) -> RwLockWriteGuard<'_, Shard> {
        self.inner.write().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Atomically swap in a replacement engine, returning the old one
    /// so the caller can tear it down outside the lock.
    pub(crate) fn replace(&self, fresh: Shard) -> Shard {
        std::mem::replace(&mut *self.write(), fresh)
    }
}

/// A cloneable handle for submitting into a shard from off-facade
/// contexts: the failover plane re-dispatches requests from scheduler
/// callback threads, where no `&Shard` is reachable. It shares the
/// shard's event channel, admission gate and token mint, so a failover
/// submission is indistinguishable from a front-door one — except that
/// it deliberately skips the brownout/SLO checks: the request was
/// already admitted once, and recovery should not re-litigate it.
#[derive(Clone)]
pub(crate) struct ShardClient {
    pub(crate) shard: usize,
    events: mpsc::Sender<Event>,
    gate: Arc<Gate>,
    next_token: Arc<AtomicU64>,
}

impl ShardClient {
    /// Admit into the gate and hand the request to the shard's
    /// scheduler (the tail of [`Shard::submit`]). The reply is dropped
    /// unfired on a synchronous failure — the error goes to the caller
    /// instead.
    pub(crate) fn submit(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        reply: Reply,
    ) -> Result<u64> {
        self.try_submit(req, ops, policy, reply).map_err(|(e, _reply, _ops)| e)
    }

    /// Like [`submit`](ShardClient::submit), but a synchronous failure
    /// hands the reply and operands back un-consumed instead of
    /// dropping them — the failover plane re-routes them to another
    /// shard.
    pub(crate) fn try_submit(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        reply: Reply,
    ) -> std::result::Result<u64, (anyhow::Error, Reply, Operands)> {
        if let Err(e) = self.gate.admit(policy, req.class) {
            return Err((e, reply, ops));
        }
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let adm = Box::new(Admitted {
            req,
            ops: Some(ops),
            submitted: Instant::now(),
            reply: Some(reply),
            token,
            gate: Arc::clone(&self.gate),
        });
        match self.events.send(Event::Admit(adm)) {
            Ok(()) => Ok(token),
            Err(mpsc::SendError(ev)) => {
                // Dead scheduler: recover the reply and operands from
                // the bounced event. `Admitted::drop` only releases the
                // slot when the reply is still inside, so release it
                // here.
                let Event::Admit(mut adm) = ev else {
                    unreachable!("submit bounced a non-admit event")
                };
                let reply = adm.reply.take().expect("reply not yet consumed");
                let ops = adm.ops.take().expect("operands not yet consumed");
                self.gate.release(req.class);
                Err((anyhow!("server is shut down"), reply, ops))
            }
        }
    }

    pub(crate) fn in_flight(&self) -> usize {
        self.gate.in_flight()
    }
}

impl Drop for Shard {
    fn drop(&mut self) {
        // Safety net for a facade start() that fails after some shards
        // spawned; the normal path drains with the configured deadline
        // through MatMulServer::stop and leaves nothing to join here.
        if self.sched.is_some() || self.forwarder.is_some() {
            self.drain(None);
            self.join();
        }
    }
}

/// Lifetime routing-decision counters kept by the facade (snapshot in
/// `ServerStats::router`).
#[derive(Default)]
pub(crate) struct RouterCounters {
    pub(crate) routed_affinity: AtomicU64,
    pub(crate) routed_least_loaded: AtomicU64,
    pub(crate) split_requests: AtomicU64,
    pub(crate) split_parts: AtomicU64,
}

impl RouterCounters {
    pub(crate) fn snapshot(&self) -> RouterStats {
        RouterStats {
            routed_affinity: self.routed_affinity.load(Ordering::Relaxed),
            routed_least_loaded: self.routed_least_loaded.load(Ordering::Relaxed),
            split_requests: self.split_requests.load(Ordering::Relaxed),
            split_parts: self.split_parts.load(Ordering::Relaxed),
        }
    }
}

/// A routing decision for one request.
pub(crate) enum Route {
    /// Serve the request unsplit on one shard.
    Whole(usize),
    /// Split along M into one contiguous row band per entry.
    Split(Vec<Band>),
}

/// One row band of an M-split request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Band {
    /// Shard the band is placed on.
    pub(crate) shard: usize,
    /// First output row of the band.
    pub(crate) row0: usize,
    /// Rows in the band (> 0).
    pub(crate) rows: usize,
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mixer, so rendezvous
/// scores are uniform even for small consecutive weight ids.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Rendezvous (highest-random-weight) shard of a weight id: the shard
/// whose mixed `(weight_id, shard)` score is highest. Deterministic,
/// uniform, and stable under resizing (growing the shard set only moves
/// keys to the new shard).
pub(crate) fn rendezvous_shard(weight_id: u64, shards: usize) -> usize {
    (0..shards)
        .max_by_key(|&s| (mix64(weight_id ^ mix64(s as u64 + 1)), std::cmp::Reverse(s)))
        .unwrap_or(0)
}

/// Cut `gm` tile rows into at most `shards` contiguous bands of
/// `nm`-row tiles (band `j` → shard `j`), balanced to within one tile.
/// The final band absorbs the fringe rows (`m % nm`), exactly like the
/// unsplit tiler.
pub(crate) fn plan_bands(m: usize, nm: usize, shards: usize) -> Vec<Band> {
    let gm = m.div_ceil(nm);
    let bands = shards.min(gm).max(1);
    let base = gm / bands;
    let rem = gm % bands;
    let mut out = Vec::with_capacity(bands);
    let mut tile0 = 0usize;
    for shard in 0..bands {
        let tiles = base + usize::from(shard < rem);
        let row0 = tile0 * nm;
        let row1 = ((tile0 + tiles) * nm).min(m);
        out.push(Band { shard, row0, rows: row1 - row0 });
        tile0 += tiles;
    }
    out
}

/// Decide where one validated request runs. `nm` is the native M-tile
/// height of the request's precision.
pub(crate) fn plan_route(
    shards: &[ShardSlot],
    req: &MatMulRequest,
    nm: usize,
    split_tiles: usize,
    affinity: bool,
    counters: &RouterCounters,
) -> Route {
    let n = shards.len();
    if n <= 1 {
        return Route::Whole(0);
    }
    let m = req.m as usize;
    let gm = m.div_ceil(nm);
    if split_tiles > 0 && gm >= split_tiles && gm >= 2 {
        let bands = plan_bands(m, nm, n);
        if bands.len() > 1 {
            counters.split_requests.fetch_add(1, Ordering::Relaxed);
            counters.split_parts.fetch_add(bands.len() as u64, Ordering::Relaxed);
            return Route::Split(bands);
        }
    }
    if affinity {
        if let Some(id) = req.weight_id {
            counters.routed_affinity.fetch_add(1, Ordering::Relaxed);
            return Route::Whole(rendezvous_shard(id, n));
        }
    }
    counters.routed_least_loaded.fetch_add(1, Ordering::Relaxed);
    let shard = shards
        .iter()
        .enumerate()
        .min_by_key(|(i, s)| (s.read().in_flight(), *i))
        .map(|(i, _)| i)
        .unwrap_or(0);
    Route::Whole(shard)
}

/// The sub-request one band submits: same id/class/precision/weight
/// identity, `m` shrunk to the band's rows.
pub(crate) fn band_request(req: &MatMulRequest, band: &Band) -> MatMulRequest {
    let mut sub = *req;
    sub.m = band.rows as u64;
    sub
}

/// The band's operands: its slice of A's rows (row-major, so a band is
/// one contiguous range) and a full clone of B.
pub(crate) fn band_operands(ops: &Operands, band: &Band, k: usize) -> Operands {
    let (r0, r1) = (band.row0 * k, (band.row0 + band.rows) * k);
    match ops {
        Operands::F32 { a, b } => Operands::F32 { a: a[r0..r1].to_vec(), b: b.clone() },
        Operands::I32 { a, b } => Operands::I32 { a: a[r0..r1].to_vec(), b: b.clone() },
    }
}

/// The reduction stage of an M-split request: collects every band's
/// result (in any completion order) and resolves the caller's reply
/// exactly once — the concatenation of the bands in band order on
/// success, or the first failing band's error (in band order, so the
/// reported error is deterministic regardless of timing).
pub(crate) struct SplitAcc {
    req: MatMulRequest,
    slots: Vec<Option<Result<MatOutput>>>,
    remaining: usize,
    sink: Option<Reply>,
}

impl SplitAcc {
    pub(crate) fn new(req: MatMulRequest, bands: usize, sink: Reply) -> Arc<Mutex<SplitAcc>> {
        Arc::new(Mutex::new(SplitAcc {
            req,
            slots: (0..bands).map(|_| None).collect(),
            remaining: bands,
            sink: Some(sink),
        }))
    }

    fn deliver(&mut self) {
        let Some(sink) = self.sink.take() else { return };
        let mut outs = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            match slot.take() {
                Some(Ok(out)) => outs.push(out),
                Some(Err(e)) => {
                    sink.send(self.req, Err(e));
                    return;
                }
                // Unreachable: deliver only runs once every band resolved.
                None => {
                    sink.send(self.req, Err(anyhow!("split band lost its result")));
                    return;
                }
            }
        }
        let total = (self.req.m * self.req.n) as usize;
        let merged = (|| {
            Ok(match self.req.precision {
                Precision::Int8 => {
                    let mut c = Vec::with_capacity(total);
                    for out in outs {
                        c.extend(out.into_i32()?);
                    }
                    MatOutput::I32(c)
                }
                _ => {
                    let mut c = Vec::with_capacity(total);
                    for out in outs {
                        c.extend(out.into_f32()?);
                    }
                    MatOutput::F32(c)
                }
            })
        })();
        sink.send(self.req, merged);
    }
}

/// The per-band reply: stores band `j`'s result in the accumulator and
/// delivers the merged reply when the last band lands. Runs on the
/// finishing shard's scheduler thread — the merge is a concatenation,
/// cheap enough to live there.
pub(crate) fn band_reply(acc: &Arc<Mutex<SplitAcc>>, j: usize) -> Reply {
    let acc = Arc::clone(acc);
    Reply::Callback(Box::new(move |_sub, out| {
        let mut g = acc.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if g.slots[j].is_none() {
            g.slots[j] = Some(out);
            g.remaining -= 1;
            if g.remaining == 0 {
                g.deliver();
            }
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_is_deterministic_and_spreads() {
        let shards = 4;
        let mut counts = vec![0usize; shards];
        for id in 0..4096u64 {
            let s = rendezvous_shard(id, shards);
            assert_eq!(s, rendezvous_shard(id, shards), "same id, same shard");
            counts[s] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // Uniform would be 1024 per shard; allow wide slack — the
            // point is no shard is starved or hot by construction.
            assert!(c > 512 && c < 1536, "shard {s} got {c} of 4096");
        }
    }

    #[test]
    fn rendezvous_resize_only_moves_keys_to_the_new_shard() {
        // The HRW property the affinity story relies on: growing the
        // shard set reassigns a key only if the *new* shard wins it —
        // survivors never trade keys among themselves, so warm caches
        // stay warm through a resize.
        for id in 0..2048u64 {
            let before = rendezvous_shard(id, 4);
            let after = rendezvous_shard(id, 5);
            assert!(after == before || after == 4, "id {id}: {before} → {after}");
        }
    }

    #[test]
    fn bands_partition_rows_on_tile_boundaries() {
        for (m, nm, shards) in
            [(40, 8, 4), (37, 8, 4), (16, 8, 4), (33, 8, 2), (8, 8, 4), (129, 16, 3)]
        {
            let bands = plan_bands(m, nm, shards);
            let gm = m.div_ceil(nm);
            assert_eq!(bands.len(), shards.min(gm));
            let mut next_row = 0usize;
            for (j, b) in bands.iter().enumerate() {
                assert_eq!(b.shard, j);
                assert_eq!(b.row0, next_row, "bands are contiguous");
                assert!(b.rows > 0);
                assert_eq!(b.row0 % nm, 0, "bands start on tile boundaries");
                if j + 1 < bands.len() {
                    assert_eq!(b.rows % nm, 0, "only the last band holds fringe rows");
                }
                next_row += b.rows;
            }
            assert_eq!(next_row, m, "bands partition every output row");
            // Balanced to within one tile.
            let tiles: Vec<usize> = bands.iter().map(|b| b.rows.div_ceil(nm)).collect();
            let (min, max) = (tiles.iter().min().unwrap(), tiles.iter().max().unwrap());
            assert!(max - min <= 1, "m={m} nm={nm}: unbalanced tiles {tiles:?}");
        }
    }

    #[test]
    fn band_operands_slice_a_rows_and_clone_b() {
        let (m, k) = (6, 3);
        let a: Vec<f32> = (0..(m * k) as i32).map(|v| v as f32).collect();
        let b = vec![1.0f32; 3 * 2];
        let ops = Operands::F32 { a: a.clone(), b: b.clone() };
        let band = Band { shard: 1, row0: 2, rows: 3 };
        match band_operands(&ops, &band, k) {
            Operands::F32 { a: sub_a, b: sub_b } => {
                assert_eq!(sub_a, a[2 * k..5 * k].to_vec());
                assert_eq!(sub_b, b);
            }
            _ => panic!("precision changed across the split"),
        }
    }

    #[test]
    fn split_acc_merges_in_band_order_regardless_of_completion_order() {
        let req = MatMulRequest::f32(9, 4, 3, 2).with_weight_id(7);
        let got = Arc::new(Mutex::new(None));
        let sink = {
            let got = Arc::clone(&got);
            Reply::Callback(Box::new(move |_req, out| {
                *got.lock().unwrap() = Some(out);
            }))
        };
        let acc = SplitAcc::new(req, 3, sink);
        // Bands of 1/2/1 rows of the 4×2 output (disjoint row blocks).
        let blocks: Vec<Vec<f32>> =
            vec![vec![0.0, 1.0], vec![2.0, 3.0, 4.0, 5.0], vec![6.0, 7.0]];
        // Deliver out of order: 2, 0, 1.
        for j in [2usize, 0, 1] {
            assert!(got.lock().unwrap().is_none(), "must not deliver early");
            band_reply(&acc, j).send(req, Ok(MatOutput::F32(blocks[j].clone())));
        }
        let out = got.lock().unwrap().take().expect("delivered once all bands landed");
        assert_eq!(
            out.unwrap().into_f32().unwrap(),
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            "concatenated in band order, not completion order"
        );
    }

    #[test]
    fn split_acc_reports_first_failing_band_deterministically() {
        let req = MatMulRequest::f32(10, 4, 2, 1);
        let got = Arc::new(Mutex::new(None));
        let sink = {
            let got = Arc::clone(&got);
            Reply::Callback(Box::new(move |_req, out| {
                *got.lock().unwrap() = Some(out);
            }))
        };
        let acc = SplitAcc::new(req, 3, sink);
        // Bands 2 and 1 fail, band 0 succeeds; completion order 2, 1, 0.
        band_reply(&acc, 2).send(req, Err(anyhow!("late failure")));
        band_reply(&acc, 1).send(req, Err(anyhow!("early failure")));
        band_reply(&acc, 0).send(req, Ok(MatOutput::F32(vec![0.0])));
        let out = got.lock().unwrap().take().expect("resolved");
        // Band order decides: band 1's error wins even though band 2
        // failed first in time.
        assert_eq!(out.unwrap_err().to_string(), "early failure");
    }

    #[test]
    fn split_acc_merges_int8_accumulators() {
        let req = MatMulRequest::int8(9, 4, 2, 1);
        let got = Arc::new(Mutex::new(None));
        let sink = {
            let got = Arc::clone(&got);
            Reply::Callback(Box::new(move |_req, out| {
                *got.lock().unwrap() = Some(out);
            }))
        };
        let acc = SplitAcc::new(req, 2, sink);
        band_reply(&acc, 1).send(req, Ok(MatOutput::I32(vec![3, 4])));
        band_reply(&acc, 0).send(req, Ok(MatOutput::I32(vec![1, 2])));
        let out = got.lock().unwrap().take().expect("resolved");
        assert_eq!(out.unwrap().into_i32().unwrap(), vec![1, 2, 3, 4]);
    }
}
