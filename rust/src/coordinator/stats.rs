//! Request/serving statistics: per-request completions, per-class
//! queueing/service percentiles, plus pipeline window occupancy (how
//! many tiles were actually in flight — the measured counterpart of the
//! configured `pipeline_depth`).

use crate::arch::precision::Precision;
use crate::util::stats::{mean, percentile};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// In-flight window occupancy aggregate, sampled once per completion
/// wait. `mean()` near 1.0 means the engine ran synchronously; near the
/// configured depth means full host/device overlap.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowOcc {
    samples: u64,
    sum: u64,
    max: usize,
}

impl WindowOcc {
    pub fn record(&mut self, in_flight: usize) {
        self.samples += 1;
        self.sum += in_flight as u64;
        self.max = self.max.max(in_flight);
    }

    /// Fold another occupancy aggregate into this one (per-shard →
    /// server-wide roll-up; absorbing a single aggregate into an empty
    /// one is an exact copy).
    pub fn absorb(&mut self, other: &WindowOcc) {
        self.samples += other.samples;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        self.sum as f64 / self.samples as f64
    }

    pub fn max(&self) -> usize {
        self.max
    }
}

/// Memory-plane snapshot: packed-weight cache counters and tile-buffer
/// recycling counters (see [`crate::coordinator::pool`]). Hits/misses/
/// evictions and recycled/allocated are lifetime totals; bytes/entries/
/// free are current gauges.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemPlaneStats {
    /// Packed-B pools served from the weight cache (packing skipped).
    pub weight_cache_hits: u64,
    /// Lookups that had to pack (cache enabled but key absent).
    pub weight_cache_misses: u64,
    /// Entries evicted to respect the byte budget.
    pub weight_cache_evictions: u64,
    /// Resident cached bytes (gauge, ≤ `weight_cache_bytes`).
    pub weight_cache_bytes: u64,
    /// Resident cached weights (gauge).
    pub weight_cache_entries: u64,
    /// Tile-buffer takes served by the free-lists (no heap allocation).
    pub tile_buffers_recycled: u64,
    /// Tile-buffer takes that fell through to a fresh heap allocation —
    /// plateaus once the server reaches its zero-alloc steady state.
    pub tile_buffers_allocated: u64,
    /// Buffers currently parked in the free-lists (gauge, bounded by
    /// [`crate::coordinator::pool::FREE_LIST_CAP`] per precision).
    pub tile_buffers_free: usize,
    /// Cache hits whose pool was CRC-verified against the checksum
    /// stamped at insert (sampled every
    /// `ServeConfig::cache_verify_interval` hits, plus the first hit on
    /// every rewarmed entry).
    pub cache_verifications: u64,
    /// Cached pools evicted because verification caught a CRC mismatch
    /// (the entry is quarantined and the request re-packs from source).
    pub poisoned_evictions: u64,
    /// Entries rescued from a dead shard's cache and re-inserted into
    /// its respawned successor's cache.
    pub rewarmed_entries: u64,
}

impl MemPlaneStats {
    /// Fold another shard's memory-plane snapshot into this roll-up
    /// (lifetime counters and gauges both sum: total resident bytes /
    /// entries / free buffers across shards).
    pub fn absorb(&mut self, other: &MemPlaneStats) {
        self.weight_cache_hits += other.weight_cache_hits;
        self.weight_cache_misses += other.weight_cache_misses;
        self.weight_cache_evictions += other.weight_cache_evictions;
        self.weight_cache_bytes += other.weight_cache_bytes;
        self.weight_cache_entries += other.weight_cache_entries;
        self.tile_buffers_recycled += other.tile_buffers_recycled;
        self.tile_buffers_allocated += other.tile_buffers_allocated;
        self.tile_buffers_free += other.tile_buffers_free;
        self.cache_verifications += other.cache_verifications;
        self.poisoned_evictions += other.poisoned_evictions;
        self.rewarmed_entries += other.rewarmed_entries;
    }
}

/// Packing-stage snapshot: how much host time the scheduler spent
/// extracting operand matrices into tile-major arenas, and how often
/// the extraction fanned out across pack workers
/// (`ServeConfig::pack_workers` — see
/// [`crate::coordinator::pool::TilePool::pack_timed`]). Since PR 8 the
/// time is split along the
/// [`PackTiming`](crate::coordinator::pool::PackTiming) seam:
/// `pack_time_s` is the extraction critical path (the busiest chunk of
/// each arena build — parallel fan-outs *shrink* it, so comparing it
/// across `pack_workers` settings measures the fan-out win directly),
/// while `pack_spawn_s` is the fan-out orchestration overhead —
/// task construction, dispatch, and join. The persistent
/// [`WorkPool`](crate::coordinator::workpool::WorkPool)
/// (`ServeConfig::pack_persistent`) attacks `pack_spawn_s`
/// specifically: comparing it against the legacy per-call scoped
/// threads (`pack_persistent = false`) is the A/B in
/// `benches/e2e_serving.rs`. A weight-cache hit skips the B build
/// (only the request's A build is counted); fingerprint hashing and
/// cache lookups are never charged here.
#[derive(Debug, Clone, Copy, Default)]
pub struct PackStats {
    /// Operand matrices packed into arenas (A + uncached B per request).
    pub matrices_packed: u64,
    /// Packs that fanned out across more than one pack worker.
    pub parallel_packs: u64,
    /// Extraction-critical-path seconds spent in arena builds (serial
    /// builds: the whole build).
    pub pack_time_s: f64,
    /// Fan-out orchestration overhead, seconds: spawn/dispatch/join
    /// around the extraction chunks (zero for serial builds).
    pub pack_spawn_s: f64,
}

impl PackStats {
    /// Fold another shard's packing snapshot into this roll-up. Pack
    /// times sum across shards (each shard has its own scheduler
    /// thread, so the roll-up is total scheduler-seconds spent packing,
    /// not wall time).
    pub fn absorb(&mut self, other: &PackStats) {
        self.matrices_packed += other.matrices_packed;
        self.parallel_packs += other.parallel_packs;
        self.pack_time_s += other.pack_time_s;
        self.pack_spawn_s += other.pack_spawn_s;
    }
}

/// Fault-plane snapshot: injection counters (bumped by the device
/// workers at the moment of injection — see
/// [`crate::coordinator::fault::FaultPlan`]) and recovery counters
/// (bumped by the scheduler's deadline/retry/verify machinery). With
/// fault injection disabled the `injected_*` and `checksum_failures`
/// counters stay zero, but timeouts/retries/deaths can still occur
/// organically (a genuinely wedged or crashed worker).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultStats {
    pub injected_errors: u64,
    pub injected_panics: u64,
    pub injected_delays: u64,
    pub injected_hangs: u64,
    pub injected_corruptions: u64,
    /// Cached packed-weight pools corrupted by the chaos layer
    /// (`FaultKind::CacheCorrupt`, injected at the scheduler).
    pub injected_cache_corruptions: u64,
    /// Scheduler threads killed by the chaos layer
    /// (`FaultKind::ShardCrash`, injected at the facade).
    pub injected_shard_crashes: u64,
    /// Tiles whose deadline expired before a completion arrived.
    pub timeouts: u64,
    /// Tiles re-dispatched after an error, timeout or checksum failure.
    pub retries: u64,
    /// Flights failed because a tile exhausted `max_tile_retries`.
    pub retries_exhausted: u64,
    /// Completions rejected by the checksum verify pass (chaos mode).
    pub checksum_failures: u64,
    /// Dead worker threads detected by supervision.
    pub worker_deaths: u64,
    /// Dead workers successfully respawned in place.
    pub respawns: u64,
    /// Workers quarantined after repeated consecutive faults.
    pub quarantined: u64,
}

impl FaultStats {
    /// Fold another shard's fault-plane snapshot into this roll-up
    /// (every field is a lifetime counter, so they all sum).
    pub fn absorb(&mut self, other: &FaultStats) {
        self.injected_errors += other.injected_errors;
        self.injected_panics += other.injected_panics;
        self.injected_delays += other.injected_delays;
        self.injected_hangs += other.injected_hangs;
        self.injected_corruptions += other.injected_corruptions;
        self.injected_cache_corruptions += other.injected_cache_corruptions;
        self.injected_shard_crashes += other.injected_shard_crashes;
        self.timeouts += other.timeouts;
        self.retries += other.retries;
        self.retries_exhausted += other.retries_exhausted;
        self.checksum_failures += other.checksum_failures;
        self.worker_deaths += other.worker_deaths;
        self.respawns += other.respawns;
        self.quarantined += other.quarantined;
    }

    /// Total injected faults across kinds.
    pub fn injected(&self) -> u64 {
        self.injected_errors
            + self.injected_panics
            + self.injected_delays
            + self.injected_hangs
            + self.injected_corruptions
            + self.injected_cache_corruptions
            + self.injected_shard_crashes
    }
}

/// Request-level robustness counters: deadline expiries, SLO/brownout
/// sheds, and the router failover plane (circuit-breaker trips, probes,
/// recoveries and re-dispatches). All lifetime counters; all zero with
/// the PR 9 knobs at their defaults (`slo_admission` off,
/// `shed_watermark = 0`, `shard_failover` off, no request deadlines).
/// The shed/deadline counters are bumped shard-side and roll up through
/// [`ShedStats::absorb`]; the failover/breaker counters are bumped by
/// the facade's router and merged into the server-wide snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShedStats {
    /// Requests rejected by the brownout shedder
    /// (`ServeConfig::shed_watermark`).
    pub shed_brownout: u64,
    /// Requests rejected by SLO-aware admission
    /// (`ServeConfig::slo_admission`).
    pub shed_slo: u64,
    /// Requests that expired in flight past their
    /// `MatMulRequest::with_deadline` budget.
    pub deadline_expired: u64,
    /// Whole requests re-dispatched to another shard after a scheduler
    /// failure (failover mode).
    pub failovers: u64,
    /// Individual row-bands of M-split requests re-dispatched after a
    /// scheduler failure.
    pub failover_bands: u64,
    /// Circuit breakers tripped closed → open.
    pub breaker_trips: u64,
    /// Half-open probe requests let through an open breaker.
    pub breaker_probes: u64,
    /// Breakers recovered half-open → closed (shard rejoined).
    pub breaker_recoveries: u64,
}

impl ShedStats {
    /// Fold another snapshot into this roll-up (every field is a
    /// lifetime counter, so they all sum).
    pub fn absorb(&mut self, other: &ShedStats) {
        self.shed_brownout += other.shed_brownout;
        self.shed_slo += other.shed_slo;
        self.deadline_expired += other.deadline_expired;
        self.failovers += other.failovers;
        self.failover_bands += other.failover_bands;
        self.breaker_trips += other.breaker_trips;
        self.breaker_probes += other.breaker_probes;
        self.breaker_recoveries += other.breaker_recoveries;
    }

    /// Total requests rejected at admission (brownout + SLO).
    pub fn shed(&self) -> u64 {
        self.shed_brownout + self.shed_slo
    }
}

/// Shard-side atomics behind the shed/deadline fields of [`ShedStats`]:
/// the submit path bumps the shed counters, the scheduler thread bumps
/// `deadline_expired`, and [`snapshot`](ShedCounters::snapshot) folds
/// them into the per-shard stats (failover/breaker fields stay zero —
/// those live at the facade).
#[derive(Debug, Default)]
pub(crate) struct ShedCounters {
    pub(crate) shed_brownout: AtomicU64,
    pub(crate) shed_slo: AtomicU64,
    pub(crate) deadline_expired: AtomicU64,
}

impl ShedCounters {
    pub(crate) fn snapshot(&self) -> ShedStats {
        ShedStats {
            shed_brownout: self.shed_brownout.load(Ordering::Relaxed),
            shed_slo: self.shed_slo.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            ..ShedStats::default()
        }
    }
}

/// One circuit breaker's position in the Closed → Open → HalfOpen walk
/// (failover mode; see `crate::coordinator::server::FailoverPlane`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests route normally.
    #[default]
    Closed,
    /// Tripped after `breaker_threshold` consecutive failures: traffic
    /// is diverted until the probe interval elapses.
    Open,
    /// One probe request has been let through; its outcome closes or
    /// re-opens the breaker.
    HalfOpen,
}

impl BreakerState {
    /// The string form used by `ServerStats::breaker_states`
    /// (`"closed"` / `"open"` / `"half-open"`).
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

impl std::fmt::Display for BreakerState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Typed per-shard breaker snapshot, surfaced in
/// [`ShardStats::breaker`] when the failover plane exists
/// (`ServeConfig::shard_failover` with `shards > 1`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BreakerSnapshot {
    pub state: BreakerState,
    /// Consecutive scheduler-level failures recorded against the shard
    /// (reset to zero by any success).
    pub consecutive_failures: u32,
    /// What the last recorded failure was (`"scheduler_panicked"`,
    /// `"drain_deadline_expired"`, `"dispatch_failed"`), `None` if the
    /// shard has never failed.
    pub last_failure: Option<&'static str>,
}

/// Recovery-plane counters (PR 10): shard respawns driven by the
/// supervisor, cache rewarm volume, and memory-plane integrity
/// verification outcomes, plus a mirror of the breaker transition
/// counters so the whole recovery story reads from one block. All
/// lifetime counters; all zero with the recovery knobs at their
/// defaults (`shard_respawn` off, `cache_verify_interval = 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Shards rebuilt in place by the respawn supervisor.
    pub respawns: u64,
    /// Respawn attempts that failed, plus shards degraded to permanent
    /// removal after exhausting `respawn_max_attempts`.
    pub respawn_failures: u64,
    /// Cache entries rescued from dead shards into their successors.
    pub rewarmed_entries: u64,
    /// Cache hits whose pool was CRC-verified against its insert stamp.
    pub cache_verifications: u64,
    /// Poisoned cache entries caught by verification and quarantined.
    pub poisoned_evictions: u64,
    /// Circuit breakers tripped closed → open.
    pub breaker_trips: u64,
    /// Half-open probe requests let through an open breaker.
    pub breaker_probes: u64,
    /// Breakers recovered half-open → closed (shard rejoined).
    pub breaker_recoveries: u64,
}

impl RecoveryStats {
    /// Fold another snapshot into this roll-up (every field is a
    /// lifetime counter, so they all sum).
    pub fn absorb(&mut self, other: &RecoveryStats) {
        self.respawns += other.respawns;
        self.respawn_failures += other.respawn_failures;
        self.rewarmed_entries += other.rewarmed_entries;
        self.cache_verifications += other.cache_verifications;
        self.poisoned_evictions += other.poisoned_evictions;
        self.breaker_trips += other.breaker_trips;
        self.breaker_probes += other.breaker_probes;
        self.breaker_recoveries += other.breaker_recoveries;
    }
}

/// One device worker's health gauges, as surfaced in
/// `ServerStats::worker_health` (see
/// [`crate::coordinator::device::DeviceHandle::health_snapshot`]).
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    /// Worker index in the pool.
    pub worker: usize,
    /// `"healthy"`, `"quarantined"` (benched after repeated consecutive
    /// faults; used only when no healthy peer remains) or `"dead"`
    /// (thread gone and respawn failed — the pool shrank).
    pub state: &'static str,
    /// Jobs dispatched to this worker and not yet completed.
    pub outstanding: usize,
    /// Tiles this worker actually executed.
    pub executed: u64,
    /// Faults charged to this worker (cumulative).
    pub faults: u64,
    /// Consecutive faults since its last clean completion.
    pub consecutive_faults: u32,
    /// Times this worker slot was respawned after a death.
    pub respawns: u32,
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub macs: u64,
    /// Precision the request ran in (fp32 or int8).
    pub precision: Precision,
    /// Priority class the request was scheduled in (clamped).
    pub class: usize,
    pub wall: Duration,
    /// Queueing delay: submission → first tile issued.
    pub queued: Duration,
    /// Service time: first tile issued → retirement.
    pub service: Duration,
    /// Device time consumed by this request's tiles (seconds).
    pub device_s: f64,
    /// Tile invocations issued.
    pub invocations: u64,
}

/// Latency samples retained for mean/percentile queries. The server is
/// long-lived (open streaming admission), so per-request state must be
/// bounded: totals below are exact running counters, latency stats are
/// over the most recent window.
pub const LATENCY_WINDOW: usize = 4096;

/// Per-class samples retained for queueing/service percentiles. Classes
/// are bounded by the request class byte (≤ 256) and in practice by the
/// configured class count, so total memory stays O(classes · window).
pub const CLASS_WINDOW: usize = 1024;

/// Bounded queueing/service/latency sample windows of one class.
#[derive(Debug, Clone, Default)]
struct ClassAgg {
    count: usize,
    queue_ms: VecDeque<f64>,
    service_ms: VecDeque<f64>,
    latency_ms: VecDeque<f64>,
}

impl ClassAgg {
    fn record(&mut self, queue_ms: f64, service_ms: f64, latency_ms: f64) {
        self.count += 1;
        for (window, v) in [
            (&mut self.queue_ms, queue_ms),
            (&mut self.service_ms, service_ms),
            (&mut self.latency_ms, latency_ms),
        ] {
            if window.len() == CLASS_WINDOW {
                window.pop_front();
            }
            window.push_back(v);
        }
    }

    fn absorb(&mut self, other: &ClassAgg) {
        self.count += other.count;
        for (window, src) in [
            (&mut self.queue_ms, &other.queue_ms),
            (&mut self.service_ms, &other.service_ms),
            (&mut self.latency_ms, &other.latency_ms),
        ] {
            window.extend(src.iter().copied());
            while window.len() > CLASS_WINDOW {
                window.pop_front();
            }
        }
    }
}

/// Percentile snapshot of one priority class (from the bounded
/// [`CLASS_WINDOW`] sample windows; counts are exact lifetime totals).
#[derive(Debug, Clone, Copy)]
pub struct ClassStats {
    pub class: usize,
    pub count: usize,
    /// Queueing delay (submission → first tile issued), ms.
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    /// Service time (first tile issued → retirement), ms.
    pub service_p50_ms: f64,
    pub service_p99_ms: f64,
    /// End-to-end wall latency, ms.
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
}

/// Aggregated serving statistics. Counts/MACs/device time are exact
/// lifetime totals; wall-latency mean/p99 are computed over the last
/// [`LATENCY_WINDOW`] completions and per-class percentiles over the
/// last [`CLASS_WINDOW`] per class, so memory stays O(1) per server.
#[derive(Debug, Clone, Default)]
pub struct StatsAgg {
    count: usize,
    count_fp32: usize,
    count_int8: usize,
    cancelled: usize,
    total_macs: u64,
    total_device_s: f64,
    recent_latency_ms: VecDeque<f64>,
    classes: BTreeMap<usize, ClassAgg>,
}

impl StatsAgg {
    pub fn record(&mut self, c: Completion) {
        self.count += 1;
        match c.precision {
            Precision::Fp32 => self.count_fp32 += 1,
            Precision::Int8 => self.count_int8 += 1,
            _ => {}
        }
        self.total_macs += c.macs;
        self.total_device_s += c.device_s;
        if self.recent_latency_ms.len() == LATENCY_WINDOW {
            self.recent_latency_ms.pop_front();
        }
        self.recent_latency_ms.push_back(c.wall.as_secs_f64() * 1e3);
        self.classes.entry(c.class).or_default().record(
            c.queued.as_secs_f64() * 1e3,
            c.service.as_secs_f64() * 1e3,
            c.wall.as_secs_f64() * 1e3,
        );
    }

    /// Count one cancelled request (not a completion — cancelled
    /// requests never enter the latency windows).
    pub fn record_cancelled(&mut self) {
        self.cancelled += 1;
    }

    /// Fold another aggregate into this one — the per-shard →
    /// server-wide roll-up. Lifetime totals sum exactly; the bounded
    /// latency/class windows concatenate (self's samples first, then
    /// `other`'s) and re-trim to their caps, which preserves mean/
    /// percentile semantics because those are order-insensitive.
    /// Absorbing one aggregate into an empty one reproduces it exactly,
    /// so a single-shard server reports identical statistics through
    /// the roll-up path.
    pub fn absorb(&mut self, other: &StatsAgg) {
        self.count += other.count;
        self.count_fp32 += other.count_fp32;
        self.count_int8 += other.count_int8;
        self.cancelled += other.cancelled;
        self.total_macs += other.total_macs;
        self.total_device_s += other.total_device_s;
        self.recent_latency_ms.extend(other.recent_latency_ms.iter().copied());
        while self.recent_latency_ms.len() > LATENCY_WINDOW {
            self.recent_latency_ms.pop_front();
        }
        for (&class, agg) in &other.classes {
            self.classes.entry(class).or_default().absorb(agg);
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Requests cancelled before completion.
    pub fn cancelled(&self) -> usize {
        self.cancelled
    }

    /// Completions that ran in `precision` (per-precision traffic split).
    pub fn count_by(&self, precision: Precision) -> usize {
        match precision {
            Precision::Fp32 => self.count_fp32,
            Precision::Int8 => self.count_int8,
            _ => 0,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.total_macs
    }

    pub fn total_device_s(&self) -> f64 {
        self.total_device_s
    }

    /// Wall latencies (ms) of the most recent completions (bounded at
    /// [`LATENCY_WINDOW`]).
    pub fn wall_latencies_ms(&self) -> Vec<f64> {
        self.recent_latency_ms.iter().copied().collect()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        mean(&self.wall_latencies_ms())
    }

    pub fn p99_latency_ms(&self) -> f64 {
        percentile(&self.wall_latencies_ms(), 99.0)
    }

    /// Per-class queueing/service/latency percentile snapshots, sorted
    /// by class index. Only classes that completed a request appear.
    pub fn class_stats(&self) -> Vec<ClassStats> {
        let pct = |w: &VecDeque<f64>, p: f64| {
            percentile(&w.iter().copied().collect::<Vec<f64>>(), p)
        };
        self.classes
            .iter()
            .map(|(&class, agg)| ClassStats {
                class,
                count: agg.count,
                queue_p50_ms: pct(&agg.queue_ms, 50.0),
                queue_p99_ms: pct(&agg.queue_ms, 99.0),
                service_p50_ms: pct(&agg.service_ms, 50.0),
                service_p99_ms: pct(&agg.service_ms, 99.0),
                latency_p50_ms: pct(&agg.latency_ms, 50.0),
                latency_p99_ms: pct(&agg.latency_ms, 99.0),
            })
            .collect()
    }

    /// Device-time throughput in ops/s (2 ops per MAC): what the VCK190
    /// would sustain on this request stream.
    pub fn device_ops_per_sec(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            return 0.0;
        }
        2.0 * self.total_macs() as f64 / t
    }
}

/// One shard's serving statistics, as surfaced in
/// `ServerStats::shards`. Field meanings match their server-wide
/// counterparts in [`crate::coordinator::server::ServerStats`], scoped
/// to the one scheduler + device pool + memory plane this shard owns.
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Shard index (position in `ServerStats::shards`).
    pub shard: usize,
    /// Requests this shard completed (split bands count individually).
    pub requests: usize,
    pub requests_fp32: usize,
    pub requests_int8: usize,
    /// Requests (or split bands) cancelled before completion.
    pub cancelled: usize,
    /// Kernel invocations issued by this shard's scheduler.
    pub invocations: u64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Per-class queueing/service percentiles for this shard's traffic.
    pub classes: Vec<ClassStats>,
    pub device_ops_per_sec: f64,
    pub device_time_s: f64,
    pub mean_in_flight: f64,
    pub max_in_flight: usize,
    /// Requests currently admitted and not yet retired — the live load
    /// gauge the router's least-loaded fallback reads.
    pub open_requests: usize,
    pub mem: MemPlaneStats,
    pub pack: PackStats,
    pub faults: FaultStats,
    /// This shard's request-level robustness counters (sheds, deadline
    /// expiries). The failover/breaker fields stay zero here — they are
    /// router-side and only appear in the server-wide roll-up.
    pub shed: ShedStats,
    /// This shard's circuit breaker, typed (`None` without a failover
    /// plane — `shard_failover` off or a single shard).
    pub breaker: Option<BreakerSnapshot>,
    /// This shard's device workers (indices are shard-local).
    pub worker_health: Vec<WorkerHealth>,
}

/// Routing decisions made by the shard router (lifetime counters; see
/// [`crate::coordinator::shard`] for the routing policy).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Whole requests placed by rendezvous hashing on their `weight_id`.
    pub routed_affinity: u64,
    /// Whole requests placed on the least-loaded shard (anonymous
    /// weights, or affinity disabled).
    pub routed_least_loaded: u64,
    /// Requests split along M across shards.
    pub split_requests: u64,
    /// Total bands those split requests fanned out into.
    pub split_parts: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(id: u64, class: usize, macs: u64, wall_ms: u64, queue_ms: u64) -> Completion {
        Completion {
            id,
            macs,
            precision: Precision::Fp32,
            class,
            wall: Duration::from_millis(wall_ms),
            queued: Duration::from_millis(queue_ms),
            service: Duration::from_millis(wall_ms.saturating_sub(queue_ms)),
            device_s: macs as f64 * 1e-9,
            invocations: 1,
        }
    }

    #[test]
    fn aggregates() {
        let mut s = StatsAgg::default();
        s.record(Completion {
            id: 0,
            macs: 1000,
            precision: Precision::Fp32,
            class: 0,
            wall: Duration::from_millis(10),
            queued: Duration::from_millis(4),
            service: Duration::from_millis(6),
            device_s: 1e-6,
            invocations: 1,
        });
        s.record(Completion {
            id: 1,
            macs: 3000,
            precision: Precision::Int8,
            class: 1,
            wall: Duration::from_millis(30),
            queued: Duration::from_millis(10),
            service: Duration::from_millis(20),
            device_s: 3e-6,
            invocations: 3,
        });
        assert_eq!(s.count(), 2);
        assert_eq!(s.count_by(Precision::Fp32), 1);
        assert_eq!(s.count_by(Precision::Int8), 1);
        assert_eq!(s.count_by(Precision::Bf16), 0);
        assert_eq!(s.cancelled(), 0);
        assert_eq!(s.total_macs(), 4000);
        assert!((s.mean_latency_ms() - 20.0).abs() < 1e-9);
        assert!((s.device_ops_per_sec() - 2.0 * 4000.0 / 4e-6).abs() < 1.0);
    }

    #[test]
    fn empty_stats_safe() {
        let s = StatsAgg::default();
        assert_eq!(s.device_ops_per_sec(), 0.0);
        assert_eq!(s.mean_latency_ms(), 0.0);
        assert!(s.class_stats().is_empty());
    }

    #[test]
    fn latency_window_is_bounded_but_totals_are_exact() {
        // A long-lived streaming server must not grow per-request state
        // without bound: totals keep counting, latencies roll over.
        let mut s = StatsAgg::default();
        let n = LATENCY_WINDOW + 100;
        for i in 0..n {
            s.record(completion(i as u64, 0, 10, 1, 0));
        }
        assert_eq!(s.count(), n);
        assert_eq!(s.count_by(Precision::Fp32), n);
        assert_eq!(s.total_macs(), 10 * n as u64);
        assert_eq!(s.wall_latencies_ms().len(), LATENCY_WINDOW);
    }

    #[test]
    fn class_windows_bounded_counts_exact() {
        let mut s = StatsAgg::default();
        let n = CLASS_WINDOW + 50;
        for i in 0..n {
            s.record(completion(i as u64, 3, 1, 2, 1));
        }
        let cs = s.class_stats();
        assert_eq!(cs.len(), 1);
        assert_eq!(cs[0].class, 3);
        assert_eq!(cs[0].count, n, "counts are lifetime-exact");
        // The windows themselves stay bounded (indirect check: the
        // percentiles still reflect the constant stream).
        assert!((cs[0].queue_p99_ms - 1.0).abs() < 1e-9);
        assert!((cs[0].latency_p50_ms - 2.0).abs() < 1e-9);
    }

    #[test]
    fn class_percentiles_split_queue_and_service() {
        let mut s = StatsAgg::default();
        // Class 0: fast service, no queueing. Class 1: queue-dominated.
        for i in 0..100 {
            s.record(completion(i, 0, 1, 2, 0));
            s.record(completion(100 + i, 1, 1, 50, 45));
        }
        let cs = s.class_stats();
        assert_eq!(cs.len(), 2);
        assert_eq!((cs[0].class, cs[1].class), (0, 1));
        assert!(cs[0].queue_p99_ms < 1e-9);
        assert!((cs[0].service_p50_ms - 2.0).abs() < 1e-9);
        assert!((cs[1].queue_p50_ms - 45.0).abs() < 1e-9);
        assert!((cs[1].service_p99_ms - 5.0).abs() < 1e-9);
        assert!(cs[1].latency_p99_ms > cs[0].latency_p99_ms);
    }

    #[test]
    fn cancelled_counted_separately() {
        let mut s = StatsAgg::default();
        s.record(completion(0, 0, 1, 1, 0));
        s.record_cancelled();
        s.record_cancelled();
        assert_eq!(s.count(), 1);
        assert_eq!(s.cancelled(), 2);
        assert_eq!(s.class_stats()[0].count, 1);
    }

    #[test]
    fn absorb_into_empty_is_identity() {
        // The server-wide roll-up for shards = 1 must report exactly
        // what the lone shard reports.
        let mut shard = StatsAgg::default();
        for i in 0..50 {
            shard.record(completion(i, i as usize % 3, 100, 5 + i, 2));
        }
        shard.record_cancelled();
        let mut agg = StatsAgg::default();
        agg.absorb(&shard);
        assert_eq!(agg.count(), shard.count());
        assert_eq!(agg.cancelled(), shard.cancelled());
        assert_eq!(agg.total_macs(), shard.total_macs());
        assert_eq!(agg.wall_latencies_ms(), shard.wall_latencies_ms());
        assert_eq!(agg.mean_latency_ms(), shard.mean_latency_ms());
        assert_eq!(agg.p99_latency_ms(), shard.p99_latency_ms());
        let (a, b) = (agg.class_stats(), shard.class_stats());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.class, x.count), (y.class, y.count));
            assert_eq!(x.latency_p99_ms, y.latency_p99_ms);
        }
    }

    #[test]
    fn absorb_sums_totals_and_bounds_windows() {
        let mut a = StatsAgg::default();
        let mut b = StatsAgg::default();
        for i in 0..LATENCY_WINDOW {
            a.record(completion(i as u64, 0, 10, 1, 0));
            b.record(completion(i as u64, 1, 20, 3, 1));
        }
        a.absorb(&b);
        assert_eq!(a.count(), 2 * LATENCY_WINDOW);
        assert_eq!(a.total_macs(), 30 * LATENCY_WINDOW as u64);
        assert_eq!(a.wall_latencies_ms().len(), LATENCY_WINDOW);
        assert_eq!(a.class_stats().len(), 2);
        // b's newer samples displaced a's from the merged window.
        assert!((a.mean_latency_ms() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_absorb_sums_fields() {
        let mut m = MemPlaneStats { weight_cache_hits: 1, tile_buffers_free: 2, ..Default::default() };
        m.absorb(&MemPlaneStats { weight_cache_hits: 4, tile_buffers_free: 3, ..Default::default() });
        assert_eq!(m.weight_cache_hits, 5);
        assert_eq!(m.tile_buffers_free, 5);

        let mut p = PackStats {
            matrices_packed: 2,
            pack_time_s: 0.5,
            pack_spawn_s: 0.125,
            ..Default::default()
        };
        p.absorb(&PackStats {
            matrices_packed: 1,
            pack_time_s: 0.25,
            pack_spawn_s: 0.0625,
            ..Default::default()
        });
        assert_eq!(p.matrices_packed, 3);
        assert!((p.pack_time_s - 0.75).abs() < 1e-12);
        assert!((p.pack_spawn_s - 0.1875).abs() < 1e-12);

        let mut f = FaultStats { retries: 2, injected_errors: 1, ..Default::default() };
        f.absorb(&FaultStats { retries: 3, injected_panics: 2, ..Default::default() });
        assert_eq!(f.retries, 5);
        assert_eq!(f.injected(), 3);

        let mut sh = ShedStats { shed_brownout: 1, deadline_expired: 2, ..Default::default() };
        sh.absorb(&ShedStats {
            shed_brownout: 3,
            shed_slo: 4,
            failovers: 1,
            breaker_trips: 1,
            ..Default::default()
        });
        assert_eq!(sh.shed_brownout, 4);
        assert_eq!(sh.shed(), 8);
        assert_eq!(sh.deadline_expired, 2);
        assert_eq!(sh.failovers, 1);
        assert_eq!(sh.breaker_trips, 1);
        assert_eq!(ShedStats::default(), ShedStats::default());

        let mut w = WindowOcc::default();
        w.record(2);
        let mut w2 = WindowOcc::default();
        w2.record(6);
        w.absorb(&w2);
        assert_eq!(w.samples(), 2);
        assert_eq!(w.max(), 6);
        assert!((w.mean() - 4.0).abs() < 1e-12);

        let mut r = RecoveryStats { respawns: 1, cache_verifications: 10, ..Default::default() };
        r.absorb(&RecoveryStats {
            respawns: 2,
            respawn_failures: 1,
            rewarmed_entries: 4,
            cache_verifications: 5,
            poisoned_evictions: 1,
            breaker_trips: 3,
            breaker_probes: 2,
            breaker_recoveries: 1,
        });
        assert_eq!(r.respawns, 3);
        assert_eq!(r.respawn_failures, 1);
        assert_eq!(r.rewarmed_entries, 4);
        assert_eq!(r.cache_verifications, 15);
        assert_eq!(r.poisoned_evictions, 1);
        assert_eq!(r.breaker_trips, 3);
        assert_eq!(r.breaker_probes, 2);
        assert_eq!(r.breaker_recoveries, 1);
        assert_eq!(RecoveryStats::default(), RecoveryStats::default());

        // The integrity counters ride the memory-plane roll-up too.
        let mut m = MemPlaneStats {
            cache_verifications: 2,
            poisoned_evictions: 1,
            rewarmed_entries: 3,
            ..Default::default()
        };
        m.absorb(&MemPlaneStats {
            cache_verifications: 5,
            poisoned_evictions: 2,
            rewarmed_entries: 1,
            ..Default::default()
        });
        assert_eq!(m.cache_verifications, 7);
        assert_eq!(m.poisoned_evictions, 3);
        assert_eq!(m.rewarmed_entries, 4);
    }

    #[test]
    fn breaker_state_strings_match_server_stats_vocabulary() {
        // `ServerStats::breaker_states` derives its strings from the
        // typed enum; these exact values are pinned by the failover
        // tests ("closed"/"open"/"half-open").
        assert_eq!(BreakerState::Closed.as_str(), "closed");
        assert_eq!(BreakerState::Open.as_str(), "open");
        assert_eq!(BreakerState::HalfOpen.as_str(), "half-open");
        assert_eq!(BreakerState::default(), BreakerState::Closed);
        let snap = BreakerSnapshot::default();
        assert_eq!(snap.state, BreakerState::Closed);
        assert_eq!(snap.consecutive_failures, 0);
        assert_eq!(snap.last_failure, None);
    }

    #[test]
    fn window_occupancy_aggregates() {
        let mut w = WindowOcc::default();
        assert_eq!(w.mean(), 0.0);
        for occ in [1, 4, 4, 3] {
            w.record(occ);
        }
        assert_eq!(w.samples(), 4);
        assert_eq!(w.max(), 4);
        assert!((w.mean() - 3.0).abs() < 1e-12);
    }
}
