//! Request/serving statistics: per-request completions plus pipeline
//! window occupancy (how many tiles were actually in flight — the
//! measured counterpart of the configured `pipeline_depth`).

use crate::util::stats::{mean, percentile};
use std::time::Duration;

/// In-flight window occupancy aggregate, sampled once per completion
/// wait. `mean()` near 1.0 means the engine ran synchronously; near the
/// configured depth means full host/device overlap.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowOcc {
    samples: u64,
    sum: u64,
    max: usize,
}

impl WindowOcc {
    pub fn record(&mut self, in_flight: usize) {
        self.samples += 1;
        self.sum += in_flight as u64;
        self.max = self.max.max(in_flight);
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        self.sum as f64 / self.samples as f64
    }

    pub fn max(&self) -> usize {
        self.max
    }

    /// Fold another aggregate into this one (per-batch → cumulative).
    pub fn merge(&mut self, other: &WindowOcc) {
        self.samples += other.samples;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub macs: u64,
    pub wall: Duration,
    /// Device time consumed by this request's tiles (seconds).
    pub device_s: f64,
    /// Tile invocations issued.
    pub invocations: u64,
}

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct StatsAgg {
    completions: Vec<Completion>,
}

impl StatsAgg {
    pub fn record(&mut self, c: Completion) {
        self.completions.push(c);
    }

    pub fn count(&self) -> usize {
        self.completions.len()
    }

    pub fn total_macs(&self) -> u64 {
        self.completions.iter().map(|c| c.macs).sum()
    }

    pub fn total_device_s(&self) -> f64 {
        self.completions.iter().map(|c| c.device_s).sum()
    }

    pub fn wall_latencies_ms(&self) -> Vec<f64> {
        self.completions
            .iter()
            .map(|c| c.wall.as_secs_f64() * 1e3)
            .collect()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        mean(&self.wall_latencies_ms())
    }

    pub fn p99_latency_ms(&self) -> f64 {
        percentile(&self.wall_latencies_ms(), 99.0)
    }

    /// Device-time throughput in ops/s (2 ops per MAC): what the VCK190
    /// would sustain on this request stream.
    pub fn device_ops_per_sec(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            return 0.0;
        }
        2.0 * self.total_macs() as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut s = StatsAgg::default();
        s.record(Completion {
            id: 0,
            macs: 1000,
            wall: Duration::from_millis(10),
            device_s: 1e-6,
            invocations: 1,
        });
        s.record(Completion {
            id: 1,
            macs: 3000,
            wall: Duration::from_millis(30),
            device_s: 3e-6,
            invocations: 3,
        });
        assert_eq!(s.count(), 2);
        assert_eq!(s.total_macs(), 4000);
        assert!((s.mean_latency_ms() - 20.0).abs() < 1e-9);
        assert!((s.device_ops_per_sec() - 2.0 * 4000.0 / 4e-6).abs() < 1.0);
    }

    #[test]
    fn empty_stats_safe() {
        let s = StatsAgg::default();
        assert_eq!(s.device_ops_per_sec(), 0.0);
        assert_eq!(s.mean_latency_ms(), 0.0);
    }

    #[test]
    fn window_occupancy_aggregates() {
        let mut w = WindowOcc::default();
        assert_eq!(w.mean(), 0.0);
        for occ in [1, 4, 4, 3] {
            w.record(occ);
        }
        assert_eq!(w.samples(), 4);
        assert_eq!(w.max(), 4);
        assert!((w.mean() - 3.0).abs() < 1e-12);

        let mut total = WindowOcc::default();
        total.record(6);
        total.merge(&w);
        assert_eq!(total.samples(), 5);
        assert_eq!(total.max(), 6);
        assert!((total.mean() - (6 + 1 + 4 + 4 + 3) as f64 / 5.0).abs() < 1e-12);
    }
}
