//! Request/serving statistics: per-request completions plus pipeline
//! window occupancy (how many tiles were actually in flight — the
//! measured counterpart of the configured `pipeline_depth`).

use crate::arch::precision::Precision;
use crate::util::stats::{mean, percentile};
use std::collections::VecDeque;
use std::time::Duration;

/// In-flight window occupancy aggregate, sampled once per completion
/// wait. `mean()` near 1.0 means the engine ran synchronously; near the
/// configured depth means full host/device overlap.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowOcc {
    samples: u64,
    sum: u64,
    max: usize,
}

impl WindowOcc {
    pub fn record(&mut self, in_flight: usize) {
        self.samples += 1;
        self.sum += in_flight as u64;
        self.max = self.max.max(in_flight);
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        self.sum as f64 / self.samples as f64
    }

    pub fn max(&self) -> usize {
        self.max
    }
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub macs: u64,
    /// Precision the request ran in (fp32 or int8).
    pub precision: Precision,
    pub wall: Duration,
    /// Device time consumed by this request's tiles (seconds).
    pub device_s: f64,
    /// Tile invocations issued.
    pub invocations: u64,
}

/// Latency samples retained for mean/percentile queries. The server is
/// long-lived (open streaming admission), so per-request state must be
/// bounded: totals below are exact running counters, latency stats are
/// over the most recent window.
pub const LATENCY_WINDOW: usize = 4096;

/// Aggregated serving statistics. Counts/MACs/device time are exact
/// lifetime totals; wall-latency mean/p99 are computed over the last
/// [`LATENCY_WINDOW`] completions so memory stays O(1) per server.
#[derive(Debug, Clone, Default)]
pub struct StatsAgg {
    count: usize,
    count_fp32: usize,
    count_int8: usize,
    total_macs: u64,
    total_device_s: f64,
    recent_latency_ms: VecDeque<f64>,
}

impl StatsAgg {
    pub fn record(&mut self, c: Completion) {
        self.count += 1;
        match c.precision {
            Precision::Fp32 => self.count_fp32 += 1,
            Precision::Int8 => self.count_int8 += 1,
            _ => {}
        }
        self.total_macs += c.macs;
        self.total_device_s += c.device_s;
        if self.recent_latency_ms.len() == LATENCY_WINDOW {
            self.recent_latency_ms.pop_front();
        }
        self.recent_latency_ms.push_back(c.wall.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Completions that ran in `precision` (per-precision traffic split).
    pub fn count_by(&self, precision: Precision) -> usize {
        match precision {
            Precision::Fp32 => self.count_fp32,
            Precision::Int8 => self.count_int8,
            _ => 0,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.total_macs
    }

    pub fn total_device_s(&self) -> f64 {
        self.total_device_s
    }

    /// Wall latencies (ms) of the most recent completions (bounded at
    /// [`LATENCY_WINDOW`]).
    pub fn wall_latencies_ms(&self) -> Vec<f64> {
        self.recent_latency_ms.iter().copied().collect()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        mean(&self.wall_latencies_ms())
    }

    pub fn p99_latency_ms(&self) -> f64 {
        percentile(&self.wall_latencies_ms(), 99.0)
    }

    /// Device-time throughput in ops/s (2 ops per MAC): what the VCK190
    /// would sustain on this request stream.
    pub fn device_ops_per_sec(&self) -> f64 {
        let t = self.total_device_s();
        if t == 0.0 {
            return 0.0;
        }
        2.0 * self.total_macs() as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut s = StatsAgg::default();
        s.record(Completion {
            id: 0,
            macs: 1000,
            precision: Precision::Fp32,
            wall: Duration::from_millis(10),
            device_s: 1e-6,
            invocations: 1,
        });
        s.record(Completion {
            id: 1,
            macs: 3000,
            precision: Precision::Int8,
            wall: Duration::from_millis(30),
            device_s: 3e-6,
            invocations: 3,
        });
        assert_eq!(s.count(), 2);
        assert_eq!(s.count_by(Precision::Fp32), 1);
        assert_eq!(s.count_by(Precision::Int8), 1);
        assert_eq!(s.count_by(Precision::Bf16), 0);
        assert_eq!(s.total_macs(), 4000);
        assert!((s.mean_latency_ms() - 20.0).abs() < 1e-9);
        assert!((s.device_ops_per_sec() - 2.0 * 4000.0 / 4e-6).abs() < 1.0);
    }

    #[test]
    fn empty_stats_safe() {
        let s = StatsAgg::default();
        assert_eq!(s.device_ops_per_sec(), 0.0);
        assert_eq!(s.mean_latency_ms(), 0.0);
    }

    #[test]
    fn latency_window_is_bounded_but_totals_are_exact() {
        // A long-lived streaming server must not grow per-request state
        // without bound: totals keep counting, latencies roll over.
        let mut s = StatsAgg::default();
        let n = LATENCY_WINDOW + 100;
        for i in 0..n {
            s.record(Completion {
                id: i as u64,
                macs: 10,
                precision: Precision::Fp32,
                wall: Duration::from_millis(1),
                device_s: 1e-9,
                invocations: 1,
            });
        }
        assert_eq!(s.count(), n);
        assert_eq!(s.count_by(Precision::Fp32), n);
        assert_eq!(s.total_macs(), 10 * n as u64);
        assert_eq!(s.wall_latencies_ms().len(), LATENCY_WINDOW);
    }

    #[test]
    fn window_occupancy_aggregates() {
        let mut w = WindowOcc::default();
        assert_eq!(w.mean(), 0.0);
        for occ in [1, 4, 4, 3] {
            w.record(occ);
        }
        assert_eq!(w.samples(), 4);
        assert_eq!(w.max(), 4);
        assert!((w.mean() - 3.0).abs() < 1e-12);
    }
}
