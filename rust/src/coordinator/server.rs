//! The MatMul serving coordinator: **streaming admission** + pluggable
//! scheduling policy + pipelined tile engine on the device worker pool.
//!
//! This module is the client-facing facade; the machinery lives in the
//! sibling modules:
//!
//! * [`crate::coordinator::admission`] — the bounded open-request gate
//!   (`queue_depth` + block/reject backpressure).
//! * [`crate::coordinator::policy`] — [`SchedPolicy`]: who issues the
//!   next tile ([`PolicyKind::Fifo`] round-robin by default,
//!   `WeightedFair` deficit round-robin with per-precision costs,
//!   `Priority` strict classes with aging).
//! * [`crate::coordinator::scheduler`] — the scheduler thread: packing,
//!   the in-flight window, ordered reduction, retirement, cancellation.
//! * [`crate::coordinator::handle`] — per-request completion delivery
//!   ([`RequestHandle`]: `wait` / `try_wait` / `cancel`) and callbacks.
//! * [`crate::coordinator::pool`] — the memory plane: contiguous arena
//!   tile pools, the byte-budgeted packed-weight cache
//!   (`ServeConfig::weight_cache_bytes` +
//!   [`MatMulRequest::with_weight_id`](crate::workloads::MatMulRequest::with_weight_id)),
//!   and the tile-buffer free-lists that give a long-lived server a
//!   zero-allocation steady state per tile ([`ServerStats::mem`]).
//!
//! # Streaming admission (the open queue)
//!
//! [`MatMulServer::submit`] admits one request into a bounded open
//! queue and returns a [`RequestHandle`] immediately; the scheduler
//! thread packs operands, feeds the in-flight window continuously,
//! reduces partials and retires requests while later submissions are
//! still arriving. Backpressure is governed by
//! `ServeConfig::queue_depth` and an [`AdmissionPolicy`]
//! (`Block` parks the producer, `Reject` fails fast with [`QueueFull`]).
//!
//! # Scheduling policy, classes and cancellation
//!
//! Every [`MatMulRequest`] carries a priority `class`; the configured
//! [`PolicyKind`] decides how classes and precisions share the window.
//! The default `Fifo` policy reproduces the PR 1/2 round-robin
//! bit-for-bit. Dropping or explicitly cancelling a [`RequestHandle`]
//! reclaims the request's queue and window slots for tiles not yet
//! dispatched — see [`RequestHandle::cancel`] and the
//! [`Cancelled`] error.
//!
//! # Per-request precision
//!
//! fp32 requests flow as f32 tiles, int8 requests as int8-range
//! operands carried in i32 with **i32 accumulation buffers** (paper
//! §IV-C1), through the same tiler/window/reduction machinery — each
//! precision with its own native tile geometry and simulated device
//! period. One server interleaves both in a single window.
//!
//! **Determinism:** outputs are bit-identical for every
//! `pipeline_depth`/`workers` combination and admission interleaving —
//! see `rust/tests/pipeline_equivalence.rs` and
//! `rust/tests/streaming_admission.rs`.

use crate::arch::precision::Precision;
use crate::config::schema::{AdmissionPolicy, PolicyKind, ServeConfig};
use crate::coordinator::admission::{Admitted, Gate};
use crate::coordinator::device::{
    spawn_device_pool_with_faults, PoolHealth, PrecisionInfo, TileDone,
};
use crate::coordinator::fault::FaultCounters;
use crate::coordinator::handle::Reply;
use crate::coordinator::policy::{PolicyParams, TileCosts};
use crate::coordinator::pool::{BufferPool, PackCounters, WeightCache, WeightCacheCounters};
use crate::coordinator::scheduler::{Event, Robustness, Scheduler, Shared};
use crate::coordinator::stats::{
    ClassStats, FaultStats, MemPlaneStats, PackStats, StatsAgg, WindowOcc, WorkerHealth,
};
use crate::coordinator::tiler::Tiler;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub use crate::coordinator::admission::QueueFull;
pub use crate::coordinator::handle::{Cancelled, RequestHandle};

/// Serving statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: usize,
    /// Requests served in fp32 / int8 (the dual-precision traffic split).
    pub requests_fp32: usize,
    pub requests_int8: usize,
    /// Requests cancelled before completion (not counted in `requests`).
    pub cancelled: usize,
    pub invocations: u64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Per-class queueing-delay / service-time percentiles (bounded
    /// windows; one entry per class that completed a request).
    pub classes: Vec<ClassStats>,
    /// Device-time throughput (ops/s) over the whole stream.
    pub device_ops_per_sec: f64,
    /// Total simulated device time (s).
    pub device_time_s: f64,
    /// Total wall time (s) spent in `run_batch` calls (streaming
    /// submissions are not attributed here).
    pub wall_time_s: f64,
    /// Configured in-flight window.
    pub pipeline_depth: usize,
    /// Measured mean window occupancy (1.0 = synchronous).
    pub mean_in_flight: f64,
    /// Measured peak window occupancy.
    pub max_in_flight: usize,
    /// Memory-plane counters: packed-weight cache hit/miss/evict and
    /// tile-buffer recycle/alloc (see [`crate::coordinator::pool`]).
    pub mem: MemPlaneStats,
    /// Packing-stage counters: matrices packed, parallel fan-outs and
    /// wall time spent packing (`ServeConfig::pack_workers`).
    pub pack: PackStats,
    /// Fault-plane counters: injected faults (chaos mode), timeouts,
    /// retries, checksum rejections, worker deaths/respawns/quarantines
    /// (see [`crate::coordinator::fault`]). All zero on a fault-free
    /// run with the fault plane disabled.
    pub faults: FaultStats,
    /// Per-worker health gauges, one entry per pool slot.
    pub worker_health: Vec<WorkerHealth>,
}

/// The serving coordinator (client handle). Cheap to share across
/// threads by reference: `submit*` take `&self`.
pub struct MatMulServer {
    events: mpsc::Sender<Event>,
    sched: Option<JoinHandle<()>>,
    forwarder: Option<JoinHandle<()>>,
    gate: Arc<Gate>,
    shared: Arc<Shared>,
    cycles: Arc<AtomicU64>,
    invocations: Arc<AtomicU64>,
    info_f32: PrecisionInfo,
    info_int8: PrecisionInfo,
    freq_hz: f64,
    backend: &'static str,
    workers: usize,
    pipeline_depth: usize,
    policy: AdmissionPolicy,
    sched_policy: PolicyKind,
    queue_depth: usize,
    /// Admission-token mint (cancellation addresses).
    next_token: AtomicU64,
    /// Weight-cache counters shared with the scheduler's cache.
    cache_counters: Arc<WeightCacheCounters>,
    /// Packing-stage counters shared with the scheduler.
    pack_counters: Arc<PackCounters>,
    /// Configured operand-packing fan-out width.
    pack_workers: usize,
    /// Tile-buffer free-lists shared with the device pool + scheduler.
    bufs: Arc<BufferPool>,
    /// Fault-plane counters shared with the device pool + scheduler.
    fault_counters: Arc<FaultCounters>,
    /// Per-worker health gauges shared with the device pool.
    health: Arc<PoolHealth>,
    /// Shutdown drain budget (`ServeConfig::drain_deadline_ms`;
    /// `None` = wait for every open request, the historical behavior).
    drain_deadline: Option<Duration>,
}

impl MatMulServer {
    /// Start the server: spawns the device worker pool, the completion
    /// forwarder and the scheduler thread.
    pub fn start(cfg: &ServeConfig) -> Result<Self> {
        let device = spawn_device_pool_with_faults(
            cfg.artifacts_dir.clone().into(),
            cfg.design.clone(),
            cfg.backend,
            cfg.workers,
            cfg.fault_plan.clone(),
        )?;
        let (cycles, invocations) = device.counters();
        let fault_counters = device.fault_counters();
        let health = device.pool_health();
        let info_f32 = device.info_for(Precision::Fp32)?;
        let info_int8 = device.info_for(Precision::Int8)?;
        let freq_hz = device.freq_hz;
        let backend = device.backend;
        let workers = device.workers;

        let gate = Arc::new(Gate::new(
            cfg.queue_depth,
            cfg.class_queue_reserve.iter().map(|&r| r as usize).collect(),
        ));
        let shared = Arc::new(Shared {
            stats: Mutex::new(StatsAgg::default()),
            window: Mutex::new(WindowOcc::default()),
            last_window: Mutex::new(WindowOcc::default()),
            wall_time_s: Mutex::new(0.0),
        });
        let (events_tx, events_rx) = mpsc::channel::<Event>();
        let (tile_tx, tile_rx) = mpsc::channel::<TileDone>();

        // Tile completions → scheduler events (std mpsc has no select;
        // a relay thread keeps the scheduler single-channel).
        let fwd_events = events_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name("maxeva-completions".into())
            .spawn(move || {
                while let Ok(done) = tile_rx.recv() {
                    if fwd_events.send(Event::Done(done)).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning completion forwarder: {e}"))?;

        // Per-precision tile costs charge the *measured* device period
        // per tile (falling back to the geometric MAC ratio when the
        // simulated periods are degenerate): this is what makes
        // WeightedFair split device time, not tiles — even when
        // MACs/cycle differ across precisions.
        let costs = TileCosts::from_periods(
            info_f32.period_cycles,
            info_int8.period_cycles,
            info_f32.native,
            info_int8.native,
        );
        let params = PolicyParams::from_config(cfg, costs);
        let cache_counters = Arc::new(WeightCacheCounters::default());
        let weight_cache =
            WeightCache::new(cfg.weight_cache_bytes, Arc::clone(&cache_counters));
        let pack_counters = Arc::new(PackCounters::default());
        let bufs = device.buffer_pool();
        // Resolve the per-tile deadline once per precision: multiplier ×
        // the precision's simulated tile period, floored so a deadline
        // is never shorter than scheduling noise. Multiplier 0 keeps
        // the historical wait-forever completion loop.
        let tile_deadline = |period_cycles: f64| -> Option<Duration> {
            if cfg.tile_timeout_mult <= 0.0 {
                return None;
            }
            let secs = (cfg.tile_timeout_mult * period_cycles / freq_hz)
                .max(cfg.tile_timeout_floor_ms as f64 / 1e3);
            Some(Duration::from_secs_f64(secs))
        };
        let robust = Robustness {
            max_tile_retries: cfg.max_tile_retries,
            deadline_f32: tile_deadline(info_f32.period_cycles),
            deadline_i32: tile_deadline(info_int8.period_cycles),
            quarantine_after: cfg.quarantine_after,
        };
        let drain_deadline = match cfg.drain_deadline_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        let sched = Scheduler::new(
            device,
            Tiler::new(info_f32.native),
            Tiler::new(info_int8.native),
            Arc::clone(&gate),
            Arc::clone(&shared),
            tile_tx,
            cfg.pipeline_depth,
            params,
            weight_cache,
            cfg.pack_workers,
            Arc::clone(&pack_counters),
            robust,
        );
        let sched = std::thread::Builder::new()
            .name("maxeva-scheduler".into())
            .spawn(move || sched.run(events_rx))
            .map_err(|e| anyhow!("spawning scheduler: {e}"))?;

        Ok(MatMulServer {
            events: events_tx,
            sched: Some(sched),
            forwarder: Some(forwarder),
            gate,
            shared,
            cycles,
            invocations,
            info_f32,
            info_int8,
            freq_hz,
            backend,
            workers,
            pipeline_depth: cfg.pipeline_depth.max(1),
            policy: cfg.admission,
            sched_policy: cfg.policy,
            queue_depth: cfg.queue_depth,
            next_token: AtomicU64::new(0),
            cache_counters,
            pack_counters,
            pack_workers: cfg.pack_workers.max(1),
            bufs,
            fault_counters,
            health,
            drain_deadline,
        })
    }

    /// Per-precision device facts — the server-side dispatch point.
    fn info_for(&self, p: Precision) -> Result<PrecisionInfo> {
        match p {
            Precision::Fp32 => Ok(self.info_f32),
            Precision::Int8 => Ok(self.info_int8),
            other => Err(anyhow!("serving supports fp32 and int8, not {other}")),
        }
    }

    /// Native fp32 design size (nm, nk, nn).
    pub fn native(&self) -> (u64, u64, u64) {
        self.info_f32.native
    }

    /// Native design size for a serving precision.
    pub fn native_for(&self, p: Precision) -> Result<(u64, u64, u64)> {
        Ok(self.info_for(p)?.native)
    }

    /// Steady-state fp32 iteration period of the design, in device cycles.
    pub fn period_cycles(&self) -> f64 {
        self.info_f32.period_cycles
    }

    /// Iteration period for a serving precision, in device cycles.
    pub fn period_cycles_for(&self, p: Precision) -> Result<f64> {
        Ok(self.info_for(p)?.period_cycles)
    }

    /// Device clock frequency, Hz.
    pub fn freq_hz(&self) -> f64 {
        self.freq_hz
    }

    /// Resolved tile-execution backend ("pjrt" or "reference").
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Device worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Operand-packing fan-out width (`ServeConfig::pack_workers`;
    /// 1 = serial packing).
    pub fn pack_workers(&self) -> usize {
        self.pack_workers
    }

    /// Configured in-flight window.
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Admission queue bound (`0` = unbounded).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The active scheduling policy.
    pub fn sched_policy(&self) -> PolicyKind {
        self.sched_policy
    }

    /// Reconfigure the in-flight window (the A/B knob; `1` = synchronous).
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth.max(1);
        let _ = self.events.send(Event::SetDepth(depth));
    }

    /// Swap the scheduling policy live (the policy A/B knob). Flights
    /// already open migrate to the new policy deterministically.
    pub fn set_sched_policy(&mut self, kind: PolicyKind) {
        self.sched_policy = kind;
        let _ = self.events.send(Event::SetPolicy(kind));
    }

    /// `(mean, max)` window occupancy since the last `run_batch` began —
    /// unlike [`ServerStats::mean_in_flight`] this is not diluted by
    /// earlier batches run at other depths.
    pub fn last_batch_occupancy(&self) -> (f64, usize) {
        let w = self.shared.last_window.lock().unwrap();
        (w.mean(), w.max())
    }

    fn validate(req: &MatMulRequest, ops: &Operands) -> Result<()> {
        match (req.precision, ops) {
            (Precision::Fp32, Operands::F32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                Ok(())
            }
            (Precision::Int8, Operands::I32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                if a.iter().chain(b.iter()).any(|v| !(-128..=127).contains(v)) {
                    return Err(anyhow!(
                        "request {}: int8 operands must be in [-128, 127]",
                        req.id
                    ));
                }
                Ok(())
            }
            (Precision::Fp32, Operands::I32 { .. }) | (Precision::Int8, Operands::F32 { .. }) => {
                Err(anyhow!(
                    "request {}: operand container does not match request precision {}",
                    req.id,
                    req.precision
                ))
            }
            (p, _) => Err(anyhow!("serving supports fp32 and int8, not {p}")),
        }
    }

    fn submit_inner(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        reply: Reply,
    ) -> Result<u64> {
        Self::validate(&req, &ops)?;
        self.gate.admit(policy, req.class)?;
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let adm = Box::new(Admitted {
            req,
            ops: Some(ops),
            submitted: Instant::now(),
            reply: Some(reply),
            token,
            gate: Arc::clone(&self.gate),
        });
        if self.events.send(Event::Admit(adm)).is_err() {
            // The returned Admitted dropped: slot freed, reply errored.
            return Err(anyhow!("server is shut down"));
        }
        Ok(token)
    }

    /// Admit one request under the configured admission policy and get a
    /// completion handle. Blocks (policy `Block`) or fails with
    /// [`QueueFull`] (policy `Reject`) when `queue_depth` requests are
    /// already open. Dropping the handle unresolved **cancels** the
    /// request ([`RequestHandle::cancel`]).
    pub fn submit(&self, req: MatMulRequest, ops: Operands) -> Result<RequestHandle> {
        self.submit_with_policy(req, ops, self.policy)
    }

    /// [`MatMulServer::submit`] with an explicit per-call policy.
    pub fn submit_with_policy(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
    ) -> Result<RequestHandle> {
        let (tx, rx) = mpsc::channel();
        let id = req.id;
        let token = self.submit_inner(req, ops, policy, Reply::Handle(tx))?;
        Ok(RequestHandle::new(id, token, rx, self.events.clone()))
    }

    /// Admit one request and deliver its completion through `callback`
    /// instead of a handle. The callback runs on the scheduler thread —
    /// keep it short (hand heavy post-processing to another thread).
    pub fn submit_with_callback(
        &self,
        req: MatMulRequest,
        ops: Operands,
        callback: impl FnOnce(MatMulRequest, Result<MatOutput>) + Send + 'static,
    ) -> Result<()> {
        self.submit_inner(req, ops, self.policy, Reply::Callback(Box::new(callback)))?;
        Ok(())
    }

    /// Execute one fp32 request synchronously (convenience path).
    pub fn execute(&mut self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let mut out = self.run_batch(vec![(req, a, b)])?;
        Ok(out.pop().unwrap())
    }

    /// Serve a closed fp32 batch through the streaming engine (submit
    /// everything with blocking admission, wait in order). Returns the
    /// outputs in request order. On error the batch's other open
    /// requests are cancelled (see [`MatMulServer::run_batch_mixed`]).
    pub fn run_batch(
        &mut self,
        batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
    ) -> Result<Vec<Vec<f32>>> {
        self.run_batch_mixed(
            batch
                .into_iter()
                .map(|(req, a, b)| (req, Operands::F32 { a, b }))
                .collect(),
        )?
        .into_iter()
        .map(MatOutput::into_f32)
        .collect()
    }

    /// Serve a closed mixed-precision batch through the streaming
    /// engine. Returns the outputs in request order.
    ///
    /// On any error — a submission rejected mid-batch or a request
    /// failing — the remaining handles are dropped, which (since PR 3)
    /// **cancels** the batch's other open requests: a failed batch
    /// reclaims its queue/window slots instead of running doomed work
    /// to completion. Those requests land in `stats().cancelled`, not
    /// `requests`.
    pub fn run_batch_mixed(
        &mut self,
        batch: Vec<(MatMulRequest, Operands)>,
    ) -> Result<Vec<MatOutput>> {
        let wall0 = Instant::now();
        let _ = self.events.send(Event::ResetEpoch);
        let mut handles = Vec::with_capacity(batch.len());
        for (req, ops) in batch {
            handles.push(self.submit_with_policy(req, ops, AdmissionPolicy::Block)?);
        }
        let outs: Result<Vec<MatOutput>> = handles.into_iter().map(RequestHandle::wait).collect();
        *self.shared.wall_time_s.lock().unwrap() += wall0.elapsed().as_secs_f64();
        outs
    }

    /// Snapshot serving statistics.
    pub fn stats(&self) -> ServerStats {
        let stats = self.shared.stats.lock().unwrap();
        let window = self.shared.window.lock().unwrap();
        let mem = MemPlaneStats {
            weight_cache_hits: self.cache_counters.hits.load(Ordering::Relaxed),
            weight_cache_misses: self.cache_counters.misses.load(Ordering::Relaxed),
            weight_cache_evictions: self.cache_counters.evictions.load(Ordering::Relaxed),
            weight_cache_bytes: self.cache_counters.bytes.load(Ordering::Relaxed),
            weight_cache_entries: self.cache_counters.entries.load(Ordering::Relaxed),
            tile_buffers_recycled: self.bufs.recycled(),
            tile_buffers_allocated: self.bufs.allocated(),
            tile_buffers_free: self.bufs.free(),
        };
        let pack = PackStats {
            matrices_packed: self.pack_counters.matrices.load(Ordering::Relaxed),
            parallel_packs: self.pack_counters.parallel.load(Ordering::Relaxed),
            pack_time_s: self.pack_counters.nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        };
        let fc = &self.fault_counters;
        let faults = FaultStats {
            injected_errors: fc.injected_errors.load(Ordering::Relaxed),
            injected_panics: fc.injected_panics.load(Ordering::Relaxed),
            injected_delays: fc.injected_delays.load(Ordering::Relaxed),
            injected_hangs: fc.injected_hangs.load(Ordering::Relaxed),
            injected_corruptions: fc.injected_corruptions.load(Ordering::Relaxed),
            timeouts: fc.timeouts.load(Ordering::Relaxed),
            retries: fc.retries.load(Ordering::Relaxed),
            retries_exhausted: fc.retries_exhausted.load(Ordering::Relaxed),
            checksum_failures: fc.checksum_failures.load(Ordering::Relaxed),
            worker_deaths: fc.worker_deaths.load(Ordering::Relaxed),
            respawns: fc.respawns.load(Ordering::Relaxed),
            quarantined: fc.quarantined.load(Ordering::Relaxed),
        };
        ServerStats {
            requests: stats.count(),
            requests_fp32: stats.count_by(Precision::Fp32),
            requests_int8: stats.count_by(Precision::Int8),
            cancelled: stats.cancelled(),
            invocations: self.invocations.load(Ordering::Relaxed),
            mean_latency_ms: stats.mean_latency_ms(),
            p99_latency_ms: stats.p99_latency_ms(),
            classes: stats.class_stats(),
            device_ops_per_sec: stats.device_ops_per_sec(),
            device_time_s: self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz,
            wall_time_s: *self.shared.wall_time_s.lock().unwrap(),
            pipeline_depth: self.pipeline_depth,
            mean_in_flight: window.mean(),
            max_in_flight: window.max(),
            mem,
            pack,
            faults,
            worker_health: self.health.snapshot(),
        }
    }

    fn stop(&mut self) {
        let _ = self.events.send(Event::Drain(self.drain_deadline));
        if let Some(j) = self.sched.take() {
            let _ = j.join();
        }
        if let Some(j) = self.forwarder.take() {
            let _ = j.join();
        }
    }

    /// Graceful shutdown: drain every open request, then stop the
    /// scheduler and device workers. With
    /// `ServeConfig::drain_deadline_ms` set, the drain is bounded:
    /// requests still open past the budget fail with
    /// [`DrainDeadlineExpired`](crate::coordinator::fault::DrainDeadlineExpired)
    /// instead of hanging shutdown on a lost tile.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// [`MatMulServer::shutdown`] with an explicit drain budget,
    /// overriding the configured `drain_deadline_ms`.
    pub fn shutdown_with_deadline(mut self, deadline: Duration) {
        self.drain_deadline = Some(deadline);
        self.stop();
    }

    /// Chaos-test hook: make the scheduler thread panic, exercising the
    /// fail-fast path that resolves every open flight with
    /// [`SchedulerPanicked`](crate::coordinator::fault::SchedulerPanicked).
    /// Kills the scheduler — the server serves nothing afterwards.
    #[doc(hidden)]
    pub fn inject_scheduler_panic(&self) {
        let _ = self.events.send(Event::ChaosPanic);
    }
}

impl Drop for MatMulServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// Integration tests (needing built artifacts) live in
// rust/tests/serving_e2e.rs; backend-independent pipelined-vs-sequential
// equivalence tests in rust/tests/pipeline_equivalence.rs; streaming
// admission, backpressure and mixed-precision tests in
// rust/tests/streaming_admission.rs; fairness and cancellation tests in
// rust/tests/policy_fairness.rs and rust/tests/cancellation.rs.
