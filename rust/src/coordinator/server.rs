//! The MatMul serving layer: request queue + dynamic tile batcher on top
//! of the device thread.
//!
//! Requests of arbitrary `M×K×N` are decomposed into native-size tile
//! jobs. The scheduler interleaves tiles of all in-flight requests
//! round-robin ("dynamic batching" at tile granularity — the device never
//! idles between requests, and small requests are not starved behind
//! large ones), accumulates partial blocks, and completes requests in
//! submission order per stream.

use crate::config::schema::ServeConfig;
use crate::coordinator::device::{spawn_device, DeviceHandle};
use crate::coordinator::stats::{Completion, StatsAgg};
use crate::coordinator::tiler::Tiler;
use crate::workloads::MatMulRequest;
use anyhow::Result;
use std::time::Instant;

/// Serving statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub invocations: u64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Device-time throughput (ops/s) over the whole stream.
    pub device_ops_per_sec: f64,
    /// Total simulated device time (s).
    pub device_time_s: f64,
    /// Total wall time (s) spent in `run_batch`.
    pub wall_time_s: f64,
}

/// One in-flight request's state.
struct InFlight {
    req: MatMulRequest,
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    /// Tile cursor: (im, ik, in) lexicographic.
    cursor: u64,
    total_tiles: u64,
    started: Instant,
    invocations: u64,
    device_s0: f64,
}

/// The serving coordinator.
pub struct MatMulServer {
    device: DeviceHandle,
    tiler: Tiler,
    stats: StatsAgg,
    wall_time_s: f64,
}

impl MatMulServer {
    /// Start the server: spawns the device thread and compiles the
    /// design's artifact.
    pub fn start(cfg: &ServeConfig) -> Result<Self> {
        let device = spawn_device(cfg.artifacts_dir.clone().into(), cfg.design.clone())?;
        let tiler = Tiler::new(device.native);
        Ok(MatMulServer {
            device,
            tiler,
            stats: StatsAgg::default(),
            wall_time_s: 0.0,
        })
    }

    /// Native design size (nm, nk, nn).
    pub fn native(&self) -> (u64, u64, u64) {
        self.device.native
    }

    /// Execute one request synchronously (convenience path).
    pub fn execute(&mut self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let mut out = self.run_batch(vec![(req, a, b)])?;
        Ok(out.pop().unwrap())
    }

    /// Execute a batch of requests with round-robin tile interleaving.
    /// Returns the outputs in request order.
    pub fn run_batch(
        &mut self,
        batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
    ) -> Result<Vec<Vec<f32>>> {
        let wall0 = Instant::now();
        let mut flights: Vec<InFlight> = batch
            .into_iter()
            .map(|(req, a, b)| {
                assert_eq!(a.len() as u64, req.m * req.k, "A shape mismatch");
                assert_eq!(b.len() as u64, req.k * req.n, "B shape mismatch");
                let (gm, gk, gn) = self.tiler.grid(req.m as usize, req.k as usize, req.n as usize);
                InFlight {
                    c: vec![0.0; (req.m * req.n) as usize],
                    cursor: 0,
                    total_tiles: (gm * gk * gn) as u64,
                    started: Instant::now(),
                    invocations: 0,
                    device_s0: self.device.device_time_s(),
                    req,
                    a,
                    b,
                }
            })
            .collect();

        let mut outputs: Vec<Option<Vec<f32>>> = (0..flights.len()).map(|_| None).collect();
        // Round-robin over in-flight requests, one tile each per turn.
        while flights.iter().any(|f| f.cursor < f.total_tiles) {
            for (idx, f) in flights.iter_mut().enumerate() {
                if f.cursor >= f.total_tiles {
                    continue;
                }
                self.step_tile(f)?;
                if f.cursor == f.total_tiles {
                    // Completed.
                    let wall = f.started.elapsed();
                    self.stats.record(Completion {
                        id: f.req.id,
                        macs: f.req.macs(),
                        wall,
                        device_s: self.device.device_time_s() - f.device_s0,
                        invocations: f.invocations,
                    });
                    outputs[idx] = Some(std::mem::take(&mut f.c));
                }
            }
        }
        self.wall_time_s += wall0.elapsed().as_secs_f64();
        Ok(outputs.into_iter().map(|o| o.unwrap()).collect())
    }

    /// Execute the next tile of one in-flight request.
    fn step_tile(&mut self, f: &mut InFlight) -> Result<()> {
        let (m, k, n) = (f.req.m as usize, f.req.k as usize, f.req.n as usize);
        let (_gm, gk, gn) = self.tiler.grid(m, k, n);
        let cur = f.cursor as usize;
        // Lexicographic (im, ik, in).
        let im = cur / (gk * gn);
        let ik = (cur / gn) % gk;
        let inn = cur % gn;
        let (nm, nk, nn) = (self.tiler.nm, self.tiler.nk, self.tiler.nn);
        let ab = Tiler::extract_block(&f.a, m, k, im, ik, nm, nk);
        let bb = Tiler::extract_block(&f.b, k, n, ik, inn, nk, nn);
        let cb = self.device.execute_tile(ab, bb)?;
        Tiler::accumulate_block(&mut f.c, m, n, im, inn, nm, nn, &cb);
        f.cursor += 1;
        f.invocations += 1;
        Ok(())
    }

    /// Snapshot serving statistics.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            requests: self.stats.count(),
            invocations: self.device.invocations(),
            mean_latency_ms: self.stats.mean_latency_ms(),
            p99_latency_ms: self.stats.p99_latency_ms(),
            device_ops_per_sec: self.stats.device_ops_per_sec(),
            device_time_s: self.device.device_time_s(),
            wall_time_s: self.wall_time_s,
        }
    }

    /// Shut the device thread down.
    pub fn shutdown(self) {
        self.device.shutdown();
    }
}

// Integration tests (needing built artifacts) live in
// rust/tests/serving_e2e.rs.
