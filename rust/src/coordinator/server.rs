//! The MatMul serving coordinator: **streaming admission** + pluggable
//! scheduling policy + pipelined tile engines on device worker pools.
//!
//! This module is the client-facing facade; the machinery lives in the
//! sibling modules:
//!
//! * [`crate::coordinator::shard`] — the sharded serving plane: each
//!   [`Shard`] is one complete scheduler + device-pool + memory-plane
//!   engine, and the router places requests on shards (weight-affinity
//!   rendezvous hashing, least-loaded fallback, M-splitting for large
//!   GEMMs — with the bit-identity-under-split contract documented
//!   there).
//! * [`crate::coordinator::admission`] — the bounded open-request gate
//!   (`queue_depth` + block/reject backpressure), one per shard.
//! * [`crate::coordinator::policy`] — [`SchedPolicy`]: who issues the
//!   next tile ([`PolicyKind::Fifo`] round-robin by default,
//!   `WeightedFair` deficit round-robin with per-precision costs,
//!   `Priority` strict classes with aging).
//! * [`crate::coordinator::scheduler`] — the scheduler thread: packing,
//!   the in-flight window, ordered reduction, retirement, cancellation.
//! * [`crate::coordinator::handle`] — per-request completion delivery
//!   ([`RequestHandle`]: `wait` / `try_wait` / `cancel`) and callbacks.
//! * [`crate::coordinator::pool`] — the memory plane: contiguous arena
//!   tile pools, the byte-budgeted packed-weight cache
//!   (`ServeConfig::weight_cache_bytes` +
//!   [`MatMulRequest::with_weight_id`](crate::workloads::MatMulRequest::with_weight_id)),
//!   and the tile-buffer free-lists that give a long-lived server a
//!   zero-allocation steady state per tile ([`ServerStats::mem`]).
//! * [`crate::coordinator::error`] — [`ServeError`], the one enum over
//!   every typed serving failure.
//!
//! # Streaming admission (the open queue)
//!
//! [`MatMulServer::submit`] admits one request into a bounded open
//! queue and returns a [`RequestHandle`] immediately; a scheduler
//! thread packs operands, feeds the in-flight window continuously,
//! reduces partials and retires requests while later submissions are
//! still arriving. Backpressure is governed by
//! `ServeConfig::queue_depth` (per shard) and an [`AdmissionPolicy`]
//! (`Block` parks the producer, `Reject` fails fast with [`QueueFull`]).
//!
//! # Sharding
//!
//! With `ServeConfig::shards = N > 1` the facade runs N engines and
//! routes each request (see [`crate::coordinator::shard`]); the default
//! `shards = 1` short-circuits the router entirely and is bit-for-bit
//! the single-engine server. [`MatMulServer::stats`] reports per-shard
//! snapshots (`ServerStats::shards`) plus rolled-up totals, and
//! `ServerStats::router` counts the routing decisions taken.
//!
//! # Scheduling policy, classes and cancellation
//!
//! Every [`MatMulRequest`] carries a priority `class`; the configured
//! [`PolicyKind`] decides how classes and precisions share each shard's
//! window. The default `Fifo` policy reproduces the PR 1/2 round-robin
//! bit-for-bit. Dropping or explicitly cancelling a [`RequestHandle`]
//! reclaims the request's queue and window slots for tiles not yet
//! dispatched — across every shard holding a band of it — see
//! [`RequestHandle::cancel`] and the [`Cancelled`] error.
//!
//! # Request-level robustness
//!
//! Three opt-in planes harden the request path (all off by default, so
//! the default build is bit-for-bit the pre-robustness server):
//! per-request **deadlines** ([`MatMulRequest::with_deadline`] — expiry
//! resolves the handle with a typed `DeadlineExceeded`, never a partial
//! output), **admission-time shedding** (`ServeConfig::slo_admission`
//! SLO estimates and the `ServeConfig::shed_watermark` brownout
//! shedder, surfaced in [`ServerStats::shed`]), and **shard failover**
//! (`ServeConfig::shard_failover`: per-shard circuit breakers plus
//! re-dispatch of whole requests and individual split-request bands off
//! a failed shard — see the crate-internal `FailoverPlane` and the
//! failure-model taxonomy in [`crate::coordinator`]).
//!
//! [`MatMulRequest::with_deadline`]: crate::workloads::MatMulRequest::with_deadline
//!
//! # Self-healing (the recovery plane)
//!
//! Two further opt-in planes close the loop from failover (route
//! *around* a failure) to recovery (repair it): **shard respawn**
//! (`ServeConfig::shard_respawn` — a supervisor thread rebuilds a
//! crashed shard's engine from the same `ServeConfig`, swaps it into
//! the shard table, optionally rewarms the hottest packed weights the
//! dying scheduler exported, and lets the breaker walk
//! Open → HalfOpen → Closed through the normal probe path) and
//! **memory-plane integrity** (`ServeConfig::cache_verify_interval` —
//! every packed pool carries an FNV-1a checksum stamped at insert,
//! sampled verify-on-hit quarantines a corrupted entry and the request
//! transparently re-packs from its own operands). Both default off;
//! counters surface in `ServerStats::recovery`.
//!
//! # Per-request precision
//!
//! fp32 requests flow as f32 tiles, int8 requests as int8-range
//! operands carried in i32 with **i32 accumulation buffers** (paper
//! §IV-C1), through the same tiler/window/reduction machinery — each
//! precision with its own native tile geometry and simulated device
//! period. One server interleaves both in a single window.
//!
//! **Determinism:** outputs are bit-identical for every
//! `pipeline_depth`/`workers`/`shards` combination and admission
//! interleaving — see `rust/tests/pipeline_equivalence.rs`,
//! `rust/tests/streaming_admission.rs` and
//! `rust/tests/shard_routing.rs`.
//!
//! [`Shard`]: crate::coordinator::shard
//! [`SchedPolicy`]: crate::coordinator::policy::SchedPolicy
//! [`QueueFull`]: crate::coordinator::admission::QueueFull
//! [`Cancelled`]: crate::coordinator::handle::Cancelled
//! [`ServeError`]: crate::coordinator::error::ServeError

use crate::arch::precision::Precision;
use crate::config::schema::{AdmissionPolicy, PolicyKind, ServeConfig};
use crate::coordinator::admission::QueueFull;
use crate::coordinator::device::PrecisionInfo;
use crate::coordinator::fault::{DrainDeadlineExpired, FaultKind, SchedulerPanicked};
use crate::coordinator::handle::{Reply, RequestHandle};
use crate::coordinator::scheduler::Event;
use crate::coordinator::shard::{
    band_operands, band_reply, band_request, plan_route, Band, Route, RouterCounters, Shard,
    ShardClient, ShardSlot, SplitAcc,
};
use crate::coordinator::stats::{
    BreakerSnapshot, BreakerState, ClassStats, FaultStats, MemPlaneStats, PackStats,
    RecoveryStats, RouterStats, ShardStats, ShedStats, StatsAgg, WindowOcc, WorkerHealth,
};
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving statistics snapshot: rolled-up totals over every shard, plus
/// the per-shard breakdown in [`ServerStats::shards`]. With one shard
/// (the default) the totals are exactly that shard's statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: usize,
    /// Requests served in fp32 / int8 (the dual-precision traffic split).
    pub requests_fp32: usize,
    pub requests_int8: usize,
    /// Requests cancelled before completion (not counted in `requests`).
    /// Bands of an M-split request count individually.
    pub cancelled: usize,
    pub invocations: u64,
    /// Mean/p99 over the most recent completions across all shards.
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Per-class queueing-delay / service-time percentiles (bounded
    /// windows; one entry per class that completed a request).
    pub classes: Vec<ClassStats>,
    /// Device-time throughput (ops/s) over the whole stream.
    pub device_ops_per_sec: f64,
    /// Total simulated device time (s), summed over shards.
    pub device_time_s: f64,
    /// Total wall time (s) spent in (deprecated) `run_batch` calls
    /// (streaming submissions are not attributed here).
    pub wall_time_s: f64,
    /// Configured in-flight window (per shard).
    pub pipeline_depth: usize,
    /// Measured mean window occupancy (1.0 = synchronous).
    pub mean_in_flight: f64,
    /// Measured peak window occupancy on any shard.
    pub max_in_flight: usize,
    /// Memory-plane counters summed over shards: packed-weight cache
    /// hit/miss/evict and tile-buffer recycle/alloc
    /// (see [`crate::coordinator::pool`]).
    pub mem: MemPlaneStats,
    /// Packing-stage counters summed over shards: matrices packed,
    /// parallel fan-outs and scheduler time spent packing
    /// (`ServeConfig::pack_workers`).
    pub pack: PackStats,
    /// Fault-plane counters summed over shards: injected faults (chaos
    /// mode), timeouts, retries, checksum rejections, worker
    /// deaths/respawns/quarantines (see [`crate::coordinator::fault`]).
    /// All zero on a fault-free run with the fault plane disabled.
    pub faults: FaultStats,
    /// Per-worker health gauges, concatenated shard by shard (worker
    /// indices are shard-local).
    pub worker_health: Vec<WorkerHealth>,
    /// Request-level robustness counters: brownout/SLO sheds and
    /// deadline expiries summed over shards, merged with the facade's
    /// failover-plane counters (re-dispatches, breaker
    /// trips/probes/recoveries). All zero with the PR 9 knobs at their
    /// defaults.
    pub shed: ShedStats,
    /// Per-shard circuit-breaker state (`"closed"`, `"open"` or
    /// `"half-open"`); one entry per shard when
    /// `ServeConfig::shard_failover` is on, empty otherwise. The typed
    /// equivalent (plus consecutive failures and last failure reason)
    /// lives in each shard's [`ShardStats::breaker`].
    pub breaker_states: Vec<&'static str>,
    /// Recovery-plane counters: shard respawns and rewarms
    /// (`ServeConfig::shard_respawn`), memory-plane integrity
    /// verifications and quarantines
    /// (`ServeConfig::cache_verify_interval`), and the breaker
    /// trip/probe/recovery walk. All zero with the recovery knobs at
    /// their defaults.
    pub recovery: RecoveryStats,
    /// Per-shard statistics, indexed by shard.
    pub shards: Vec<ShardStats>,
    /// Routing decisions taken by the shard router (all zero with one
    /// shard — the router short-circuits).
    pub router: RouterStats,
}

/// Circuit-breaker phase for one shard (see [`FailoverPlane`]). The
/// private working state; the typed public projection is
/// [`BreakerState`] in [`crate::coordinator::stats`].
enum BreakerPhase {
    /// Healthy: traffic flows.
    Closed,
    /// Tripped: no traffic until the probe interval elapses.
    Open { since: Instant },
    /// Probing: the next requests through test whether the shard
    /// recovered — a success closes the breaker, a failure reopens it.
    HalfOpen,
}

struct Breaker {
    state: BreakerPhase,
    /// Consecutive scheduler-level failures (reset by any successful —
    /// or merely alive — resolution).
    failures: u32,
    /// Why this breaker last counted a failure (sticky across resets,
    /// so a recovered shard still reports its last incident).
    last_failure: Option<&'static str>,
}

/// A reply shared between failover attempts: whichever attempt resolves
/// first takes the reply out, so a request resolves exactly once no
/// matter how many shards it visited.
type ReplySlot = Arc<Mutex<Option<Reply>>>;

fn send_slot(slot: &ReplySlot, req: MatMulRequest, out: Result<MatOutput>) {
    if let Some(r) = slot.lock().unwrap_or_else(PoisonError::into_inner).take() {
        r.send(req, out);
    }
}

/// The router-side failover plane (`ServeConfig::shard_failover`): a
/// per-shard circuit breaker plus the re-dispatch machinery that moves
/// whole requests — and individual row-bands of split requests — off a
/// failed shard onto healthy ones.
///
/// A breaker trips open after `breaker_threshold` consecutive
/// scheduler-level failures ([`SchedulerPanicked`] resolutions, or
/// submissions bounced off a dead event channel). An open breaker takes
/// no traffic; after `breaker_probe_ms` it turns half-open and the next
/// request through is the probe — probing is lazy (piggybacked on
/// routing), so no background thread exists. A successful probe closes
/// the breaker and the shard rejoins the rotation; a failed one reopens
/// it.
///
/// Re-dispatch retains one clone of the operands per in-flight attempt
/// (failover trades memory for availability) and re-enters the normal
/// admission path on the target shard, so a recovered request's output
/// is produced by the same deterministic engine path as any other —
/// bit-identical to a fault-free run, including band-concat merges of
/// split requests.
pub(crate) struct FailoverPlane {
    /// One submission client per shard. Behind an `RwLock` so the
    /// respawn supervisor can swap in the replacement engine's client;
    /// submitters clone the client out under a short read guard and
    /// never hold the lock across a (possibly blocking) admission.
    clients: Vec<RwLock<ShardClient>>,
    breakers: Vec<Mutex<Breaker>>,
    threshold: u32,
    probe_after: Duration,
    failovers: AtomicU64,
    failover_bands: AtomicU64,
    trips: AtomicU64,
    probes: AtomicU64,
    recoveries: AtomicU64,
    /// Successful shard respawns / permanently failed respawn attempts
    /// (`ServeConfig::shard_respawn`; counted by the supervisor).
    respawns: AtomicU64,
    respawn_failures: AtomicU64,
    /// Wakes the respawn supervisor when a breaker counts a failure.
    /// `None` with `shard_respawn` off — and cleared at the head of
    /// shutdown so no respawn races the drain.
    respawn_tx: Mutex<Option<mpsc::Sender<usize>>>,
}

impl FailoverPlane {
    fn new(clients: Vec<ShardClient>, threshold: u32, probe_after: Duration) -> Arc<Self> {
        let breakers = clients
            .iter()
            .map(|_| {
                Mutex::new(Breaker {
                    state: BreakerPhase::Closed,
                    failures: 0,
                    last_failure: None,
                })
            })
            .collect();
        Arc::new(FailoverPlane {
            clients: clients.into_iter().map(RwLock::new).collect(),
            breakers,
            threshold: threshold.max(1),
            probe_after,
            failovers: AtomicU64::new(0),
            failover_bands: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            respawn_failures: AtomicU64::new(0),
            respawn_tx: Mutex::new(None),
        })
    }

    fn breaker(&self, shard: usize) -> std::sync::MutexGuard<'_, Breaker> {
        self.breakers[shard].lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The current submission client for `shard`, cloned out under a
    /// short read guard (never held across a blocking admission).
    fn client(&self, shard: usize) -> ShardClient {
        self.clients[shard].read().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Point `shard`'s slot at a freshly respawned engine (supervisor
    /// only). In-flight submissions that cloned the old client bounce
    /// off its dead channel and re-enter through the failover chain.
    fn refresh_client(&self, shard: usize, fresh: ShardClient) {
        *self.clients[shard].write().unwrap_or_else(PoisonError::into_inner) = fresh;
    }

    /// Arm the respawn notification channel (facade start-up, with
    /// `ServeConfig::shard_respawn` on).
    fn set_respawn_tx(&self, tx: mpsc::Sender<usize>) {
        *self.respawn_tx.lock().unwrap_or_else(PoisonError::into_inner) = Some(tx);
    }

    /// Disconnect the supervisor (head of shutdown): drops the sender,
    /// so the supervisor's receive loop observes the disconnect.
    fn detach_respawn(&self) {
        self.respawn_tx.lock().unwrap_or_else(PoisonError::into_inner).take();
    }

    fn notify_respawn(&self, shard: usize) {
        if let Some(tx) =
            self.respawn_tx.lock().unwrap_or_else(PoisonError::into_inner).as_ref()
        {
            let _ = tx.send(shard);
        }
    }

    /// Route-time eligibility: closed and half-open breakers accept
    /// traffic; an open one turns half-open once the probe interval
    /// elapsed — the request that observed the transition is the probe.
    fn eligible(&self, shard: usize) -> bool {
        let mut b = self.breaker(shard);
        match b.state {
            BreakerPhase::Closed | BreakerPhase::HalfOpen => true,
            BreakerPhase::Open { since } => {
                if since.elapsed() >= self.probe_after {
                    b.state = BreakerPhase::HalfOpen;
                    self.probes.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Any resolution proving the scheduler alive resets the breaker; a
    /// half-open success is a recovery — the shard rejoins. The last
    /// failure reason is deliberately sticky.
    fn record_success(&self, shard: usize) {
        let mut b = self.breaker(shard);
        b.failures = 0;
        if matches!(b.state, BreakerPhase::HalfOpen) {
            self.recoveries.fetch_add(1, Ordering::Relaxed);
        }
        b.state = BreakerPhase::Closed;
    }

    /// A scheduler-level failure: trip closed → open at the threshold;
    /// a failed half-open probe reopens immediately. Every counted
    /// failure also nudges the respawn supervisor (when armed) — the
    /// supervisor dedups by checking whether the scheduler thread
    /// actually died.
    fn record_failure(&self, shard: usize, reason: &'static str) {
        let mut b = self.breaker(shard);
        b.failures += 1;
        b.last_failure = Some(reason);
        match b.state {
            BreakerPhase::Closed if b.failures >= self.threshold => {
                b.state = BreakerPhase::Open { since: Instant::now() };
                self.trips.fetch_add(1, Ordering::Relaxed);
            }
            BreakerPhase::HalfOpen => {
                b.state = BreakerPhase::Open { since: Instant::now() };
            }
            _ => {}
        }
        drop(b);
        self.notify_respawn(shard);
    }

    /// The healthiest re-dispatch target: breaker-eligible, not yet
    /// tried by this request, least loaded (ties to the lowest index).
    fn pick(&self, tried: &[usize]) -> Option<usize> {
        (0..self.clients.len())
            .filter(|s| !tried.contains(s))
            .filter(|&s| self.eligible(s))
            .min_by_key(|&s| {
                let open = self.clients[s]
                    .read()
                    .unwrap_or_else(PoisonError::into_inner)
                    .in_flight();
                (open, s)
            })
    }

    /// Place one request (or one band of a split request) on `preferred`
    /// — diverted up front if its breaker is open — wrapping `inner` so
    /// a [`SchedulerPanicked`] resolution re-dispatches instead of
    /// surfacing. Returns the shard actually admitted and its token; an
    /// error means no shard admitted the request and the caller still
    /// owns it (the reply never fired).
    fn dispatch(
        self: &Arc<Self>,
        preferred: usize,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        band: bool,
        inner: Reply,
    ) -> Result<(usize, u64)> {
        let first = if self.eligible(preferred) {
            preferred
        } else {
            self.pick(&[]).unwrap_or(preferred)
        };
        let slot: ReplySlot = Arc::new(Mutex::new(Some(inner)));
        self.try_chain(first, req, ops, policy, Vec::new(), band, &slot)
    }

    /// Walk the failover chain starting at `shard`: submit with a
    /// wrapped reply; on a synchronous dead-scheduler bounce, move to
    /// the next eligible shard. [`QueueFull`] stops the walk — a full
    /// queue is backpressure, not a fault. On exhaustion the last error
    /// returns with the slot still holding the reply.
    #[allow(clippy::too_many_arguments)]
    fn try_chain(
        self: &Arc<Self>,
        shard: usize,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        mut tried: Vec<usize>,
        band: bool,
        slot: &ReplySlot,
    ) -> Result<(usize, u64)> {
        let mut shard = shard;
        let mut ops = ops;
        loop {
            tried.push(shard);
            let plane = Arc::clone(self);
            let retained = ops.clone();
            let tried_next = tried.clone();
            let slot_next = Arc::clone(slot);
            let at = shard;
            let wrapped = Reply::Callback(Box::new(move |rq, out| {
                plane.resolve(at, rq, out, retained, policy, tried_next, band, slot_next);
            }));
            match self.client(shard).try_submit(req, ops, policy, wrapped) {
                Ok(token) => return Ok((shard, token)),
                Err((e, _wrapped, ops_back)) => {
                    if e.downcast_ref::<QueueFull>().is_some() {
                        return Err(e);
                    }
                    self.record_failure(shard, "dispatch_failed");
                    match self.pick(&tried) {
                        Some(next) => {
                            shard = next;
                            ops = ops_back;
                        }
                        None => return Err(e),
                    }
                }
            }
        }
    }

    /// One attempt resolved: success (or any proof-of-life error)
    /// passes through to the caller's reply; a [`SchedulerPanicked`]
    /// resolution re-dispatches to the next healthy shard — the
    /// original error surfaces only when every shard was tried. Runs on
    /// scheduler threads.
    #[allow(clippy::too_many_arguments)]
    fn resolve(
        self: &Arc<Self>,
        shard: usize,
        req: MatMulRequest,
        out: Result<MatOutput>,
        retained: Operands,
        policy: AdmissionPolicy,
        tried: Vec<usize>,
        band: bool,
        slot: ReplySlot,
    ) {
        match out {
            Err(e) if e.downcast_ref::<SchedulerPanicked>().is_some() => {
                self.record_failure(shard, "scheduler_panicked");
                match self.pick(&tried) {
                    Some(next) => {
                        if band {
                            self.failover_bands.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        if let Err(e2) =
                            self.try_chain(next, req, retained, policy, tried, band, &slot)
                        {
                            send_slot(&slot, req, Err(e2));
                        }
                    }
                    None => send_slot(&slot, req, Err(e)),
                }
            }
            Err(e) if e.downcast_ref::<DrainDeadlineExpired>().is_some() => {
                // Counts against the breaker but is never re-dispatched
                // — the server is shutting down.
                self.record_failure(shard, "drain_deadline_expired");
                send_slot(&slot, req, Err(e));
            }
            out => {
                // The scheduler answered — even a typed failure proves
                // it alive.
                self.record_success(shard);
                send_slot(&slot, req, out);
            }
        }
    }

    /// The failover/breaker half of [`ShedStats`] (the shed/deadline
    /// half comes from the shards).
    fn snapshot(&self) -> ShedStats {
        ShedStats {
            failovers: self.failovers.load(Ordering::Relaxed),
            failover_bands: self.failover_bands.load(Ordering::Relaxed),
            breaker_trips: self.trips.load(Ordering::Relaxed),
            breaker_probes: self.probes.load(Ordering::Relaxed),
            breaker_recoveries: self.recoveries.load(Ordering::Relaxed),
            ..ShedStats::default()
        }
    }

    /// Typed breaker snapshot per shard (a peek — does not transition
    /// open breakers to half-open).
    fn snapshot_breakers(&self) -> Vec<BreakerSnapshot> {
        (0..self.clients.len())
            .map(|s| {
                let b = self.breaker(s);
                BreakerSnapshot {
                    state: match b.state {
                        BreakerPhase::Closed => BreakerState::Closed,
                        BreakerPhase::Open { .. } => BreakerState::Open,
                        BreakerPhase::HalfOpen => BreakerState::HalfOpen,
                    },
                    consecutive_failures: b.failures,
                    last_failure: b.last_failure,
                }
            })
            .collect()
    }
}

/// The serving coordinator (client handle): a facade over
/// `ServeConfig::shards` independent engines. Cheap to share across
/// threads by reference: `submit*` take `&self`.
pub struct MatMulServer {
    /// The shard table, shared with the respawn supervisor. Each slot
    /// is a `Shard` behind an `RwLock`; with `shard_respawn` off (the
    /// default) the lock is never write-acquired and every access is an
    /// uncontended read.
    shards: Arc<Vec<ShardSlot>>,
    router: RouterCounters,
    pipeline_depth: usize,
    policy: AdmissionPolicy,
    sched_policy: PolicyKind,
    queue_depth: usize,
    pack_workers: usize,
    /// M-tile threshold for splitting a request across shards
    /// (`ServeConfig::shard_split_tiles`; 0 = never split).
    split_tiles: usize,
    /// Weight-affinity routing on/off (`ServeConfig::shard_affinity`).
    affinity: bool,
    /// Wall time accumulated by the deprecated batch-replay wrappers.
    wall_time_s: Mutex<f64>,
    /// Shutdown drain budget (`ServeConfig::drain_deadline_ms`;
    /// `None` = wait for every open request, the historical behavior).
    drain_deadline: Option<Duration>,
    /// The failover plane (`ServeConfig::shard_failover`); `None` (the
    /// default) keeps the pre-failover dispatch path untouched.
    failover: Option<Arc<FailoverPlane>>,
    /// The respawn supervisor thread (`ServeConfig::shard_respawn`):
    /// rebuilds crashed shards from the `ServeConfig` and swaps them
    /// into the shard table. `None` with respawn off.
    supervisor: Option<JoinHandle<()>>,
    /// Raised at the head of shutdown: stops the supervisor from
    /// starting new respawns (including mid-backoff) before any shard
    /// is drained.
    shutting_down: Arc<AtomicBool>,
}

/// The respawn supervisor loop (`ServeConfig::shard_respawn`): woken by
/// breaker failure notifications, it verifies the shard's scheduler
/// thread actually died ([`Shard::sched_dead`] — a drain-deadline trip
/// on a live shard needs no respawn), rebuilds the engine from the same
/// `ServeConfig` at the same index, and atomically swaps it into the
/// shard table. State reconciliation is deliberately minimal: in-flight
/// requests were already re-dispatched by the failover plane (the old
/// scheduler's fail-fast path resolved them), so the replacement starts
/// empty except for an optional rewarm of the hottest packed weights
/// the dying scheduler exported (`respawn_rewarm_top_k`) — each rewarmed
/// entry keeps its pre-crash CRC stamp and fully verifies on first hit.
/// Attempts per shard are bounded (`respawn_max_attempts`) with linear
/// backoff (`respawn_backoff_ms`); a shard that exhausts its budget is
/// permanently removed — its breaker stays open and routing avoids it,
/// exactly as with respawn off.
fn run_respawn_supervisor(
    cfg: ServeConfig,
    shards: Arc<Vec<ShardSlot>>,
    plane: Arc<FailoverPlane>,
    rx: mpsc::Receiver<usize>,
    shutting_down: Arc<AtomicBool>,
) {
    let max_attempts = cfg.respawn_max_attempts.max(1);
    let mut attempts = vec![0u32; shards.len()];
    while !shutting_down.load(Ordering::SeqCst) {
        let shard = match rx.recv_timeout(Duration::from_millis(250)) {
            Ok(s) => s,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        if shard >= shards.len() || !shards[shard].read().sched_dead() {
            continue; // alive shard (e.g. drain-deadline trip), or a stale duplicate
        }
        if attempts[shard] >= max_attempts {
            continue; // permanently removed: breaker stays open
        }
        attempts[shard] += 1;
        // Linear backoff before the rebuild, interruptible by shutdown.
        let mut wait_ms = cfg.respawn_backoff_ms.saturating_mul(u64::from(attempts[shard] - 1));
        while wait_ms > 0 && !shutting_down.load(Ordering::SeqCst) {
            let step = wait_ms.min(50);
            std::thread::sleep(Duration::from_millis(step));
            wait_ms -= step;
        }
        if shutting_down.load(Ordering::SeqCst) {
            return;
        }
        match Shard::start(&cfg, shard) {
            Ok(fresh) => {
                let client = fresh.client();
                let old = shards[shard].replace(fresh);
                let rescued = old.take_rescue();
                // Tear the dead engine down outside the lock (the
                // scheduler already exited; this joins the threads and
                // drops the device pool).
                drop(old);
                if let Some(entries) = rescued {
                    shards[shard].read().rewarm(entries);
                }
                plane.refresh_client(shard, client);
                plane.respawns.fetch_add(1, Ordering::Relaxed);
                // The breaker walks Open → HalfOpen → Closed through
                // the existing lazy probe machinery: after
                // `breaker_probe_ms` the next routed request probes the
                // replacement, and its success closes the breaker.
            }
            Err(_) => {
                plane.respawn_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl MatMulServer {
    /// Start the server: spawns `cfg.shards` engines (device worker
    /// pool + completion forwarder + scheduler thread each). Prefer
    /// constructing `cfg` through [`ServeConfig::builder`], which
    /// validates the cross-field constraints this constructor clamps.
    pub fn start(cfg: &ServeConfig) -> Result<Self> {
        let n = cfg.shards.max(1);
        let mut engines = Vec::with_capacity(n);
        for index in 0..n {
            engines.push(Shard::start(cfg, index)?);
        }
        let drain_deadline = match cfg.drain_deadline_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        let failover = cfg.shard_failover.then(|| {
            FailoverPlane::new(
                engines.iter().map(Shard::client).collect(),
                cfg.breaker_threshold,
                Duration::from_millis(cfg.breaker_probe_ms),
            )
        });
        let shards: Arc<Vec<ShardSlot>> =
            Arc::new(engines.into_iter().map(ShardSlot::new).collect());
        let shutting_down = Arc::new(AtomicBool::new(false));
        let supervisor = match &failover {
            Some(plane) if cfg.shard_respawn => {
                let (tx, rx) = mpsc::channel();
                plane.set_respawn_tx(tx);
                let cfg = cfg.clone();
                let shards = Arc::clone(&shards);
                let plane = Arc::clone(plane);
                let down = Arc::clone(&shutting_down);
                Some(
                    std::thread::Builder::new()
                        .name("maxeva-respawn".into())
                        .spawn(move || run_respawn_supervisor(cfg, shards, plane, rx, down))?,
                )
            }
            _ => None,
        };
        Ok(MatMulServer {
            shards,
            router: RouterCounters::default(),
            pipeline_depth: cfg.pipeline_depth.max(1),
            policy: cfg.admission,
            sched_policy: cfg.policy,
            queue_depth: cfg.queue_depth,
            pack_workers: cfg.pack_workers.max(1),
            split_tiles: cfg.shard_split_tiles,
            affinity: cfg.shard_affinity,
            wall_time_s: Mutex::new(0.0),
            drain_deadline,
            failover,
            supervisor,
            shutting_down,
        })
    }

    /// Per-precision device facts — the server-side dispatch point.
    fn info_for(&self, p: Precision) -> Result<PrecisionInfo> {
        match p {
            Precision::Fp32 => Ok(self.shards[0].read().info_f32),
            Precision::Int8 => Ok(self.shards[0].read().info_int8),
            other => Err(anyhow!("serving supports fp32 and int8, not {other}")),
        }
    }

    /// Native fp32 design size (nm, nk, nn).
    pub fn native(&self) -> (u64, u64, u64) {
        self.shards[0].read().info_f32.native
    }

    /// Native design size for a serving precision.
    pub fn native_for(&self, p: Precision) -> Result<(u64, u64, u64)> {
        Ok(self.info_for(p)?.native)
    }

    /// Steady-state fp32 iteration period of the design, in device cycles.
    pub fn period_cycles(&self) -> f64 {
        self.shards[0].read().info_f32.period_cycles
    }

    /// Iteration period for a serving precision, in device cycles.
    pub fn period_cycles_for(&self, p: Precision) -> Result<f64> {
        Ok(self.info_for(p)?.period_cycles)
    }

    /// Device clock frequency, Hz.
    pub fn freq_hz(&self) -> f64 {
        self.shards[0].read().freq_hz
    }

    /// Resolved tile-execution backend ("pjrt" or "reference").
    pub fn backend(&self) -> &'static str {
        self.shards[0].read().backend
    }

    /// Device worker threads **per shard**.
    pub fn workers(&self) -> usize {
        self.shards[0].read().workers
    }

    /// Serving shards (engines) behind this facade.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Operand-packing fan-out width (`ServeConfig::pack_workers`;
    /// 1 = serial packing).
    pub fn pack_workers(&self) -> usize {
        self.pack_workers
    }

    /// Configured in-flight window (per shard).
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Admission queue bound per shard (`0` = unbounded).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The active scheduling policy.
    pub fn sched_policy(&self) -> PolicyKind {
        self.sched_policy
    }

    /// Reconfigure the in-flight window on every shard (the A/B knob;
    /// `1` = synchronous).
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth.max(1);
        for s in self.shards.iter() {
            let _ = s.read().events.send(Event::SetDepth(depth));
        }
    }

    /// Swap the scheduling policy live on every shard (the policy A/B
    /// knob). Flights already open migrate to the new policy
    /// deterministically.
    pub fn set_sched_policy(&mut self, kind: PolicyKind) {
        self.sched_policy = kind;
        for s in self.shards.iter() {
            let _ = s.read().events.send(Event::SetPolicy(kind));
        }
    }

    /// `(mean, max)` window occupancy since the last epoch reset, over
    /// every shard — unlike [`ServerStats::mean_in_flight`] this is not
    /// diluted by earlier batches run at other depths.
    pub fn last_batch_occupancy(&self) -> (f64, usize) {
        let mut w = WindowOcc::default();
        for s in self.shards.iter() {
            let g = s.read();
            w.absorb(&g.shared.last_window.lock().unwrap());
        }
        (w.mean(), w.max())
    }

    /// Start a new occupancy-attribution epoch on every shard (used by
    /// the batch-replay wrappers in [`crate::coordinator::compat`]).
    pub(crate) fn reset_epoch(&self) {
        for s in self.shards.iter() {
            let _ = s.read().events.send(Event::ResetEpoch);
        }
    }

    /// Attribute wall time to `ServerStats::wall_time_s` (used by the
    /// batch-replay wrappers).
    pub(crate) fn add_wall_time(&self, secs: f64) {
        *self.wall_time_s.lock().unwrap() += secs;
    }

    fn validate(req: &MatMulRequest, ops: &Operands) -> Result<()> {
        match (req.precision, ops) {
            (Precision::Fp32, Operands::F32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                Ok(())
            }
            (Precision::Int8, Operands::I32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                if a.iter().chain(b.iter()).any(|v| !(-128..=127).contains(v)) {
                    return Err(anyhow!(
                        "request {}: int8 operands must be in [-128, 127]",
                        req.id
                    ));
                }
                Ok(())
            }
            (Precision::Fp32, Operands::I32 { .. }) | (Precision::Int8, Operands::F32 { .. }) => {
                Err(anyhow!(
                    "request {}: operand container does not match request precision {}",
                    req.id,
                    req.precision
                ))
            }
            (p, _) => Err(anyhow!("serving supports fp32 and int8, not {p}")),
        }
    }

    /// Route one validated request (single-shard servers short-circuit
    /// inside [`plan_route`] without touching the router counters).
    fn route(&self, req: &MatMulRequest) -> Route {
        let nm = match req.precision {
            Precision::Int8 => self.shards[0].read().info_int8.native.0,
            _ => self.shards[0].read().info_f32.native.0,
        } as usize;
        plan_route(&self.shards[..], req, nm, self.split_tiles, self.affinity, &self.router)
    }

    /// Submit every band of an M-split request to its shard, wiring the
    /// band replies into one [`SplitAcc`] that resolves `sink` exactly
    /// once. Returns the cancel routes. If a band's admission fails,
    /// the bands already admitted are cancelled and the error is
    /// returned to the caller — the sink never fires.
    fn submit_split(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        bands: Vec<Band>,
        sink: Reply,
    ) -> Result<Vec<(mpsc::Sender<Event>, u64)>> {
        let k = req.k as usize;
        let acc = SplitAcc::new(req, bands.len(), sink);
        let mut routes = Vec::with_capacity(bands.len());
        for (j, band) in bands.iter().enumerate() {
            let sub_ops = band_operands(&ops, band, k);
            let sub_req = band_request(&req, band);
            let result = match &self.failover {
                Some(plane) => {
                    // The admission check is non-blocking (shed/SLO
                    // gates); the guard drops before the dispatch so no
                    // slot lock is held across a blocking admission.
                    let checked = self.shards[band.shard].read().check_admission(&sub_req);
                    checked.and_then(|()| {
                        plane
                            .dispatch(
                                band.shard,
                                sub_req,
                                sub_ops,
                                policy,
                                true,
                                band_reply(&acc, j),
                            )
                            .map(|(s, token)| (self.shards[s].read().events.clone(), token))
                    })
                }
                None => {
                    // Without failover there is no supervisor and the
                    // slot is never write-locked — holding the read
                    // guard across a blocking admission is free.
                    let shard = self.shards[band.shard].read();
                    shard
                        .submit(sub_req, sub_ops, policy, band_reply(&acc, j))
                        .map(|token| (shard.events.clone(), token))
                }
            };
            match result {
                Ok(route) => routes.push(route),
                Err(e) => {
                    // Roll back: cancel the admitted bands. Their
                    // band replies land in the accumulator but the
                    // unsubmitted bands keep `remaining` above zero,
                    // so the sink never delivers — the caller owns
                    // this error exclusively.
                    for (events, token) in &routes {
                        let _ = events.send(Event::Cancel(*token));
                    }
                    return Err(e);
                }
            }
        }
        Ok(routes)
    }

    /// Admit one request under the configured admission policy and get a
    /// completion handle. Blocks (policy `Block`) or fails with
    /// [`QueueFull`](crate::coordinator::admission::QueueFull) (policy
    /// `Reject`) when `queue_depth` requests are already open on the
    /// target shard. Dropping the handle unresolved **cancels** the
    /// request ([`RequestHandle::cancel`]).
    pub fn submit(&self, req: MatMulRequest, ops: Operands) -> Result<RequestHandle> {
        self.submit_with_policy(req, ops, self.policy)
    }

    /// [`MatMulServer::submit`] with an explicit per-call policy.
    pub fn submit_with_policy(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
    ) -> Result<RequestHandle> {
        Self::validate(&req, &ops)?;
        let (tx, rx) = mpsc::channel();
        let routes = match self.route(&req) {
            Route::Whole(s) => match &self.failover {
                Some(plane) => {
                    self.shards[s].read().check_admission(&req)?;
                    let (at, token) =
                        plane.dispatch(s, req, ops, policy, false, Reply::Handle(tx))?;
                    vec![(self.shards[at].read().events.clone(), token)]
                }
                None => {
                    let shard = self.shards[s].read();
                    let token = shard.submit(req, ops, policy, Reply::Handle(tx))?;
                    vec![(shard.events.clone(), token)]
                }
            },
            Route::Split(bands) => self.submit_split(req, ops, policy, bands, Reply::Handle(tx))?,
        };
        Ok(RequestHandle::new(req.id, rx, routes))
    }

    /// Admit one request and deliver its completion through `callback`
    /// instead of a handle. The callback runs on a scheduler thread —
    /// keep it short (hand heavy post-processing to another thread).
    pub fn submit_with_callback(
        &self,
        req: MatMulRequest,
        ops: Operands,
        callback: impl FnOnce(MatMulRequest, Result<MatOutput>) + Send + 'static,
    ) -> Result<()> {
        Self::validate(&req, &ops)?;
        let reply = Reply::Callback(Box::new(callback));
        match self.route(&req) {
            Route::Whole(s) => match &self.failover {
                Some(plane) => {
                    self.shards[s].read().check_admission(&req)?;
                    plane.dispatch(s, req, ops, self.policy, false, reply)?;
                }
                None => {
                    self.shards[s].read().submit(req, ops, self.policy, reply)?;
                }
            },
            Route::Split(bands) => {
                self.submit_split(req, ops, self.policy, bands, reply)?;
            }
        }
        Ok(())
    }

    /// Snapshot serving statistics: rolled-up totals plus the per-shard
    /// breakdown.
    pub fn stats(&self) -> ServerStats {
        let mut shards: Vec<ShardStats> =
            self.shards.iter().map(|s| s.read().stats()).collect();
        let mut agg = StatsAgg::default();
        let mut window = WindowOcc::default();
        for s in self.shards.iter() {
            let g = s.read();
            agg.absorb(&g.shared.stats.lock().unwrap());
            window.absorb(&g.shared.window.lock().unwrap());
        }
        let mut mem = MemPlaneStats::default();
        let mut pack = PackStats::default();
        let mut faults = FaultStats::default();
        let mut shed = ShedStats::default();
        for st in &shards {
            mem.absorb(&st.mem);
            pack.absorb(&st.pack);
            faults.absorb(&st.faults);
            shed.absorb(&st.shed);
        }
        // The memory-plane integrity counters live in the shards; the
        // respawn/breaker counters live in the failover plane. The
        // recovery block unifies both views.
        let mut recovery = RecoveryStats {
            rewarmed_entries: mem.rewarmed_entries,
            cache_verifications: mem.cache_verifications,
            poisoned_evictions: mem.poisoned_evictions,
            ..RecoveryStats::default()
        };
        let breaker_states = match &self.failover {
            Some(plane) => {
                shed.absorb(&plane.snapshot());
                recovery.respawns = plane.respawns.load(Ordering::Relaxed);
                recovery.respawn_failures = plane.respawn_failures.load(Ordering::Relaxed);
                recovery.breaker_trips = plane.trips.load(Ordering::Relaxed);
                recovery.breaker_probes = plane.probes.load(Ordering::Relaxed);
                recovery.breaker_recoveries = plane.recoveries.load(Ordering::Relaxed);
                let snaps = plane.snapshot_breakers();
                for (st, snap) in shards.iter_mut().zip(&snaps) {
                    st.breaker = Some(*snap);
                }
                snaps.iter().map(|b| b.state.as_str()).collect()
            }
            None => Vec::new(),
        };
        ServerStats {
            requests: agg.count(),
            requests_fp32: agg.count_by(Precision::Fp32),
            requests_int8: agg.count_by(Precision::Int8),
            cancelled: agg.cancelled(),
            invocations: shards.iter().map(|s| s.invocations).sum(),
            mean_latency_ms: agg.mean_latency_ms(),
            p99_latency_ms: agg.p99_latency_ms(),
            classes: agg.class_stats(),
            device_ops_per_sec: agg.device_ops_per_sec(),
            device_time_s: shards.iter().map(|s| s.device_time_s).sum(),
            wall_time_s: *self.wall_time_s.lock().unwrap(),
            pipeline_depth: self.pipeline_depth,
            mean_in_flight: window.mean(),
            max_in_flight: window.max(),
            mem,
            pack,
            faults,
            worker_health: shards.iter().flat_map(|s| s.worker_health.clone()).collect(),
            shed,
            breaker_states,
            recovery,
            shards,
            router: self.router.snapshot(),
        }
    }

    fn stop(&mut self) {
        // Stop the recovery plane FIRST: a shard replaced after its
        // drain stamp would never be drained or joined. Raising the
        // flag interrupts a supervisor mid-backoff; detaching the
        // notification channel wakes one blocked in receive. Joining
        // the supervisor before any drain guarantees the shard table is
        // frozen for the rest of shutdown.
        self.shutting_down.store(true, Ordering::SeqCst);
        if let Some(plane) = &self.failover {
            plane.detach_respawn();
        }
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
        // One absolute deadline stamped up front and fanned out before
        // any join: every shard drains concurrently against the same
        // instant, so total shutdown wall time is bounded by the
        // slowest shard — not the sum — even when one shard's workers
        // are hung and it must run its budget to the end.
        let by = self.drain_deadline.map(|d| Instant::now() + d);
        for s in self.shards.iter() {
            s.read().drain(by);
        }
        for s in self.shards.iter() {
            s.write().join();
        }
    }

    /// Graceful shutdown: drain every open request on every shard, then
    /// stop the schedulers and device workers. With
    /// `ServeConfig::drain_deadline_ms` set, the drain is bounded:
    /// requests still open past the budget fail with
    /// [`DrainDeadlineExpired`](crate::coordinator::fault::DrainDeadlineExpired)
    /// instead of hanging shutdown on a lost tile.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// [`MatMulServer::shutdown`] with an explicit drain budget,
    /// overriding the configured `drain_deadline_ms`. The budget is one
    /// absolute wall-clock deadline shared by every shard — shards
    /// drain concurrently, so shutdown takes at most the budget (plus
    /// join overhead) no matter how many shards are wedged.
    pub fn shutdown_with_deadline(mut self, deadline: Duration) {
        self.drain_deadline = Some(deadline);
        self.stop();
    }

    /// Chaos-test hook: make every shard's scheduler thread panic,
    /// exercising the fail-fast path that resolves every open flight
    /// with
    /// [`SchedulerPanicked`](crate::coordinator::fault::SchedulerPanicked).
    /// Kills the schedulers — the server serves nothing afterwards.
    #[doc(hidden)]
    pub fn inject_scheduler_panic(&self) {
        for s in self.shards.iter() {
            let g = s.read();
            if g.events.send(Event::ChaosPanic).is_ok() {
                g.count_injected(FaultKind::ShardCrash);
            }
        }
    }

    /// Chaos-test hook: panic a single shard's scheduler thread —
    /// shard-granular chaos for the failover and respawn tests (counts
    /// one injected [`FaultKind::ShardCrash`]). Out-of-range indices
    /// are a no-op.
    #[doc(hidden)]
    pub fn inject_scheduler_panic_on(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            let g = s.read();
            if g.events.send(Event::ChaosPanic).is_ok() {
                g.count_injected(FaultKind::ShardCrash);
            }
        }
    }

    /// Chaos-test hook: flip one bit in the coldest packed-weight cache
    /// entry on `shard` (counts one injected
    /// [`FaultKind::CacheCorrupt`] when an entry existed to corrupt).
    /// With `ServeConfig::cache_verify_interval` set, the sampled
    /// verify-on-hit detects the mismatch, quarantines the entry and
    /// transparently re-packs — see `ServerStats::recovery`. Only the
    /// at-rest pool is corrupted; tiles already referencing it keep the
    /// clean bytes.
    #[doc(hidden)]
    pub fn inject_cache_corrupt_on(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            let _ = s.read().events.send(Event::ChaosCorruptCache);
        }
    }
}

impl Drop for MatMulServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// Integration tests (needing built artifacts) live in
// rust/tests/serving_e2e.rs; backend-independent pipelined-vs-sequential
// equivalence tests in rust/tests/pipeline_equivalence.rs; streaming
// admission, backpressure and mixed-precision tests in
// rust/tests/streaming_admission.rs; fairness and cancellation tests in
// rust/tests/policy_fairness.rs and rust/tests/cancellation.rs; shard
// routing, split bit-identity and affinity tests in
// rust/tests/shard_routing.rs.
