//! The MatMul serving layer: **streaming admission** + pipelined tile
//! engine on top of the device worker pool.
//!
//! # Streaming admission (the open queue)
//!
//! Unlike the PR 1 engine, which replayed a pre-closed batch, this
//! server is a long-lived stream processor. [`MatMulServer::submit`]
//! admits one request into a bounded open queue and returns a
//! [`RequestHandle`] immediately; a dedicated **scheduler thread** packs
//! operands, feeds the in-flight window continuously, reduces partials
//! and retires requests while later submissions are still arriving — so
//! requests are admitted, scheduled and completed concurrently, not in
//! batch lockstep.
//!
//! **Backpressure** is governed by `ServeConfig::queue_depth` — the
//! maximum number of *open* requests (admitted but not yet retired;
//! `0` = unbounded) — and an [`AdmissionPolicy`]:
//!
//! * [`AdmissionPolicy::Block`] parks the submitting thread until a
//!   slot frees (producers run at the engine's pace).
//! * [`AdmissionPolicy::Reject`] fails fast with [`QueueFull`] so the
//!   caller can shed load or retry.
//!
//! Completions are delivered per request: [`RequestHandle::wait`] /
//! [`RequestHandle::try_wait`], or a callback registered with
//! [`MatMulServer::submit_with_callback`] (invoked on the scheduler
//! thread — keep it short). [`MatMulServer::run_batch`] remains as a
//! thin convenience wrapper: submit everything (blocking policy), wait
//! in order — every batch test therefore exercises the streaming path.
//!
//! # Per-request precision
//!
//! Each [`MatMulRequest`] names its [`Precision`]: fp32 requests flow as
//! f32 tiles, int8 requests as int8-range operands carried in i32 with
//! **i32 accumulation buffers** (paper §IV-C1), through the *same*
//! tiler/window/reduction machinery. Each precision has its own native
//! tile geometry (the paper's int8 kernel is 32×128×32 vs fp32's
//! 32×32×32) and its own simulated device period. One server interleaves
//! both in a single window.
//!
//! # The pipeline (unchanged mechanics)
//!
//! 1. **Tile-major packing (zero-copy)** — on first schedule each
//!    request's A and B are packed once into tile-major pools of `Arc`'d
//!    native blocks ([`Tiler::pack_tile_major`]); a tile job borrows its
//!    two blocks by `Arc` clone.
//! 2. **Windowed submission** — up to `pipeline_depth` tagged jobs are
//!    kept in flight on one completion channel, overlapping host
//!    pack/reduce with device execution. `pipeline_depth = 1` reproduces
//!    the synchronous engine exactly.
//! 3. **Reuse-ordered scheduling** — each request walks its tiles
//!    k-innermost per `(im, inn)` output block; fairness across requests
//!    is round-robin at the window level.
//!
//! **Determinism:** completions may arrive out of order, but partials
//! are applied to each output block strictly in ascending `ik` order
//! (late partials park in a per-block reorder map), so outputs are
//! bit-identical for every `pipeline_depth`/`workers` combination and
//! admission interleaving — f32 by ordered summation, i32 trivially
//! (wrapping integer addition is associative). See
//! `rust/tests/pipeline_equivalence.rs` and
//! `rust/tests/streaming_admission.rs`.

use crate::arch::precision::Precision;
use crate::config::schema::{AdmissionPolicy, ServeConfig};
use crate::coordinator::device::{
    spawn_device_pool, DeviceHandle, PrecisionInfo, TileDone, TileJob, TileOutput, TilePayload,
};
use crate::coordinator::stats::{Completion, StatsAgg, WindowOcc};
use crate::coordinator::tiler::Tiler;
use crate::workloads::{MatMulRequest, MatOutput, Operands};
use anyhow::{anyhow, Result};
use rustc_hash::FxHashMap;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Returned by a [`AdmissionPolicy::Reject`] submission when
/// `queue_depth` requests are already open. Recover it from the anyhow
/// chain with `err.downcast_ref::<QueueFull>()`.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("admission queue full ({0} open requests)")]
pub struct QueueFull(pub usize);

/// Serving statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: usize,
    /// Requests served in fp32 / int8 (the dual-precision traffic split).
    pub requests_fp32: usize,
    pub requests_int8: usize,
    pub invocations: u64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Device-time throughput (ops/s) over the whole stream.
    pub device_ops_per_sec: f64,
    /// Total simulated device time (s).
    pub device_time_s: f64,
    /// Total wall time (s) spent in `run_batch` calls (streaming
    /// submissions are not attributed here).
    pub wall_time_s: f64,
    /// Configured in-flight window.
    pub pipeline_depth: usize,
    /// Measured mean window occupancy (1.0 = synchronous).
    pub mean_in_flight: f64,
    /// Measured peak window occupancy.
    pub max_in_flight: usize,
}

/// Per-request completion delivery.
enum Reply {
    Handle(mpsc::Sender<Result<MatOutput>>),
    Callback(Box<dyn FnOnce(MatMulRequest, Result<MatOutput>) + Send>),
}

impl Reply {
    fn send(self, req: MatMulRequest, out: Result<MatOutput>) {
        match self {
            Reply::Handle(tx) => {
                let _ = tx.send(out);
            }
            // User code runs on the scheduler thread; a panicking
            // callback must not take the whole stream down with it.
            Reply::Callback(cb) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| cb(req, out)));
            }
        }
    }
}

/// A request admitted by a client thread, in flight to the scheduler.
///
/// `ops`/`reply` are `Option`s taken out on the normal path; the `Drop`
/// impl is the safety net for every other path (scheduler draining, the
/// event channel torn down with admits still queued, send failure): it
/// frees the admission slot and delivers a shutdown error, so a
/// successful `submit` always resolves its handle/callback.
struct Admitted {
    req: MatMulRequest,
    ops: Option<Operands>,
    submitted: Instant,
    reply: Option<Reply>,
    gate: Arc<Gate>,
}

impl Drop for Admitted {
    fn drop(&mut self) {
        if let Some(reply) = self.reply.take() {
            self.gate.release();
            reply.send(self.req, Err(anyhow!("server is shutting down")));
        }
    }
}

/// Scheduler-thread events: admissions from clients and tile
/// completions (forwarded from the device pool) share one channel, so
/// the scheduler is a single ordered state machine.
enum Event {
    Admit(Box<Admitted>),
    Done(TileDone),
    SetDepth(usize),
    ResetEpoch,
    Drain,
}

/// The admission gate: a counting semaphore over open requests with a
/// closed flag so blocked producers wake when the server goes away.
struct Gate {
    /// `0` = unbounded.
    depth: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    open: usize,
    closed: bool,
}

/// Closes the gate when dropped — even if the scheduler thread unwinds,
/// producers parked in [`Gate::admit`] wake up instead of hanging.
struct GateCloser(Arc<Gate>);

impl Drop for GateCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

impl Gate {
    fn new(depth: usize) -> Self {
        Gate {
            depth,
            state: Mutex::new(GateState { open: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    fn admit(&self, policy: AdmissionPolicy) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(anyhow!("server is shut down"));
            }
            if self.depth == 0 || st.open < self.depth {
                st.open += 1;
                return Ok(());
            }
            match policy {
                AdmissionPolicy::Reject => return Err(QueueFull(self.depth).into()),
                AdmissionPolicy::Block => st = self.cv.wait(st).unwrap(),
            }
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = st.open.saturating_sub(1);
        drop(st);
        self.cv.notify_one();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// State shared between the scheduler thread and client-side snapshots.
struct Shared {
    stats: Mutex<StatsAgg>,
    /// Cumulative window occupancy over the server's lifetime.
    window: Mutex<WindowOcc>,
    /// Occupancy since the last epoch reset (A/B attribution).
    last_window: Mutex<WindowOcc>,
    /// Wall time spent inside `run_batch` calls.
    wall_time_s: Mutex<f64>,
}

/// A completion handle for one admitted request.
pub struct RequestHandle {
    id: u64,
    rx: mpsc::Receiver<Result<MatOutput>>,
}

impl RequestHandle {
    /// The submitted request's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request retires and take its output.
    pub fn wait(self) -> Result<MatOutput> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("server dropped request {} without replying", self.id))?
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<MatOutput>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("server dropped request {} without replying", self.id)))
            }
        }
    }
}

/// Element type the reduction machinery is generic over: f32 sums, the
/// int8 path accumulates i32 with wrapping adds (both orderings are
/// fixed by the ascending-`ik` rule; wrapping keeps i32 bit-exact even
/// on overflow).
trait Elem: Copy + Default + Send + Sync + 'static {
    fn acc(&mut self, other: Self);
}

impl Elem for f32 {
    fn acc(&mut self, other: Self) {
        *self += other;
    }
}

impl Elem for i32 {
    fn acc(&mut self, other: Self) {
        *self = self.wrapping_add(other);
    }
}

/// One precision's operand pools and output matrix.
struct Pools<T> {
    /// Raw row-major operands, held until this request's first tile is
    /// scheduled: packing then happens *inside* the pipeline, overlapping
    /// the tiles of earlier requests already executing on the workers.
    raw: Option<(Vec<T>, Vec<T>)>,
    /// Tile-major A pool, indexed `[im·gk + ik]`.
    a_tiles: Vec<Arc<Vec<T>>>,
    /// Tile-major B pool, indexed `[ik·gn + inn]`.
    b_tiles: Vec<Arc<Vec<T>>>,
    c: Vec<T>,
}

impl<T: Elem> Pools<T> {
    fn fresh(a: Vec<T>, b: Vec<T>, out_len: usize) -> Self {
        Pools {
            raw: Some((a, b)),
            a_tiles: Vec::new(),
            b_tiles: Vec::new(),
            c: vec![T::default(); out_len],
        }
    }

    /// First schedule of this request: pack its operands into the
    /// tile-major pools now — one extract pass per block, total,
    /// overlapping whatever is already in flight.
    fn pack(&mut self, m: usize, k: usize, n: usize, t: Tiler) {
        if let Some((a, b)) = self.raw.take() {
            self.a_tiles = Tiler::pack_tile_major(&a, m, k, t.nm, t.nk)
                .into_iter()
                .map(Arc::new)
                .collect();
            self.b_tiles = Tiler::pack_tile_major(&b, k, n, t.nk, t.nn)
                .into_iter()
                .map(Arc::new)
                .collect();
        }
    }
}

/// Typed flight data — the only precision-specific part of a flight.
enum FlightData {
    F32(Pools<f32>),
    I32(Pools<i32>),
}

/// One open request's state in the scheduler.
struct Flight {
    req: MatMulRequest,
    /// Block grid `(gm, gk, gn)` in this request's precision geometry.
    grid: (usize, usize, usize),
    /// This request's precision tiler (native tile sizes are
    /// per-precision).
    tiler: Tiler,
    data: FlightData,
    /// Cursor into the k-innermost tile walk.
    next_tile: usize,
    total_tiles: usize,
    /// Tiles whose partials have been reduced (in order).
    done_tiles: usize,
    started: Instant,
    invocations: u64,
    reply: Reply,
}

/// Where a tagged in-flight job lands when it completes.
#[derive(Debug, Clone, Copy)]
struct JobDesc {
    flight: u64,
    im: usize,
    inn: usize,
    ik: usize,
}

/// Per-output-block accumulation state (the "small accumulation buffer
/// per in-flight block").
struct BlockAcc<T> {
    /// Dense `nm×nn` running sum.
    buf: Vec<T>,
    /// Next `ik` to reduce — enforces the bit-exact reduction order.
    next_ik: usize,
    /// Out-of-order partials parked until their turn.
    pending: BTreeMap<usize, Vec<T>>,
}

/// Reduce one completed partial into its output block, preserving
/// ascending-`ik` order; write the block back once full.
#[allow(clippy::too_many_arguments)]
fn reduce_partial<T: Elem>(
    accs: &mut FxHashMap<(u64, usize, usize), BlockAcc<T>>,
    c: &mut [T],
    done_tiles: &mut usize,
    tiler: Tiler,
    gk: usize,
    m: usize,
    n: usize,
    fid: u64,
    desc: JobDesc,
    partial: Vec<T>,
) {
    let key = (fid, desc.im, desc.inn);
    let acc = accs.entry(key).or_insert_with(|| BlockAcc {
        buf: vec![T::default(); tiler.nm * tiler.nn],
        next_ik: 0,
        pending: BTreeMap::new(),
    });
    acc.pending.insert(desc.ik, partial);
    while let Some(p) = acc.pending.remove(&acc.next_ik) {
        for (dst, src) in acc.buf.iter_mut().zip(&p) {
            dst.acc(*src);
        }
        acc.next_ik += 1;
        *done_tiles += 1;
    }
    if acc.next_ik == gk {
        let full = accs.remove(&key).unwrap();
        Tiler::write_block(c, m, n, desc.im, desc.inn, tiler.nm, tiler.nn, &full.buf);
    }
}

/// The scheduler: a single-threaded state machine owning the device
/// pool, the open flights and the in-flight window.
struct Scheduler {
    device: DeviceHandle,
    tiler_f32: Tiler,
    tiler_i32: Tiler,
    gate: Arc<Gate>,
    shared: Arc<Shared>,
    /// Sender cloned into every tile job; a forwarder thread relays
    /// completions into the scheduler's event channel.
    tile_tx: mpsc::Sender<TileDone>,
    depth: usize,
    draining: bool,
    flights: FxHashMap<u64, Flight>,
    /// Window-level round-robin: each ready request submits one tile,
    /// then rotates to the back.
    ready: VecDeque<u64>,
    descs: FxHashMap<u64, JobDesc>,
    accs_f32: FxHashMap<(u64, usize, usize), BlockAcc<f32>>,
    accs_i32: FxHashMap<(u64, usize, usize), BlockAcc<i32>>,
    next_flight: u64,
    next_tag: u64,
    in_flight: usize,
}

impl Scheduler {
    fn run(mut self, events: mpsc::Receiver<Event>) {
        // Wake any producer parked on the admission gate when this
        // thread exits — normally or by unwinding.
        let _gate_closer = GateCloser(Arc::clone(&self.gate));
        loop {
            // Fill the window from the ready rotation.
            while self.in_flight < self.depth {
                let Some(fid) = self.ready.pop_front() else { break };
                self.submit_one(fid);
            }
            if self.draining && self.flights.is_empty() && self.in_flight == 0 {
                break;
            }
            // Block for the next admission or completion.
            let Ok(ev) = events.recv() else { break };
            match ev {
                Event::Admit(adm) => self.handle_admit(adm),
                Event::Done(done) => self.handle_done(done),
                Event::SetDepth(d) => self.depth = d.max(1),
                Event::ResetEpoch => {
                    *self.shared.last_window.lock().unwrap() = WindowOcc::default()
                }
                Event::Drain => self.draining = true,
            }
        }
        // `_gate_closer` closes the admission gate as it drops;
        // dropping `self.device` stops the worker pool.
    }

    fn tiler_for(&self, p: Precision) -> Tiler {
        match p {
            Precision::Int8 => self.tiler_i32,
            _ => self.tiler_f32,
        }
    }

    fn handle_admit(&mut self, mut adm: Box<Admitted>) {
        if self.draining {
            return; // Admitted::drop frees the slot and errors the reply
        }
        let req = adm.req;
        let submitted = adm.submitted;
        let ops = adm.ops.take().expect("operands consumed once");
        let reply = adm.reply.take().expect("reply consumed once");
        let (m, k, n) = (req.m as usize, req.k as usize, req.n as usize);
        let tiler = self.tiler_for(req.precision);
        let grid = tiler.grid(m, k, n);
        let (gm, gk, gn) = grid;
        let total_tiles = gm * gk * gn;
        // Degenerate (zero-tile) requests retire immediately — still
        // recorded, so stats().requests matches the replies delivered.
        if total_tiles == 0 {
            self.shared.stats.lock().unwrap().record(Completion {
                id: req.id,
                macs: req.macs(),
                precision: req.precision,
                wall: submitted.elapsed(),
                device_s: 0.0,
                invocations: 0,
            });
            let out = match ops {
                Operands::F32 { .. } => MatOutput::F32(vec![0.0; m * n]),
                Operands::I32 { .. } => MatOutput::I32(vec![0; m * n]),
            };
            self.gate.release();
            reply.send(req, Ok(out));
            return;
        }
        let data = match ops {
            Operands::F32 { a, b } => FlightData::F32(Pools::fresh(a, b, m * n)),
            Operands::I32 { a, b } => FlightData::I32(Pools::fresh(a, b, m * n)),
        };
        let fid = self.next_flight;
        self.next_flight += 1;
        self.flights.insert(
            fid,
            Flight {
                req,
                grid,
                tiler,
                data,
                next_tile: 0,
                total_tiles,
                done_tiles: 0,
                started: submitted,
                invocations: 0,
                reply,
            },
        );
        self.ready.push_back(fid);
    }

    /// Schedule the next tile of flight `fid` into the window.
    fn submit_one(&mut self, fid: u64) {
        let tag = self.next_tag;
        self.next_tag += 1;
        let (payload, desc, requeue) = {
            let Some(f) = self.flights.get_mut(&fid) else { return };
            let (_gm, gk, gn) = f.grid;
            let (m, k, n) = (f.req.m as usize, f.req.k as usize, f.req.n as usize);
            let tiler = f.tiler;
            // k-innermost walk: tile t = (im·gn + inn)·gk + ik.
            let t = f.next_tile;
            f.next_tile += 1;
            let ik = t % gk;
            let blk = t / gk;
            let im = blk / gn;
            let inn = blk % gn;
            let payload = match &mut f.data {
                FlightData::F32(p) => {
                    p.pack(m, k, n, tiler);
                    TilePayload::F32 {
                        a: Arc::clone(&p.a_tiles[im * gk + ik]),
                        b: Arc::clone(&p.b_tiles[ik * gn + inn]),
                    }
                }
                FlightData::I32(p) => {
                    p.pack(m, k, n, tiler);
                    TilePayload::I32 {
                        a: Arc::clone(&p.a_tiles[im * gk + ik]),
                        b: Arc::clone(&p.b_tiles[ik * gn + inn]),
                    }
                }
            };
            f.invocations += 1;
            (payload, JobDesc { flight: fid, im, inn, ik }, f.next_tile < f.total_tiles)
        };
        self.descs.insert(tag, desc);
        if requeue {
            self.ready.push_back(fid);
        }
        match self.device.submit(TileJob { tag, payload, done: self.tile_tx.clone() }) {
            Ok(()) => self.in_flight += 1,
            Err(e) => {
                self.descs.remove(&tag);
                self.fail_flight(fid, e);
            }
        }
    }

    fn handle_done(&mut self, done: TileDone) {
        // Sample the window as it stood while this tile completed.
        let occ = self.in_flight;
        self.shared.window.lock().unwrap().record(occ);
        self.shared.last_window.lock().unwrap().record(occ);
        self.in_flight = self.in_flight.saturating_sub(1);
        let Some(desc) = self.descs.remove(&done.tag) else {
            return; // stale tag (defensive; tags are scheduler-issued)
        };
        let fid = desc.flight;
        if !self.flights.contains_key(&fid) {
            return; // flight already failed; drop the straggler tile
        }
        let output = match done.result {
            Ok(o) => o,
            Err(e) => {
                self.fail_flight(fid, e);
                return;
            }
        };
        let matched = {
            let f = self.flights.get_mut(&fid).unwrap();
            let tiler = f.tiler;
            let (_gm, gk, _gn) = f.grid;
            let (m, n) = (f.req.m as usize, f.req.n as usize);
            match (&mut f.data, output) {
                (FlightData::F32(p), TileOutput::F32(partial)) => {
                    reduce_partial(
                        &mut self.accs_f32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                    );
                    true
                }
                (FlightData::I32(p), TileOutput::I32(partial)) => {
                    reduce_partial(
                        &mut self.accs_i32,
                        &mut p.c,
                        &mut f.done_tiles,
                        tiler,
                        gk,
                        m,
                        n,
                        fid,
                        desc,
                        partial,
                    );
                    true
                }
                _ => false,
            }
        };
        if !matched {
            self.fail_flight(fid, anyhow!("device returned a tile in the wrong precision"));
            return;
        }
        let f = &self.flights[&fid];
        if f.done_tiles == f.total_tiles {
            self.retire(fid);
        }
    }

    /// Deliver a finished flight's output and free its admission slot.
    fn retire(&mut self, fid: u64) {
        let mut f = self.flights.remove(&fid).unwrap();
        // Charge the flight exactly its own tiles (period × invocations)
        // — the shared device clock spans concurrently open flights and
        // would double-count overlap.
        let period = self
            .device
            .info_for(f.req.precision)
            .map(|i| i.period_cycles)
            .unwrap_or_default();
        self.shared.stats.lock().unwrap().record(Completion {
            id: f.req.id,
            macs: f.req.macs(),
            precision: f.req.precision,
            wall: f.started.elapsed(),
            device_s: period * f.invocations as f64 / self.device.freq_hz,
            invocations: f.invocations,
        });
        let out = match &mut f.data {
            FlightData::F32(p) => MatOutput::F32(std::mem::take(&mut p.c)),
            FlightData::I32(p) => MatOutput::I32(std::mem::take(&mut p.c)),
        };
        self.gate.release();
        f.reply.send(f.req, Ok(out));
    }

    /// Fail one flight without tearing the stream down: later tiles of
    /// the flight still in the window are dropped on arrival.
    fn fail_flight(&mut self, fid: u64, err: anyhow::Error) {
        let Some(f) = self.flights.remove(&fid) else { return };
        self.ready.retain(|&x| x != fid);
        self.accs_f32.retain(|k, _| k.0 != fid);
        self.accs_i32.retain(|k, _| k.0 != fid);
        self.gate.release();
        f.reply.send(f.req, Err(err));
    }
}

/// The serving coordinator (client handle). Cheap to share across
/// threads by reference: `submit*` take `&self`.
pub struct MatMulServer {
    events: mpsc::Sender<Event>,
    sched: Option<JoinHandle<()>>,
    forwarder: Option<JoinHandle<()>>,
    gate: Arc<Gate>,
    shared: Arc<Shared>,
    cycles: Arc<AtomicU64>,
    invocations: Arc<AtomicU64>,
    info_f32: PrecisionInfo,
    info_int8: PrecisionInfo,
    freq_hz: f64,
    backend: &'static str,
    workers: usize,
    pipeline_depth: usize,
    policy: AdmissionPolicy,
    queue_depth: usize,
}

impl MatMulServer {
    /// Start the server: spawns the device worker pool, the completion
    /// forwarder and the scheduler thread.
    pub fn start(cfg: &ServeConfig) -> Result<Self> {
        let device = spawn_device_pool(
            cfg.artifacts_dir.clone().into(),
            cfg.design.clone(),
            cfg.backend,
            cfg.workers,
        )?;
        let (cycles, invocations) = device.counters();
        let info_f32 = device.info_for(Precision::Fp32)?;
        let info_int8 = device.info_for(Precision::Int8)?;
        let freq_hz = device.freq_hz;
        let backend = device.backend;
        let workers = device.workers;

        let gate = Arc::new(Gate::new(cfg.queue_depth));
        let shared = Arc::new(Shared {
            stats: Mutex::new(StatsAgg::default()),
            window: Mutex::new(WindowOcc::default()),
            last_window: Mutex::new(WindowOcc::default()),
            wall_time_s: Mutex::new(0.0),
        });
        let (events_tx, events_rx) = mpsc::channel::<Event>();
        let (tile_tx, tile_rx) = mpsc::channel::<TileDone>();

        // Tile completions → scheduler events (std mpsc has no select;
        // a relay thread keeps the scheduler single-channel).
        let fwd_events = events_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name("maxeva-completions".into())
            .spawn(move || {
                while let Ok(done) = tile_rx.recv() {
                    if fwd_events.send(Event::Done(done)).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning completion forwarder: {e}"))?;

        let sched = Scheduler {
            device,
            tiler_f32: Tiler::new(info_f32.native),
            tiler_i32: Tiler::new(info_int8.native),
            gate: Arc::clone(&gate),
            shared: Arc::clone(&shared),
            tile_tx,
            depth: cfg.pipeline_depth.max(1),
            draining: false,
            flights: FxHashMap::default(),
            ready: VecDeque::new(),
            descs: FxHashMap::default(),
            accs_f32: FxHashMap::default(),
            accs_i32: FxHashMap::default(),
            next_flight: 0,
            next_tag: 0,
            in_flight: 0,
        };
        let sched = std::thread::Builder::new()
            .name("maxeva-scheduler".into())
            .spawn(move || sched.run(events_rx))
            .map_err(|e| anyhow!("spawning scheduler: {e}"))?;

        Ok(MatMulServer {
            events: events_tx,
            sched: Some(sched),
            forwarder: Some(forwarder),
            gate,
            shared,
            cycles,
            invocations,
            info_f32,
            info_int8,
            freq_hz,
            backend,
            workers,
            pipeline_depth: cfg.pipeline_depth.max(1),
            policy: cfg.admission,
            queue_depth: cfg.queue_depth,
        })
    }

    /// Per-precision device facts — the server-side dispatch point.
    fn info_for(&self, p: Precision) -> Result<PrecisionInfo> {
        match p {
            Precision::Fp32 => Ok(self.info_f32),
            Precision::Int8 => Ok(self.info_int8),
            other => Err(anyhow!("serving supports fp32 and int8, not {other}")),
        }
    }

    /// Native fp32 design size (nm, nk, nn).
    pub fn native(&self) -> (u64, u64, u64) {
        self.info_f32.native
    }

    /// Native design size for a serving precision.
    pub fn native_for(&self, p: Precision) -> Result<(u64, u64, u64)> {
        Ok(self.info_for(p)?.native)
    }

    /// Steady-state fp32 iteration period of the design, in device cycles.
    pub fn period_cycles(&self) -> f64 {
        self.info_f32.period_cycles
    }

    /// Iteration period for a serving precision, in device cycles.
    pub fn period_cycles_for(&self, p: Precision) -> Result<f64> {
        Ok(self.info_for(p)?.period_cycles)
    }

    /// Device clock frequency, Hz.
    pub fn freq_hz(&self) -> f64 {
        self.freq_hz
    }

    /// Resolved tile-execution backend ("pjrt" or "reference").
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Device worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Configured in-flight window.
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Admission queue bound (`0` = unbounded).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Reconfigure the in-flight window (the A/B knob; `1` = synchronous).
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth.max(1);
        let _ = self.events.send(Event::SetDepth(depth));
    }

    /// `(mean, max)` window occupancy since the last `run_batch` began —
    /// unlike [`ServerStats::mean_in_flight`] this is not diluted by
    /// earlier batches run at other depths.
    pub fn last_batch_occupancy(&self) -> (f64, usize) {
        let w = self.shared.last_window.lock().unwrap();
        (w.mean(), w.max())
    }

    fn validate(req: &MatMulRequest, ops: &Operands) -> Result<()> {
        match (req.precision, ops) {
            (Precision::Fp32, Operands::F32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                Ok(())
            }
            (Precision::Int8, Operands::I32 { a, b }) => {
                if a.len() as u64 != req.m * req.k {
                    return Err(anyhow!("request {}: A shape mismatch", req.id));
                }
                if b.len() as u64 != req.k * req.n {
                    return Err(anyhow!("request {}: B shape mismatch", req.id));
                }
                if a.iter().chain(b.iter()).any(|v| !(-128..=127).contains(v)) {
                    return Err(anyhow!(
                        "request {}: int8 operands must be in [-128, 127]",
                        req.id
                    ));
                }
                Ok(())
            }
            (Precision::Fp32, Operands::I32 { .. }) | (Precision::Int8, Operands::F32 { .. }) => {
                Err(anyhow!(
                    "request {}: operand container does not match request precision {}",
                    req.id,
                    req.precision
                ))
            }
            (p, _) => Err(anyhow!("serving supports fp32 and int8, not {p}")),
        }
    }

    fn submit_inner(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
        reply: Reply,
    ) -> Result<()> {
        Self::validate(&req, &ops)?;
        self.gate.admit(policy)?;
        let adm = Box::new(Admitted {
            req,
            ops: Some(ops),
            submitted: Instant::now(),
            reply: Some(reply),
            gate: Arc::clone(&self.gate),
        });
        if self.events.send(Event::Admit(adm)).is_err() {
            // The returned Admitted dropped: slot freed, reply errored.
            return Err(anyhow!("server is shut down"));
        }
        Ok(())
    }

    /// Admit one request under the configured admission policy and get a
    /// completion handle. Blocks (policy `Block`) or fails with
    /// [`QueueFull`] (policy `Reject`) when `queue_depth` requests are
    /// already open.
    pub fn submit(&self, req: MatMulRequest, ops: Operands) -> Result<RequestHandle> {
        self.submit_with_policy(req, ops, self.policy)
    }

    /// [`MatMulServer::submit`] with an explicit per-call policy.
    pub fn submit_with_policy(
        &self,
        req: MatMulRequest,
        ops: Operands,
        policy: AdmissionPolicy,
    ) -> Result<RequestHandle> {
        let (tx, rx) = mpsc::channel();
        let id = req.id;
        self.submit_inner(req, ops, policy, Reply::Handle(tx))?;
        Ok(RequestHandle { id, rx })
    }

    /// Admit one request and deliver its completion through `callback`
    /// instead of a handle. The callback runs on the scheduler thread —
    /// keep it short (hand heavy post-processing to another thread).
    pub fn submit_with_callback(
        &self,
        req: MatMulRequest,
        ops: Operands,
        callback: impl FnOnce(MatMulRequest, Result<MatOutput>) + Send + 'static,
    ) -> Result<()> {
        self.submit_inner(req, ops, self.policy, Reply::Callback(Box::new(callback)))
    }

    /// Execute one fp32 request synchronously (convenience path).
    pub fn execute(&mut self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let mut out = self.run_batch(vec![(req, a, b)])?;
        Ok(out.pop().unwrap())
    }

    /// Serve a closed fp32 batch through the streaming engine (submit
    /// everything with blocking admission, wait in order). Returns the
    /// outputs in request order.
    pub fn run_batch(
        &mut self,
        batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
    ) -> Result<Vec<Vec<f32>>> {
        self.run_batch_mixed(
            batch
                .into_iter()
                .map(|(req, a, b)| (req, Operands::F32 { a, b }))
                .collect(),
        )?
        .into_iter()
        .map(MatOutput::into_f32)
        .collect()
    }

    /// Serve a closed mixed-precision batch through the streaming
    /// engine. Returns the outputs in request order.
    pub fn run_batch_mixed(
        &mut self,
        batch: Vec<(MatMulRequest, Operands)>,
    ) -> Result<Vec<MatOutput>> {
        let wall0 = Instant::now();
        let _ = self.events.send(Event::ResetEpoch);
        let mut handles = Vec::with_capacity(batch.len());
        for (req, ops) in batch {
            handles.push(self.submit_with_policy(req, ops, AdmissionPolicy::Block)?);
        }
        let outs: Result<Vec<MatOutput>> = handles.into_iter().map(RequestHandle::wait).collect();
        *self.shared.wall_time_s.lock().unwrap() += wall0.elapsed().as_secs_f64();
        outs
    }

    /// Snapshot serving statistics.
    pub fn stats(&self) -> ServerStats {
        let stats = self.shared.stats.lock().unwrap();
        let window = self.shared.window.lock().unwrap();
        ServerStats {
            requests: stats.count(),
            requests_fp32: stats.count_by(Precision::Fp32),
            requests_int8: stats.count_by(Precision::Int8),
            invocations: self.invocations.load(Ordering::Relaxed),
            mean_latency_ms: stats.mean_latency_ms(),
            p99_latency_ms: stats.p99_latency_ms(),
            device_ops_per_sec: stats.device_ops_per_sec(),
            device_time_s: self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz,
            wall_time_s: *self.shared.wall_time_s.lock().unwrap(),
            pipeline_depth: self.pipeline_depth,
            mean_in_flight: window.mean(),
            max_in_flight: window.max(),
        }
    }

    fn stop(&mut self) {
        let _ = self.events.send(Event::Drain);
        if let Some(j) = self.sched.take() {
            let _ = j.join();
        }
        if let Some(j) = self.forwarder.take() {
            let _ = j.join();
        }
    }

    /// Graceful shutdown: drain every open request, then stop the
    /// scheduler and device workers.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for MatMulServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// Integration tests (needing built artifacts) live in
// rust/tests/serving_e2e.rs; backend-independent pipelined-vs-sequential
// equivalence tests in rust/tests/pipeline_equivalence.rs; streaming
// admission, backpressure and mixed-precision tests in
// rust/tests/streaming_admission.rs.
