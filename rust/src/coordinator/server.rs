//! The MatMul serving layer: request queue + pipelined tile engine on top
//! of the device worker pool.
//!
//! Requests of arbitrary `M×K×N` are decomposed into native-size tile
//! jobs and streamed through an **asynchronous in-flight window** — the
//! host-side analogue of the paper's ping-pong (double) buffering, eq. 2:
//! the AIE kernel only sustains its rate because DMA refills one buffer
//! while the datapath consumes the other, and likewise this engine only
//! keeps the device workers busy because block packing and accumulation
//! for tiles `i±window` happen while tile `i` executes. Three mechanisms
//! cooperate:
//!
//! 1. **Tile-major packing (zero-copy)** — on admission each request's A
//!    and B are packed once into tile-major pools of `Arc`'d native
//!    blocks ([`Tiler::pack_tile_major`]). A tile job borrows its two
//!    blocks by `Arc` clone; nothing is re-extracted or copied per tile.
//!    The old engine extracted the `(im,ik)` A-block `gn` times and the
//!    `(ik,inn)` B-block `gm` times per request.
//! 2. **Windowed submission** — up to `pipeline_depth` tagged jobs are
//!    kept in flight on a single completion channel, overlapping host
//!    pack/reduce work with device execution (and, with `workers > 1`,
//!    device executions with each other). `pipeline_depth = 1` reproduces
//!    the synchronous one-tile-at-a-time engine exactly — the A/B knob
//!    for measuring the win.
//! 3. **Reuse-ordered scheduling** — each request walks its tiles
//!    k-innermost per `(im, inn)` output block, so partial products
//!    reduce into a dense per-block accumulation buffer and the strided
//!    output matrix is written once per block, not once per tile.
//!    Fairness across requests is round-robin at the *window* level (a
//!    ready-queue rotation per submitted tile), not a rescan of every
//!    in-flight request per tile.
//!
//! **Determinism:** completions may arrive out of order (multiple
//! workers), but partials are applied to each output block strictly in
//! ascending `ik` order (late partials park in a per-block reorder map),
//! so outputs are bit-identical for every `pipeline_depth`/`workers`
//! combination — see `rust/tests/pipeline_equivalence.rs`.

use crate::config::schema::ServeConfig;
use crate::coordinator::device::{spawn_device_pool, DeviceHandle, TileDone, TileJobF32};
use crate::coordinator::stats::{Completion, StatsAgg, WindowOcc};
use crate::coordinator::tiler::Tiler;
use crate::workloads::MatMulRequest;
use anyhow::{anyhow, Result};
use rustc_hash::FxHashMap;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Serving statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub invocations: u64,
    pub mean_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Device-time throughput (ops/s) over the whole stream.
    pub device_ops_per_sec: f64,
    /// Total simulated device time (s).
    pub device_time_s: f64,
    /// Total wall time (s) spent in `run_batch`.
    pub wall_time_s: f64,
    /// Configured in-flight window.
    pub pipeline_depth: usize,
    /// Measured mean window occupancy (1.0 = synchronous).
    pub mean_in_flight: f64,
    /// Measured peak window occupancy.
    pub max_in_flight: usize,
}

/// One in-flight request's state: operands packed tile-major at
/// admission, grid cached (never recomputed per tile).
struct InFlight {
    req: MatMulRequest,
    /// Block grid `(gm, gk, gn)`, computed once at admission.
    grid: (usize, usize, usize),
    /// Raw row-major operands, held until this request's first tile is
    /// scheduled: packing then happens *inside* the pipeline, overlapping
    /// the tiles of earlier requests already executing on the workers.
    raw: Option<(Vec<f32>, Vec<f32>)>,
    /// Tile-major A pool, indexed `[im·gk + ik]` (filled at first
    /// schedule).
    a_tiles: Vec<Arc<Vec<f32>>>,
    /// Tile-major B pool, indexed `[ik·gn + inn]` (filled at first
    /// schedule).
    b_tiles: Vec<Arc<Vec<f32>>>,
    c: Vec<f32>,
    /// Cursor into the k-innermost tile walk.
    next_tile: usize,
    total_tiles: usize,
    /// Tiles whose partials have been reduced (in order).
    done_tiles: usize,
    started: Instant,
    invocations: u64,
    device_s0: f64,
}

/// Where a tagged in-flight job lands when it completes.
#[derive(Debug, Clone, Copy)]
struct JobDesc {
    flight: usize,
    im: usize,
    inn: usize,
    ik: usize,
}

/// Per-output-block accumulation state (the "small accumulation buffer
/// per in-flight block").
struct BlockAcc {
    /// Dense `nm×nn` running sum.
    buf: Vec<f32>,
    /// Next `ik` to reduce — enforces the bit-exact reduction order.
    next_ik: usize,
    /// Out-of-order partials parked until their turn.
    pending: BTreeMap<usize, Vec<f32>>,
}

/// The serving coordinator.
pub struct MatMulServer {
    device: DeviceHandle,
    tiler: Tiler,
    stats: StatsAgg,
    /// Cumulative window occupancy over the server's lifetime.
    window: WindowOcc,
    /// Occupancy of the most recent `run_batch` only (A/B attribution).
    last_window: WindowOcc,
    pipeline_depth: usize,
    wall_time_s: f64,
}

impl MatMulServer {
    /// Start the server: spawns the device worker pool and compiles the
    /// design's artifact (or brings up the reference backend, per
    /// `cfg.backend`).
    pub fn start(cfg: &ServeConfig) -> Result<Self> {
        let device = spawn_device_pool(
            cfg.artifacts_dir.clone().into(),
            cfg.design.clone(),
            cfg.backend,
            cfg.workers,
        )?;
        let tiler = Tiler::new(device.native);
        Ok(MatMulServer {
            device,
            tiler,
            stats: StatsAgg::default(),
            window: WindowOcc::default(),
            last_window: WindowOcc::default(),
            pipeline_depth: cfg.pipeline_depth.max(1),
            wall_time_s: 0.0,
        })
    }

    /// Native design size (nm, nk, nn).
    pub fn native(&self) -> (u64, u64, u64) {
        self.device.native
    }

    /// Steady-state iteration period of the design, in device cycles.
    pub fn period_cycles(&self) -> f64 {
        self.device.period_cycles
    }

    /// Device clock frequency, Hz.
    pub fn freq_hz(&self) -> f64 {
        self.device.freq_hz
    }

    /// Resolved tile-execution backend ("pjrt" or "reference").
    pub fn backend(&self) -> &'static str {
        self.device.backend
    }

    /// Device worker threads.
    pub fn workers(&self) -> usize {
        self.device.workers
    }

    /// Configured in-flight window.
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Reconfigure the in-flight window (the A/B knob; `1` = synchronous).
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth.max(1);
    }

    /// `(mean, max)` window occupancy of the most recent `run_batch` —
    /// unlike [`ServerStats::mean_in_flight`] this is not diluted by
    /// earlier batches run at other depths.
    pub fn last_batch_occupancy(&self) -> (f64, usize) {
        (self.last_window.mean(), self.last_window.max())
    }

    /// Execute one request synchronously (convenience path).
    pub fn execute(&mut self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let mut out = self.run_batch(vec![(req, a, b)])?;
        Ok(out.pop().unwrap())
    }

    /// Admit one request: validate shapes and cache the grid. Packing is
    /// deferred to the request's first schedule (see [`InFlight::raw`]).
    fn admit(&self, req: MatMulRequest, a: Vec<f32>, b: Vec<f32>, device_s0: f64) -> InFlight {
        assert_eq!(a.len() as u64, req.m * req.k, "A shape mismatch");
        assert_eq!(b.len() as u64, req.k * req.n, "B shape mismatch");
        let (m, k, n) = (req.m as usize, req.k as usize, req.n as usize);
        let grid = self.tiler.grid(m, k, n);
        let (gm, gk, gn) = grid;
        InFlight {
            grid,
            raw: Some((a, b)),
            a_tiles: Vec::new(),
            b_tiles: Vec::new(),
            c: vec![0.0; m * n],
            next_tile: 0,
            total_tiles: gm * gk * gn,
            done_tiles: 0,
            started: Instant::now(),
            invocations: 0,
            device_s0,
            req,
        }
    }

    /// Execute a batch of requests through the pipelined engine.
    /// Returns the outputs in request order.
    pub fn run_batch(
        &mut self,
        batch: Vec<(MatMulRequest, Vec<f32>, Vec<f32>)>,
    ) -> Result<Vec<Vec<f32>>> {
        let wall0 = Instant::now();
        let depth = self.pipeline_depth;
        self.last_window = WindowOcc::default();
        let device_s0 = self.device.device_time_s();
        let mut flights: Vec<InFlight> = batch
            .into_iter()
            .map(|(req, a, b)| self.admit(req, a, b, device_s0))
            .collect();

        let mut outputs: Vec<Option<Vec<f32>>> = (0..flights.len()).map(|_| None).collect();
        // Degenerate (zero-tile) requests complete immediately — still
        // recorded, so stats().requests matches the outputs returned.
        for (idx, f) in flights.iter_mut().enumerate() {
            if f.total_tiles == 0 {
                self.stats.record(Completion {
                    id: f.req.id,
                    macs: f.req.macs(),
                    wall: f.started.elapsed(),
                    device_s: 0.0,
                    invocations: 0,
                });
                outputs[idx] = Some(std::mem::take(&mut f.c));
            }
        }

        // Window-level round-robin: each ready request submits one tile,
        // then rotates to the back of the queue.
        let mut ready: VecDeque<usize> = (0..flights.len())
            .filter(|&i| flights[i].total_tiles > 0)
            .collect();
        let (done_tx, done_rx) = mpsc::channel::<TileDone>();
        let mut descs: FxHashMap<u64, JobDesc> = FxHashMap::default();
        let mut accs: FxHashMap<(usize, usize, usize), BlockAcc> = FxHashMap::default();
        let mut next_tag: u64 = 0;
        let mut in_flight = 0usize;

        loop {
            // Fill the window.
            while in_flight < depth {
                let Some(fi) = ready.pop_front() else { break };
                let f = &mut flights[fi];
                let (_gm, gk, gn) = f.grid;
                // First schedule of this request: pack its operands into
                // the tile-major pools now — one extract pass per block,
                // total, overlapping whatever is already in flight.
                if let Some((a, b)) = f.raw.take() {
                    let (m, k, n) =
                        (f.req.m as usize, f.req.k as usize, f.req.n as usize);
                    let (nm, nk, nn) = (self.tiler.nm, self.tiler.nk, self.tiler.nn);
                    f.a_tiles = Tiler::pack_tile_major(&a, m, k, nm, nk)
                        .into_iter()
                        .map(Arc::new)
                        .collect();
                    f.b_tiles = Tiler::pack_tile_major(&b, k, n, nk, nn)
                        .into_iter()
                        .map(Arc::new)
                        .collect();
                }
                // k-innermost walk: tile t = (im·gn + inn)·gk + ik.
                let t = f.next_tile;
                f.next_tile += 1;
                let ik = t % gk;
                let blk = t / gk;
                let im = blk / gn;
                let inn = blk % gn;
                let tag = next_tag;
                next_tag += 1;
                descs.insert(tag, JobDesc { flight: fi, im, inn, ik });
                f.invocations += 1;
                if f.next_tile < f.total_tiles {
                    ready.push_back(fi);
                }
                self.device.submit(TileJobF32 {
                    tag,
                    a: Arc::clone(&f.a_tiles[im * gk + ik]),
                    b: Arc::clone(&f.b_tiles[ik * gn + inn]),
                    done: done_tx.clone(),
                })?;
                in_flight += 1;
            }
            if in_flight == 0 {
                break;
            }
            self.last_window.record(in_flight);

            // Drain one completion (host reduce overlaps the tiles still
            // executing on the workers).
            let done = done_rx
                .recv()
                .map_err(|_| anyhow!("device completion channel closed"))?;
            in_flight -= 1;
            let desc = descs
                .remove(&done.tag)
                .ok_or_else(|| anyhow!("unknown completion tag {}", done.tag))?;
            let partial = done.result?;
            self.reduce_partial(&mut flights, &mut accs, desc, partial);
            let f = &mut flights[desc.flight];
            if f.done_tiles == f.total_tiles && outputs[desc.flight].is_none() {
                let wall = f.started.elapsed();
                self.stats.record(Completion {
                    id: f.req.id,
                    macs: f.req.macs(),
                    wall,
                    device_s: self.device.device_time_s() - f.device_s0,
                    invocations: f.invocations,
                });
                outputs[desc.flight] = Some(std::mem::take(&mut f.c));
            }
        }
        self.window.merge(&self.last_window);
        self.wall_time_s += wall0.elapsed().as_secs_f64();
        Ok(outputs.into_iter().map(|o| o.unwrap()).collect())
    }

    /// Reduce one completed partial product into its output block,
    /// preserving ascending-`ik` order; write the block back once full.
    fn reduce_partial(
        &mut self,
        flights: &mut [InFlight],
        accs: &mut FxHashMap<(usize, usize, usize), BlockAcc>,
        desc: JobDesc,
        partial: Vec<f32>,
    ) {
        let (nm, nn) = (self.tiler.nm, self.tiler.nn);
        let f = &mut flights[desc.flight];
        let (_gm, gk, _gn) = f.grid;
        let key = (desc.flight, desc.im, desc.inn);
        let acc = accs.entry(key).or_insert_with(|| BlockAcc {
            buf: vec![0.0; nm * nn],
            next_ik: 0,
            pending: BTreeMap::new(),
        });
        acc.pending.insert(desc.ik, partial);
        while let Some(p) = acc.pending.remove(&acc.next_ik) {
            for (dst, src) in acc.buf.iter_mut().zip(&p) {
                *dst += *src;
            }
            acc.next_ik += 1;
            f.done_tiles += 1;
        }
        if acc.next_ik == gk {
            let full = accs.remove(&key).unwrap();
            let (m, n) = (f.req.m as usize, f.req.n as usize);
            Tiler::write_block(&mut f.c, m, n, desc.im, desc.inn, nm, nn, &full.buf);
        }
    }

    /// Snapshot serving statistics.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            requests: self.stats.count(),
            invocations: self.device.invocations(),
            mean_latency_ms: self.stats.mean_latency_ms(),
            p99_latency_ms: self.stats.p99_latency_ms(),
            device_ops_per_sec: self.stats.device_ops_per_sec(),
            device_time_s: self.device.device_time_s(),
            wall_time_s: self.wall_time_s,
            pipeline_depth: self.pipeline_depth,
            mean_in_flight: self.window.mean(),
            max_in_flight: self.window.max(),
        }
    }

    /// Shut the device workers down.
    pub fn shutdown(self) {
        self.device.shutdown();
    }
}

// Integration tests (needing built artifacts) live in
// rust/tests/serving_e2e.rs; backend-independent pipelined-vs-sequential
// equivalence tests live in rust/tests/pipeline_equivalence.rs.
