//! The device worker pool: owns the PJRT runtime (whose handles are not
//! `Send`) and serves native-size tile jobs over a channel — the software
//! stand-in for the AIE array device.
//!
//! # Job model (the pipelined dataflow)
//!
//! Jobs are **tagged** and carry `Arc`'d operand tiles from the server's
//! tile-major pools — submission is zero-copy, the worker reads the
//! slices in place. Every job names its own completion sender, and the
//! serving engine points *all* of a batch's jobs at one channel, so a
//! single `recv` loop drains completions for a whole in-flight window
//! regardless of which worker executed which tile. This is the host-side
//! mirror of the paper's ping-pong buffering (eq. 2): while a worker
//! multiplies tile *i*, the host packs/accumulates tiles *i±window*.
//!
//! Each invocation advances the simulated device clock by the design's
//! steady-state iteration period, giving VCK190-equivalent device time
//! (the clock sums busy periods across workers, i.e. it stays the serial
//! device-equivalent time).
//!
//! # Backends
//!
//! * **PJRT** — the AOT-compiled JAX/Pallas artifact, one
//!   `Runtime`/`Executable` per worker thread (handles are not `Send`).
//!   Needs the `pjrt` cargo feature and `make artifacts`.
//! * **Reference** — a pure-Rust native-tile matmul with identical tile
//!   semantics. No artifacts needed; lets the full serving stack (and its
//!   equivalence tests) run in any build environment.

use crate::config::schema::{BackendKind, DesignConfig};
use crate::coordinator::tiler::matmul_ref_f32;
use crate::placement::placer::place_design;
use crate::runtime::{artifacts_available, pjrt_compiled, Runtime};
use crate::sim::engine::{simulate_design, SimConfig};
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A tagged native-size f32 tile job: `a` is `nm×nk`, `b` is `nk×nn`
/// row-major, shared zero-copy from the server's packed pools.
pub struct TileJobF32 {
    /// Correlation tag echoed back in [`TileDone`].
    pub tag: u64,
    pub a: Arc<Vec<f32>>,
    pub b: Arc<Vec<f32>>,
    /// Completion channel; the serving engine points a whole window of
    /// jobs at one sender.
    pub done: mpsc::Sender<TileDone>,
}

/// Completion of one tile job.
pub struct TileDone {
    pub tag: u64,
    pub result: Result<Vec<f32>>,
}

enum Msg {
    Job(TileJobF32),
    Shutdown,
}

/// Handle to the running device worker pool.
pub struct DeviceHandle {
    tx: mpsc::Sender<Msg>,
    joins: Vec<JoinHandle<()>>,
    /// Native design size (nm, nk, nn).
    pub native: (u64, u64, u64),
    /// Simulated device cycles consumed (fixed-point: whole cycles).
    cycles: Arc<AtomicU64>,
    /// Iteration period in cycles (diagnostics).
    pub period_cycles: f64,
    /// Device frequency.
    pub freq_hz: f64,
    /// Number of device worker threads.
    pub workers: usize,
    /// Resolved backend ("pjrt" or "reference").
    pub backend: &'static str,
    /// Number of invocations served.
    invocations: Arc<AtomicU64>,
}

impl DeviceHandle {
    /// Submit one tagged native tile job.
    pub fn submit(&self, job: TileJobF32) -> Result<()> {
        self.tx
            .send(Msg::Job(job))
            .map_err(|_| anyhow!("device workers gone"))
    }

    /// Convenience: execute one tile synchronously.
    pub fn execute_tile(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let (done, rx) = mpsc::channel();
        self.submit(TileJobF32 { tag: 0, a: Arc::new(a), b: Arc::new(b), done })?;
        rx.recv().context("device reply channel closed")?.result
    }

    /// Simulated device time consumed so far, seconds.
    pub fn device_time_s(&self) -> f64 {
        self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz
    }

    /// Invocations served.
    pub fn invocations(&self) -> u64 {
        self.invocations.load(Ordering::Relaxed)
    }

    fn stop(&mut self) {
        for _ in &self.joins {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }

    /// Stop all device workers and wait for them.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for DeviceHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Artifact name for a design (shared scheme with aot.py).
pub fn artifact_name(design: &DesignConfig) -> String {
    format!(
        "array_{}_{}x{}x{}",
        design.precision, design.x, design.y, design.z
    )
}

/// What a worker thread executes per tile.
enum WorkerBackend {
    Pjrt { _rt: Runtime, exe: crate::runtime::Executable },
    Reference,
}

/// Spawn the device worker pool for `design` with the legacy defaults:
/// PJRT backend, one worker. Fails fast if the artifact is missing.
pub fn spawn_device(artifacts_dir: PathBuf, design: DesignConfig) -> Result<DeviceHandle> {
    spawn_device_pool(artifacts_dir, design, BackendKind::Pjrt, 1)
}

/// Spawn `workers` device threads serving tile jobs from a shared queue.
///
/// Backend resolution: `Pjrt` requires the `pjrt` feature *and* the
/// artifact on disk (fails fast otherwise, pointing at `make artifacts`);
/// `Reference` needs nothing; `Auto` picks PJRT when possible and falls
/// back to the reference backend.
pub fn spawn_device_pool(
    artifacts_dir: PathBuf,
    design: DesignConfig,
    backend: BackendKind,
    workers: usize,
) -> Result<DeviceHandle> {
    let have_artifacts = artifacts_available(&artifacts_dir);
    let use_pjrt = match backend {
        BackendKind::Pjrt => {
            if !have_artifacts {
                return Err(anyhow!(
                    "artifacts not found in {} — run `make artifacts` first",
                    artifacts_dir.display()
                ));
            }
            if !pjrt_compiled() {
                return Err(anyhow!(
                    "backend `pjrt` requested but maxeva was built without the \
                     `pjrt` feature"
                ));
            }
            true
        }
        BackendKind::Reference => false,
        BackendKind::Auto => have_artifacts && pjrt_compiled(),
    };

    let dev = design.device()?;
    let cand = design.candidate();
    let kernel = design.kernel();
    let native = (cand.x * kernel.m, cand.y * kernel.k, cand.z * kernel.n);

    // Device-time model from the simulator.
    let placed = place_design(&dev, cand, design.pattern, kernel)
        .map_err(|e| anyhow!("placement failed: {e}"))?;
    let sim = simulate_design(&dev, &placed, &SimConfig::default());
    let period = sim.period_cycles;
    let freq = dev.freq_hz;

    let workers = workers.max(1);
    let cycles = Arc::new(AtomicU64::new(0));
    let invocations = Arc::new(AtomicU64::new(0));
    let (tx, rx) = mpsc::channel::<Msg>();
    // std mpsc is single-consumer; the pool shares the receiver behind a
    // mutex (locked only to pop, never while executing a tile).
    let rx = Arc::new(Mutex::new(rx));
    let name = artifact_name(&design);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

    let mut joins = Vec::with_capacity(workers);
    for w in 0..workers {
        let rx_w = Arc::clone(&rx);
        let cycles_w = Arc::clone(&cycles);
        let invocations_w = Arc::clone(&invocations);
        let ready_w = ready_tx.clone();
        let dir_w = artifacts_dir.clone();
        let name_w = name.clone();
        let join = std::thread::Builder::new()
            .name(format!("maxeva-device-{w}"))
            .spawn(move || {
                // PJRT handles are created inside the thread (not Send).
                // §Perf: prefer the panel-scheduled `_fast` artifact (same
                // Pallas kernel, coarsened BlockSpec — ~11× faster on CPU
                // PJRT, identical reduction order; EXPERIMENTS.md §Perf).
                let init = (|| -> Result<WorkerBackend> {
                    if !use_pjrt {
                        return Ok(WorkerBackend::Reference);
                    }
                    let rt = Runtime::cpu()?;
                    let fast = crate::runtime::artifact_path(&dir_w, &format!("{name_w}_fast"));
                    let exe = if fast.exists() {
                        rt.load(&fast)?
                    } else {
                        rt.load_named(&dir_w, &name_w)?
                    };
                    Ok(WorkerBackend::Pjrt { _rt: rt, exe })
                })();
                let backend = match init {
                    Ok(b) => {
                        let _ = ready_w.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_w.send(Err(e));
                        return;
                    }
                };
                // Close this worker's ready sender now: if any sibling
                // worker dies during init without sending, the spawn-side
                // wait must see the channel disconnect, not hang.
                drop(ready_w);
                let (nm, nk, nn) = (native.0 as usize, native.1 as usize, native.2 as usize);
                loop {
                    // Pop under the lock, execute outside it so workers
                    // overlap.
                    let msg = match rx_w.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    let job = match msg {
                        Ok(Msg::Job(job)) => job,
                        Ok(Msg::Shutdown) | Err(_) => break,
                    };
                    // A panic inside the backend (e.g. PJRT FFI) must
                    // still produce a completion — otherwise the server's
                    // recv loop would wait forever for this tag.
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || match &backend {
                            WorkerBackend::Pjrt { exe, .. } => exe.run_f32(&[
                                (job.a.as_slice(), &[nm as i64, nk as i64][..]),
                                (job.b.as_slice(), &[nk as i64, nn as i64][..]),
                            ]),
                            WorkerBackend::Reference => {
                                Ok(matmul_ref_f32(&job.a, &job.b, nm, nk, nn))
                            }
                        },
                    ))
                    .unwrap_or_else(|_| Err(anyhow!("device worker panicked executing tile")));
                    cycles_w.fetch_add(period as u64, Ordering::Relaxed);
                    invocations_w.fetch_add(1, Ordering::Relaxed);
                    let _ = job.done.send(TileDone { tag: job.tag, result: res });
                }
            })
            .context("spawning device worker")?;
        joins.push(join);
    }
    drop(ready_tx);

    // Wait for every worker's backend to come up (or fail).
    for _ in 0..workers {
        match ready_rx.recv().context("device worker died during init") {
            Ok(Ok(())) => {}
            Ok(Err(e)) | Err(e) => {
                // Tear the pool down before propagating.
                for _ in 0..workers {
                    let _ = tx.send(Msg::Shutdown);
                }
                for j in joins {
                    let _ = j.join();
                }
                return Err(e);
            }
        }
    }

    Ok(DeviceHandle {
        tx,
        joins,
        native,
        cycles,
        period_cycles: period,
        freq_hz: freq,
        workers,
        backend: if use_pjrt { "pjrt" } else { "reference" },
        invocations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    #[test]
    fn artifact_name_scheme() {
        let d = DesignConfig::flagship(Precision::Fp32);
        assert_eq!(artifact_name(&d), "array_fp32_13x4x6");
        let d8 = DesignConfig::flagship(Precision::Int8);
        assert_eq!(artifact_name(&d8), "array_int8_13x4x6");
    }

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join("maxeva_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        match spawn_device(dir, DesignConfig::flagship(Precision::Fp32)) {
            Err(err) => assert!(err.to_string().contains("make artifacts"), "{err}"),
            Ok(_) => panic!("spawn must fail without artifacts"),
        }
    }

    #[test]
    fn reference_pool_executes_tagged_jobs() {
        // Small 2×4×2 array of 4×4×4 kernels → native (8, 16, 8); the
        // reference backend needs no artifacts.
        let mut design = DesignConfig::flagship(Precision::Fp32);
        (design.x, design.y, design.z) = (2, 4, 2);
        (design.m, design.k, design.n) = (4, 4, 4);
        let dir = std::env::temp_dir().join("maxeva_ref_pool");
        std::fs::create_dir_all(&dir).unwrap();
        let dev = spawn_device_pool(dir, design, BackendKind::Reference, 2).unwrap();
        assert_eq!(dev.native, (8, 16, 8));
        assert_eq!(dev.backend, "reference");
        let (nm, nk, nn) = (8usize, 16usize, 8usize);
        let a: Vec<f32> = (0..nm * nk).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..nk * nn).map(|i| (i % 7) as f32 - 3.0).collect();
        let want = matmul_ref_f32(&a, &b, nm, nk, nn);

        // Tagged async submission on one completion channel.
        let (done_tx, done_rx) = mpsc::channel();
        let a = Arc::new(a);
        let b = Arc::new(b);
        for tag in 0..6u64 {
            dev.submit(TileJobF32 {
                tag,
                a: Arc::clone(&a),
                b: Arc::clone(&b),
                done: done_tx.clone(),
            })
            .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let d = done_rx.recv().unwrap();
            assert_eq!(d.result.unwrap(), want);
            seen.push(d.tag);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(dev.invocations(), 6);
        assert!(dev.device_time_s() > 0.0);
        dev.shutdown();
    }

    // Full execution tests live in rust/tests/runtime_artifacts.rs.
}
