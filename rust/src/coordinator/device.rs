//! The device thread: owns the PJRT runtime (whose handles are not
//! `Send`) and serves native-size tile jobs over a channel — the software
//! stand-in for the AIE array device.
//!
//! Each invocation advances the simulated device clock by the design's
//! steady-state iteration period, giving VCK190-equivalent device time.

use crate::config::schema::DesignConfig;
use crate::runtime::{artifacts_available, Runtime};
use crate::sim::engine::{simulate_design, SimConfig};
use crate::placement::placer::place_design;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// A native-size f32 tile job: `a` is `nm×nk`, `b` is `nk×nn` row-major.
pub struct TileJobF32 {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Job(TileJobF32),
    Shutdown,
}

/// Handle to the running device thread.
pub struct DeviceHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
    /// Native design size (nm, nk, nn).
    pub native: (u64, u64, u64),
    /// Simulated device cycles consumed (fixed-point: whole cycles).
    cycles: Arc<AtomicU64>,
    /// Iteration period in cycles (diagnostics).
    pub period_cycles: f64,
    /// Device frequency.
    pub freq_hz: f64,
    /// Number of invocations served.
    invocations: Arc<AtomicU64>,
}

impl DeviceHandle {
    /// Submit one native tile job.
    pub fn submit(&self, job: TileJobF32) -> Result<()> {
        self.tx
            .send(Msg::Job(job))
            .map_err(|_| anyhow!("device thread gone"))
    }

    /// Convenience: execute one tile synchronously.
    pub fn execute_tile(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.submit(TileJobF32 { a, b, reply })?;
        rx.recv().context("device reply channel closed")?
    }

    /// Simulated device time consumed so far, seconds.
    pub fn device_time_s(&self) -> f64 {
        self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz
    }

    /// Invocations served.
    pub fn invocations(&self) -> u64 {
        self.invocations.load(Ordering::Relaxed)
    }

    /// Stop the device thread and wait for it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for DeviceHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Artifact name for a design (shared scheme with aot.py).
pub fn artifact_name(design: &DesignConfig) -> String {
    format!(
        "array_{}_{}x{}x{}",
        design.precision, design.x, design.y, design.z
    )
}

/// Spawn the device thread for `design`, loading its artifact from
/// `artifacts_dir`. Fails fast if the artifact is missing.
pub fn spawn_device(artifacts_dir: PathBuf, design: DesignConfig) -> Result<DeviceHandle> {
    if !artifacts_available(&artifacts_dir) {
        return Err(anyhow!(
            "artifacts not found in {} — run `make artifacts` first",
            artifacts_dir.display()
        ));
    }
    let dev = design.device()?;
    let cand = design.candidate();
    let kernel = design.kernel();
    let native = (cand.x * kernel.m, cand.y * kernel.k, cand.z * kernel.n);

    // Device-time model from the simulator.
    let placed = place_design(&dev, cand, design.pattern, kernel)
        .map_err(|e| anyhow!("placement failed: {e}"))?;
    let sim = simulate_design(&dev, &placed, &SimConfig::default());
    let period = sim.period_cycles;
    let freq = dev.freq_hz;

    let cycles = Arc::new(AtomicU64::new(0));
    let invocations = Arc::new(AtomicU64::new(0));
    let (tx, rx) = mpsc::channel::<Msg>();
    let name = artifact_name(&design);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

    let cycles_t = Arc::clone(&cycles);
    let invocations_t = Arc::clone(&invocations);
    let join = std::thread::Builder::new()
        .name("maxeva-device".into())
        .spawn(move || {
            // PJRT handles are created inside the thread (not Send).
            // §Perf: prefer the panel-scheduled `_fast` artifact (same
            // Pallas kernel, coarsened BlockSpec — ~11× faster on CPU
            // PJRT, identical reduction order; EXPERIMENTS.md §Perf).
            let init = (|| -> Result<_> {
                let rt = Runtime::cpu()?;
                let fast = crate::runtime::artifact_path(&artifacts_dir, &format!("{name}_fast"));
                let exe = if fast.exists() {
                    rt.load(&fast)?
                } else {
                    rt.load_named(&artifacts_dir, &name)?
                };
                Ok((rt, exe))
            })();
            let exe = match init {
                Ok((_rt, exe)) => {
                    let _ = ready_tx.send(Ok(()));
                    exe
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let (nm, nk, nn) = (native.0 as i64, native.1 as i64, native.2 as i64);
            while let Ok(Msg::Job(job)) = rx.recv() {
                let res = exe.run_f32(&[
                    (job.a.as_slice(), &[nm, nk][..]),
                    (job.b.as_slice(), &[nk, nn][..]),
                ]);
                cycles_t.fetch_add(period as u64, Ordering::Relaxed);
                invocations_t.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(res);
            }
        })
        .context("spawning device thread")?;

    // Wait for the artifact to compile (or fail).
    ready_rx
        .recv()
        .context("device thread died during init")??;

    Ok(DeviceHandle {
        tx,
        join: Some(join),
        native,
        cycles,
        period_cycles: period,
        freq_hz: freq,
        invocations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::precision::Precision;

    #[test]
    fn artifact_name_scheme() {
        let d = DesignConfig::flagship(Precision::Fp32);
        assert_eq!(artifact_name(&d), "array_fp32_13x4x6");
        let d8 = DesignConfig::flagship(Precision::Int8);
        assert_eq!(artifact_name(&d8), "array_int8_13x4x6");
    }

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join("maxeva_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        match spawn_device(dir, DesignConfig::flagship(Precision::Fp32)) {
            Err(err) => assert!(err.to_string().contains("make artifacts"), "{err}"),
            Ok(_) => panic!("spawn must fail without artifacts"),
        }
    }

    // Full execution tests live in rust/tests/runtime_artifacts.rs.
}
