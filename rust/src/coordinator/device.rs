//! The device worker pool: owns the PJRT runtime (whose handles are not
//! `Send`) and serves native-size tile jobs over a channel — the software
//! stand-in for the AIE array device.
//!
//! # Job model (the pipelined dataflow)
//!
//! Jobs are **tagged** and carry [`TileRef`]s into the server's
//! contiguous tile-major arenas ([`crate::coordinator::pool::TilePool`])
//! — submission is zero-copy (an `Arc` bump), the worker reads the
//! stride-addressed slices in place. Reference-backend output buffers
//! come from the pool's per-precision free-lists and are returned by
//! the scheduler after reduction, so the steady-state loop allocates
//! nothing per tile. Every job names its own completion sender, and the
//! serving engine points *all* of a window's jobs at one channel, so a
//! single `recv` loop drains completions regardless of which worker
//! executed which tile. This is the host-side mirror of the paper's
//! ping-pong buffering (eq. 2): while a worker multiplies tile *i*, the
//! host packs/accumulates tiles *i±window*.
//!
//! # Precision
//!
//! The pool is **dual-precision**: a job's payload selects the fp32 or
//! the int8 (i32-carried, i32-accumulating) datapath per tile, mirroring
//! the paper's two headline designs (5.44 TFLOPs fp32 / 77.01 TOPs int8).
//! Each precision has its own native tile size and its own steady-state
//! iteration period from the simulator; every invocation advances the
//! simulated device clock by the period of the precision it ran in,
//! giving VCK190-equivalent device time (the clock sums busy periods
//! across workers, i.e. it stays the serial device-equivalent time).
//!
//! # Backends
//!
//! * **PJRT** — the AOT-compiled JAX/Pallas artifacts, one
//!   `Runtime`/`Executable` set per worker thread (handles are not
//!   `Send`). The fp32 artifact is required; the int8 artifact is loaded
//!   when present and int8 jobs fail cleanly when it is not. Needs the
//!   `pjrt` cargo feature and `make artifacts`.
//! * **Reference** — the register-tiled host compute plane
//!   ([`crate::coordinator::microkernel`]): MR×NR-blocked f32 and
//!   wrapping-i32 native-tile matmuls, bit-identical to the historical
//!   scalar loops. No artifacts needed; lets the full serving stack
//!   (and its equivalence tests) run in any build environment at
//!   vectorized rather than scalar speed.

use crate::arch::precision::Precision;
use crate::config::schema::{BackendKind, DesignConfig};
use crate::coordinator::microkernel::{matmul_f32, matmul_i32};
use crate::coordinator::pool::{BufferPool, TileRef, FREE_LIST_CAP};
use crate::placement::placer::place_design;
use crate::runtime::{
    artifact_path, artifacts_available, named_artifact_available, pjrt_compiled, Runtime,
};
use crate::sim::engine::{simulate_design, SimConfig};
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Operand tiles of one job, typed by precision. `F32` carries an
/// `nm×nk` A and `nk×nn` B in the fp32 geometry; `I32` likewise in the
/// int8 geometry (int8-range values carried as i32, matching
/// [`crate::runtime::Executable::run_i32`]). Tiles are [`TileRef`]s —
/// stride-addressed slices into the server's contiguous arena pools
/// ([`crate::coordinator::pool::TilePool`]); submission is an `Arc`
/// bump, the worker reads the slices in place.
pub enum TilePayload {
    F32 { a: TileRef<f32>, b: TileRef<f32> },
    I32 { a: TileRef<i32>, b: TileRef<i32> },
}

impl TilePayload {
    /// The precision whose datapath (and device period) this job uses.
    pub fn precision(&self) -> Precision {
        match self {
            TilePayload::F32 { .. } => Precision::Fp32,
            TilePayload::I32 { .. } => Precision::Int8,
        }
    }
}

/// Result elements of one tile job, matching the payload's precision.
#[derive(Debug, Clone, PartialEq)]
pub enum TileOutput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A tagged native-size tile job.
pub struct TileJob {
    /// Correlation tag echoed back in [`TileDone`].
    pub tag: u64,
    pub payload: TilePayload,
    /// Completion channel; the serving engine points a whole window of
    /// jobs at one sender.
    pub done: mpsc::Sender<TileDone>,
}

/// Completion of one tile job.
pub struct TileDone {
    pub tag: u64,
    pub result: Result<TileOutput>,
}

enum Msg {
    Job(TileJob),
    Shutdown,
}

/// Per-precision device facts: native tile size and steady-state
/// iteration period, both derived from the placed design's simulation.
/// `period_cycles` is also the per-tile cost input the scheduling
/// policies weigh precisions by — see
/// [`crate::coordinator::policy::TileCosts::from_periods`], which
/// charges measured device time per tile and falls back to the
/// geometric MAC ratio ([`TileCosts::from_native`]) when the simulated
/// periods are degenerate.
///
/// [`TileCosts::from_native`]: crate::coordinator::policy::TileCosts::from_native
#[derive(Debug, Clone, Copy)]
pub struct PrecisionInfo {
    /// Native design size (nm, nk, nn).
    pub native: (u64, u64, u64),
    /// Iteration period in cycles.
    pub period_cycles: f64,
}

/// Handle to the running device worker pool.
pub struct DeviceHandle {
    tx: mpsc::Sender<Msg>,
    joins: Vec<JoinHandle<()>>,
    /// Native fp32 design size (nm, nk, nn).
    pub native: (u64, u64, u64),
    /// Native int8 design size (nm, nk, nn) — differs from fp32 because
    /// the paper's int8 kernel is 32×128×32 vs fp32's 32×32×32.
    pub native_int8: (u64, u64, u64),
    /// Simulated device cycles consumed (fixed-point: whole cycles).
    cycles: Arc<AtomicU64>,
    /// fp32 iteration period in cycles (diagnostics).
    pub period_cycles: f64,
    /// int8 iteration period in cycles (diagnostics).
    pub period_cycles_int8: f64,
    /// Device frequency.
    pub freq_hz: f64,
    /// Number of device worker threads.
    pub workers: usize,
    /// Resolved backend ("pjrt" or "reference").
    pub backend: &'static str,
    /// Number of invocations served.
    invocations: Arc<AtomicU64>,
    /// Per-precision free-lists of native-tile output buffers, shared
    /// with the scheduler's completion loop (the buffer-recycling layer
    /// of the memory plane — see [`crate::coordinator::pool`]).
    bufs: Arc<BufferPool>,
}

impl DeviceHandle {
    /// Submit one tagged native tile job.
    pub fn submit(&self, job: TileJob) -> Result<()> {
        self.tx
            .send(Msg::Job(job))
            .map_err(|_| anyhow!("device workers gone"))
    }

    /// Convenience: execute one fp32 tile synchronously.
    pub fn execute_tile(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        let (done, rx) = mpsc::channel();
        self.submit(TileJob {
            tag: 0,
            payload: TilePayload::F32 { a: TileRef::single(a), b: TileRef::single(b) },
            done,
        })?;
        match rx.recv().context("device reply channel closed")?.result? {
            TileOutput::F32(v) => Ok(v),
            TileOutput::I32(_) => Err(anyhow!("f32 tile returned i32 output")),
        }
    }

    /// Per-precision device facts for a serving precision — the single
    /// dispatch point between a [`Precision`] and this pool's geometry.
    pub fn info_for(&self, p: Precision) -> Result<PrecisionInfo> {
        match p {
            Precision::Fp32 => {
                Ok(PrecisionInfo { native: self.native, period_cycles: self.period_cycles })
            }
            Precision::Int8 => Ok(PrecisionInfo {
                native: self.native_int8,
                period_cycles: self.period_cycles_int8,
            }),
            other => Err(anyhow!("serving supports fp32 and int8, not {other}")),
        }
    }

    /// Native tile size for a serving precision.
    pub fn native_for(&self, p: Precision) -> Result<(u64, u64, u64)> {
        Ok(self.info_for(p)?.native)
    }

    /// Iteration period (cycles) for a serving precision.
    pub fn period_cycles_for(&self, p: Precision) -> Result<f64> {
        Ok(self.info_for(p)?.period_cycles)
    }

    /// Simulated device time consumed so far, seconds.
    pub fn device_time_s(&self) -> f64 {
        self.cycles.load(Ordering::Relaxed) as f64 / self.freq_hz
    }

    /// Invocations served.
    pub fn invocations(&self) -> u64 {
        self.invocations.load(Ordering::Relaxed)
    }

    /// Shared cycle/invocation counters, for observers that outlive or
    /// run apart from the handle (the streaming server's stats path).
    pub(crate) fn counters(&self) -> (Arc<AtomicU64>, Arc<AtomicU64>) {
        (Arc::clone(&self.cycles), Arc::clone(&self.invocations))
    }

    /// The pool's tile-buffer free-lists. The scheduler returns reduced
    /// partials and retired accumulation buffers here; the (reference)
    /// workers take their output buffers from it, closing the recycle
    /// loop.
    pub fn buffer_pool(&self) -> Arc<BufferPool> {
        Arc::clone(&self.bufs)
    }

    fn stop(&mut self) {
        for _ in &self.joins {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }

    /// Stop all device workers and wait for them.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for DeviceHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Artifact name for a design (shared scheme with aot.py).
pub fn artifact_name(design: &DesignConfig) -> String {
    format!(
        "array_{}_{}x{}x{}",
        design.precision, design.x, design.y, design.z
    )
}

/// What a worker thread executes per tile. PJRT holds one executable per
/// precision; the int8 one is optional (artifact may not be built).
enum WorkerBackend {
    Pjrt {
        _rt: Runtime,
        exe_f32: crate::runtime::Executable,
        exe_i32: Option<crate::runtime::Executable>,
    },
    Reference,
}

/// Spawn the device worker pool for `design` with the legacy defaults:
/// PJRT backend, one worker. Fails fast if the artifact is missing.
pub fn spawn_device(artifacts_dir: PathBuf, design: DesignConfig) -> Result<DeviceHandle> {
    spawn_device_pool(artifacts_dir, design, BackendKind::Pjrt, 1)
}

/// Native size and iteration period of one precision's design, from
/// placement + simulation.
fn precision_info(design: &DesignConfig) -> Result<PrecisionInfo> {
    let dev = design.device()?;
    let cand = design.candidate();
    let kernel = design.kernel();
    let native = (cand.x * kernel.m, cand.y * kernel.k, cand.z * kernel.n);
    let placed = place_design(&dev, cand, design.pattern, kernel)
        .map_err(|e| anyhow!("placement failed for {}: {e}", artifact_name(design)))?;
    let sim = simulate_design(&dev, &placed, &SimConfig::default());
    Ok(PrecisionInfo { native, period_cycles: sim.period_cycles })
}

/// Load a PJRT executable for a design, preferring the panel-scheduled
/// `_fast` artifact variant (same Pallas kernel, coarsened BlockSpec —
/// ~11× faster on CPU PJRT, identical reduction order; EXPERIMENTS.md
/// §Perf).
fn load_exe(rt: &Runtime, dir: &std::path::Path, name: &str) -> Result<crate::runtime::Executable> {
    let fast = artifact_path(dir, &format!("{name}_fast"));
    if fast.exists() {
        rt.load(&fast)
    } else {
        rt.load_named(dir, name)
    }
}

/// Spawn `workers` device threads serving tile jobs from a shared queue.
///
/// Backend resolution: `Pjrt` requires the `pjrt` feature *and* the
/// fp32 artifact on disk (fails fast otherwise, pointing at
/// `make artifacts`); `Reference` needs nothing; `Auto` picks PJRT when
/// possible and falls back to the reference backend. Either way the pool
/// serves **both** precisions: the int8 geometry is derived from the
/// design via [`DesignConfig::with_precision`].
pub fn spawn_device_pool(
    artifacts_dir: PathBuf,
    design: DesignConfig,
    backend: BackendKind,
    workers: usize,
) -> Result<DeviceHandle> {
    let have_artifacts = artifacts_available(&artifacts_dir);
    let use_pjrt = match backend {
        BackendKind::Pjrt => {
            if !have_artifacts {
                return Err(anyhow!(
                    "artifacts not found in {} — run `make artifacts` first",
                    artifacts_dir.display()
                ));
            }
            if !pjrt_compiled() {
                return Err(anyhow!(
                    "backend `pjrt` requested but maxeva was built without the \
                     `pjrt` feature"
                ));
            }
            true
        }
        BackendKind::Reference => false,
        BackendKind::Auto => have_artifacts && pjrt_compiled(),
    };

    // Device-time model from the simulator, once per precision.
    let design_f32 = design.with_precision(Precision::Fp32);
    let design_i32 = design.with_precision(Precision::Int8);
    let info_f32 = precision_info(&design_f32)?;
    let info_i32 = precision_info(&design_i32)?;
    let freq = design.device()?.freq_hz;

    let workers = workers.max(1);
    let cycles = Arc::new(AtomicU64::new(0));
    let invocations = Arc::new(AtomicU64::new(0));
    let bufs = Arc::new(BufferPool::new(FREE_LIST_CAP));
    let (tx, rx) = mpsc::channel::<Msg>();
    // std mpsc is single-consumer; the pool shares the receiver behind a
    // mutex (locked only to pop, never while executing a tile).
    let rx = Arc::new(Mutex::new(rx));
    let name_f32 = artifact_name(&design_f32);
    let name_i32 = artifact_name(&design_i32);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

    let mut joins = Vec::with_capacity(workers);
    for w in 0..workers {
        let rx_w = Arc::clone(&rx);
        let cycles_w = Arc::clone(&cycles);
        let invocations_w = Arc::clone(&invocations);
        let bufs_w = Arc::clone(&bufs);
        let ready_w = ready_tx.clone();
        let dir_w = artifacts_dir.clone();
        let name_f32_w = name_f32.clone();
        let name_i32_w = name_i32.clone();
        let join = std::thread::Builder::new()
            .name(format!("maxeva-device-{w}"))
            .spawn(move || {
                // PJRT handles are created inside the thread (not Send).
                let init = (|| -> Result<WorkerBackend> {
                    if !use_pjrt {
                        return Ok(WorkerBackend::Reference);
                    }
                    let rt = Runtime::cpu()?;
                    let exe_f32 = load_exe(&rt, &dir_w, &name_f32_w)?;
                    // The int8 artifact is optional: load it when built,
                    // otherwise int8 jobs fail cleanly at execution.
                    let exe_i32 = if named_artifact_available(&dir_w, &name_i32_w) {
                        Some(load_exe(&rt, &dir_w, &name_i32_w)?)
                    } else {
                        None
                    };
                    Ok(WorkerBackend::Pjrt { _rt: rt, exe_f32, exe_i32 })
                })();
                let backend = match init {
                    Ok(b) => {
                        let _ = ready_w.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_w.send(Err(e));
                        return;
                    }
                };
                // Close this worker's ready sender now: if any sibling
                // worker dies during init without sending, the spawn-side
                // wait must see the channel disconnect, not hang.
                drop(ready_w);
                let nf = info_f32.native;
                let ni = info_i32.native;
                let (pf, pi) = (info_f32.period_cycles as u64, info_i32.period_cycles as u64);
                loop {
                    // Pop under the lock, execute outside it so workers
                    // overlap.
                    let msg = match rx_w.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    let job = match msg {
                        Ok(Msg::Job(job)) => job,
                        Ok(Msg::Shutdown) | Err(_) => break,
                    };
                    let period = match job.payload.precision() {
                        Precision::Int8 => pi,
                        _ => pf,
                    };
                    // A panic inside the backend (e.g. PJRT FFI) must
                    // still produce a completion — otherwise the server's
                    // recv loop would wait forever for this tag.
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || run_tile(&backend, &job.payload, nf, ni, &bufs_w),
                    ))
                    .unwrap_or_else(|_| Err(anyhow!("device worker panicked executing tile")));
                    cycles_w.fetch_add(period, Ordering::Relaxed);
                    invocations_w.fetch_add(1, Ordering::Relaxed);
                    let _ = job.done.send(TileDone { tag: job.tag, result: res });
                }
            })
            .context("spawning device worker")?;
        joins.push(join);
    }
    drop(ready_tx);

    // Wait for every worker's backend to come up (or fail).
    for _ in 0..workers {
        match ready_rx.recv().context("device worker died during init") {
            Ok(Ok(())) => {}
            Ok(Err(e)) | Err(e) => {
                // Tear the pool down before propagating.
                for _ in 0..workers {
                    let _ = tx.send(Msg::Shutdown);
                }
                for j in joins {
                    let _ = j.join();
                }
                return Err(e);
            }
        }
    }

    Ok(DeviceHandle {
        tx,
        joins,
        native: info_f32.native,
        native_int8: info_i32.native,
        cycles,
        period_cycles: info_f32.period_cycles,
        period_cycles_int8: info_i32.period_cycles,
        freq_hz: freq,
        workers,
        backend: if use_pjrt { "pjrt" } else { "reference" },
        invocations,
        bufs,
    })
}

/// Execute one tile on whichever datapath its payload selects. The
/// reference backend draws its output buffer from the shared free-lists
/// (zero-allocation steady state); the PJRT path cannot — the FFI
/// allocates the result — so only the scheduler-side recycling applies
/// there.
fn run_tile(
    backend: &WorkerBackend,
    payload: &TilePayload,
    native_f32: (u64, u64, u64),
    native_i32: (u64, u64, u64),
    bufs: &BufferPool,
) -> Result<TileOutput> {
    match payload {
        TilePayload::F32 { a, b } => {
            let (nm, nk, nn) =
                (native_f32.0 as usize, native_f32.1 as usize, native_f32.2 as usize);
            match backend {
                WorkerBackend::Pjrt { exe_f32, .. } => exe_f32
                    .run_f32(&[
                        (a.as_slice(), &[nm as i64, nk as i64][..]),
                        (b.as_slice(), &[nk as i64, nn as i64][..]),
                    ])
                    .map(TileOutput::F32),
                WorkerBackend::Reference => {
                    let mut out = bufs.fp32.take(nm * nn);
                    matmul_f32(&mut out, a.as_slice(), b.as_slice(), nm, nk, nn);
                    Ok(TileOutput::F32(out))
                }
            }
        }
        TilePayload::I32 { a, b } => {
            let (nm, nk, nn) =
                (native_i32.0 as usize, native_i32.1 as usize, native_i32.2 as usize);
            match backend {
                WorkerBackend::Pjrt { exe_i32: Some(exe), .. } => exe
                    .run_i32(&[
                        (a.as_slice(), &[nm as i64, nk as i64][..]),
                        (b.as_slice(), &[nk as i64, nn as i64][..]),
                    ])
                    .map(TileOutput::I32),
                WorkerBackend::Pjrt { exe_i32: None, .. } => Err(anyhow!(
                    "int8 artifact not built — run `make artifacts` with the int8 design"
                )),
                WorkerBackend::Reference => {
                    let mut out = bufs.int8.take(nm * nn);
                    matmul_i32(&mut out, a.as_slice(), b.as_slice(), nm, nk, nn);
                    Ok(TileOutput::I32(out))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::TilePool;
    use crate::coordinator::tiler::{matmul_ref_f32, matmul_ref_i32};

    #[test]
    fn artifact_name_scheme() {
        let d = DesignConfig::flagship(Precision::Fp32);
        assert_eq!(artifact_name(&d), "array_fp32_13x4x6");
        let d8 = DesignConfig::flagship(Precision::Int8);
        assert_eq!(artifact_name(&d8), "array_int8_13x4x6");
    }

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join("maxeva_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        match spawn_device(dir, DesignConfig::flagship(Precision::Fp32)) {
            Err(err) => assert!(err.to_string().contains("make artifacts"), "{err}"),
            Ok(_) => panic!("spawn must fail without artifacts"),
        }
    }

    #[test]
    fn reference_pool_executes_tagged_jobs() {
        // Small 2×4×2 array of 4×4×4 kernels → native (8, 16, 8); the
        // reference backend needs no artifacts.
        let mut design = DesignConfig::flagship(Precision::Fp32);
        (design.x, design.y, design.z) = (2, 4, 2);
        (design.m, design.k, design.n) = (4, 4, 4);
        let dir = std::env::temp_dir().join("maxeva_ref_pool");
        std::fs::create_dir_all(&dir).unwrap();
        let dev = spawn_device_pool(dir, design, BackendKind::Reference, 2).unwrap();
        assert_eq!(dev.native, (8, 16, 8));
        // Custom (non-paper) kernel → the int8 sibling keeps the same
        // tile geometry.
        assert_eq!(dev.native_int8, (8, 16, 8));
        assert_eq!(dev.backend, "reference");
        let (nm, nk, nn) = (8usize, 16usize, 8usize);
        let a: Vec<f32> = (0..nm * nk).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..nk * nn).map(|i| (i % 7) as f32 - 3.0).collect();
        let want = matmul_ref_f32(&a, &b, nm, nk, nn);

        // Tagged async submission on one completion channel; all six
        // jobs share one arena tile zero-copy.
        let (done_tx, done_rx) = mpsc::channel();
        let a = TilePool::from_tile(a);
        let b = TilePool::from_tile(b);
        for tag in 0..6u64 {
            dev.submit(TileJob {
                tag,
                payload: TilePayload::F32 { a: a.tile_ref(0), b: b.tile_ref(0) },
                done: done_tx.clone(),
            })
            .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let d = done_rx.recv().unwrap();
            assert_eq!(d.result.unwrap(), TileOutput::F32(want.clone()));
            seen.push(d.tag);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(dev.invocations(), 6);
        assert!(dev.device_time_s() > 0.0);
        dev.shutdown();
    }

    #[test]
    fn reference_pool_serves_both_precisions() {
        let mut design = DesignConfig::flagship(Precision::Fp32);
        (design.x, design.y, design.z) = (2, 4, 2);
        (design.m, design.k, design.n) = (4, 4, 4);
        let dir = std::env::temp_dir().join("maxeva_ref_pool_i8");
        std::fs::create_dir_all(&dir).unwrap();
        let dev = spawn_device_pool(dir, design, BackendKind::Reference, 2).unwrap();
        let (nm, nk, nn) = (8usize, 16usize, 8usize);
        let ai: Vec<i32> = (0..nm * nk).map(|i| (i % 256) as i32 - 128).collect();
        let bi: Vec<i32> = (0..nk * nn).map(|i| (i % 251) as i32 - 125).collect();
        let want_i = matmul_ref_i32(&ai, &bi, nm, nk, nn);
        let af: Vec<f32> = (0..nm * nk).map(|i| (i % 5) as f32).collect();
        let bf: Vec<f32> = (0..nk * nn).map(|i| (i % 3) as f32 - 1.0).collect();
        let want_f = matmul_ref_f32(&af, &bf, nm, nk, nn);

        let (done_tx, done_rx) = mpsc::channel();
        dev.submit(TileJob {
            tag: 1,
            payload: TilePayload::I32 { a: TileRef::single(ai), b: TileRef::single(bi) },
            done: done_tx.clone(),
        })
        .unwrap();
        dev.submit(TileJob {
            tag: 2,
            payload: TilePayload::F32 { a: TileRef::single(af), b: TileRef::single(bf) },
            done: done_tx.clone(),
        })
        .unwrap();
        let t0 = dev.device_time_s();
        let mut got = 0;
        for _ in 0..2 {
            let d = done_rx.recv().unwrap();
            match d.result.unwrap() {
                TileOutput::I32(v) => {
                    assert_eq!(d.tag, 1);
                    assert_eq!(v, want_i);
                    got += 1;
                }
                TileOutput::F32(v) => {
                    assert_eq!(d.tag, 2);
                    assert_eq!(v, want_f);
                    got += 1;
                }
            }
        }
        assert_eq!(got, 2);
        assert!(dev.device_time_s() >= t0);
        assert!(dev.period_cycles_for(Precision::Int8).unwrap() > 0.0);
        assert!(dev.native_for(Precision::Bf16).is_err());
        dev.shutdown();
    }

    #[test]
    fn flagship_precisions_have_distinct_natives() {
        let dir = std::env::temp_dir().join("maxeva_flagship_natives");
        std::fs::create_dir_all(&dir).unwrap();
        let dev = spawn_device_pool(
            dir,
            DesignConfig::flagship(Precision::Fp32),
            BackendKind::Reference,
            1,
        )
        .unwrap();
        // 13·32 × 4·32 × 6·32 vs 13·32 × 4·128 × 6·32 (int8 kernel K=128).
        assert_eq!(dev.native, (416, 128, 192));
        assert_eq!(dev.native_int8, (416, 512, 192));
        assert!(dev.period_cycles > 0.0 && dev.period_cycles_int8 > 0.0);
        // Geometric fallback ratio (4× MACs) stays pinned…
        let geo = crate::coordinator::policy::TileCosts::from_native(
            dev.info_for(Precision::Fp32).unwrap().native,
            dev.info_for(Precision::Int8).unwrap().native,
        );
        assert_eq!(geo.int8, 4 * geo.fp32);
        // …but the fair policies now charge measured device periods
        // per tile (PR 4): the simulated flagship periods are healthy,
        // so the derivation is exact, not the fallback.
        let info_f = dev.info_for(Precision::Fp32).unwrap();
        let info_i = dev.info_for(Precision::Int8).unwrap();
        let costs = crate::coordinator::policy::TileCosts::from_periods(
            info_f.period_cycles,
            info_i.period_cycles,
            info_f.native,
            info_i.native,
        );
        assert_eq!(costs.fp32, info_f.period_cycles.round() as u64);
        assert_eq!(costs.int8, info_i.period_cycles.round() as u64);
        dev.shutdown();
    }

    // Full execution tests live in rust/tests/runtime_artifacts.rs.
}
